"""Paper Fig. 7 — approximate-matching accuracy (AA = d_ED(exact) /
d_ED(approximate)), sSAX/tSAX vs SAX — plus the anytime indexed tier:
``TreeCandidates`` approximate mode (bounded collect) reporting
achieved top-k recall vs the exact oracle and the error-bar
certificate, per collect budget."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import cached, emit_row
from repro.core import SAX, SSAX, TSAX, approximate_match
from repro.core.matching import RawStore, pairwise_euclidean
from repro.data.synthetic import season_dataset, trend_dataset

N_Q = 24


def _aa(technique, Q, D, ed):
    rq = technique.encode(jnp.asarray(Q))
    rx = technique.encode(jnp.asarray(D))
    dists = np.asarray(technique.pairwise_distance(rq, rx))
    vals = []
    for i in range(len(Q)):
        r = approximate_match(Q[i], dists[i], RawStore.hbm(D))
        vals.append(ed[i].min() / max(r.distance, 1e-12))
    return float(np.mean(vals))


def _anytime_rows(dryrun: bool) -> list:
    """Anytime tier: exact seed walk + bounded collect; recall vs the
    exact oracle and the fraction of queries whose error bar certifies
    the answer exact, per collect budget."""
    from repro.core import make_technique
    from repro.core.engine import MatchEngine
    from repro.obs import REGISTRY
    from repro.store import SymbolicStore

    n, T, k = (256, 480, 4) if dryrun else (2048, 960, 8)
    X = cached(("season", T, 0.7, "anytime", n),
               lambda: season_dataset(n + N_Q, T, 10, 0.7,
                                      per_series_strength=True, seed=17))
    Q, D = X[:N_Q], X[N_Q:]
    tech = make_technique("ssax", T=T, W=48, L=10, r2_season=0.7)
    store = SymbolicStore.from_rows(tech, D, media="ssd")
    store.build_index(leaf_fill=16 if dryrun else 64)
    eng = MatchEngine(tech, store, verify="host", batch_size=64)
    exact = eng.topk(Q, k=k, source="index")
    rows = []
    for collect in (k, 4 * k, 16 * k):
        res = eng.topk_approx(Q, k=k, collect=collect)
        hit = [np.intersect1d(a, e).size / k
               for a, e in zip(res.indices, exact.indices)]
        recall = float(np.mean(hit))
        bars = np.asarray(res.error_bar)
        certified = int((bars == 0).sum())
        rows.append(("approx/anytime",
                     f"collect={collect} k={k} recall={recall:.3f} "
                     f"cands/q={res.raw_accesses.mean():.0f} "
                     f"error_bar_mean={bars.mean():.4f} "
                     f"exact_certified={certified}/{N_Q}"))
        REGISTRY.gauge(f"bench.approx_recall.collect{collect}").set(recall)
    return rows


def run(dryrun: bool = False):
    rows = []
    for s in [0.1, 0.5, 0.9]:
        X = cached(("season", 960, s, "pp"),
                   lambda s=s: season_dataset(400, 960, 10, s, seed=10))
        Q, D = X[:N_Q], X[N_Q:]
        ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
        aa_sax = _aa(SAX(T=960, W=48, A=64), Q, D, ed)
        aa_ss = _aa(SSAX(T=960, W=48, L=10, A_seas=9, A_res=64,
                         r2_season=s), Q, D, ed)
        rows.append(("approx/season",
                     f"R2={s} sax={aa_sax:.4f} ssax={aa_ss:.4f} "
                     f"gain_pp={(aa_ss - aa_sax) * 100:.2f}"))
    for s in [0.1, 0.5, 0.9]:
        X = trend_dataset(400, 960, s, seed=12)
        Q, D = X[:N_Q], X[N_Q:]
        ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
        aa_sax = _aa(SAX(T=960, W=48, A=64), Q, D, ed)
        aa_ts = _aa(TSAX(T=960, W=48, A_tr=64, A_res=64, r2_trend=s),
                    Q, D, ed)
        rows.append(("approx/trend",
                     f"R2={s} sax={aa_sax:.4f} tsax={aa_ts:.4f} "
                     f"gain_pp={(aa_ts - aa_sax) * 100:.2f}"))
    rows.extend(_anytime_rows(dryrun))
    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run(dryrun=True)
