"""§Perf generator: turn results/hillclimb.json into the
hypothesis -> change -> before -> after -> verdict log, with roofline
terms recomputed per variant (same methodology as benchmarks/roofline.py);
plus the unified bench summary — one table over every
``results/BENCH_<suite>.json`` reporting the same five registry-derived
numbers (pruning power, rows fetched, modeled I/O, wall, host bytes)
regardless of which suite produced them.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import roofline_record, ICI_BW


def _terms(rec):
    if "compute_s" in rec:
        return rec
    return roofline_record(rec)


def perf_log(path: str) -> str:
    recs = json.load(open(path))
    by_cell: dict = {}
    for r in recs:
        by_cell.setdefault(r["cell"], []).append(r)

    out = []
    for cell, rows in by_cell.items():
        out.append(f"\n### Cell: {cell}\n")
        base = None
        for r in rows:
            if r.get("status", "ok") != "ok":
                out.append(f"* **{r['variant']}** — ERROR: {r.get('error')}")
                continue
            if cell == "matching-engine":
                line = (f"| {r['variant']} | cpu {r['cpu_s']*1e3:.0f} ms/q | "
                        f"tpu-bound {r['tpu_bound']:.2e} s "
                        f"({r['n_candidates']/r['tpu_bound']/1e9:.2f} Gcand/s) |")
                if base is None:
                    base = r["tpu_bound"]
                    verdict = "baseline"
                else:
                    gain = base / r["tpu_bound"]
                    verdict = f"{gain:.2f}x vs baseline"
                out.append(f"* **{r['variant']}** — {verdict}")
                out.append(f"  * hypothesis: {r['hypothesis']}")
                out.append(f"  * measured: {line}")
                continue
            rr = _terms(r)
            terms = (f"compute {rr['compute_s']:.3e}s / memory "
                     f"{rr['memory_s']:.3e}s / collective "
                     f"{rr['collective_s']:.3e}s -> dominant "
                     f"**{rr['dominant']}**, roofline frac "
                     f"{rr['roofline_fraction']:.2f}")
            if base is None:
                base = rr
                verdict = "baseline"
            else:
                b = max(base["compute_s"], base["memory_s"],
                        base["collective_s"])
                n = max(rr["compute_s"], rr["memory_s"], rr["collective_s"])
                verdict = (f"step-time bound {b:.3e}s -> {n:.3e}s "
                           f"({b/max(n,1e-30):.2f}x)")
            out.append(f"* **{r['variant']}** — {verdict}")
            out.append(f"  * hypothesis: {r['hypothesis']}")
            out.append(f"  * measured: {terms}; collective bytes/dev "
                       f"{rr['coll_bytes_per_dev']/1e6:.1f} MB")
    return "\n".join(out) + "\n"


def _fmt(v, spec=".4g"):
    return "-" if v is None else format(v, spec)


def _serve_quantiles(rec):
    """(p50, p99) of the per-request serving latency from the suite's
    embedded ``serve.request_latency_s`` histogram; (None, None) for
    suites that never served a request."""
    snap = rec.get("metrics") or {}
    h = (snap.get("histograms") or {}).get("serve.request_latency_s")
    if not h or not h.get("count"):
        return None, None
    from repro.obs.metrics import Histogram
    hist = Histogram.from_dict(h)
    return hist.quantile(0.5), hist.quantile(0.99)


def bench_summary(results_dir: str) -> str:
    """Markdown table over every ``BENCH_<suite>.json`` summary block
    (suites that predate the unified schema show dashes)."""
    lines = ["| suite | ok | pruning_power | rows_fetched | modeled_io_s "
             "| wall_s | host_bytes | serve_p50_s | serve_p99_s |",
             "|---|---|---|---|---|---|---|---|---|"]
    found = 0
    for path in sorted(glob.glob(os.path.join(results_dir,
                                              "BENCH_*.json"))):
        rec = json.load(open(path))
        s = rec.get("summary") or {}
        found += 1
        suite = rec.get("suite", os.path.basename(path))
        ok = "ok" if rec.get("ok") else "ERROR"
        if rec.get("dryrun"):
            ok += " (dryrun)"
        p50, p99 = _serve_quantiles(rec)
        lines.append(
            f"| {suite} | {ok} | {_fmt(s.get('pruning_power'))} "
            f"| {_fmt(s.get('rows_fetched'), '.0f')} "
            f"| {_fmt(s.get('modeled_io_s'))} "
            f"| {_fmt(s.get('wall_s'), '.2f')} "
            f"| {_fmt(s.get('host_bytes'), '.0f')} "
            f"| {_fmt(p50, '.3g')} | {_fmt(p99, '.3g')} |")
    return "\n".join(lines) if found else ""


def run():
    results = os.path.join(os.path.dirname(__file__), "..", "results")

    table = bench_summary(results)
    if table:
        out = os.path.join(results, "bench_summary.md")
        with open(out, "w") as f:
            f.write("# Bench suites — unified summary\n\n"
                    "Registry-derived (`repro.obs`) per-suite numbers; "
                    "see ROADMAP 'Observability subsystem' for the "
                    "metric definitions.\n\n" + table + "\n")
        print(f"perf/bench_summary,,written {out} "
              f"({table.count(chr(10)) - 1} suites)")
    else:
        print("perf/bench_summary,,no results/BENCH_*.json")

    path = os.path.join(results, "hillclimb.json")
    if not os.path.exists(path):
        print("perf/skipped,,no results/hillclimb.json")
        return
    log = perf_log(path)
    out = os.path.join(results, "perf_log.md")
    with open(out, "w") as f:
        f.write("# §Perf — hillclimb log\n" + log)
    print(f"perf/log,,written {out}")


if __name__ == "__main__":
    run()
