"""§Perf generator: turn results/hillclimb.json into the
hypothesis -> change -> before -> after -> verdict log, with roofline
terms recomputed per variant (same methodology as benchmarks/roofline.py).
"""

from __future__ import annotations

import json
import os

from benchmarks.roofline import roofline_record, ICI_BW


def _terms(rec):
    if "compute_s" in rec:
        return rec
    return roofline_record(rec)


def perf_log(path: str) -> str:
    recs = json.load(open(path))
    by_cell: dict = {}
    for r in recs:
        by_cell.setdefault(r["cell"], []).append(r)

    out = []
    for cell, rows in by_cell.items():
        out.append(f"\n### Cell: {cell}\n")
        base = None
        for r in rows:
            if r.get("status", "ok") != "ok":
                out.append(f"* **{r['variant']}** — ERROR: {r.get('error')}")
                continue
            if cell == "matching-engine":
                line = (f"| {r['variant']} | cpu {r['cpu_s']*1e3:.0f} ms/q | "
                        f"tpu-bound {r['tpu_bound']:.2e} s "
                        f"({r['n_candidates']/r['tpu_bound']/1e9:.2f} Gcand/s) |")
                if base is None:
                    base = r["tpu_bound"]
                    verdict = "baseline"
                else:
                    gain = base / r["tpu_bound"]
                    verdict = f"{gain:.2f}x vs baseline"
                out.append(f"* **{r['variant']}** — {verdict}")
                out.append(f"  * hypothesis: {r['hypothesis']}")
                out.append(f"  * measured: {line}")
                continue
            rr = _terms(r)
            terms = (f"compute {rr['compute_s']:.3e}s / memory "
                     f"{rr['memory_s']:.3e}s / collective "
                     f"{rr['collective_s']:.3e}s -> dominant "
                     f"**{rr['dominant']}**, roofline frac "
                     f"{rr['roofline_fraction']:.2f}")
            if base is None:
                base = rr
                verdict = "baseline"
            else:
                b = max(base["compute_s"], base["memory_s"],
                        base["collective_s"])
                n = max(rr["compute_s"], rr["memory_s"], rr["collective_s"])
                verdict = (f"step-time bound {b:.3e}s -> {n:.3e}s "
                           f"({b/max(n,1e-30):.2f}x)")
            out.append(f"* **{r['variant']}** — {verdict}")
            out.append(f"  * hypothesis: {r['hypothesis']}")
            out.append(f"  * measured: {terms}; collective bytes/dev "
                       f"{rr['coll_bytes_per_dev']/1e6:.1f} MB")
    return "\n".join(out) + "\n"


def run():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "hillclimb.json")
    if not os.path.exists(path):
        print("perf/skipped,,no results/hillclimb.json")
        return
    log = perf_log(path)
    out = os.path.join(os.path.dirname(path), "perf_log.md")
    with open(out, "w") as f:
        f.write("# §Perf — hillclimb log\n" + log)
    print(f"perf/log,,written {out}")


if __name__ == "__main__":
    run()
