"""Beyond-paper extensions benchmark: stSAX (the paper's §6 future work)
on combined season+trend data, and the sSAX index vs the linear pruned
scan."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_row
from repro.core import SAX, SSAX, TSAX, STSAX, SSaxIndex, exact_match
from repro.core.matching import (
    RawStore, pairwise_euclidean, tightness_of_lower_bound)
from repro.data.synthetic import _znorm_np, random_walk


def season_trend_dataset(n, T, L, s_seas, s_tr, seed=0):
    rng = np.random.default_rng(seed)
    base = _znorm_np(random_walk(rng, n, T))
    mask = rng.normal(size=(n, L)).astype(np.float32)
    mask -= mask.mean(1, keepdims=True)
    seas = _znorm_np(np.tile(mask, (1, T // L)))
    t = np.arange(T, dtype=np.float32)
    tc = (t - t.mean()) / t.std()
    tr = np.sign(rng.normal(size=(n, 1))).astype(np.float32) * tc[None]
    x = (np.sqrt(s_seas) * seas + np.sqrt(s_tr) * tr
         + np.sqrt(max(0, 1 - s_seas - s_tr)) * base)
    return _znorm_np(x)


def run():
    rows = []
    # -- stSAX vs single-component techniques on combined data ----------
    for s_seas, s_tr in [(0.45, 0.35), (0.25, 0.55), (0.6, 0.2)]:
        X = season_trend_dataset(400, 960, 8, s_seas, s_tr, seed=19)
        Q, D = X[:16], X[16:]
        ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))

        def tlb(t):
            d = np.asarray(t.pairwise_distance(
                t.encode(jnp.asarray(Q)), t.encode(jnp.asarray(D))))
            return tightness_of_lower_bound(d, ed)

        t_sax = tlb(SAX(T=960, W=48, A=64))
        t_ss = tlb(SSAX(T=960, W=24, L=8, A_seas=64, A_res=256,
                        r2_season=s_seas))
        t_ts = tlb(TSAX(T=960, W=48, A_tr=64, A_res=32, r2_trend=s_tr))
        t_st = tlb(STSAX(T=960, W=24, L=8, A_tr=64, A_seas=64, A_res=256,
                         r2_trend=s_tr,
                         r2_season=s_seas / max(1 - s_tr, 1e-6)))
        rows.append(("ext/stsax_tlb",
                     f"R2s={s_seas} R2t={s_tr} sax={t_sax:.3f} "
                     f"ssax={t_ss:.3f} tsax={t_ts:.3f} stsax={t_st:.3f}"))

    # -- index vs linear pruned scan -------------------------------------
    from repro.data.synthetic import season_dataset
    X = season_dataset(20_000, 480, 8, 0.7, seed=23,
                       per_series_strength=True)
    Q, D = X[:8], X[8:]
    ss = SSAX(T=480, W=20, L=8, A_seas=64, A_res=64, r2_season=0.7)
    sigma, resbar = ss.features(jnp.asarray(D))
    t0 = time.perf_counter()
    idx = SSaxIndex(np.asarray(sigma), np.asarray(resbar), T=480,
                    sd_seas=ss.sd_seas, sd_res=ss.sd_res, max_bits=6,
                    leaf_capacity=64)
    t_build = time.perf_counter() - t0
    rep_q = ss.encode(jnp.asarray(Q))
    rep_d = ss.encode(jnp.asarray(D))
    dists = np.asarray(ss.pairwise_distance(rep_q, rep_d))
    sq, rq = ss.features(jnp.asarray(Q))
    acc_i = acc_l = 0
    t_iq = t_lq = 0.0
    for qi in range(len(Q)):
        st = RawStore.ssd(D)
        t0 = time.perf_counter()
        r1 = idx.query(np.asarray(sq[qi]), np.asarray(rq[qi]), st, Q[qi])
        t_iq += time.perf_counter() - t0
        acc_i += r1.raw_accesses
        t0 = time.perf_counter()
        r2 = exact_match(Q[qi], dists[qi], RawStore.ssd(D))
        t_lq += time.perf_counter() - t0
        acc_l += r2.raw_accesses
        assert r1.index == r2.index
    rows.append(("ext/index_vs_linear",
                 f"N=20000 nodes={idx.n_nodes} build_s={t_build:.2f} "
                 f"idx_raw={acc_i / 8:.0f} lin_raw={acc_l / 8:.0f} "
                 f"idx_q_s={t_iq / 8:.4f} lin_q_s={t_lq / 8:.4f} "
                 f"(linear includes the O(N) distance sweep per query)"))
    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run()
