"""Sharded-verification benchmark (``core.distributed``).

For each representation (SAX / sSAX / tSAX / stSAX), measures exact
top-k with **device-resident** raw verification (``verify="device"``:
raw rows sharded across the mesh next to the representation, candidates
distanced per shard through the multi-query Pallas euclid kernel,
device-side merge) against the **host** fallback (``verify="host"``:
one batched store fetch per round, same kernel distance math), in both
regimes:

* **whole-series**: ``make_engine_service`` over a Season corpus;
* **windowed**: ``SubseqEngine`` with a sharded window sweep — window
  candidates are sliced + z-normalized on device from the sharded
  source rows.

Reported per path: verification wall-clock and **candidates moved to
host** (``store_accesses`` — the device path must move zero).  The two
paths share one distance definition (the kernel's f32 reduction), so
results must be bit-identical — any divergence or any host movement on
the device path fails the run (the CI dryrun legs run this on a forced
4-device host platform).

``--dryrun`` shrinks everything so CI exercises the full path — sharded
mirrors, shard_map verification, device merge — in seconds.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit_row, observe_topk
from repro.core import make_technique
from repro.data.synthetic import season_dataset
from repro.subseq import SubseqEngine, WindowView

L = 10

FULL = dict(n=2048, T=480, queries=6, k=16, batch=256,
            sub_n=24, sub_T=1200, m=240, stride=2, sub_k=8, sub_queries=3)
DRY = dict(n=96, T=240, queries=2, k=4, batch=64,
           sub_n=5, sub_T=610, m=120, stride=7, sub_k=3, sub_queries=2)


def _encoders(T):
    w = T // (2 * L)
    return {
        "sax": make_technique("sax", T=T, W=w, L=L),
        "ssax": make_technique("ssax", T=T, W=w, L=L, r2_season=0.7),
        "tsax": make_technique("tsax", T=T, W=w, L=L, r2_trend=0.3),
        "stsax": make_technique("stsax", T=T, W=w, L=L, r2_season=0.5),
    }


def _whole(cfg, mesh, rows, failures):
    import jax.numpy as jnp

    from repro.core import MatchEngine
    from repro.core.distributed import make_engine_service
    n, T, k = cfg["n"], cfg["T"], cfg["k"]
    X = season_dataset(n + cfg["queries"], T, L, strength=0.7,
                       per_series_strength=True, seed=41)
    Q, D = X[:cfg["queries"]], X[cfg["queries"]:]
    for tech, enc in _encoders(T).items():
        dev = make_engine_service(enc, jnp.asarray(D), mesh,
                                  verify="device", batch_size=cfg["batch"])
        # the host path under comparison is the plain SymbolicStore
        # engine (store fetch + the same kernel math) — no sharded sweep
        host = MatchEngine(enc, dev.store, verify="host",
                           batch_size=cfg["batch"])
        t0 = time.perf_counter()
        r_d = dev.topk(Q, k=k)
        t_dev = time.perf_counter() - t0
        observe_topk(f"sharded_verify/whole/{tech}/device", r_d, t_dev)
        t0 = time.perf_counter()
        r_h = host.topk(Q, k=k)
        t_host = time.perf_counter() - t0
        observe_topk(f"sharded_verify/whole/{tech}/host", r_h, t_host)
        agree = int(np.array_equal(r_d.indices, r_h.indices)
                    and np.array_equal(r_d.distances, r_h.distances))
        # the exact path must order candidates on device: zero bound
        # bytes pulled to host (the legacy (Q, N) matrix hop)
        order_b = dev.sweep.host_order_bytes
        if not agree or r_d.store_accesses != 0 or order_b != 0:
            failures.append(f"whole/{tech}")
        rows.append((
            f"sharded_verify/whole/{tech}",
            f"n={n} k={k} moved_dev={r_d.store_accesses} "
            f"moved_host={r_h.store_accesses} order_bytes={order_b} "
            f"h2d_bytes={dev.sweep.h2d_bytes} bitwise={agree} "
            f"io_host_s={r_h.io_seconds:.5f} wall_dev_s={t_dev:.2f} "
            f"wall_host_s={t_host:.2f}"))


def _windowed(cfg, mesh, rows, failures):
    n, T, m, stride, k = (cfg["sub_n"], cfg["sub_T"], cfg["m"],
                          cfg["stride"], cfg["sub_k"])
    n_q = cfg["sub_queries"]
    rng = np.random.default_rng(43)
    D = season_dataset(n, T, L, strength=0.7,
                       per_series_strength=True, seed=43)
    q_rows = rng.integers(0, n, size=n_q)
    offs = rng.integers(0, T - m, size=n_q)
    Q = np.stack([D[r, o:o + m] for r, o in zip(q_rows, offs)])
    Q = Q + 0.05 * rng.normal(size=Q.shape).astype(np.float32)
    for tech, enc in _encoders(m).items():
        view = WindowView(enc, D, stride=stride, media="ssd")
        e_dev = SubseqEngine(view, mesh=mesh, verify="device",
                             batch_size=cfg["batch"])
        e_host = SubseqEngine(view, verify="host", batch_size=cfg["batch"])
        t0 = time.perf_counter()
        r_d = e_dev.topk(Q, k=k)
        t_dev = time.perf_counter() - t0
        observe_topk(f"sharded_verify/windowed/{tech}/device", r_d, t_dev)
        view.reset()
        t0 = time.perf_counter()
        r_h = e_host.topk(Q, k=k)
        t_host = time.perf_counter() - t0
        observe_topk(f"sharded_verify/windowed/{tech}/host", r_h, t_host)
        agree = int(np.array_equal(r_d.window_ids, r_h.window_ids)
                    and np.array_equal(r_d.distances, r_h.distances))
        order_b = e_dev._sweep.host_order_bytes
        if not agree or r_d.store_accesses != 0 or order_b != 0:
            failures.append(f"windowed/{tech}")
        rows.append((
            f"sharded_verify/windowed/{tech}",
            f"windows={view.n} k={k} moved_dev={r_d.store_accesses} "
            f"moved_host={r_h.store_accesses} order_bytes={order_b} "
            f"bitwise={agree} "
            f"io_host_s={r_h.io_seconds:.5f} wall_dev_s={t_dev:.2f} "
            f"wall_host_s={t_host:.2f}"))


def run(dryrun: bool = False):
    import jax

    from repro.launch.mesh import make_mesh_compat
    cfg = DRY if dryrun else FULL
    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    rows: list = []
    failures: list = []
    _whole(cfg, mesh, rows, failures)
    _windowed(cfg, mesh, rows, failures)
    verdict = "PASS" if not failures else "FAIL " + ",".join(failures)
    rows.append((
        "sharded_verify/acceptance",
        f"devices={n_dev} (target: device path bit-identical to host "
        f"fallback with zero candidates moved to host) {verdict}"))
    for name, derived in rows:
        emit_row(name, derived)
    if failures:
        raise RuntimeError(
            "device-resident verification broke its contract "
            "(bit-identity to the host path / zero host movement): "
            + ", ".join(failures))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny sizes + forced multi-device fleet (CI)")
    args = ap.parse_args()
    run(dryrun=args.dryrun)
