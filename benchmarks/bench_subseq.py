"""Subsequence-matching benchmark (``repro.subseq``).

For each representation (SAX / sSAX / tSAX / stSAX) over a Season corpus
of long series, measures the pruned windowed scan
(``SubseqEngine.topk``) against the brute-force windowed baseline
(``SubseqEngine.scan_topk`` — the MASS-style Pallas kernel streaming the
whole corpus):

* **pruning power**: fraction of windows never verified per query;
* **modeled I/O**: deduplicated underlying-row reads through the
  ``RawStore`` cost model vs one streaming pass over the corpus — the
  acceptance regime is >= 10k windows, where the symbolic-pruned path
  must beat the brute-force scan;
* **agreement**: the pruned top-1 window must be the scan's top-1.

``--dryrun`` shrinks everything so CI can exercise the full path —
including the windowed Pallas kernel in interpret mode — in seconds.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit_row, observe_topk
from repro.core import make_technique
from repro.data.synthetic import season_dataset
from repro.subseq import SubseqEngine, WindowView

L = 10

FULL = dict(n=128, T=3600, m=240, stride=4, k=8, queries=4,
            use_kernel=False)     # ref profile off-TPU: interpret is slow
DRY = dict(n=8, T=600, m=120, stride=4, k=4, queries=2,
           use_kernel=True)       # tiny: exercise the Pallas kernel path


def _encoders(m):
    w = m // L
    return {
        "sax": make_technique("sax", T=m, W=w, L=L),
        "ssax": make_technique("ssax", T=m, W=w, L=L, r2_season=0.7),
        "tsax": make_technique("tsax", T=m, W=w, L=L, r2_trend=0.3),
        "stsax": make_technique("stsax", T=m, W=w, L=L, r2_season=0.5),
    }


def run(dryrun: bool = False):
    cfg = DRY if dryrun else FULL
    n, T, m, stride, k = (cfg["n"], cfg["T"], cfg["m"], cfg["stride"],
                          cfg["k"])
    n_q = cfg["queries"]
    rng = np.random.default_rng(23)
    D = season_dataset(n, T, L, strength=0.7,
                       per_series_strength=True, seed=23)
    # queries: noisy snippets of the corpus itself (the subsequence
    # workload: the observed pattern occurs SOMEWHERE in the corpus and
    # the engine must localize it)
    q_rows = rng.integers(0, n, size=n_q)
    offs = rng.integers(0, T - m, size=n_q)
    Q = np.stack([D[r, o:o + m] for r, o in zip(q_rows, offs)])
    Q = Q + 0.05 * rng.normal(size=Q.shape).astype(np.float32)

    rows = []
    n_windows = None
    speedups = {}
    diverged = []
    for tech, enc in _encoders(m).items():
        view = WindowView(enc, D, stride=stride, media="ssd")
        n_windows = view.n
        eng = SubseqEngine(view, verify="numpy", batch_size=512)
        view.reset()
        t0 = time.perf_counter()
        res = eng.topk(Q, k=k)
        t_pruned = time.perf_counter() - t0
        observe_topk(f"subseq/{tech}", res, t_pruned)
        t0 = time.perf_counter()
        scan = eng.scan_topk(Q, k=k, use_kernel=cfg["use_kernel"])
        t_scan = time.perf_counter() - t0
        hit1 = int(sum(res.window_ids[qi, 0] == scan.window_ids[qi, 0]
                       for qi in range(n_q)))
        # ids must match exactly; distances to kernel tolerance (the
        # scan profile comes from the MASS-style rolling-stats kernel,
        # a different f32 computation than the pruned path's verifier)
        if not (np.array_equal(res.window_ids, scan.window_ids)
                and np.allclose(res.distances, scan.distances,
                                rtol=1e-3, atol=1e-3)):
            diverged.append(tech)
        speedup = scan.io_seconds / max(res.io_seconds, 1e-12)
        speedups[tech] = speedup
        rows.append((
            f"subseq/{tech}",
            f"windows={view.n} pruned={res.pruned_fraction.mean():.3f} "
            f"verified_per_q={res.raw_accesses.mean():.0f} "
            f"rows_read={res.store_accesses} of {view.n_rows} "
            f"io_pruned_s={res.io_seconds:.5f} "
            f"io_scan_s={scan.io_seconds:.5f} "
            f"io_speedup={speedup:.1f}x hit1={hit1}/{n_q} "
            f"wall_pruned_s={t_pruned:.2f} wall_scan_s={t_scan:.2f}"))
    best = max(speedups, key=speedups.get)
    ok = n_windows >= 10_000 and speedups[best] > 1.0
    verdict = ("PASS" if ok else
               "dryrun (acceptance judged at full size)" if dryrun
               else "MISS")
    rows.append((
        "subseq/acceptance",
        f"windows={n_windows} best={best} "
        f"io_speedup={speedups[best]:.1f}x "
        f"(target: pruned beats scan at >= 10k windows) {verdict}"))
    for name, derived in rows:
        emit_row(name, derived)
    # exactness is a hard contract: the pruned windowed scan must return
    # the brute-force scan's top-k for every representation — any
    # divergence fails the run (and the CI dryrun leg), not just a print
    if diverged:
        raise RuntimeError("pruned top-k diverged from the brute-force "
                           "windowed scan for: " + ", ".join(diverged))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny sizes + Pallas kernel path (CI)")
    args = ap.parse_args()
    run(dryrun=args.dryrun)
