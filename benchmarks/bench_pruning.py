"""Paper Fig. 6 — pruning power of exact matching, sSAX/tSAX vs SAX at
equal representation size; plus the k-NN generalization (pruning against
the k-th true neighbour, the bound the batched engine stops on)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import cached, emit_row
from repro.core import SAX, SSAX, TSAX
from repro.core.matching import pairwise_euclidean, pruning_power
from repro.data.synthetic import season_dataset, trend_dataset

N_Q = 24


def _pp(technique, Q, D, k: int = 1):
    rq = technique.encode(jnp.asarray(Q))
    rx = technique.encode(jnp.asarray(D))
    d = np.asarray(technique.pairwise_distance(rq, rx))
    return float(np.mean([pruning_power(Q[i], d[i], D, k=k)
                          for i in range(len(Q))]))


def _record(label: str, value: float):
    """Registry gauge under the same ``bench.*.pruning_power`` name the
    engine suites use, so BENCH_pruning.json carries the unified
    summary schema too."""
    from repro.obs import REGISTRY
    REGISTRY.gauge(f"bench.pruning_power.{label}").set(value)


def run():
    rows = []
    for s in [0.1, 0.5, 0.9]:
        X = cached(("season", 960, s, "pp"),
                   lambda s=s: season_dataset(400, 960, 10, s, seed=10))
        Q, D = X[:N_Q], X[N_Q:]
        pp_sax = max(_pp(SAX(T=960, W=32, A=1024), Q, D),
                     _pp(SAX(T=960, W=48, A=64), Q, D))
        pp_ss = max(_pp(SSAX(T=960, W=24, L=10, A_seas=256, A_res=1024,
                             r2_season=s), Q, D),
                    _pp(SSAX(T=960, W=48, L=10, A_seas=9, A_res=64,
                             r2_season=s), Q, D))
        _record(f"season/R2={s}/sax", pp_sax)
        _record(f"season/R2={s}/ssax", pp_ss)
        rows.append(("pruning/season",
                     f"R2={s} sax={pp_sax:.4f} ssax={pp_ss:.4f} "
                     f"gain_pp={(pp_ss - pp_sax) * 100:.1f}"))
    for s in [0.1, 0.5, 0.9]:
        X = trend_dataset(400, 960, s, seed=11)
        Q, D = X[:N_Q], X[N_Q:]
        pp_sax = _pp(SAX(T=960, W=48, A=64), Q, D)
        pp_ts = _pp(TSAX(T=960, W=48, A_tr=64, A_res=64, r2_trend=s), Q, D)
        _record(f"trend/R2={s}/sax", pp_sax)
        _record(f"trend/R2={s}/tsax", pp_ts)
        rows.append(("pruning/trend",
                     f"R2={s} sax={pp_sax:.4f} tsax={pp_ts:.4f} "
                     f"gain_pp={(pp_ts - pp_sax) * 100:.1f}"))
    # k-NN pruning power: the fraction of the dataset the engine's
    # generalized (k-th-best-so-far) early stop can never touch
    X = cached(("season", 960, 0.7, "pp"),
               lambda: season_dataset(400, 960, 10, 0.7, seed=10))
    Q, D = X[:N_Q], X[N_Q:]
    ss = SSAX(T=960, W=48, L=10, A_seas=9, A_res=64, r2_season=0.7)
    for k in (1, 8, 32):
        pp_k = _pp(ss, Q, D, k=k)
        _record(f"season_knn/k={k}/ssax", pp_k)
        rows.append((f"pruning/season_knn_k{k}",
                     f"R2=0.7 k={k} ssax={pp_k:.4f}"))
    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run()
