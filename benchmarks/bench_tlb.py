"""Paper Fig. 5 — tightness of lower bound at EQUAL representation size.

Synthetic grids report the best configuration per technique at the fixed
320-bit budget (paper Table 4); real-world surrogates compare best-config
TLB for SAX vs sSAX (Metering-like, 3640-bit budget) and SAX vs tSAX vs
1d-SAX (Economy-like, 80-bit budget)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import cached, emit_row
from repro.core import SAX, SSAX, TSAX
from repro.core.matching import pairwise_euclidean, tightness_of_lower_bound
from repro.core.onedsax import OneDSAX
from repro.data.datasets import economy_like, metering_like
from repro.data.synthetic import season_dataset, trend_dataset

N_Q = 24     # queries per dataset (vs the rest) — keeps CPU wall time sane


def _tlb(technique, Q, D, ed):
    rq = technique.encode(jnp.asarray(Q))
    rx = technique.encode(jnp.asarray(D))
    d = np.asarray(technique.pairwise_distance(rq, rx))
    return tightness_of_lower_bound(d, ed)


def _best(cands, Q, D, ed):
    vals = [(_tlb(c, Q, D, ed), c) for c in cands]
    return max(vals, key=lambda t: t[0])


# paper Table 4: 320-bit configurations (W=[32,40,48,96], A=[1024,256,101,10])
def sax_configs(T):
    return [SAX(T=T, W=32, A=1024), SAX(T=T, W=40, A=256),
            SAX(T=T, W=48, A=101), SAX(T=T, W=96, A=10)]


def ssax_configs(T, r2):
    return [SSAX(T=T, W=24, L=10, A_seas=256, A_res=1024, r2_season=r2),
            SSAX(T=T, W=48, L=10, A_seas=256, A_res=32, r2_season=r2),
            SSAX(T=T, W=48, L=10, A_seas=9, A_res=64, r2_season=r2)]


def tsax_configs(T, r2):
    return [TSAX(T=T, W=32, A_tr=32, A_res=2 ** 9, r2_trend=r2),
            TSAX(T=T, W=40, A_tr=128, A_res=2 ** 7, r2_trend=r2),
            TSAX(T=T, W=48, A_tr=1024, A_res=2 ** 6, r2_trend=r2)]


def run():
    rows = []
    for s in [0.1, 0.5, 0.9]:
        for T in [480, 960, 1920]:
            X = cached(("season", T, s, "tlb"),
                       lambda T=T, s=s: season_dataset(400, T, 10, s, seed=8))
            Q, D = X[:N_Q], X[N_Q:]
            ed = np.asarray(pairwise_euclidean(jnp.asarray(Q),
                                               jnp.asarray(D)))
            b_sax, _ = _best(sax_configs(T), Q, D, ed)
            b_ss, _ = _best(ssax_configs(T, s), Q, D, ed)
            rows.append(("tlb/season",
                         f"T={T} R2={s} sax={b_sax:.4f} ssax={b_ss:.4f} "
                         f"gain_pp={(b_ss - b_sax) * 100:.1f}"))
    for s in [0.1, 0.5, 0.9]:
        X = trend_dataset(400, 960, s, seed=9)
        Q, D = X[:N_Q], X[N_Q:]
        ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
        b_sax, _ = _best(sax_configs(960), Q, D, ed)
        b_ts, _ = _best(tsax_configs(960, s), Q, D, ed)
        rows.append(("tlb/trend",
                     f"T=960 R2={s} sax={b_sax:.4f} tsax={b_ts:.4f} "
                     f"gain_pp={(b_ts - b_sax) * 100:.1f}"))

    # Metering-like (daily season L=48); budget = 3640 bits (Table 4).
    # W=455 with L=48 needs W*L | T: the paper's full series T=21840=455*48.
    Xm = metering_like(n=200, days=455)
    T = Xm.shape[1]
    Q, D = Xm[:N_Q], Xm[N_Q:]
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    sax_m = [SAX(T=T, W=455, A=256), SAX(T=T, W=520, A=128),
             SAX(T=T, W=728, A=32)]
    # sSAX at W=455: A_res per Table 4 heuristic (approximated to pow2)
    ss_m = [SSAX(T=T, W=455, L=48, A_seas=a, A_res=r, r2_season=0.183)
            for a, r in [(16, 128), (64, 128), (256, 64)]]
    b_sax, _ = _best(sax_m, Q, D, ed)
    b_ss, _ = _best(ss_m, Q, D, ed)
    rows.append(("tlb/metering_like",
                 f"sax={b_sax:.4f} ssax={b_ss:.4f} "
                 f"gain_pp={(b_ss - b_sax) * 100:.1f}"))

    # Economy-like; 80-bit budget, include 1d-SAX (Table 4)
    Xe = economy_like(n=400, T=300)
    Q, D = Xe[:N_Q], Xe[N_Q:]
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    sax_e = [SAX(T=300, W=10, A=256), SAX(T=300, W=12, A=101),
             SAX(T=300, W=20, A=16)]
    tsax_e = [TSAX(T=300, W=10, A_tr=16, A_res=2 ** 7, r2_trend=0.6),
              TSAX(T=300, W=12, A_tr=64, A_res=2 ** 6, r2_trend=0.6),
              TSAX(T=300, W=15, A_tr=256, A_res=2 ** 4, r2_trend=0.6)]
    oned_e = [OneDSAX(T=300, W=10, A_a=32, A_s=8),
              OneDSAX(T=300, W=10, A_a=16, A_s=16)]
    b_sax, _ = _best(sax_e, Q, D, ed)
    b_ts, _ = _best(tsax_e, Q, D, ed)
    b_1d, _ = _best(oned_e, Q, D, ed)
    rows.append(("tlb/economy_like",
                 f"sax={b_sax:.4f} tsax={b_ts:.4f} onedsax={b_1d:.4f}"))
    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run()
