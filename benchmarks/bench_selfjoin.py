"""Matrix-profile self-join benchmark (``repro.profile``).

Three measurements:

1. **FFT vs accumulation crossover** — ``kernels.fft_dot``'s MASS-style
   rfft/irfft sliding dot product against the m-step accumulation twin
   (both plain jitted XLA; off-TPU the Pallas kernel benchmarks the
   interpreter, not the algorithm), swept over window length m.  The
   crossover m (first m where FFT wins) lands in ``BENCH_selfjoin.json``
   — the acceptance regime is m >= 1k, where the O(T log T) transform
   must beat the O(T m) accumulation.  Numeric agreement of the
   ``ops.windowed_euclid`` method dispatch is asserted within the
   documented ``fft_dot.fft_tolerance(m)`` contract.
2. **Pruning power per encoder** — ``SelfJoinEngine.profile`` (exact
   per-window nearest non-trivial neighbor) for SAX / sSAX / tSAX /
   stSAX, bit-identity against the brute-force profile oracle
   (``scan_profile``) as a hard contract, plus the modeled I/O of the
   pruned profile vs the oracle's streaming pass.
3. **Device residency** — the sharded stream path over every local
   device with ``verify="device"``: bit-identity against the host twin
   AND ``host_order_bytes == 0`` / ``rows_to_host == 0`` via
   ``repro.obs.check_trace`` (the CI 8-device leg's gate).

``--dryrun`` shrinks everything to CI scale; any bitwise divergence or
device-invariant violation raises (the ``--strict`` gate).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit_row, observe_topk, time_fn
from repro.core import make_technique
from repro.data.synthetic import season_dataset

L = 10

FULL = dict(n=16, T=960, m=120, stride=8,
            dot_n=8, dot_T=8192, dot_q=4,
            dot_ms=(64, 256, 1024, 2048))
DRY = dict(n=6, T=240, m=60, stride=6,
           dot_n=4, dot_T=512, dot_q=2,
           dot_ms=(32, 128))


def _encoders(m):
    w = m // L
    return {
        "sax": make_technique("sax", T=m, W=w, L=L),
        "ssax": make_technique("ssax", T=m, W=w, L=L, r2_season=0.7),
        "tsax": make_technique("tsax", T=m, W=w, L=L, r2_trend=0.3),
        "stsax": make_technique("stsax", T=m, W=w, L=L, r2_season=0.5),
    }


def _dot_crossover(cfg, rows, diverged):
    """FFT vs accumulation sliding dot product over m; returns the
    crossover m (first m where the FFT path is faster), or None."""
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.fft_dot import (fft_tolerance, sliding_dot_accum,
                                       sliding_dot_fft)
    from repro.kernels.ref import sliding_dot_ref

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(cfg["dot_n"], cfg["dot_T"])),
                    jnp.float32)
    crossover = None
    for m in cfg["dot_ms"]:
        q = rng.normal(size=(cfg["dot_q"], m)).astype(np.float32)
        q = (q - q.mean(1, keepdims=True)) / q.std(1, keepdims=True)
        qd = jnp.asarray(q)
        t_fft = time_fn(lambda: sliding_dot_fft(x, qd))
        t_acc = time_fn(lambda: sliding_dot_accum(x, qd))
        ok = np.allclose(np.asarray(sliding_dot_fft(x, qd)),
                         np.asarray(sliding_dot_accum(x, qd)),
                         **fft_tolerance(m))
        ref_ok = np.allclose(np.asarray(sliding_dot_fft(x, qd)),
                             np.asarray(sliding_dot_ref(x, qd)),
                             **fft_tolerance(m))
        if not (ok and ref_ok):
            diverged.append(f"dot/m{m}")
        if crossover is None and t_fft < t_acc:
            crossover = m
        rows.append((
            f"selfjoin/dot_m{m}",
            f"T={cfg['dot_T']} fft_s={t_fft:.5f} accum_s={t_acc:.5f} "
            f"speedup={t_acc / max(t_fft, 1e-12):.2f}x "
            f"tol_ok={'yes' if ok and ref_ok else 'NO'}"))
    # the distance-expansion dispatch must agree with the accumulation
    # oracle within the same documented contract (small fixed case —
    # the interpret-mode kernel is the reference, so keep it tiny)
    xs = jnp.asarray(rng.normal(size=(3, 200)), jnp.float32)
    qs = rng.normal(size=(2, 40)).astype(np.float32)
    qs = (qs - qs.mean(1, keepdims=True)) / qs.std(1, keepdims=True)
    d_fft = np.asarray(ops.windowed_euclid(xs, jnp.asarray(qs), stride=2,
                                           method="fft"))
    d_acc = np.asarray(ops.windowed_euclid(xs, jnp.asarray(qs), stride=2,
                                           method="accum"))
    if not np.allclose(d_fft, d_acc, **fft_tolerance(40)):
        diverged.append("dot/dispatch")
    return crossover


def run(dryrun: bool = False):
    cfg = DRY if dryrun else FULL
    n, T, m, stride = cfg["n"], cfg["T"], cfg["m"], cfg["stride"]
    rows, diverged = [], []

    crossover = _dot_crossover(cfg, rows, diverged)
    big_ok = crossover is not None and crossover <= 1024
    verdict = ("PASS" if big_ok else
               "dryrun (crossover judged at full size)" if dryrun
               else "MISS")
    rows.append((
        "selfjoin/crossover",
        f"crossover_m={crossover} "
        f"(target: fft beats accumulation at m >= 1k) {verdict}"))

    from repro.profile import SelfJoinEngine, topk_discords, topk_motifs
    from repro.subseq import WindowView

    D = season_dataset(n, T, L, strength=0.7, per_series_strength=True,
                       seed=29)
    view0 = None
    for tech, enc in _encoders(m).items():
        view = WindowView(enc, D, stride=stride, media="ssd")
        if view0 is None:
            view0 = view
        eng = SelfJoinEngine(view, verify="numpy", batch_size=256)
        view.reset()
        t0 = time.perf_counter()
        prof = eng.profile()
        t_prof = time.perf_counter() - t0
        observe_topk(f"selfjoin/{tech}", prof, t_prof)
        t0 = time.perf_counter()
        oracle = eng.scan_profile()
        t_scan = time.perf_counter() - t0
        same = (np.array_equal(prof.distances, oracle.distances)
                and np.array_equal(prof.neighbors, oracle.neighbors))
        motifs_same = (topk_motifs(prof, view.locate, 4)
                       == topk_motifs(oracle, view.locate, 4))
        discords_same = (topk_discords(prof, view.locate, 4)
                         == topk_discords(oracle, view.locate, 4))
        if not (same and motifs_same and discords_same):
            diverged.append(tech)
        rows.append((
            f"selfjoin/{tech}",
            f"windows={prof.n} pruned={prof.pruned_fraction.mean():.3f} "
            f"verified_per_w={prof.raw_accesses.mean():.0f} "
            f"io_profile_s={prof.io_seconds:.5f} "
            f"io_scan_s={oracle.io_seconds:.5f} "
            f"bitwise={'yes' if same else 'NO'} "
            f"motifs={'yes' if motifs_same else 'NO'} "
            f"discords={'yes' if discords_same else 'NO'} "
            f"wall_profile_s={t_prof:.2f} wall_scan_s={t_scan:.2f}"))

    # device residency: sharded stream + device verify over every local
    # device, gated by the trace's transfer invariants
    import jax

    from repro.launch.mesh import make_mesh_compat
    from repro.obs import check_trace

    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    host = SelfJoinEngine(view0, verify="host", batch_size=256)
    p_host = host.profile(use_index=False)
    dev = SelfJoinEngine(view0, verify="device", mesh=mesh,
                         batch_size=256)
    t0 = time.perf_counter()
    p_dev = dev.profile(explain=True)
    t_dev = time.perf_counter() - t0
    dev_same = (np.array_equal(p_dev.distances, p_host.distances)
                and np.array_equal(p_dev.neighbors, p_host.neighbors))
    problems = check_trace(p_dev.trace, device=True)
    if not dev_same or problems:
        diverged.append(f"device({';'.join(problems) or 'bitwise'})")
    rows.append((
        "selfjoin/device",
        f"devices={n_dev} bitwise_vs_host={'yes' if dev_same else 'NO'} "
        f"host_order_bytes={p_dev.trace.get('host_order_bytes')} "
        f"rows_to_host={p_dev.trace.get('rows_to_host')} "
        f"trace={'ok' if not problems else ';'.join(problems)} "
        f"wall_s={t_dev:.2f}"))

    for name, derived in rows:
        emit_row(name, derived)
    # exactness and device residency are hard contracts — any bitwise
    # divergence, tolerance breach or transfer-invariant violation fails
    # the run (the CI --strict gate), not just a print
    if diverged:
        raise RuntimeError("self-join contracts violated for: "
                           + ", ".join(diverged))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny sizes (CI)")
    args = ap.parse_args()
    run(dryrun=args.dryrun)
