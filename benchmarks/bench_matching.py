"""Paper Table 5 — exact-matching efficiency on Season (Large), served by
the unified batched k-NN engine.

The paper's 50/100 Gb datasets are I/O-bound on HDD/SSD; the result is
pruning-power-driven.  We reproduce the *mechanism* at container scale:
a scaled-down Season (Large) (same T=960, per-series strength spread),
measured representation-sweep wall time (the "Repr." column, real), and
the engine's per-query raw-access counts converted through the
batch-accounted I/O cost model at the paper's HDD/SSD rates AND at
TPU-HBM rates (DESIGN.md §8.1), for k=1 (the paper's setting) and k=32
(the k-NN generalization).  The headline ratio (sSAX total / SAX total)
is the reproduced claim.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit_row, observe_topk, time_fn
from repro.core import SAX, SSAX, MatchEngine
from repro.core.matching import RawStore
from repro.data.synthetic import season_dataset
from repro.kernels import ops

N = 20_000            # series of T=960 f32 = ~77 MB raw (scaled-down 50Gb)
N_Q = 8


def run():
    rows = []
    for s in [0.1, 0.5, 0.9]:
        X = season_dataset(N + N_Q, 960, 10, s, seed=13,
                           per_series_strength=True)
        Q, D = X[:N_Q], X[N_Q:]
        sax = SAX(T=960, W=48, A=64)
        ss = SSAX(T=960, W=48, L=10, A_seas=9, A_res=64, r2_season=s)

        syms_sax = sax.encode(jnp.asarray(D))
        rep_ss = ss.encode(jnp.asarray(D))
        q_sax = sax.encode(jnp.asarray(Q))
        q_ss = ss.encode(jnp.asarray(Q))

        # measured representation-sweep time per query (kernel path)
        tab = ops.make_sax_query_table(q_sax[0], sax.breakpoints)
        t_rep_sax = time_fn(lambda: ops.sax_dist(syms_sax, tab), iters=3)
        tabs = ops.make_ssax_query_tables(q_ss[0][0], q_ss[1][0],
                                          ss.b_seas, ss.b_res)
        t_rep_ss = time_fn(
            lambda: ops.ssax_dist(rep_ss[0], rep_ss[1], *tabs), iters=3)

        # batched multi-query exact top-k through the engine
        stores = {"sax": RawStore.hdd(D), "ssax": RawStore.hdd(D)}
        engines = {
            "sax": MatchEngine(sax, stores["sax"], rep=syms_sax,
                               batch_size=256),
            "ssax": MatchEngine(ss, stores["ssax"], rep=rep_ss,
                                batch_size=256),
        }
        import time as _time
        for k in (1, 32):
            res = {}
            for name, eng in engines.items():
                stores[name].reset()
                t0 = _time.perf_counter()
                res[name] = eng.topk(Q, k=k)
                observe_topk(f"matching/{name}/R2={s}/k={k}", res[name],
                             _time.perf_counter() - t0)
            acc_sax = float(res["sax"].raw_accesses.mean())
            acc_ss = float(res["ssax"].raw_accesses.mean())
            fetch_sax = res["sax"].store_fetches
            fetch_ss = res["ssax"].store_fetches
            for store_name, store in [("hdd", RawStore.hdd(D)),
                                      ("ssd", RawStore.ssd(D)),
                                      ("hbm", RawStore.hbm(D))]:
                io_sax = store.modeled_io_seconds(
                    res["sax"].store_accesses, fetch_sax) / N_Q
                io_ss = store.modeled_io_seconds(
                    res["ssax"].store_accesses, fetch_ss) / N_Q
                tot_sax = t_rep_sax + io_sax
                tot_ss = t_rep_ss + io_ss
                rows.append((
                    f"matching/season_large_{store_name}_k{k}",
                    f"R2={s} N={N} k={k} "
                    f"sax_repr_s={t_rep_sax:.4f} sax_raw_q={acc_sax:.0f} "
                    f"sax_io_q_s={io_sax:.4f} "
                    f"ssax_repr_s={t_rep_ss:.4f} ssax_raw_q={acc_ss:.0f} "
                    f"ssax_io_q_s={io_ss:.4f} "
                    f"speedup={tot_sax / max(tot_ss, 1e-9):.1f}x"))
    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run()
