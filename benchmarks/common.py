"""Shared benchmark helpers: timing, dataset cache, CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np

_DATA_CACHE = {}


def cached(key, builder):
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = builder()
    return _DATA_CACHE[key]


def time_fn(fn, *args, warmup: int = 1, iters: int = 5):
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """The runner's CSV contract: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.2f},{derived}")


def emit_row(name: str, derived: str):
    print(f"{name},,{derived}")
