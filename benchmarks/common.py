"""Shared benchmark helpers: timing, dataset cache, CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np

_DATA_CACHE = {}


def cached(key, builder):
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = builder()
    return _DATA_CACHE[key]


def time_fn(fn, *args, warmup: int = 1, iters: int = 5):
    """Median wall time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """The runner's CSV contract: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.2f},{derived}")


def emit_row(name: str, derived: str):
    print(f"{name},,{derived}")


def observe_topk(label: str, res, wall_s=None):
    """Record one measured ``MatchEngine``/``SubseqEngine`` top-k result
    into the process registry under the unified ``bench.*`` schema.

    ``benchmarks.run`` resets the registry at each suite boundary and
    embeds the snapshot (plus the cross-suite ``summary`` — pruning
    power, rows fetched, modeled I/O, wall, host bytes) into that
    suite's ``results/BENCH_<suite>.json``, so every suite that calls
    this reports through the same schema instead of ad-hoc strings."""
    from repro.obs import REGISTRY
    REGISTRY.counter("bench.queries").inc(int(res.raw_accesses.shape[0]))
    REGISTRY.counter("bench.candidates_verified").inc(
        int(res.raw_accesses.sum()))
    REGISTRY.counter("bench.rows_fetched").inc(int(res.store_accesses))
    REGISTRY.counter("bench.seeks").inc(int(res.store_fetches))
    REGISTRY.counter("bench.modeled_io_s").inc(float(res.io_seconds))
    REGISTRY.gauge(f"bench.pruning_power.{label}").set(
        float(res.pruned_fraction.mean()))
    if wall_s is not None:
        REGISTRY.histogram("bench.topk_latency_s").observe(float(wall_s))
