"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU
(the Pallas path targets TPU; interpret mode is a correctness harness, not
a performance surface) plus derived TPU-roofline throughput estimates for
the kernel formulations (DESIGN.md §3)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import SAX, SSAX
from repro.data.synthetic import season_dataset
from repro.kernels import ops, ref

HBM = 819e9          # B/s
MXU = 197e12         # flop/s


def run():
    X = season_dataset(20_000, 960, 10, 0.5, seed=14)
    sax = SAX(T=960, W=48, A=256)
    syms = sax.encode(jnp.asarray(X))
    tab = ops.make_sax_query_table(syms[0], sax.breakpoints)
    t = time_fn(lambda: ops.sax_dist(syms, tab, use_kernel=False), iters=5)
    n, w = syms.shape
    a = tab.shape[1]
    # TPU estimate: HBM-bound on int8 symbols vs MXU-bound on one-hot dot
    t_mem = n * w * 1 / HBM
    t_mxu = n * w * a * 2 / MXU
    emit("kernel/sax_dist_cpu_ref", t,
         f"N={n} W={w} A={a} cpu_gcand/s={n / t / 1e9:.3f} "
         f"tpu_est_bound={'mxu' if t_mxu > t_mem else 'hbm'} "
         f"tpu_est_s={max(t_mxu, t_mem):.2e}")

    ss = SSAX(T=960, W=48, L=10, A_seas=64, A_res=64, r2_season=0.5)
    s_syms, r_syms = ss.encode(jnp.asarray(X))
    tabs = ops.make_ssax_query_tables(s_syms[0], r_syms[0],
                                      ss.b_seas, ss.b_res)
    t = time_fn(lambda: ops.ssax_dist(s_syms, r_syms, *tabs,
                                      use_kernel=False), iters=5)
    L = s_syms.shape[1]
    t_vpu = n * L * w * 4 / (MXU / 16)       # cross-term on the VPU
    emit("kernel/ssax_dist_cpu_ref", t,
         f"N={n} L={L} W={w} cpu_gcand/s={n / t / 1e9:.3f} "
         f"tpu_est_s={t_vpu:.2e}")

    x = jnp.asarray(X)
    t = time_fn(lambda: ops.paa_segments(x, 48, use_kernel=False), iters=5)
    emit("kernel/paa_cpu_ref", t,
         f"N={n} T=960 tpu_est_s={n * 960 * 4 / HBM:.2e} (stream-bound)")

    q = x[0]
    t = time_fn(lambda: ops.euclid_batch(x, q, use_kernel=False), iters=5)
    emit("kernel/euclid_cpu_ref", t,
         f"N={n} T=960 tpu_est_s={n * 960 * 4 / HBM:.2e} (stream-bound)")

    # interpret-mode spot check cost (correctness harness latency)
    small = syms[:2048]
    t = time_fn(lambda: ops.sax_dist(small, tab), iters=2)
    emit("kernel/sax_dist_interpret", t, "N=2048 (correctness mode)")
    return []


if __name__ == "__main__":
    run()
