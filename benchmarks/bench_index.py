"""Index-subsystem benchmark (``repro.index``).

For each representation (SAX / sSAX / tSAX / stSAX), measures the
split-tree candidate source against the linear lower-bound sweep —
both exact, both through ``core.engine.topk_verify``, so the only
difference is HOW MANY candidates each examines and what raw I/O the
verification order costs:

* **whole-series**: a Season corpus of >= 10k rows in a
  ``SymbolicStore``; ``MatchEngine.topk(source="index")`` vs the linear
  ``topk``;
* **windowed**: >= 100k sliding windows in a ``WindowView``;
  ``SubseqEngine.topk`` with the window index vs the linear window
  sweep;
* **acceptance**: the indexed sSAX path must examine strictly fewer
  candidates than the linear sweep in both regimes (the index, not the
  encoder, is where sublinear behavior is won), with bit-identical
  top-k.

``--dryrun`` shrinks everything so CI exercises the full path —
incremental build, tree traversal, engine integration — in seconds.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import emit_row, observe_topk
from repro.core import MatchEngine, make_technique
from repro.data.synthetic import season_dataset
from repro.subseq import SubseqEngine, WindowView

L = 10

FULL = dict(n=12_000, T=480, queries=8, k=8,
            sub_n=32, sub_T=3600, m=240, stride=1, sub_k=8, sub_queries=4)
DRY = dict(n=400, T=240, queries=2, k=4,
           sub_n=6, sub_T=600, m=120, stride=4, sub_k=4, sub_queries=2)


def _encoders(T):
    w = T // (2 * L)
    return {
        "sax": make_technique("sax", T=T, W=w, L=L),
        "ssax": make_technique("ssax", T=T, W=w, L=L, r2_season=0.7),
        "tsax": make_technique("tsax", T=T, W=w, L=L, r2_trend=0.3),
        "stsax": make_technique("stsax", T=T, W=w, L=L, r2_season=0.5),
    }


def _whole(cfg, rows, examined):
    from repro.store import SymbolicStore
    n, T, k = cfg["n"], cfg["T"], cfg["k"]
    X = season_dataset(n + cfg["queries"], T, L, strength=0.7,
                       per_series_strength=True, seed=31)
    Q, D = X[:cfg["queries"]], X[cfg["queries"]:]
    for tech, enc in _encoders(T).items():
        store = SymbolicStore.from_rows(enc, D, media="ssd")
        engine = MatchEngine(enc, store, verify="numpy", batch_size=256)
        store.reset()
        t0 = time.perf_counter()
        lin = engine.topk(Q, k=k)
        t_lin = time.perf_counter() - t0
        observe_topk(f"index/whole/{tech}/linear", lin, t_lin)
        io_lin = lin.io_seconds
        t0 = time.perf_counter()
        store.build_index(leaf_fill=64)
        t_build = time.perf_counter() - t0
        store.reset()
        t0 = time.perf_counter()
        idx = engine.topk(Q, k=k, source="index")
        t_idx = time.perf_counter() - t0
        observe_topk(f"index/whole/{tech}/indexed", idx, t_idx)
        agree = int(np.array_equal(idx.indices, lin.indices)
                    and np.array_equal(idx.distances, lin.distances))
        examined[f"bitwise/whole/{tech}"] = agree
        examined[f"whole/{tech}"] = (idx.raw_accesses.mean(),
                                     lin.raw_accesses.mean())
        rows.append((
            f"index/whole/{tech}",
            f"n={n} cand_idx={idx.raw_accesses.mean():.0f} "
            f"cand_lin={lin.raw_accesses.mean():.0f} "
            f"io_idx_s={idx.io_seconds:.5f} io_lin_s={io_lin:.5f} "
            f"nodes={store.index.n_nodes} build_s={t_build:.2f} "
            f"bitwise={agree} wall_idx_s={t_idx:.2f} "
            f"wall_lin_s={t_lin:.2f}"))


def _windowed(cfg, rows, examined):
    n, T, m, stride, k = (cfg["sub_n"], cfg["sub_T"], cfg["m"],
                          cfg["stride"], cfg["sub_k"])
    n_q = cfg["sub_queries"]
    rng = np.random.default_rng(37)
    D = season_dataset(n, T, L, strength=0.7,
                       per_series_strength=True, seed=37)
    q_rows = rng.integers(0, n, size=n_q)
    offs = rng.integers(0, T - m, size=n_q)
    Q = np.stack([D[r, o:o + m] for r, o in zip(q_rows, offs)])
    Q = Q + 0.05 * rng.normal(size=Q.shape).astype(np.float32)
    for tech, enc in _encoders(m).items():
        view = WindowView(enc, D, stride=stride, media="ssd")
        eng = SubseqEngine(view, verify="numpy", batch_size=512)
        view.reset()
        t0 = time.perf_counter()
        lin = eng.topk(Q, k=k, use_index=False)
        t_lin = time.perf_counter() - t0
        observe_topk(f"index/windowed/{tech}/linear", lin, t_lin)
        io_lin = lin.io_seconds
        t0 = time.perf_counter()
        view.build_index(leaf_fill=64)
        t_build = time.perf_counter() - t0
        view.reset()
        t0 = time.perf_counter()
        idx = eng.topk(Q, k=k)
        t_idx = time.perf_counter() - t0
        observe_topk(f"index/windowed/{tech}/indexed", idx, t_idx)
        agree = int(np.array_equal(idx.window_ids, lin.window_ids)
                    and np.array_equal(idx.distances, lin.distances))
        examined[f"bitwise/windowed/{tech}"] = agree
        examined[f"windowed/{tech}"] = (idx.raw_accesses.mean(),
                                        lin.raw_accesses.mean())
        rows.append((
            f"index/windowed/{tech}",
            f"windows={view.n} cand_idx={idx.raw_accesses.mean():.0f} "
            f"cand_lin={lin.raw_accesses.mean():.0f} "
            f"io_idx_s={idx.io_seconds:.5f} io_lin_s={io_lin:.5f} "
            f"nodes={view.index.n_nodes} build_s={t_build:.2f} "
            f"bitwise={agree} wall_idx_s={t_idx:.2f} "
            f"wall_lin_s={t_lin:.2f}"))
        examined[f"windows/{tech}"] = view.n


def run(dryrun: bool = False):
    cfg = DRY if dryrun else FULL
    rows: list = []
    examined: dict = {}
    _whole(cfg, rows, examined)
    _windowed(cfg, rows, examined)
    w_idx, w_lin = examined["whole/ssax"]
    s_idx, s_lin = examined["windowed/ssax"]
    ok = (cfg["n"] >= 10_000 and w_idx < w_lin
          and examined["windows/ssax"] >= 100_000 and s_idx < s_lin)
    verdict = ("PASS" if ok else
               "dryrun (acceptance judged at full size)" if dryrun
               else "MISS")
    rows.append((
        "index/acceptance",
        f"ssax whole {w_idx:.0f}<{w_lin:.0f}@n={cfg['n']} windowed "
        f"{s_idx:.0f}<{s_lin:.0f}@windows={examined['windows/ssax']} "
        f"(target: indexed examines strictly fewer candidates at >=10k "
        f"rows / >=100k windows) {verdict}"))
    for name, derived in rows:
        emit_row(name, derived)
    # bit-identity is a hard contract, not a printed observation: any
    # indexed-vs-linear divergence fails the run (and the CI dryrun leg)
    diverged = sorted(key for key, agree in examined.items()
                      if key.startswith("bitwise/") and not agree)
    if diverged:
        raise RuntimeError(
            "indexed results diverged from the linear sweep: "
            + ", ".join(k.removeprefix("bitwise/") for k in diverged))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="tiny sizes (CI)")
    args = ap.parse_args()
    run(dryrun=args.dryrun)
