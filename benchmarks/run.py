"""Benchmark runner — one module per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV rows and writes one
machine-readable ``results/BENCH_<suite>.json`` per suite (wall-clock,
the suite's result rows — candidates examined, bytes moved, bitwise
verdicts — and any error), so the perf trajectory is diffable across
PRs instead of living in log text.

    PYTHONPATH=src python -m benchmarks.run [--only entropy,tlb,...]

Paper artifact map:
    entropy  -> Fig. 4      tlb      -> Fig. 5     pruning -> Fig. 6
    approx   -> Fig. 7      matching -> Table 5    kernels -> (engine)
    ingest   -> (store subsystem: append throughput + query-under-ingest)
    subseq   -> (subsequence subsystem: pruned windowed scan vs brute)
    index    -> (index subsystem: tree candidates vs linear sweep)
    sharded_verify -> (device-resident sharded verification vs host)
    serving  -> (service subsystem: coalescing queue + planner under load)
    selfjoin -> (profile subsystem: FFT dot crossover + exact motifs)
    roofline -> EXPERIMENTS.md §Roofline (from results/dryrun.json)
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import sys
import time

SUITES = ["entropy", "tlb", "pruning", "approx", "matching", "kernels",
          "extensions", "ingest", "subseq", "index", "sharded_verify",
          "serving", "selfjoin", "roofline", "perf"]

RESULTS_DIR = "results"


def _rows_payload(rows) -> list:
    """Normalize a suite's ``run()`` return into [{"name", "derived"}]
    — suites return a list of (name, derived) pairs, None, or their own
    shapes; anything unrecognized is dropped, never fatal."""
    out = []
    if isinstance(rows, (list, tuple)):
        for r in rows:
            if (isinstance(r, (list, tuple)) and len(r) == 2
                    and isinstance(r[0], str)):
                out.append({"name": r[0], "derived": str(r[1])})
    return out


def _write_json(suite: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"BENCH_{suite}.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


def _summary(snap: dict, seconds: float) -> dict:
    """The cross-suite comparable summary every BENCH json carries —
    the same five numbers no matter which suite produced them, pooled
    from whatever ``bench.*`` / ``match.*`` / ``subseq.*`` metrics the
    suite recorded (suites record through
    ``benchmarks.common.observe_topk`` or an engine's ``metrics=``)."""
    c, g = snap["counters"], snap["gauges"]

    def _tot(suffix):
        return sum(v for k, v in c.items() if k.endswith(suffix))

    pp = [v for k, v in g.items() if ".pruning_power" in k]
    return {
        "pruning_power": (sum(pp) / len(pp)) if pp else None,
        "rows_fetched": _tot(".rows_fetched"),
        "modeled_io_s": _tot(".modeled_io_s"),
        "wall_s": seconds,
        "host_bytes": _tot(".host_order_bytes") + _tot(".h2d_bytes"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--dryrun", action="store_true",
                    help="forward dryrun=True to every suite that "
                    "accepts it (tiny CI sizes)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero if any selected suite errored "
                    "(CI: a diverging bench fails the leg, with the "
                    "BENCH json still written for the artifact upload)")
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else SUITES

    from repro.obs import REGISTRY

    failed = []
    print("name,us_per_call,derived")
    for suite in SUITES:
        if suite not in chosen:
            continue
        t0 = time.time()
        modname = {"roofline": "benchmarks.roofline",
                   "perf": "benchmarks.perf_report"}.get(
                       suite, f"benchmarks.bench_{suite}")
        # suite boundary: metrics recorded by one suite must never bleed
        # into the next suite's snapshot
        REGISTRY.reset()
        try:
            mod = importlib.import_module(modname)
            kwargs = {}
            if args.dryrun and "dryrun" in inspect.signature(
                    mod.run).parameters:
                kwargs["dryrun"] = True
            rows = mod.run(**kwargs)
            seconds = time.time() - t0
            snap = REGISTRY.snapshot()
            _write_json(suite, {"suite": suite, "ok": True,
                                "seconds": seconds,
                                "dryrun": args.dryrun,
                                "rows": _rows_payload(rows),
                                "metrics": snap,
                                "summary": _summary(snap, seconds)})
            print(f"suite/{suite},{seconds * 1e6:.0f},ok", flush=True)
        except Exception as e:   # noqa: BLE001 — report, keep going
            seconds = time.time() - t0
            snap = REGISTRY.snapshot()
            _write_json(suite, {"suite": suite, "ok": False,
                                "seconds": seconds,
                                "dryrun": args.dryrun,
                                "error": f"{type(e).__name__}: {e}",
                                "metrics": snap,
                                "summary": _summary(snap, seconds)})
            print(f"suite/{suite},,ERROR {type(e).__name__}: {e}",
                  flush=True)
            failed.append(suite)
    if failed and args.strict:
        sys.exit(f"benchmarks failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
