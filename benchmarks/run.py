"""Benchmark runner — one module per paper table/figure plus the roofline
report.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only entropy,tlb,...]

Paper artifact map:
    entropy  -> Fig. 4      tlb      -> Fig. 5     pruning -> Fig. 6
    approx   -> Fig. 7      matching -> Table 5    kernels -> (engine)
    ingest   -> (store subsystem: append throughput + query-under-ingest)
    subseq   -> (subsequence subsystem: pruned windowed scan vs brute)
    index    -> (index subsystem: tree candidates vs linear sweep)
    sharded_verify -> (device-resident sharded verification vs host)
    roofline -> EXPERIMENTS.md §Roofline (from results/dryrun.json)
"""

from __future__ import annotations

import argparse
import importlib
import time

SUITES = ["entropy", "tlb", "pruning", "approx", "matching", "kernels",
          "extensions", "ingest", "subseq", "index", "sharded_verify",
          "roofline", "perf"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(SUITES))
    args = ap.parse_args()
    chosen = args.only.split(",") if args.only else SUITES

    print("name,us_per_call,derived")
    for suite in SUITES:
        if suite not in chosen:
            continue
        t0 = time.time()
        modname = {"roofline": "benchmarks.roofline",
                   "perf": "benchmarks.perf_report"}.get(
                       suite, f"benchmarks.bench_{suite}")
        try:
            mod = importlib.import_module(modname)
            mod.run()
            print(f"suite/{suite},{(time.time() - t0) * 1e6:.0f},ok",
                  flush=True)
        except Exception as e:   # noqa: BLE001 — report, keep going
            print(f"suite/{suite},,ERROR {type(e).__name__}: {e}",
                  flush=True)


if __name__ == "__main__":
    main()
