"""Paper Fig. 4 — symbolic-distribution entropy of SAX vs sSAX (Season)
and SAX vs tSAX (Trend), by length, #segments, component strength, plus
the real-world surrogates (A = A_res = 256 throughout, H_max = 8)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import cached, emit_row
from repro.core import SAX, SSAX, TSAX
from repro.data.datasets import economy_like, metering_like
from repro.data.synthetic import season_dataset, trend_dataset

A = 256


def entropy(symbols, alphabet: int) -> float:
    """Eq. 32 over all symbols of a dataset representation."""
    counts = np.bincount(np.asarray(symbols).reshape(-1),
                         minlength=alphabet).astype(np.float64)
    p = counts / counts.sum()
    nz = p > 0
    return float(-(p[nz] * np.log2(p[nz])).sum())


def run():
    rows = []
    # -- Season: entropy by length (Fig 4a), strength fixed 50%
    for T in [480, 960, 1440, 1920]:
        X = cached(("season", T, 0.5),
                   lambda T=T: season_dataset(1000, T, 10, 0.5, seed=4))
        W = T // 20
        sax = SAX(T=T, W=W, A=A)
        ss = SSAX(T=T, W=W, L=10, A_seas=A, A_res=A, r2_season=0.5)
        h_sax = entropy(sax.encode(jnp.asarray(X)), A)
        h_ss = entropy(ss.encode(jnp.asarray(X))[1], A)
        rows.append(("entropy/season_by_length",
                     f"T={T} H_sax={h_sax:.3f} H_ssax={h_ss:.3f}"))
    # -- Season: entropy by #segments (Fig 4b), T=960
    X = cached(("season", 960, 0.5),
               lambda: season_dataset(1000, 960, 10, 0.5, seed=4))
    for W in [24, 48, 96]:
        sax = SAX(T=960, W=W, A=A)
        ss = SSAX(T=960, W=W, L=10, A_seas=A, A_res=A, r2_season=0.5)
        h_sax = entropy(sax.encode(jnp.asarray(X)), A)
        h_ss = entropy(ss.encode(jnp.asarray(X))[1], A)
        rows.append(("entropy/season_by_segments",
                     f"W={W} H_sax={h_sax:.3f} H_ssax={h_ss:.3f}"))
    # -- Season: entropy by strength (Fig 4c)
    for s in [0.1, 0.5, 0.9, 0.99]:
        X = season_dataset(1000, 960, 10, s, seed=5)
        sax = SAX(T=960, W=48, A=A)
        ss = SSAX(T=960, W=48, L=10, A_seas=A, A_res=A, r2_season=s)
        h_sax = entropy(sax.encode(jnp.asarray(X)), A)
        h_ss = entropy(ss.encode(jnp.asarray(X))[1], A)
        rows.append(("entropy/season_by_strength",
                     f"R2={s} H_sax={h_sax:.3f} H_ssax={h_ss:.3f}"))
    # -- Trend: by length / strength (Fig 4d-f)
    for T in [480, 960, 1920]:
        X = trend_dataset(1000, T, 0.5, seed=6)
        W = T // 20
        sax = SAX(T=T, W=W, A=A)
        ts = TSAX(T=T, W=W, A_tr=A, A_res=A, r2_trend=0.5)
        h_sax = entropy(sax.encode(jnp.asarray(X)), A)
        h_ts = entropy(ts.encode(jnp.asarray(X))[1], A)
        rows.append(("entropy/trend_by_length",
                     f"T={T} H_sax={h_sax:.3f} H_tsax={h_ts:.3f}"))
    for s in [0.1, 0.5, 0.9]:
        X = trend_dataset(1000, 960, s, seed=7)
        sax = SAX(T=960, W=48, A=A)
        ts = TSAX(T=960, W=48, A_tr=A, A_res=A, r2_trend=s)
        h_sax = entropy(sax.encode(jnp.asarray(X)), A)
        h_ts = entropy(ts.encode(jnp.asarray(X))[1], A)
        rows.append(("entropy/trend_by_strength",
                     f"R2={s} H_sax={h_sax:.3f} H_tsax={h_ts:.3f}"))
    # -- real-world surrogates (paper §5.1: 6.96 -> 7.09 and 7.92 -> 7.95)
    Xm = metering_like(n=512, days=65)
    Tm = Xm.shape[1]
    sax = SAX(T=Tm, W=Tm // 48, A=A)
    ss = SSAX(T=Tm, W=Tm // 48, L=48, A_seas=A, A_res=A, r2_season=0.183)
    rows.append(("entropy/metering_like",
                 f"H_sax={entropy(sax.encode(jnp.asarray(Xm)), A):.3f} "
                 f"H_ssax={entropy(ss.encode(jnp.asarray(Xm))[1], A):.3f}"))
    Xe = economy_like(n=512)
    sax = SAX(T=300, W=20, A=A)
    ts = TSAX(T=300, W=20, A_tr=A, A_res=A, r2_trend=0.6)
    rows.append(("entropy/economy_like",
                 f"H_sax={entropy(sax.encode(jnp.asarray(Xe)), A):.3f} "
                 f"H_tsax={entropy(ts.encode(jnp.asarray(Xe))[1], A):.3f}"))
    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run()
