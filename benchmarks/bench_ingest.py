"""Streaming-ingest benchmark for the ``repro.store.SymbolicStore``.

Two measurements over a >= 10k-row Season corpus:

* **Append throughput** (rows/s): ingesting one chunk into a warm corpus
  via ``SymbolicStore.append`` (encodes only the chunk) vs the
  full-re-encode baseline — what the pre-store ``MatchEngine`` did at
  construction: re-encode the entire corpus whenever the dataset changed.
  The acceptance target is incremental >= 10x faster at corpus >= 10k.
* **Query latency under ingest**: exact top-k latency through a
  ``SymbolicStore``-backed engine immediately after each append (the
  ingest-while-serving path) vs on the static corpus.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_row
from repro.core import SSAX, MatchEngine
from repro.data.synthetic import season_dataset
from repro.store import SymbolicStore

N = 10_240            # warm corpus (acceptance regime: >= 10k rows)
CHUNK = 512
N_Q = 4
T, L = 960, 10


def _timed(fn, iters: int = 3) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    rows = []
    X = season_dataset(N + N_Q + 4 * CHUNK, T, L, strength=0.7,
                       per_series_strength=True, seed=17)
    Q, D = X[:N_Q], X[N_Q:N_Q + N]
    pool = X[N_Q + N:]
    ss = SSAX(T=T, W=48, L=L, A_seas=16, A_res=32, r2_season=0.7)

    store = SymbolicStore.from_rows(ss, D, media="ssd")
    engine = MatchEngine(ss, store, batch_size=256)

    # -- append throughput: incremental vs full re-encode ----------------
    chunks = iter(np.split(pool, len(pool) // CHUNK))
    t_inc = _timed(lambda: store.append(next(chunks)), iters=3)
    n_now = store.n

    def full_reencode():
        # the pre-store behaviour: corpus changed => encode everything
        ss.encode(jnp.asarray(store.data))[0].block_until_ready()

    t_full = _timed(full_reencode, iters=3)
    speedup = t_full / max(t_inc, 1e-9)
    rows.append((
        "ingest/append_incremental",
        f"chunk={CHUNK} corpus={n_now} rows_s={CHUNK / max(t_inc, 1e-9):.0f} "
        f"s={t_inc:.4f}"))
    rows.append((
        "ingest/append_full_reencode",
        f"corpus={n_now} rows_s={n_now / max(t_full, 1e-9):.0f} "
        f"s={t_full:.4f}"))
    rows.append((
        "ingest/append_speedup",
        f"incremental_vs_full={speedup:.1f}x (target >= 10x at >= 10k)"))

    # -- query latency under ingest --------------------------------------
    t_static = _timed(lambda: engine.topk(Q, k=8), iters=3)

    def query_under_ingest():
        store.append(next(chunks))
        engine.topk(Q, k=8)

    t_under = _timed(query_under_ingest, iters=1)
    rows.append((
        "ingest/query_static",
        f"k=8 corpus={store.n} q_latency_s={t_static:.4f}"))
    rows.append((
        "ingest/query_under_ingest",
        f"k=8 corpus={store.n} append+query_s={t_under:.4f}"))

    for name, derived in rows:
        emit_row(name, derived)
    return rows


if __name__ == "__main__":
    run()
