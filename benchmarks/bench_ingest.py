"""Streaming-ingest benchmark for the ``repro.store.SymbolicStore``.

Two measurements over a >= 10k-row Season corpus:

* **Append throughput** (rows/s): ingesting one chunk into a warm corpus
  via ``SymbolicStore.append`` (encodes only the chunk) vs the
  full-re-encode baseline — what the pre-store ``MatchEngine`` did at
  construction: re-encode the entire corpus whenever the dataset changed.
  The acceptance target is incremental >= 10x faster at corpus >= 10k.
* **Query latency under ingest**: exact top-k latency through a
  ``SymbolicStore``-backed engine immediately after each append (the
  ingest-while-serving path) vs on the static corpus.

**Scale mode** (``--scale`` / ``--dryrun-scale``) runs the sharded
service on a multi-device mesh and GATES the million-row contracts
(RuntimeError on violation, so CI exits non-zero):

* per-append device upload is byte-identical at every corpus size —
  O(chunk) round-robin mirror appends, never an O(corpus) re-layout;
* the exact top-k orders candidates on device: zero bound-matrix bytes
  pulled to the host (``host_order_bytes == 0``) and zero raw rows
  moved (``store_accesses == 0``, device-resident verification);
* results stay bitwise-identical to the single-host engine and to the
  f64 numpy oracle at the final corpus.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_row
from repro.core import SSAX, MatchEngine
from repro.data.synthetic import season_dataset
from repro.store import SymbolicStore

N = 10_240            # warm corpus (acceptance regime: >= 10k rows)
CHUNK = 512
N_Q = 4
T, L = 960, 10


def _timed(fn, iters: int = 3) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    rows = []
    X = season_dataset(N + N_Q + 4 * CHUNK, T, L, strength=0.7,
                       per_series_strength=True, seed=17)
    Q, D = X[:N_Q], X[N_Q:N_Q + N]
    pool = X[N_Q + N:]
    ss = SSAX(T=T, W=48, L=L, A_seas=16, A_res=32, r2_season=0.7)

    store = SymbolicStore.from_rows(ss, D, media="ssd")
    engine = MatchEngine(ss, store, batch_size=256)

    # -- append throughput: incremental vs full re-encode ----------------
    chunks = iter(np.split(pool, len(pool) // CHUNK))
    t_inc = _timed(lambda: store.append(next(chunks)), iters=3)
    n_now = store.n

    def full_reencode():
        # the pre-store behaviour: corpus changed => encode everything
        ss.encode(jnp.asarray(store.data))[0].block_until_ready()

    t_full = _timed(full_reencode, iters=3)
    speedup = t_full / max(t_inc, 1e-9)
    rows.append((
        "ingest/append_incremental",
        f"chunk={CHUNK} corpus={n_now} rows_s={CHUNK / max(t_inc, 1e-9):.0f} "
        f"s={t_inc:.4f}"))
    rows.append((
        "ingest/append_full_reencode",
        f"corpus={n_now} rows_s={n_now / max(t_full, 1e-9):.0f} "
        f"s={t_full:.4f}"))
    rows.append((
        "ingest/append_speedup",
        f"incremental_vs_full={speedup:.1f}x (target >= 10x at >= 10k)"))

    # -- query latency under ingest --------------------------------------
    t_static = _timed(lambda: engine.topk(Q, k=8), iters=3)

    def query_under_ingest():
        store.append(next(chunks))
        engine.topk(Q, k=8)

    t_under = _timed(query_under_ingest, iters=1)
    rows.append((
        "ingest/query_static",
        f"k=8 corpus={store.n} q_latency_s={t_static:.4f}"))
    rows.append((
        "ingest/query_under_ingest",
        f"k=8 corpus={store.n} append+query_s={t_under:.4f}"))

    for name, derived in rows:
        emit_row(name, derived)
    return rows


SCALE_FULL = dict(n0=10_240, chunk=512, growth=3, T=960, W=48,
                  queries=4, k=8, batch=256)
SCALE_DRY = dict(n0=192, chunk=48, growth=3, T=240, W=12,
                 queries=2, k=4, batch=64)


def _oracle_topk(Q, data, k: int) -> np.ndarray:
    """f64 brute-force top-k indices, (distance, id) tie-break, chunked
    so the (Q, N, T) broadcast never materializes."""
    q = np.asarray(Q, np.float64)
    d = np.asarray(data, np.float64)
    parts = []
    for r0 in range(0, d.shape[0], 4096):
        blk = d[r0:r0 + 4096]
        parts.append(np.sqrt(((q[:, None] - blk[None]) ** 2).sum(-1)))
    ed = np.concatenate(parts, axis=1)
    ids = np.broadcast_to(np.arange(ed.shape[1]), ed.shape)
    return np.lexsort((ids, ed), axis=1)[:, :k]


def run_scale(dryrun: bool = False):
    """Scale-mode gates: flat O(chunk) per-append upload, zero host
    hops on the candidate path, bitwise identity to host + oracle."""
    import jax

    from repro.core import MatchEngine
    from repro.core.distributed import make_engine_service
    from repro.launch.mesh import make_mesh_compat

    cfg = SCALE_DRY if dryrun else SCALE_FULL
    n0, chunk, growth = cfg["n0"], cfg["chunk"], cfg["growth"]
    t_len, k = cfg["T"], cfg["k"]
    n_dev = len(jax.devices())
    assert chunk % n_dev == 0 and n0 % n_dev == 0, \
        f"scale config must be divisible by the {n_dev}-device fleet"
    total = n0 * growth + chunk + cfg["queries"]
    X = season_dataset(total, t_len, L, strength=0.7,
                       per_series_strength=True, seed=23)
    Q, pool = X[:cfg["queries"]], X[cfg["queries"]:]
    ss = SSAX(T=t_len, W=cfg["W"], L=L, A_seas=16, A_res=32,
              r2_season=0.7)

    mesh = make_mesh_compat((n_dev,), ("data",))
    dev = make_engine_service(ss, jnp.asarray(pool[:n0]), mesh,
                              verify="device", batch_size=cfg["batch"])
    dev.topk(Q, k=k)                     # warm mirrors + compile caches

    rows, failures = [], []

    # -- flat per-append cost: one chunk appended at each corpus size —
    # the mirror upload delta must be byte-identical every time
    deltas, times = [], []
    pos = n0
    for step in range(growth):
        if step:                         # bulk-grow to the next corpus
            grow = n0 - chunk            # size and SYNC outside the
            dev.ingest(pool[pos:pos + grow])      # measured window
            dev.topk(Q[:1], k=1)
            pos += grow
        assert dev.store.n == n0 * (step + 1)
        before = dev.sweep.h2d_bytes
        t0 = time.perf_counter()
        dev.ingest(pool[pos:pos + chunk])
        dev.topk(Q[:1], k=1)             # sync mirrors + serve new rows
        times.append(time.perf_counter() - t0)
        deltas.append(dev.sweep.h2d_bytes - before)
        pos += chunk
    flat = int(len(set(deltas)) == 1)
    if not flat:
        failures.append("append_not_O(chunk)")
    for s, (d, t) in enumerate(zip(deltas, times)):
        rows.append((
            f"ingest_scale/append@{n0 * (s + 1)}",
            f"chunk={chunk} h2d_delta_bytes={d} append+query_s={t:.4f}"))
    rows.append((
        "ingest_scale/append_flat",
        f"per-append upload identical across corpus sizes: "
        f"{'yes' if flat else 'NO ' + str(deltas)}"))

    # -- zero host hops + bitwise identity at the final corpus ----------
    r_d = dev.topk(Q, k=k)
    host = MatchEngine(ss, dev.store, verify="host",
                       batch_size=cfg["batch"])
    r_h = host.topk(Q, k=k)
    oracle = _oracle_topk(Q, dev.store.data, k)
    agree_host = int(np.array_equal(r_d.indices, r_h.indices)
                     and np.array_equal(r_d.distances, r_h.distances))
    agree_oracle = int(np.array_equal(r_d.indices, oracle))
    order_b = dev.sweep.host_order_bytes
    moved = r_d.store_accesses
    if not agree_host:
        failures.append("dev_vs_host")
    if not agree_oracle:
        failures.append("dev_vs_oracle")
    if order_b != 0:
        failures.append("host_order_bytes")
    if moved != 0:
        failures.append("rows_moved_to_host")
    rows.append((
        "ingest_scale/exact_topk",
        f"corpus={dev.store.n} k={k} bitwise_host={agree_host} "
        f"bitwise_oracle={agree_oracle} order_bytes={order_b} "
        f"moved_dev={moved} h2d_bytes={dev.sweep.h2d_bytes}"))
    verdict = "PASS" if not failures else "FAIL " + ",".join(failures)
    rows.append((
        "ingest_scale/acceptance",
        f"devices={n_dev} (target: O(chunk) appends, zero host hops, "
        f"bitwise to host+oracle) {verdict}"))
    for name, derived in rows:
        emit_row(name, derived)
    if failures:
        raise RuntimeError("scale-mode ingest broke its contract: "
                           + ", ".join(failures))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", action="store_true",
                    help="sharded scale-mode gates (O(chunk) appends, "
                         "zero host hops, bitwise identity)")
    ap.add_argument("--dryrun-scale", action="store_true",
                    help="tiny scale mode for CI (forced device fleet)")
    args = ap.parse_args()
    if args.scale or args.dryrun_scale:
        run_scale(dryrun=args.dryrun_scale)
    else:
        run()
