"""Roofline analysis over the dry-run artifacts.

Methodology (EXPERIMENTS.md §Roofline):

* ``compiled.cost_analysis()`` on this backend reports PER-DEVICE numbers
  and counts each ``lax.scan``/while body ONCE (verified empirically in
  the bring-up probe) — a 30-layer scanned model under-reports ~30-100x.
  We therefore pair every cell with an ANALYTICAL per-device FLOP/byte
  model (this file), use the analytical numbers for the roofline terms,
  and report the raw HLO numbers alongside for transparency.  The
  analytic model was spot-validated against cost_analysis on unscanned
  single-layer lowers (see tests in spot_check()).
* collective bytes are parsed from the post-SPMD HLO (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute), volume
  = max(result, operand) per op; the collective term conservatively
  assumes ONE 50 GB/s ICI link per chip (v5e has more; axis-parallel
  transfers overlap in practice).

Terms per (arch x shape x mesh), TPU v5e-class constants:
    compute_s    = flops_per_dev / 197e12
    memory_s     = bytes_per_dev / 819e9
    collective_s = coll_bytes_per_dev / 50e9
    ideal_s      = max(MODEL_FLOPS/(chips*197e12), floor_bytes/(chips*819e9))
    fraction     = ideal_s / max(compute_s, memory_s, collective_s)

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (serve);
floor_bytes = the irreducible HBM traffic (weight stream + cache stream +
one optimizer pass) — the physics floor a perfect implementation hits.
"""

from __future__ import annotations

import json
import os

from repro.configs import ARCHITECTURES, SHAPES, get_config, shape_for
from repro.configs.base import ATTN, MAMBA, RWKV, ModelConfig, ShapeSpec

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


def analytic_costs(cfg: ModelConfig, shape: ShapeSpec, *, microbatch: int,
                   q_chunk: int = 512, causal_skip: bool = False,
                   remat_policy: str = "full",
                   serve_dtype_bytes: int = 4) -> dict:
    """Global (all-chip) analytical FLOPs and HBM bytes for one step."""
    B, S = shape.global_batch, shape.seq_len
    mode = shape.mode
    tokens = B * (S if mode != "decode" else 1)
    V, d = cfg.padded_vocab, cfg.d_model
    tot, act = cfg.param_counts()

    # matmul-participating active params (embedding gather is not a matmul;
    # the LM head matmul is, tied or not)
    p_mm = act - V * d + V * d          # tied: head reuses the table
    if not cfg.tie_embeddings:
        p_mm = act - V * d              # gather excluded, head already in act

    # mixer-core extra flops (not parameter matmuls)
    core = 0.0
    cache_bytes = 0.0
    dt_c = 2                            # bf16 compute/cache bytes
    for spec in cfg.layer_specs():
        if spec.kind == ATTN:
            kv_len = S if spec.window is None else min(S, spec.window)
            if mode == "decode":
                core += 4 * B * 1 * kv_len * cfg.n_heads * cfg.head_dim
                cache_bytes += 2 * B * kv_len * cfg.kv_dim * dt_c
            else:
                # flash over S x kv_len blocks; static causal skipping
                # halves the visible area (window layers already bounded)
                eff = kv_len
                if causal_skip and spec.window is None:
                    eff = (S + q_chunk) / 2
                elif causal_skip:
                    eff = min(kv_len + q_chunk, S)
                core += 4 * B * S * eff * cfg.n_heads * cfg.head_dim
            if spec.cross_attn and mode != "decode":
                core += 4 * B * S * cfg.encoder_seq * \
                    cfg.n_heads * cfg.head_dim
        elif spec.kind == MAMBA:
            n_tok = tokens
            core += 12 * n_tok * cfg.d_inner * cfg.mamba_d_state
            if mode == "decode":
                cache_bytes += B * cfg.d_inner * cfg.mamba_d_state * 4
        else:                           # rwkv
            n_tok = tokens
            core += 6 * n_tok * cfg.n_rwkv_heads * cfg.rwkv_head_dim ** 2
            if mode == "decode":
                cache_bytes += B * cfg.n_rwkv_heads * \
                    cfg.rwkv_head_dim ** 2 * 4

    fwd = 2 * tokens * p_mm + core
    if mode == "train":
        # bwd = 2x fwd; remat recompute factor depends on policy
        remat_f = {"full": 4.0, "dots": 3.2}.get(remat_policy, 4.0)
        flops = remat_f * fwd + 12 * tot
        act_bytes = 24 * tokens * d * cfg.n_layers       # fwd+bwd+remat
        param_bytes = 28 * tot        # p r/w (f32) + grads + adam m,v r/w
        bytes_ = act_bytes + param_bytes
    elif mode == "prefill":
        flops = fwd
        bytes_ = serve_dtype_bytes * act + 8 * tokens * d * cfg.n_layers
    else:
        flops = fwd
        bytes_ = serve_dtype_bytes * act + cache_bytes   # weights + cache
    model_flops = (6 if mode == "train" else 2) * act * tokens

    # irreducible floor (bf16 weight stream is always achievable)
    if mode == "train":
        floor_bytes = 16 * tot                 # one params+grads+adam pass
    else:
        floor_bytes = 2 * act + cache_bytes
    return {"flops_global": flops, "bytes_global": bytes_,
            "model_flops": model_flops, "floor_bytes": floor_bytes,
            "tokens": tokens}


def roofline_record(rec: dict) -> dict:
    """Augment one dry-run JSON record with roofline terms."""
    if rec.get("status") != "ok":
        return dict(rec)
    cfg = get_config(rec["arch"])
    shape = shape_for(cfg, rec["shape"])
    chips = rec["n_chips"]
    rc = rec.get("rc", {})
    dt_b = 2 if rec.get("serve_dtype") == "bfloat16" else 4
    ana = analytic_costs(cfg, shape, microbatch=rec.get("microbatch", 0),
                         causal_skip=rc.get("causal_skip", False),
                         remat_policy=rc.get("remat_policy", "full"),
                         serve_dtype_bytes=dt_b)

    compute_s = ana["flops_global"] / chips / PEAK_FLOPS
    memory_s = ana["bytes_global"] / chips / HBM_BW
    colls = rec["collectives"]
    coll_bytes = sum(colls[k] for k in
                     ("all-reduce", "all-gather", "reduce-scatter",
                      "all-to-all", "collective-permute"))
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ideal_s = max(ana["model_flops"] / chips / PEAK_FLOPS,
                  ana["floor_bytes"] / chips / HBM_BW)
    achieved = max(terms.values())
    out = dict(rec)
    out.update({
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "coll_bytes_per_dev": coll_bytes,
        "model_flops": ana["model_flops"],
        "analytic_flops_global": ana["flops_global"],
        "useful_ratio": ana["model_flops"] / max(ana["flops_global"], 1.0),
        "ideal_s": ideal_s,
        "roofline_fraction": ideal_s / max(achieved, 1e-30),
    })
    return out


_LEVERS = {
    "collective": "cut collective bytes: reshard to reduce all-gathers "
                  "(FSDP prefetch granularity, TP axis choice) or overlap",
    "compute": "raise useful-flops share: causal block skipping in flash, "
               "drop remat on cheap layers, fuse small ops",
    "memory": "cut HBM traffic: bf16 optimizer/master, larger microbatch, "
              "wider fusion of elementwise chains",
}


def build_table(dryrun_json: str, *, multi_pod=False) -> list:
    recs = json.load(open(dryrun_json))
    rows = []
    for rec in recs:
        if rec.get("multi_pod") != multi_pod:
            continue
        rr = roofline_record(rec)
        if rr.get("status") == "ok":
            rr["lever"] = _LEVERS[rr["dominant"]]
        rows.append(rr)
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_FLOPS/HLO_est | roofline_frac | bytes/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — |")
            continue
        mem = r.get("memory", {}).get("argument_bytes", 0) + \
            r.get("memory", {}).get("temp_bytes", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {mem/1e9:.2f}G |")
    return hdr + "\n".join(lines) + "\n"


def run():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")
    if not os.path.exists(path):
        print("roofline/skipped,,no results/dryrun.json (run "
              "repro.launch.dryrun first)")
        return []
    rows = build_table(path, multi_pod=False)
    out_md = os.path.join(os.path.dirname(path), "roofline.md")
    with open(out_md, "w") as f:
        f.write("# Roofline — single-pod (16x16) baseline\n\n")
        f.write(markdown_table(rows))
        f.write("\n# Multi-pod (2x16x16) cross-check\n\n")
        f.write(markdown_table(build_table(path, multi_pod=True)))
    for r in rows:
        if r.get("status") != "ok":
            print(f"roofline/{r['arch']}/{r['shape']},,{r['status']}")
            continue
        print(f"roofline/{r['arch']}/{r['shape']},,"
              f"dom={r['dominant']} comp={r['compute_s']:.2e} "
              f"mem={r['memory_s']:.2e} coll={r['collective_s']:.2e} "
              f"frac={r['roofline_fraction']:.2f}")
    return rows


if __name__ == "__main__":
    run()
