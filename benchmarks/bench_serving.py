"""Serving benchmark — the always-on matching service under load.

Drives :class:`repro.service.MatchSession` (coalescing queue +
telemetry-driven planner over the device-resident sharded engine) and
reports what serving a paper-exact matcher actually costs:

* **bit-identity gate** — planner-routed exact-tier answers must equal
  direct ``engine.topk`` for both the index and linear tiers
  (RuntimeError otherwise; this is a CI gate, not a statistic).
* **coalescing** — closed-loop burst at concurrency >= 32: serial
  dispatch (``max_batch=1``) vs coalesced (``max_batch=64``); the
  coalesced configuration must beat serial QPS.
* **open-loop Poisson** — seeded-arrival load; p50/p99 request
  latency and achieved QPS (the numbers ``perf_report`` tabulates from
  the ``serve.request_latency_s`` histogram embedded in
  ``BENCH_serving.json``).
* **overload shedding** — tiny queue + tight deadlines; the
  per-reason ``serve.shed.*`` counters must sum exactly to
  ``serve.rejected`` (never-silent-drop accounting gate).
* **deadline downgrade** — calibrated planner under a mid budget:
  tier mix, approx-tier recall vs the exact oracle, and the error-bar
  certificate.
* **window sweep** — QPS / requests-per-dispatch vs coalescing
  window.
* **ingest-while-serving** — 2 engine replicas over the shared store,
  a writer thread appending rows throughout a closed-loop burst:
  every answer must carry its admission-pinned corpus epoch and match
  an epoch-pinned oracle (gate); the achieved QPS is compared to a
  frozen-corpus burst with the same replicas (ratio >= 0.9 gated in
  full runs; reported-only under ``--dryrun``, where timing is noise).
* **replica failover** — kill one replica with requests in flight;
  every request must be REQUEUED onto the survivor and served — the
  leg gates zero sheds (``python -m benchmarks.bench_serving
  --kill-replica`` runs just this leg).

Under ``verify="device"`` (any mesh size, including the CI
forced-8-device leg) the run additionally gates
``match.host_order_bytes == 0`` — serving must not regress the
device-residency invariant.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import emit_row

CONCURRENCY = 32


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) \
        else float("nan")


def _burst(session, queries, k, *, n_clients=CONCURRENCY):
    """Closed-loop: n_clients threads each submit their share and wait."""
    reqs = [None] * len(queries)

    def client(c):
        for i in range(c, len(queries), n_clients):
            r = session.submit(queries[i], k=k)
            r.wait(120)
            reqs[i] = r

    t0 = time.perf_counter()
    ts = [threading.Thread(target=client, args=(c,))
          for c in range(n_clients)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    wall = time.perf_counter() - t0
    ok = [r for r in reqs if r is not None and r.ok]
    return ok, wall


def _recall(approx_ids, exact_ids) -> float:
    """Mean per-query top-k overlap with the exact oracle frontier."""
    vals = [np.intersect1d(a[a >= 0], e[e >= 0]).size
            / max((e >= 0).sum(), 1)
            for a, e in zip(approx_ids, exact_ids)]
    return float(np.mean(vals)) if vals else 0.0


def run(dryrun: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.core import make_technique
    from repro.core.distributed import make_engine_service
    from repro.data.synthetic import season_dataset
    from repro.launch.mesh import make_mesh_compat
    from repro.obs import REGISTRY
    from repro.service import MatchSession

    n, T, k = (256, 480, 4) if dryrun else (4096, 960, 8)
    n_open = 16 if dryrun else 96
    rate_qps = 50.0 if dryrun else 200.0
    rows = []

    n_dev = len(jax.devices())
    n = max((n // n_dev) * n_dev, n_dev)
    X = season_dataset(n + 2 * CONCURRENCY, T, 10, 0.7,
                       per_series_strength=True, seed=21)
    Q, D = X[:2 * CONCURRENCY], X[2 * CONCURRENCY:]
    tech = make_technique("ssax", T=T, W=48, L=10, r2_season=0.7)
    mesh = make_mesh_compat((n_dev,), ("data",))
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=64, verify="device",
                                 media="ssd", metrics=REGISTRY)
    engine.store.build_index(leaf_fill=16 if dryrun else 64)
    jax.block_until_ready(engine.rep)
    # warm the kernels over the session's power-of-two batch buckets so
    # serial-vs-coalesced compares steady state, not compile time
    q_n = 1
    while q_n <= CONCURRENCY:
        engine.topk(Q[:q_n], k=k, source="index")
        q_n *= 2
    engine.topk(Q[:1], k=k)
    engine.topk_approx(Q[:1], k=k)

    # -- gate 1: exact-tier bit-identity ---------------------------------
    for tier, src in (("index", "index"), ("linear", None)):
        with MatchSession(engine, metrics=REGISTRY, window_s=0.002,
                          max_batch=CONCURRENCY) as s:
            reqs = s.serve(Q[:CONCURRENCY], k=k, tier=tier)
        oracle = engine.topk(Q[:CONCURRENCY], k=k, source=src)
        for i, r in enumerate(reqs):
            if not r.ok:
                raise RuntimeError(f"serving/{tier}: request {i} shed: "
                                   f"{r.error}")
            if not (np.array_equal(r.indices, oracle.indices[i])
                    and np.array_equal(r.distances, oracle.distances[i])):
                raise RuntimeError(
                    f"serving/{tier}: request {i} diverged from the "
                    "direct engine oracle (exactness gate)")
        rows.append((f"serving/exact_{tier}",
                     f"bit_identical=yes n={len(reqs)} k={k}"))

    # -- phase 2: serial vs coalesced at fixed concurrency ---------------
    qps = {}
    for label, mb, win in (("serial", 1, 0.0),
                           ("coalesced", CONCURRENCY, 0.002)):
        with MatchSession(engine, metrics=REGISTRY, window_s=win,
                          max_batch=mb, max_queue=4 * CONCURRENCY) as s:
            ok, wall = _burst(s, Q[:CONCURRENCY], k)
        if len(ok) != CONCURRENCY:
            raise RuntimeError(f"serving/{label}: {CONCURRENCY - len(ok)} "
                               "requests shed in a closed-loop burst")
        qps[label] = len(ok) / max(wall, 1e-9)
        snap = REGISTRY.snapshot()["counters"]
        rows.append((f"serving/{label}",
                     f"conc={CONCURRENCY} qps={qps[label]:.0f} "
                     f"p50={_pct([r.latency_s for r in ok], 50) * 1e3:.1f}"
                     f"ms p99="
                     f"{_pct([r.latency_s for r in ok], 99) * 1e3:.1f}ms"))
    speedup = qps["coalesced"] / max(qps["serial"], 1e-9)
    rows.append(("serving/coalescing_speedup", f"{speedup:.2f}x"))
    if qps["coalesced"] <= qps["serial"]:
        raise RuntimeError(
            f"coalescing did not improve QPS over serial dispatch at "
            f"concurrency {CONCURRENCY}: {qps['coalesced']:.0f} vs "
            f"{qps['serial']:.0f}")

    # -- phase 3: open-loop Poisson --------------------------------------
    rng = np.random.default_rng(33)
    gaps = rng.exponential(1.0 / rate_qps, size=n_open)
    with MatchSession(engine, metrics=REGISTRY, window_s=0.002,
                      max_batch=CONCURRENCY,
                      max_queue=8 * CONCURRENCY) as s:
        reqs = []
        t0 = time.perf_counter()
        for i in range(n_open):
            time.sleep(gaps[i])
            reqs.append(s.submit(Q[i % len(Q)], k=k))
        for r in reqs:
            r.wait(120)
        wall = time.perf_counter() - t0
    ok = [r for r in reqs if r.ok]
    lat = [r.latency_s for r in ok]
    shed_rate = 1.0 - len(ok) / max(len(reqs), 1)
    rows.append(("serving/poisson",
                 f"rate={rate_qps:.0f}qps served={len(ok)}/{n_open} "
                 f"qps={len(ok) / max(wall, 1e-9):.0f} "
                 f"p50={_pct(lat, 50) * 1e3:.1f}ms "
                 f"p99={_pct(lat, 99) * 1e3:.1f}ms "
                 f"shed={shed_rate:.2%}"))

    # -- phase 4: overload shedding + accounting gate --------------------
    with MatchSession(engine, metrics=REGISTRY, window_s=0.0,
                      max_batch=4, max_queue=4) as s:
        reqs = [s.submit(Q[i % len(Q)], k=k, deadline_s=1e-4)
                for i in range(2 * CONCURRENCY)]
        for r in reqs:
            r.wait(120)
    shed = [r for r in reqs if not r.ok]
    reasons = {}
    for r in shed:
        reasons[r.shed_reason] = reasons.get(r.shed_reason, 0) + 1
    c = REGISTRY.snapshot()["counters"]
    shed_total = sum(v for name, v in c.items()
                     if name.startswith("serve.shed."))
    rejected = c.get("serve.rejected", 0)
    if shed_total != rejected:
        raise RuntimeError(
            f"shed-reason accounting broken: sum(serve.shed.*)="
            f"{shed_total} != serve.rejected={rejected}")
    if not shed:
        raise RuntimeError("overload phase shed nothing — the admission "
                           "path was not exercised")
    rows.append(("serving/overload",
                 f"shed={len(shed)}/{len(reqs)} reasons={reasons} "
                 f"accounting=exact"))

    # -- phase 5: deadline downgrade + approx recall/error bar -----------
    with MatchSession(engine, metrics=REGISTRY, window_s=0.002,
                      max_batch=CONCURRENCY) as s:
        s.calibrate(Q[:1], k=k)
        budget = max(2e-3, 0.5 * s.planner.estimate("index"))
        reqs = s.serve(Q[:CONCURRENCY], k=k, deadline_s=budget)
    served = [r for r in reqs if r.ok]
    tiers = {}
    for r in served:
        tiers[r.tier_served] = tiers.get(r.tier_served, 0) + 1
    apx = [r for r in served if r.tier_served == "approx"]
    recall = float("nan")
    bars = [r.error_bar for r in apx if r.error_bar is not None]
    if apx:
        oracle = engine.topk(np.stack([r.query for r in apx]), k=k)
        recall = _recall([r.indices for r in apx], oracle.indices)
    rows.append(("serving/deadline",
                 f"budget={budget * 1e3:.1f}ms tiers={tiers} "
                 f"approx_recall={recall:.3f} "
                 f"error_bar_mean={np.mean(bars) if bars else 0.0:.4f} "
                 f"exact_certified="
                 f"{sum(1 for b in bars if b == 0)}/{len(bars)}"))
    REGISTRY.gauge("bench.approx_recall.serving").set(
        recall if recall == recall else 1.0)

    # -- phase 6: coalescing window sweep --------------------------------
    for win_ms in (0.0, 2.0, 8.0):
        with MatchSession(engine, metrics=REGISTRY,
                          window_s=win_ms * 1e-3,
                          max_batch=CONCURRENCY,
                          max_queue=4 * CONCURRENCY) as s:
            b0 = REGISTRY.snapshot()["counters"]
            ok, wall = _burst(s, Q, k)
        b1 = REGISTRY.snapshot()["counters"]
        disp = b1.get("serve.batches", 0) - b0.get("serve.batches", 0)
        per = len(ok) / max(disp, 1)
        rows.append((f"serving/window_{win_ms:g}ms",
                     f"qps={len(ok) / max(wall, 1e-9):.0f} "
                     f"req_per_dispatch={per:.1f} "
                     f"p50={_pct([r.latency_s for r in ok], 50) * 1e3:.1f}"
                     "ms"))

    # -- phase 7: ingest-while-serving over 2 replicas -------------------
    # (runs after the fixed-corpus phases: the writer below grows the
    # shared store, so ordering keeps the earlier numbers comparable)
    replica = make_engine_service(tech, None, mesh, store=engine.store,
                                  batch_size=64, verify="device",
                                  media="ssd")
    n_ing = max((max(n // 8, n_dev) // n_dev) * n_dev, n_dev)
    D_ing = season_dataset(n_ing, T, 10, 0.7,
                           per_series_strength=True, seed=55)
    qps_rep = {}
    for label, ingest in (("frozen", False), ("ingest", True)):
        with MatchSession(engine, replicas=[replica], metrics=REGISTRY,
                          window_s=0.002, max_batch=CONCURRENCY,
                          max_queue=8 * CONCURRENCY) as s:
            stop = threading.Event()
            wt = None
            if ingest:
                def writer():
                    chunk = max(n_dev, n_ing // 8)
                    for lo in range(0, n_ing, chunk):
                        if stop.is_set():
                            break
                        engine.ingest(D_ing[lo:lo + chunk])
                        time.sleep(0.001)
                wt = threading.Thread(target=writer)
                wt.start()
            ok, wall = _burst(s, Q, k)
            if wt is not None:
                stop.set()
                wt.join()
        if len(ok) != len(Q):
            raise RuntimeError(
                f"serving/{label}: {len(Q) - len(ok)} requests shed in "
                "a closed-loop replicated burst")
        qps_rep[label] = len(ok) / max(wall, 1e-9)
        if ingest:
            if any(r.epoch is None for r in ok):
                raise RuntimeError("ingest-while-serving: a served "
                                   "request carries no epoch pin")
            # epoch-pinned bit-identity spot check: answers must equal
            # the oracle at each request's ADMISSION frontier, not the
            # live (already-grown) corpus
            for r in ok[::max(len(ok) // 8, 1)]:
                if r.tier_served == "approx":
                    continue
                o = engine.topk(
                    r.query[None], k=r.k,
                    source="index" if r.tier_served == "index"
                    else None, epoch=r.epoch)
                if not (np.array_equal(r.indices, o.indices[0])
                        and np.array_equal(r.distances,
                                           o.distances[0])):
                    raise RuntimeError(
                        "ingest-while-serving: answer diverged from "
                        f"the epoch-pinned oracle at {r.epoch}")
    ratio = qps_rep["ingest"] / max(qps_rep["frozen"], 1e-9)
    rows.append(("serving/ingest_while_serving",
                 f"replicas=2 qps_frozen={qps_rep['frozen']:.0f} "
                 f"qps_ingest={qps_rep['ingest']:.0f} "
                 f"ratio={ratio:.2f} epoch_pinned=yes"))
    if not dryrun and ratio < 0.9:
        raise RuntimeError(
            f"ingest-while-serving QPS fell below 0.9x the frozen-"
            f"corpus baseline: ratio={ratio:.2f}")

    # -- phase 8: replica failover — requeue, never shed -----------------
    rows.append(_failover_leg(engine, replica, Q, k))

    # -- gate: serving must keep the device path device-resident ---------
    hob = REGISTRY.snapshot()["counters"].get("match.host_order_bytes", 0)
    if int(hob) != 0:
        raise RuntimeError(f"serving moved candidate order to the host: "
                           f"match.host_order_bytes={int(hob)}")
    rows.append(("serving/device_residency", "host_order_bytes=0"))

    for name, derived in rows:
        emit_row(name, derived)
    return rows


def _failover_leg(engine, replica, Q, k):
    """Kill replica 1 with requests in flight: every request must be
    requeued onto the survivor and served — zero sheds (gated)."""
    from repro.obs import REGISTRY
    from repro.service import MatchSession

    def _sheds(c):
        return sum(v for name, v in c.items()
                   if name.startswith("serve.shed."))

    c0 = REGISTRY.snapshot()["counters"]
    s = MatchSession(engine, replicas=[replica], metrics=REGISTRY,
                     window_s=0.0, max_batch=4,
                     max_queue=8 * CONCURRENCY)
    # submit BEFORE start: the whole burst is backlog when the
    # coalescer comes up, so batches are queued on both replicas'
    # inboxes when the kill lands — the requeue path actually runs
    reqs = [s.submit(Q[i % len(Q)], k=k)
            for i in range(2 * CONCURRENCY)]
    s.start()
    time.sleep(0.005)
    s.kill_replica(1)                # batches on it requeue, not shed
    for r in reqs:
        r.wait(240)
    s.close()
    not_ok = [r for r in reqs if not r.ok]
    if not_ok:
        raise RuntimeError(
            f"failover: {len(not_ok)} requests shed on replica kill "
            f"(first: {not_ok[0].error})")
    c1 = REGISTRY.snapshot()["counters"]
    if _sheds(c1) != _sheds(c0):
        raise RuntimeError("failover: replica kill shed requests "
                           "instead of requeueing them")
    requeued = c1.get("serve.requeued", 0) - c0.get("serve.requeued", 0)
    return ("serving/failover",
            f"killed=1 served={len(reqs)}/{len(reqs)} "
            f"requeued={requeued} shed=0")


def run_failover(dryrun: bool = True):
    """Standalone replica-failover leg (``--kill-replica``): minimal
    engine setup, then the same gated kill/requeue sequence ``run()``
    executes as phase 8."""
    import jax
    import jax.numpy as jnp

    from repro.core import make_technique
    from repro.core.distributed import make_engine_service
    from repro.data.synthetic import season_dataset
    from repro.launch.mesh import make_mesh_compat
    from repro.obs import REGISTRY
    from repro.service import MatchSession  # noqa: F401 — leg import

    n, T, k = (256, 480, 4) if dryrun else (4096, 960, 8)
    n_dev = len(jax.devices())
    n = max((n // n_dev) * n_dev, n_dev)
    X = season_dataset(n + CONCURRENCY, T, 10, 0.7,
                       per_series_strength=True, seed=21)
    Q, D = X[:CONCURRENCY], X[CONCURRENCY:]
    tech = make_technique("ssax", T=T, W=48, L=10, r2_season=0.7)
    mesh = make_mesh_compat((n_dev,), ("data",))
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=64, verify="device",
                                 media="ssd", metrics=REGISTRY)
    engine.store.build_index(leaf_fill=16 if dryrun else 64)
    replica = make_engine_service(tech, None, mesh, store=engine.store,
                                  batch_size=64, verify="device",
                                  media="ssd")
    name, derived = _failover_leg(engine, replica, Q, k)
    emit_row(name, derived)
    return [(name, derived)]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--kill-replica", action="store_true",
                    help="run only the replica-failover leg")
    ap.add_argument("--full", action="store_true",
                    help="full-size run (default: dryrun sizes)")
    a = ap.parse_args()
    if a.kill_replica:
        run_failover(dryrun=not a.full)
    else:
        run(dryrun=not a.full)
