"""End-to-end driver: the paper's system as a mesh service.

Shards a Season dataset over 8 (placeholder) devices, builds sSAX
representations in one shard_map pass, answers queries with local sweeps +
a global top-k merge, then verifies the survivors against the cold store —
the full production pipeline of DESIGN.md §2.1 at container scale.

    PYTHONPATH=src python examples/distributed_matching.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SSAX
from repro.core.distributed import encode_sharded, repr_topk_sharded
from repro.core.engine import verify_candidates
from repro.core.matching import RawStore, pairwise_euclidean
from repro.data.synthetic import season_dataset
from repro.launch.mesh import make_mesh_compat


def main():
    mesh = make_mesh_compat((8,), ("data",))
    print(f"mesh: {mesh.devices.size} devices on axis 'data'")

    N, T, L = 40_000, 960, 10
    X = season_dataset(N, T, L, strength=0.7, seed=3,
                       per_series_strength=True)
    queries = jnp.asarray(X[:4])
    data = jnp.asarray(X[4:N - (N - 4) % 8 + 4]) if (N - 4) % 8 else \
        jnp.asarray(X[4:])
    data = jnp.asarray(X[4:4 + ((N - 4) // 8) * 8])
    print(f"dataset: {data.shape[0]} x {T} "
          f"({data.nbytes / 1e6:.0f} MB raw, sharded)")

    ssax = SSAX(T=T, W=48, L=L, A_seas=16, A_res=32, r2_season=0.7)

    t0 = time.perf_counter()
    rep = encode_sharded(ssax, data, mesh)       # one pass, shard-parallel
    jax.block_until_ready(rep)
    print(f"encode: {time.perf_counter() - t0:.2f}s "
          f"({sum(x.nbytes for x in jax.tree.leaves(rep)) / 1e6:.1f} MB "
          f"of symbols vs {data.nbytes / 1e6:.0f} MB raw)")

    rep_q = ssax.encode(queries)
    t0 = time.perf_counter()
    dists, idx = repr_topk_sharded(ssax, rep_q, rep, mesh, k=32)
    jax.block_until_ready(dists)
    print(f"sweep + global top-32 merge: {time.perf_counter() - t0:.2f}s")

    # verify survivors against the cold store through the batched engine
    store = RawStore.ssd(np.asarray(data))
    res = verify_candidates(np.asarray(queries), np.asarray(idx), store)
    ed = np.asarray(pairwise_euclidean(queries, data))
    for qi in range(queries.shape[0]):
        best = int(res.indices[qi, 0])
        truth = int(np.argmin(ed[qi]))
        print(f"  query {qi}: best candidate #{best} "
              f"(true NN #{truth}, hit={best == truth}, "
              f"verified {int(res.raw_accesses[qi])}/{data.shape[0]} "
              f"series)")
    print(f"  one batched fetch: {res.store_fetches} seek(s), "
          f"{res.store_accesses} rows, modeled ssd I/O "
          f"{res.io_seconds * 1e3:.2f} ms")


if __name__ == "__main__":
    main()
