"""End-to-end driver: the paper's system as a mesh service.

Shards a Season dataset over 8 (placeholder) devices, builds sSAX
representations in one shard_map pass, answers queries with local sweeps +
a global top-k merge, then verifies the survivors against the cold store —
the full production pipeline of DESIGN.md §2.1 at container scale.
Finishes with the streaming path: ingest chunks into the
``repro.store.SymbolicStore`` behind the service while answering queries
between appends (only new rows are encoded), and snapshot/reopen the
store with results intact.

    PYTHONPATH=src python examples/distributed_matching.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SSAX
from repro.core.distributed import encode_sharded, repr_topk_sharded
from repro.core.engine import verify_candidates
from repro.core.matching import RawStore, pairwise_euclidean
from repro.data.synthetic import season_dataset
from repro.launch.mesh import make_mesh_compat


def main():
    mesh = make_mesh_compat((8,), ("data",))
    print(f"mesh: {mesh.devices.size} devices on axis 'data'")

    N, T, L = 40_000, 960, 10
    X = season_dataset(N, T, L, strength=0.7, seed=3,
                       per_series_strength=True)
    queries = jnp.asarray(X[:4])
    data = jnp.asarray(X[4:N - (N - 4) % 8 + 4]) if (N - 4) % 8 else \
        jnp.asarray(X[4:])
    data = jnp.asarray(X[4:4 + ((N - 4) // 8) * 8])
    print(f"dataset: {data.shape[0]} x {T} "
          f"({data.nbytes / 1e6:.0f} MB raw, sharded)")

    ssax = SSAX(T=T, W=48, L=L, A_seas=16, A_res=32, r2_season=0.7)

    t0 = time.perf_counter()
    rep = encode_sharded(ssax, data, mesh)       # one pass, shard-parallel
    jax.block_until_ready(rep)
    print(f"encode: {time.perf_counter() - t0:.2f}s "
          f"({sum(x.nbytes for x in jax.tree.leaves(rep)) / 1e6:.1f} MB "
          f"of symbols vs {data.nbytes / 1e6:.0f} MB raw)")

    rep_q = ssax.encode(queries)
    t0 = time.perf_counter()
    dists, idx = repr_topk_sharded(ssax, rep_q, rep, mesh, k=32)
    jax.block_until_ready(dists)
    print(f"sweep + global top-32 merge: {time.perf_counter() - t0:.2f}s")

    # verify survivors against the cold store through the batched engine
    store = RawStore.ssd(np.asarray(data))
    res = verify_candidates(np.asarray(queries), np.asarray(idx), store)
    ed = np.asarray(pairwise_euclidean(queries, data))
    for qi in range(queries.shape[0]):
        best = int(res.indices[qi, 0])
        truth = int(np.argmin(ed[qi]))
        print(f"  query {qi}: best candidate #{best} "
              f"(true NN #{truth}, hit={best == truth}, "
              f"verified {int(res.raw_accesses[qi])}/{data.shape[0]} "
              f"series)")
    print(f"  one batched fetch: {res.store_fetches} seek(s), "
          f"{res.store_accesses} rows, modeled ssd I/O "
          f"{res.io_seconds * 1e3:.2f} ms")

    # --- ingest while serving -------------------------------------------
    # the same pipeline as a SymbolicStore-backed service: appends encode
    # only the new chunk; the next query serves the new rows.  The store
    # is seeded with the representation computed sharded above — the
    # precomputed-rep append path, no re-encode
    import tempfile

    from repro.core.distributed import make_engine_service
    from repro.store import SymbolicStore

    sym = SymbolicStore(ssax, media="ssd")
    sym.append(np.asarray(data),
               rep=tuple(np.asarray(leaf) for leaf in rep))
    engine = make_engine_service(ssax, None, mesh, sym)
    chunks = season_dataset(3 * 1000, T, L, strength=0.7, seed=9,
                            per_series_strength=True).reshape(3, 1000, T)
    for c, chunk in enumerate(chunks):
        t0 = time.perf_counter()
        engine.ingest(chunk)
        t_ing = time.perf_counter() - t0
        t0 = time.perf_counter()
        r = engine.topk(np.asarray(queries), k=8, exact=False)
        t_q = time.perf_counter() - t0
        print(f"  ingest {c + 1}/3: +{chunk.shape[0]} rows "
              f"({chunk.shape[0] / max(t_ing, 1e-9):.0f} rows/s, only the "
              f"chunk encoded), corpus {sym.n}; query under ingest "
              f"{t_q * 1e3:.0f} ms")

    # appended rows are served immediately: ingest the queries themselves
    ids = engine.ingest(np.asarray(queries))
    r = engine.topk(np.asarray(queries), k=1)
    hits = int((r.indices[:, 0] == ids).sum())
    print(f"  ingested the {len(ids)} queries: exact 1-NN hits their new "
          f"rows {hits}/{len(ids)} at d_ED ~ "
          f"{float(r.distances.max()):.1e}")

    # snapshot -> reopen -> identical answers, no re-encode
    with tempfile.TemporaryDirectory() as snap_dir:
        sym.save(snap_dir)
        from repro.store import SymbolicStore
        reopened = SymbolicStore.open(snap_dir)
        from repro.core.engine import MatchEngine
        engine2 = MatchEngine(ssax, reopened)
        r2 = engine2.topk(np.asarray(queries), k=1)
        same = bool(np.array_equal(r2.indices, r.indices))
        print(f"  snapshot round-trip: {reopened.n} rows reopened, "
              f"answers identical={same}")


if __name__ == "__main__":
    main()
