"""Quickstart: encode a seasonal dataset with SAX and sSAX, run a pruned
exact match, and see the paper's effect first-hand.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import SAX, SSAX, exact_match, season_strength
from repro.core.matching import RawStore, pairwise_euclidean
from repro.data.synthetic import season_dataset


def main():
    # 1. a dataset with a strong (90%) season of length 10
    X = season_dataset(n=2000, T=960, L=10, strength=0.9, seed=0)
    query, data = X[0], X[1:]
    print(f"dataset: {data.shape[0]} series of T={data.shape[1]}, "
          f"mean season strength "
          f"{float(np.mean(np.asarray(season_strength(jnp.asarray(X), 10)))):.2f}")

    # 2. encode with SAX and with sSAX at the SAME representation budget
    sax = SAX(T=960, W=48, A=64)                      # 288 bits
    ssax = SSAX(T=960, W=48, L=10, A_seas=9, A_res=32,
                r2_season=0.9)                        # ~272 bits
    d_sax = np.asarray(sax.pairwise_distance(
        sax.encode(jnp.asarray(query[None])), sax.encode(jnp.asarray(data))))[0]
    d_ssax = np.asarray(ssax.pairwise_distance(
        ssax.encode(jnp.asarray(query[None])), ssax.encode(jnp.asarray(data))))[0]

    # 3. pruned exact matching from a simulated HDD cold store
    r_sax = exact_match(query, d_sax, RawStore.hdd(data))
    r_ssax = exact_match(query, d_ssax, RawStore.hdd(data))
    truth = int(np.argmin(np.asarray(pairwise_euclidean(
        jnp.asarray(query[None]), jnp.asarray(data)))[0]))

    print(f"true nearest neighbour: #{truth}")
    for name, r in [("SAX ", r_sax), ("sSAX", r_ssax)]:
        io = RawStore.hdd(data).modeled_io_seconds(r.raw_accesses)
        print(f"  {name}: match #{r.index} (correct={r.index == truth})  "
              f"raw reads {r.raw_accesses:5d} ({r.pruned_fraction:5.1%} pruned)"
              f"  modeled HDD time {io:7.2f}s")
    print("-> season-aware symbols prune harder, touch less cold storage, "
          "and return the same exact answer (the paper's Table 5 effect).")


if __name__ == "__main__":
    main()
