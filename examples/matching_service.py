"""Walkthrough: the always-on matching service (`repro.service`).

Builds a device-sharded sSAX engine with its split-tree index, wraps
it in a :class:`repro.service.MatchSession`, and demonstrates the
service contract step by step:

1. concurrent clients — single-query requests from many threads
   coalesce into one (Q, T) kernel dispatch per batching window;
2. exactness — a planner-routed exact answer is bit-identical to
   calling ``engine.topk`` directly;
3. deadline downgrade — a request whose budget the exact tiers cannot
   meet is served from the anytime tier with an error-bar certificate
   (zero bar == provably exact) instead of being shed;
4. graceful shedding — overload rejects with a reason, and the
   per-reason counters sum exactly to ``serve.rejected``;
5. EXPLAIN — pass ``--explain`` to render the per-dispatch query plan
   (spans, candidates, pruning, transfer counters, rounds).

    PYTHONPATH=src python examples/matching_service.py [--explain]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_technique
from repro.core.distributed import make_engine_service
from repro.data.synthetic import season_dataset
from repro.launch.mesh import make_mesh_compat
from repro.obs import REGISTRY, render_trace
from repro.service import MatchSession


def main():
    explain = "--explain" in sys.argv
    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    n, T, L, k = 4096, 480, 10, 8
    n = (n // n_dev) * n_dev

    X = season_dataset(n + 64, T, L, 0.7, per_series_strength=True,
                       seed=42)
    Q, D = X[:64], X[64:]
    tech = make_technique("ssax", T=T, W=48, L=L, r2_season=0.7)
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=64, verify="device",
                                 media="ssd", metrics=REGISTRY)
    engine.store.build_index(leaf_fill=32)
    print(f"engine: {n} x {T} rows sharded over {n_dev} devices, "
          f"split-tree index ready")

    # ---- 1. concurrent clients, coalesced dispatch ---------------------
    session = MatchSession(engine, metrics=REGISTRY, window_s=0.004,
                           max_batch=32).start()
    session.calibrate(Q[:1], k=k)   # prime the planner's estimates

    results = {}

    def client(cid):
        req = session.submit(Q[cid], k=k,
                             explain=explain and cid == 0)
        req.wait(60)
        results[cid] = req

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(32)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    served = [r for r in results.values() if r.ok]
    c = REGISTRY.snapshot()["counters"]
    print(f"1. served {len(served)}/32 concurrent requests in "
          f"{c['serve.batches']:.0f} coalesced dispatches "
          f"({c['serve.batched_requests'] / c['serve.batches']:.1f} "
          f"requests each)")

    # ---- 2. exactness: service answer == direct engine call ------------
    r0 = results[0]
    direct = engine.topk(
        Q[0][None], k=k,
        source="index" if r0.tier_served == "index" else None)
    same = (np.array_equal(r0.indices, direct.indices[0])
            and np.array_equal(r0.distances, direct.distances[0]))
    print(f"2. planner routed tier={r0.tier_served}; bit-identical to "
          f"direct topk: {same}")
    assert same

    # ---- 3. deadline downgrade with an error bar -----------------------
    # pretend the exact tiers are slow (as they would be at scale) so a
    # tight budget forces the anytime tier
    session.planner._est["index"].wall_s = 10.0
    session.planner._est["linear"].wall_s = 10.0
    reqs = session.serve(Q[32:40], k=k, deadline_s=5.0)
    down = [r for r in reqs if r.ok and r.plan is not None
            and r.plan.downgraded]
    bars = [r.error_bar for r in down if r.error_bar is not None]
    print(f"3. tight budget: {len(down)}/8 downgraded to approx; "
          f"error bars {['%.4f' % b for b in bars[:4]]}... "
          f"({sum(1 for b in bars if b == 0)}/{len(bars)} provably "
          f"exact)")

    # ---- 4. graceful shedding under overload ---------------------------
    small = MatchSession(engine, metrics=REGISTRY, window_s=0.0,
                         max_batch=2, max_queue=2)
    burst = [small.submit(Q[i % 64], k=k) for i in range(16)]
    small.start()
    small.close()
    shed = [r for r in burst if not r.ok]
    reasons = {}
    for r in shed:
        reasons[r.shed_reason] = reasons.get(r.shed_reason, 0) + 1
    c = REGISTRY.snapshot()["counters"]
    total_shed = sum(v for name, v in c.items()
                     if name.startswith("serve.shed."))
    print(f"4. overload: {len(shed)}/16 shed with reasons {reasons}; "
          f"sum(serve.shed.*)={total_shed:.0f} == "
          f"serve.rejected={c['serve.rejected']:.0f}")
    assert total_shed == c["serve.rejected"]

    # ---- 5. EXPLAIN ----------------------------------------------------
    if explain and results[0].trace is not None:
        print("5. EXPLAIN of the coalesced dispatch request 0 rode in:")
        print(render_trace(results[0].trace))
    else:
        print("5. (re-run with --explain for the per-dispatch plan)")

    session.close()
    print("planner estimates:", session.planner.snapshot())


if __name__ == "__main__":
    main()
