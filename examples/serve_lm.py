"""Serve a small model with batched requests through the continuous-
batching engine (prefill -> slot splice -> shared decode steps).

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.transformer import RunConfig
from repro.serving.engine import Request, ServeEngine


def main():
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-0.6b"), d_model=128, n_heads=4,
                head_dim=32, d_ff=384),
        compute_dtype="float32")
    rc = RunConfig(q_chunk=32, kv_chunk=32, loss_chunk=32)
    model = build_model(cfg, rc=rc)
    params = model.init(jax.random.PRNGKey(0))
    tot, _ = cfg.param_counts()
    print(f"serving {cfg.name}: {tot / 1e6:.1f}M params, 4 slots")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(8, 24))
                                        ).astype(np.int32),
                    max_new_tokens=16)
            for i in range(10)]

    eng = ServeEngine(model, params, n_slots=4, max_len=128)
    t0 = time.perf_counter()
    done = eng.run(list(reqs))
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    for r in done[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.out_tokens[:8]}...")
    print(f"{len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
