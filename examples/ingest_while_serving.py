"""Walkthrough: epoch-pinned answers under live ingest.

The store publishes an immutable :class:`repro.store.CorpusEpoch`
frontier as the LAST step of every mutation, and every dispatch pins
the epoch at admission — so an answer is bit-identical to the corpus
as it stood when the query was admitted, no matter how much ingest
happens while it is queued or running.  This script makes that
contract tangible with a planted motif:

1. build a seasonal corpus that does NOT contain a close match for a
   probe query, and freeze epoch ``e0``;
2. append a chunk that hides a near-duplicate of the probe (the
   planted motif), producing epoch ``e1``;
3. ask the engine the same question at both epochs — pinned at ``e0``
   the motif is invisible (the answer is the pre-append nearest
   neighbor), pinned at ``e1`` it is the top hit.  No index rebuild,
   no store copy: the as-of read is a prefix slice + a leaf-id
   filter;
4. serve the probe through a two-replica :class:`MatchSession` while
   a writer thread keeps appending — every request comes back tagged
   with its admission epoch and verifies bit-identical against a
   direct ``engine.topk`` oracle pinned to that same epoch.

    PYTHONPATH=src python examples/ingest_while_serving.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_technique
from repro.core.distributed import make_engine_service
from repro.data.synthetic import season_dataset
from repro.launch.mesh import make_mesh_compat
from repro.obs import REGISTRY
from repro.service import MatchSession


def main():
    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    n, T, L, k = 2048, 480, 10, 4
    n = (n // n_dev) * n_dev

    rng = np.random.default_rng(7)
    X = season_dataset(n + 1 + 4 * n_dev * 4, T, L, 0.7,
                       per_series_strength=True, seed=7)
    D, probe, tail = X[:n], X[n], X[n + 1:]
    tech = make_technique("ssax", T=T, W=48, L=L, r2_season=0.7)
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=64, verify="device",
                                 media="ssd", metrics=REGISTRY)
    engine.store.build_index(leaf_fill=32)
    print(f"engine: {n} x {T} rows sharded over {n_dev} devices, "
          f"split-tree index ready")

    # ---- 1. freeze the pre-append frontier -----------------------------
    e0 = engine.store.current_epoch()
    pre = engine.topk(probe[None], k=1, source="index")
    d_pre = float(pre.distances[0, 0])
    print(f"1. epoch e0 = {e0.n_rows} rows; probe's nearest neighbor "
          f"today: row {int(pre.indices[0, 0])} at distance {d_pre:.3f}")

    # ---- 2. append a chunk hiding the planted motif --------------------
    chunk = np.array(tail[:n_dev - 1], np.float32)
    motif = probe + rng.normal(0.0, 1e-3, probe.shape).astype(np.float32)
    chunk = np.concatenate([chunk, motif[None]], axis=0)   # n_dev rows
    motif_id = engine.store.n + len(chunk) - 1
    engine.ingest(chunk)
    e1 = engine.store.current_epoch()
    print(f"2. appended {len(chunk)} rows (motif hidden at row "
          f"{motif_id}); epoch e1 = {e1.n_rows} rows — index NOT "
          f"rebuilt, mirrors uploaded O(chunk)")

    # ---- 3. same question, two epochs ----------------------------------
    at_e0 = engine.topk(probe[None], k=1, source="index", epoch=e0)
    at_e1 = engine.topk(probe[None], k=1, source="index", epoch=e1)
    print(f"3. pinned at e0: row {int(at_e0.indices[0, 0])} at "
          f"{float(at_e0.distances[0, 0]):.3f} (motif invisible); "
          f"pinned at e1: row {int(at_e1.indices[0, 0])} at "
          f"{float(at_e1.distances[0, 0]):.4f} (the planted motif)")
    assert int(at_e0.indices[0, 0]) == int(pre.indices[0, 0])
    assert int(at_e0.indices[0, 0]) != motif_id
    assert int(at_e1.indices[0, 0]) == motif_id

    # ---- 4. serve through replicas while a writer keeps appending ------
    replica = make_engine_service(tech, None, mesh, store=engine.store,
                                  batch_size=64, verify="device",
                                  media="ssd")
    session = MatchSession(engine, replicas=[replica], metrics=REGISTRY,
                           window_s=0.002, max_batch=4).start()
    session.calibrate(probe[None], k=k)

    stop = threading.Event()

    def writer():
        # chunks of n_dev rows: the shape step 2 already compiled, so
        # the first append lands fast instead of behind a jit compile
        rest = tail[n_dev - 1:]
        rest = rest[:len(rest) // n_dev * n_dev]
        step = n_dev
        for lo in range(0, len(rest), step):
            if stop.is_set():
                return
            engine.ingest(np.array(rest[lo:lo + step], np.float32))
            time.sleep(0.002)

    wt = threading.Thread(target=writer)
    wt.start()
    queries = np.concatenate([probe[None]] * 2
                             + [np.array(tail[:14], np.float32)])
    reqs = []
    for q in queries:       # spread admissions so epochs advance between
        reqs.append(session.submit(q, k=k))
        time.sleep(0.02)
    for r in reqs:
        r.wait(120.0)
    stop.set()
    wt.join()

    served = [r for r in reqs if r.ok]
    epochs = sorted({r.epoch.n_rows for r in served})
    mism = 0
    for r in served:
        if r.tier_served == "approx":
            continue
        oracle = engine.topk(
            r.query[None], k=r.k,
            source="index" if r.tier_served == "index" else None,
            epoch=r.epoch)
        if not (np.array_equal(r.indices, oracle.indices[0])
                and np.array_equal(r.distances, oracle.distances[0])):
            mism += 1
    assert mism == 0
    assert all(r.epoch is not None for r in served)
    assert all(int(r.indices[0]) == motif_id for r in served[:2])
    by_rep = {}
    for r in served:
        by_rep[r.replica] = by_rep.get(r.replica, 0) + 1
    print(f"4. served {len(served)}/{len(reqs)} requests over 2 "
          f"replicas (placement {by_rep}) while ingest grew the store "
          f"to {engine.store.n} rows; answers pinned across "
          f"{len(epochs)} epochs ({epochs[0]}..{epochs[-1]} rows), "
          f"every exact answer bit-identical to a direct topk oracle "
          f"at its pinned epoch; the probe finds the motif post-e1")

    session.close()
    print("done: ingest never blocks serving, and serving never sees "
          "a torn corpus — answers are exact at their admission epoch")


if __name__ == "__main__":
    main()
