"""Subsequence matching walkthrough: plant a pattern inside long series,
then localize it exactly — anywhere, at any offset — through the
season-aware pruned windowed scan (repro.subseq).

    PYTHONPATH=src python examples/subsequence_matching.py

The flow mirrors the paper's whole-matching pipeline (quickstart.py)
lifted to sliding windows:

1. a corpus of long seasonal series; a noisy copy of one snippet is
   implanted at known (row, offset) positions;
2. a ``WindowView`` encodes every z-normalized window of length m
   incrementally (representation only — the window matrix never
   materializes);
3. a ``SubseqEngine`` answers exact top-k window queries through the
   same frontier machinery as whole matching, reading only the
   underlying rows the candidate order touches — and a split-tree
   window index (``view.build_index()``) generates those candidates
   sublinearly instead of sweeping every window, bit-identically;
4. non-overlap suppression returns the k distinct occurrences instead
   of k shifted copies of the best one;
5. appended series are searchable immediately (streaming ingest) and
   the window index follows along without a rebuild.
"""

import numpy as np

from repro.core import SSAX
from repro.data.synthetic import season_dataset
from repro.subseq import SubseqEngine, WindowView

N, T = 24, 2400          # corpus: 24 series of 2400 samples
M, STRIDE = 240, 1       # windows: length 240, every offset
L = 10


def main():
    rng = np.random.default_rng(11)
    X = season_dataset(N, T, L, strength=0.7,
                       per_series_strength=True, seed=11)

    # 1. implant a noisy copy of one snippet at three known positions
    template = X[7, 1000:1000 + M].copy()
    plants = [(7, 1000), (15, 416), (21, 1812)]      # (row, offset)
    for r, o in plants[1:]:
        X[r, o:o + M] = template + 0.1 * rng.normal(size=M)\
            .astype(np.float32)

    # 2. window view: every z-normalized window, encoded incrementally
    ssax = SSAX(T=M, W=M // L, L=L, A_seas=16, A_res=32, r2_season=0.7)
    view = WindowView(ssax, X, stride=STRIDE, media="hdd")
    print(f"corpus: {N} series x {T} samples -> {view.n} windows "
          f"(m={M}, stride={STRIDE}); only the symbolic rep is stored")

    # 3. exact top-1: localize the pattern from a fresh noisy observation.
    # The window index turns candidate generation sublinear: instead of
    # sorting a distance to every window, the tree walk hands the engine
    # a compact candidate set — same answer, bit for bit.
    engine = SubseqEngine(view, batch_size=256)
    query = template + 0.02 * rng.normal(size=M).astype(np.float32)
    view.reset()
    lin = engine.topk(query, k=1, use_index=False)
    view.build_index(leaf_fill=64)
    view.reset()
    res = engine.topk(query, k=1)
    assert np.array_equal(res.window_ids, lin.window_ids)
    r, s = res.rows[0, 0], res.starts[0, 0]
    print(f"top-1: row {r} @ {s} (planted at {plants[0]}), "
          f"d={res.distances[0, 0]:.3f}; indexed: examined "
          f"{res.raw_accesses[0]} of {view.n} windows "
          f"({res.pruned_fraction[0]:.1%} pruned; linear sweep examined "
          f"{lin.raw_accesses[0]}), read "
          f"{res.store_accesses}/{N} rows, modeled HDD "
          f"{res.io_seconds * 1e3:.1f}ms")

    # 4. top-3 occurrences need suppression: without it, the best
    # window's one-sample shifts crowd out the other plants
    naive = engine.topk(query, k=3)
    sup = engine.topk(query, k=3, exclusion=M // 2)
    fmt = lambda rr: ", ".join(
        f"(row {a} @ {b})" for a, b in zip(rr.rows[0], rr.starts[0]))
    print(f"top-3 without suppression: {fmt(naive)}")
    print(f"top-3 with  suppression:   {fmt(sup)}   "
          f"<- the three planted occurrences")

    # 5. streaming: a new series with a fourth occurrence
    extra = season_dataset(1, T, L, 0.7, seed=99)
    extra[0, 600:600 + M] = template + 0.1 * rng.normal(size=M)\
        .astype(np.float32)
    view.append(extra)
    assert view.index.n == view.n        # index followed the append
    res = engine.topk(query, k=4, exclusion=M // 2)
    print(f"after append: top-4 occurrences {fmt(res)}")
    print("-> the window set AND its index grew by one series; the new "
          "occurrence is found without re-encoding or rebuilding "
          "anything")


if __name__ == "__main__":
    main()
