"""Beyond-paper integration (DESIGN.md §Arch-applicability): the paper's
technique applied to the LM substrate.

Per-channel hidden-state traces of a transformer form time series over
sequence position; channels carry strong deterministic structure (drift
from residual accumulation ~ trend, positional/periodic features ~
season).  We z-normalize per-channel traces, encode them with tSAX, and
retrieve the channels of a *probe* prompt that behave most like a target
channel — exact matching with lower-bound pruning over the activation
bank, without scanning raw traces.

    PYTHONPATH=src python examples/activation_retrieval.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import TSAX, exact_match, trend_strength, znormalize
from repro.core.matching import RawStore, pairwise_euclidean
from repro.models import build_model
from repro.models.transformer import RunConfig


def main():
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-0.6b"), d_model=128, n_heads=4,
                head_dim=32, d_ff=384, n_layers=4),
        compute_dtype="float32")
    rc = RunConfig(q_chunk=32, kv_chunk=32, loss_chunk=32)
    model = build_model(cfg, rc=rc)
    params = model.init(jax.random.PRNGKey(0))

    # an activation bank: hidden traces of B prompts, per channel
    rng = np.random.default_rng(0)
    T = 64
    B = 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    h, _ = model.hidden_states(params, {"tokens": toks})   # (B, T, d)
    traces = np.asarray(h.transpose(0, 2, 1)).reshape(-1, T)   # (B*d, T)
    bank = np.asarray(znormalize(jnp.asarray(traces)))

    ts_strength = float(np.mean(np.asarray(
        trend_strength(jnp.asarray(bank)))))
    print(f"activation bank: {bank.shape[0]} channel traces of length {T}; "
          f"mean trend strength {ts_strength:.2f}")

    tsax = TSAX(T=T, W=16, A_tr=64, A_res=64, r2_trend=ts_strength)
    rep_bank = tsax.encode(jnp.asarray(bank[1:]))
    rep_q = tsax.encode(jnp.asarray(bank[:1]))
    dists = np.asarray(tsax.pairwise_distance(rep_q, rep_bank))[0]

    store = RawStore.hbm(bank[1:])
    res = exact_match(bank[0], dists, store)
    ed = np.asarray(pairwise_euclidean(
        jnp.asarray(bank[:1]), jnp.asarray(bank[1:])))[0]
    truth = int(np.argmin(ed))
    prompt, chan = divmod(res.index + 1, cfg.d_model)
    print(f"query: prompt 0 / channel 0 -> most similar trace: "
          f"prompt {prompt} / channel {chan}")
    print(f"exact={res.index == truth}, pruned {res.pruned_fraction:.1%} "
          f"of the bank without reading raw traces")


if __name__ == "__main__":
    main()
