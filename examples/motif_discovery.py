"""Motif + discord discovery walkthrough: plant a repeated pattern and
a one-off anomaly in a seasonal corpus, then recover both exactly from
the matrix profile — the corpus self-join (repro.profile).

    PYTHONPATH=src python examples/motif_discovery.py

The flow:

1. a corpus of long seasonal series; a near-identical snippet is
   implanted in TWO different series (the motif — the corpus's most
   similar non-trivial window pair) and a one-off burst in a third
   (the discord — the window farthest from everything else);
2. ``SelfJoinEngine`` computes the exact matrix profile: every
   window's nearest neighbor OUTSIDE its trivial-match zone (same
   series, starts closer than the exclusion — a window trivially
   matches its own one-sample shifts), through the same symbolic
   pruning + bitwise verification machinery as subsequence search;
3. ``topk_motifs`` / ``topk_discords`` read the answers straight off
   the profile, greedily non-overlapping — and both are bit-identical
   to the brute-force all-pairs oracle (``scan_profile``), which this
   walkthrough checks;
4. for profile-scale window lengths, the MASS-style FFT sliding dot
   product (``kernels.fft_dot``) computes all-window distances in
   O(T log T) per row under a documented tolerance contract — the
   sweep half of the self-join at m >= 1k (exact verification stays
   on the bitwise kernel path; ``benchmarks/bench_selfjoin.py``
   records the FFT-vs-accumulation crossover).
"""

import numpy as np

from repro.core import SSAX
from repro.data.synthetic import season_dataset
from repro.profile import SelfJoinEngine
from repro.subseq import WindowView

N, T = 12, 1200          # corpus: 12 series of 1200 samples
M, STRIDE = 120, 4       # windows: length 120, every 4th offset
L = 10


def main():
    rng = np.random.default_rng(23)
    X = np.asarray(season_dataset(N, T, L, strength=0.6,
                                  per_series_strength=True, seed=23),
                   np.float64).copy()

    # 1. plant: the motif pair in rows 2 and 9, the discord in row 5
    o_a, o_b = 480, 700
    snippet = 2.0 * np.sin(np.linspace(0, 6 * np.pi, M))
    X[2, o_a:o_a + M] = snippet + 0.01 * rng.normal(size=M)
    X[9, o_b:o_b + M] = snippet + 0.01 * rng.normal(size=M)
    o_d = 300
    X[5, o_d:o_d + M] += 6.0 * np.hanning(M)
    X = X.astype(np.float32)

    # 2. the exact matrix profile over every window
    ssax = SSAX(T=M, W=M // L, L=L, A_seas=16, A_res=32, r2_season=0.7)
    view = WindowView(ssax, X, stride=STRIDE, media="hdd")
    eng = SelfJoinEngine(view, batch_size=256)
    prof = eng.profile()
    print(f"corpus: {N} series x {T} samples -> {view.n} windows "
          f"(m={M}, stride={STRIDE}); exclusion={eng.exclusion} samples")
    print(f"profile: pruned {prof.pruned_fraction.mean():.1%} of "
          f"window verifications on average; modeled HDD "
          f"{prof.io_seconds * 1e3:.1f}ms vs the oracle's full "
          f"streaming pass")

    # 3a. top motif: the planted pair, localized
    (a, b, d), *rest = eng.topk_motifs(3)
    rows, starts = view.locate(np.asarray([a, b], np.int64))
    print(f"motif #1: row {rows[0]} @ {starts[0]}  <->  "
          f"row {rows[1]} @ {starts[1]}  d={d:.4f}   "
          f"(planted: row 2 @ {o_a} / row 9 @ {o_b})")
    assert sorted(rows.tolist()) == [2, 9]

    # 3b. top discord: the burst
    (w, dd), *_ = eng.topk_discords(3)
    r, s = (int(v[0]) for v in view.locate(np.asarray([w], np.int64)))
    print(f"discord #1: row {r} @ {s}  d={dd:.4f}   "
          f"(planted burst: row 5 @ {o_d})")
    assert r == 5

    # 3c. exactness: the engine's pruned profile IS the brute-force
    # all-pairs profile, bit for bit
    oracle = eng.scan_profile()
    assert np.array_equal(prof.distances, oracle.distances)
    assert np.array_equal(prof.neighbors, oracle.neighbors)
    print("-> profile bit-identical to the brute-force all-pairs "
          "oracle (distances AND neighbor ids)")

    # 4. the FFT sliding dot product at profile scale: every window
    # distance of one query against the whole corpus in one transform
    import jax.numpy as jnp

    from repro.kernels.fft_dot import fft_tolerance, windowed_euclid_fft
    from repro.kernels.ref import windowed_euclid_ref
    q = X[2, o_a:o_a + M]
    q = (q - q.mean()) / q.std()
    d_fft = np.asarray(windowed_euclid_fft(X, q[None], stride=STRIDE))
    d_ref = np.asarray(windowed_euclid_ref(jnp.asarray(X),
                                           jnp.asarray(q[None]),
                                           STRIDE))
    np.testing.assert_allclose(d_fft, d_ref, **fft_tolerance(M))
    j = np.unravel_index(np.argmin(d_fft[0]), d_fft[0].shape)
    print(f"FFT sweep: nearest window of the motif query is row {j[0]} "
          f"@ {j[1] * STRIDE} — within the documented fft_tolerance"
          f"({M}) of the exact expansion (the exact top-k path stays "
          f"on the bitwise kernel; the FFT is the m>=1k sweep engine)")


if __name__ == "__main__":
    main()
