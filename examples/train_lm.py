"""End-to-end LM training driver: a ~20M-param smollm-family model for a
few hundred steps on the synthetic motif stream, with checkpointing and
an injected failure to show the restart path.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The identical code path scales to the production mesh via
``python -m repro.launch.train --scale full``.)
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()
    train_main([
        "--arch", "smollm-135m", "--scale", "reduced",
        "--d-model", str(args.width), "--n-layers", str(args.layers),
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm_ckpt", "--ckpt-every", "100",
        "--inject-failures", str(args.steps // 2),
        "--lr", "1e-3",
    ])


if __name__ == "__main__":
    main()
