"""Device-resident sharded verification (core.distributed): the device
path (``verify="device"``) must be bit-identical to the host fallback
(``verify="host"`` — store fetch + the same kernel distance math) for
every encoder at 1, 2 and 4 mocked hosts, whole-series and windowed,
while moving ZERO raw rows to the host; ingest must keep the raw and
representation mirrors in sync without re-encoding; the device shard
unit must equal the snapshot raw manifest's row ranges.

Runs in a subprocess with 4 placeholder host devices (XLA device count
is process-global) — meshes over 1, 2 and 4 of them mock 1/2/4 hosts.
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PRELUDE = """
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import make_technique
    from repro.data.synthetic import season_dataset
    from repro.launch.mesh import make_mesh_compat

    def encoders(T):
        w = T // 20
        return {
            "sax": make_technique("sax", T=T, W=w, L=10),
            "ssax": make_technique("ssax", T=T, W=w, L=10, r2_season=0.7),
            "tsax": make_technique("tsax", T=T, W=w, L=10, r2_trend=0.3),
            "stsax": make_technique("stsax", T=T, W=w, L=10,
                                    r2_season=0.5),
        }
"""


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    src = textwrap.dedent(_PRELUDE) + textwrap.dedent(code)
    r = subprocess.run([sys.executable, "-c", src],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_device_verification_bitwise_equals_host_all_encoders_shards():
    """Whole-series: every encoder x 1/2/4 shards, ragged tail included;
    the device path returns bit-identical (indices AND distances) top-k
    while touching zero host rows, and the device shard unit matches the
    snapshot raw manifest."""
    out = _run("""
        from repro.core import MatchEngine
        from repro.core.distributed import make_engine_service
        from repro.store import SymbolicStore
        from repro.store.snapshot import _shard_ranges

        X = season_dataset(n=53, T=120, L=10, strength=0.7, seed=11)
        Q, D = X[:2], X[2:]                    # 51 rows: ragged at 2 and 4
        for name, enc in encoders(120).items():
            # encode once per encoder (the ingest test covers the
            # sharded-encode path); the host comparison target is the
            # plain SymbolicStore engine (store fetch + same kernel math)
            store = SymbolicStore.from_rows(enc, D)
            host = MatchEngine(enc, store, verify="host", batch_size=64)
            r_h = host.topk(Q, k=5)
            assert r_h.store_accesses > 0
            for shards in (1, 2, 4):
                mesh = make_mesh_compat((shards,), ("data",))
                dev = make_engine_service(enc, None, mesh, store=store,
                                          verify="device", batch_size=64)
                r_d = dev.topk(Q, k=5)
                np.testing.assert_array_equal(r_d.indices, r_h.indices)
                np.testing.assert_array_equal(r_d.distances,
                                              r_h.distances)
                assert r_d.store_accesses == 0, (shards, name)
                assert r_d.store_fetches == 0 and r_d.io_seconds == 0.0
                head = dev.sweep._head
                assert head == (51 // shards) * shards
                # shard_ranges() keeps the snapshot MANIFEST semantics
                # (contiguous on disk) while the device mirror lays
                # rows out round-robin — two independent contracts
                assert dev.sweep.shard_ranges() == \\
                    _shard_ranges(head, shards), (shards, name)
                assert dev.sweep.mirror_layout == "round_robin"
                for s in range(shards):
                    np.testing.assert_array_equal(
                        dev.sweep.owned_rows(s),
                        np.arange(s, head, shards))
        print("whole-series device==host OK")
    """)
    assert "whole-series device==host OK" in out


def test_device_verification_ingest_and_approx():
    """Ingest keeps BOTH device mirrors (raw + representation) fresh
    without re-encoding: after a ragged append the device path still
    matches the host path bitwise, exact and approximate."""
    out = _run("""
        from repro.core import MatchEngine
        from repro.core.distributed import make_engine_service

        X = season_dataset(n=60, T=240, L=10, strength=0.7, seed=13)
        Q, D, extra = X[:2], X[2:41], X[41:]   # append 19 rows (ragged)
        mesh = make_mesh_compat((4,), ("data",))
        enc = encoders(240)["ssax"]
        dev = make_engine_service(enc, jnp.asarray(D), mesh,
                                  verify="device", batch_size=64)
        host = MatchEngine(enc, dev.store, verify="host", batch_size=64)
        dev.topk(Q, k=3)                       # warm mirrors pre-ingest
        dev.ingest(extra)
        r_d = dev.topk(Q, k=5)
        r_h = host.topk(Q, k=5)
        np.testing.assert_array_equal(r_d.indices, r_h.indices)
        np.testing.assert_array_equal(r_d.distances, r_h.distances)
        assert r_d.store_accesses == 0
        r_da = dev.topk(Q, k=5, exact=False)
        r_ha = host.topk(Q, k=5, exact=False)
        np.testing.assert_array_equal(r_da.indices, r_ha.indices)
        np.testing.assert_array_equal(r_da.distances, r_ha.distances)
        assert r_da.store_accesses == 0
        # indexed exact path, device-resident
        dev.store.build_index(leaf_fill=16)
        r_di = dev.topk(Q, k=5, source="index")
        np.testing.assert_array_equal(r_di.indices, r_d.indices)
        np.testing.assert_array_equal(r_di.distances, r_d.distances)
        assert r_di.store_accesses == 0
        print("ingest + approx + indexed OK")
    """)
    assert "ingest + approx + indexed OK" in out


def test_ingest_tail_rows_encoded_exactly_once():
    """Regression for the remainder-path duplication: ragged ingests
    must run the sharded chunk encode exactly once per ingest (the tail
    is never re-encoded by the sweep), and the stored representation
    stays bitwise-equal to a one-shot host encode."""
    out = _run("""
        from repro.core.distributed import make_engine_service
        from repro.store.symbolic import rep_leaves

        X = season_dataset(n=46, T=240, L=10, strength=0.7, seed=19)
        Q, D1, D2 = X[:2], X[2:25], X[25:]     # 23 + 21 rows, both ragged
        mesh = make_mesh_compat((4,), ("data",))
        enc = encoders(240)["stsax"]
        dev = make_engine_service(enc, None, mesh, batch_size=64)
        calls = []
        orig = dev.sweep._encode_chunk
        dev.sweep._encode_chunk = \\
            lambda rows: (calls.append(rows.shape[0]), orig(rows))[1]
        dev.ingest(D1)
        dev.topk(Q, k=3)                       # sweeps must not re-encode
        dev.ingest(D2)
        dev.topk(Q, k=3)
        dev.topk(Q, k=3, exact=False)
        assert calls == [23, 21], calls
        ref = tuple(np.asarray(l) for l in rep_leaves(
            enc.encode(jnp.asarray(np.concatenate([D1, D2])))))
        for got, want in zip(rep_leaves(dev.store.rep_view()), ref):
            np.testing.assert_array_equal(np.asarray(got), want)
        print("tail encoded once OK")
    """)
    assert "tail encoded once OK" in out


def test_snapshot_contiguous_save_opens_into_round_robin_mirrors():
    """Snapshot layout independence: a store saved with contiguous
    n_hosts=2 shards must open and answer BIT-identically when served
    through the round-robin device mirrors (the on-disk ranges are a
    manifest concept, not a device layout)."""
    out = _run("""
        import tempfile
        from repro.core import MatchEngine
        from repro.core.distributed import make_engine_service
        from repro.store import SymbolicStore

        X = season_dataset(n=41, T=240, L=10, strength=0.7, seed=29)
        Q, D = X[:2], X[2:]                    # 39 rows: ragged at 2/4
        enc = encoders(240)["ssax"]
        with tempfile.TemporaryDirectory() as d:
            SymbolicStore.from_rows(enc, D).save(d, n_hosts=2)
            store = SymbolicStore.open(d)
        host = MatchEngine(enc, store, verify="host", batch_size=64)
        r_h = host.topk(Q, k=5)
        for shards in (2, 4):
            mesh = make_mesh_compat((shards,), ("data",))
            dev = make_engine_service(enc, None, mesh, store=store,
                                      verify="device", batch_size=64)
            assert dev.sweep.mirror_layout == "round_robin"
            r_d = dev.topk(Q, k=5)
            np.testing.assert_array_equal(r_d.indices, r_h.indices)
            np.testing.assert_array_equal(r_d.distances, r_h.distances)
            assert r_d.store_accesses == 0
        print("snapshot layout independence OK")
    """)
    assert "snapshot layout independence OK" in out


def test_sharded_index_build_bitwise_equals_host_build():
    """Sharded bulk index build (device feature extraction + root-subtree
    grouped routing) must produce the identical tree — leaf membership,
    node count — and identical indexed top-k for every encoder, with the
    candidate order generated on device (zero host-ordered bytes)."""
    out = _run("""
        from repro.core import MatchEngine
        from repro.core.distributed import make_engine_service
        from repro.index import SeriesIndex
        from repro.store import SymbolicStore

        X = season_dataset(n=93, T=120, L=10, strength=0.7, seed=31)
        Q, D = X[:2], X[2:]                    # 91 rows, ragged at 4
        mesh = make_mesh_compat((4,), ("data",))
        for name, enc in encoders(120).items():
            store = SymbolicStore.from_rows(enc, D)
            ref = SeriesIndex.from_store(store, leaf_fill=12, max_bits=4)
            host = MatchEngine(enc, store, verify="host", batch_size=64)
            host.store.build_index(leaf_fill=12, max_bits=4)
            r_h = host.topk(Q, k=5, source="index")
            dev = make_engine_service(enc, None, mesh, store=store,
                                      verify="device", batch_size=64)
            idx = dev.store.build_index(leaf_fill=12, max_bits=4,
                                        mesh=mesh, n_shards=4)
            assert idx.n_nodes == ref.n_nodes, name
            assert idx.tree.leaf_membership() == \\
                ref.tree.leaf_membership(), name
            np.testing.assert_array_equal(
                idx.tree.feats, ref.tree.feats)
            r_d = dev.topk(Q, k=5, source="index")
            np.testing.assert_array_equal(r_d.indices, r_h.indices)
            np.testing.assert_array_equal(r_d.distances, r_h.distances)
            assert r_d.store_accesses == 0, name
            assert dev.sweep.host_order_bytes == 0, name
        print("sharded index build OK")
    """)
    assert "sharded index build OK" in out


def test_device_window_verification_bitwise_equals_host():
    """Windowed (--subseq): every encoder x 1/2/4 shards over a ragged
    (stride-indivisible) corpus — sharded window sweep + device window
    verification vs the host fetch path, bit-identical, zero rows moved;
    suppression and the window index ride the same contract."""
    out = _run("""
        from repro.subseq import SubseqEngine, WindowView

        X = season_dataset(n=7, T=610, L=10, strength=0.7, seed=7)
        rng = np.random.default_rng(0)
        Q = np.stack([X[0, 37:157],
                      X[3, 250:370]
                      + 0.1 * rng.normal(size=120).astype(np.float32)])
        for name, enc in encoders(120).items():
            view = WindowView(enc, X, stride=7)   # encoded once per enc
            e_h = SubseqEngine(view, verify="host", batch_size=128)
            view.reset()
            r_h = e_h.topk(Q, k=4)
            assert r_h.store_accesses > 0
            for shards in (1, 2, 4):
                mesh = make_mesh_compat((shards,), ("data",))
                e_d = SubseqEngine(view, verify="device", mesh=mesh,
                                   batch_size=128)
                r_d = e_d.topk(Q, k=4)
                np.testing.assert_array_equal(r_d.window_ids,
                                              r_h.window_ids)
                np.testing.assert_array_equal(r_d.distances,
                                              r_h.distances)
                assert r_d.store_accesses == 0, (shards, name)
        # suppression + index at 2 shards (ssax): same contract
        mesh = make_mesh_compat((2,), ("data",))
        enc = encoders(120)["ssax"]
        view = WindowView(enc, X, stride=7)
        view.build_index(leaf_fill=16)
        e_h = SubseqEngine(view, verify="host", batch_size=128)
        e_d = SubseqEngine(view, verify="device", mesh=mesh,
                           batch_size=128)
        r_h = e_h.topk(Q, k=3, exclusion=60)
        r_d = e_d.topk(Q, k=3, exclusion=60)
        np.testing.assert_array_equal(r_d.window_ids, r_h.window_ids)
        np.testing.assert_array_equal(r_d.distances, r_h.distances)
        assert r_d.store_accesses == 0
        print("windowed device==host OK")
    """)
    assert "windowed device==host OK" in out
