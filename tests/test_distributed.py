"""Distributed tests run in a subprocess with 8 placeholder host devices
(XLA device count is process-global, so the main pytest process stays at
one device).  Covers: shard_map matching engine vs single-device oracle,
sharded train step vs unsharded, elastic checkpoint re-shard 4->8."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_matching_equals_oracle():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SSAX
        from repro.core.distributed import encode_sharded, repr_topk_sharded
        from repro.data.synthetic import season_dataset
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((8,), ("data",))
        X = season_dataset(n=512, T=480, L=10, strength=0.7, seed=5)
        ss = SSAX(T=480, W=24, L=10, A_seas=32, A_res=32, r2_season=0.7)
        Xd = jnp.asarray(X)
        rep = encode_sharded(ss, Xd, mesh)
        # oracle: unsharded encode
        rep0 = ss.encode(Xd)
        for a, b in zip(jax.tree.leaves(rep), jax.tree.leaves(rep0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        Q = Xd[:4]
        rq = ss.encode(Q)
        d, idx = repr_topk_sharded(ss, rq, rep, mesh, k=16)
        d0 = np.asarray(ss.pairwise_distance(rq, rep0))
        for qi in range(4):
            want = np.sort(d0[qi])[:16]
            got = np.sort(np.asarray(d[qi]))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
            # indices point at the right rows
            np.testing.assert_allclose(
                np.sort(d0[qi][np.asarray(idx[qi])]), want,
                rtol=1e-4, atol=1e-4)
        print("sharded matching OK")
    """)
    assert "sharded matching OK" in out


def test_engine_service_ingest_while_serving():
    """make_engine_service over a SymbolicStore: ragged chunks are encoded
    sharded (old rows never re-encoded) and served by the next query,
    exact and approximate, matching the single-device oracle."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import SSAX
        from repro.core.distributed import make_engine_service
        from repro.core.matching import pairwise_euclidean
        from repro.data.synthetic import season_dataset
        from repro.launch.mesh import make_mesh_compat

        mesh = make_mesh_compat((8,), ("data",))
        X = season_dataset(n=560, T=480, L=10, strength=0.7, seed=5)
        Q, D = X[:4], X[4:516]                        # 512 = 8 shards x 64
        ss = SSAX(T=480, W=24, L=10, A_seas=32, A_res=32, r2_season=0.7)
        engine = make_engine_service(ss, jnp.asarray(D), mesh)
        base_version = engine.store.version

        extra = X[516:547]                            # ragged: 31 rows
        engine.ingest(extra)
        assert engine.store.version == base_version + 1
        D2 = np.concatenate([D, extra])
        ed = np.asarray(pairwise_euclidean(jnp.asarray(Q),
                                           jnp.asarray(D2)))
        res = engine.topk(Q, k=8)
        np.testing.assert_array_equal(
            res.indices, np.argsort(ed, axis=1, kind="stable")[:, :8])

        # chunk-encoded rep must equal the store's own host encode path
        rep_one = ss.encode(jnp.asarray(D2, jnp.float32))
        for got, want in zip(engine.store.rep_view(), rep_one):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))

        # ingest the queries: both paths serve them immediately
        ids = engine.ingest(Q)
        res = engine.topk(Q, k=1)
        np.testing.assert_array_equal(res.indices[:, 0], ids)
        assert np.allclose(res.distances, 0.0, atol=1e-5)
        res = engine.topk(Q, k=4, exact=False)
        np.testing.assert_array_equal(res.indices[:, 0], ids)
        print("service ingest OK")
    """)
    assert "service ingest OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config, reduced
        from repro.models.transformer import RunConfig
        from repro.optim.adamw import AdamWConfig
        from repro.sharding.specs import ShardingRules
        from repro.train.state import init_train_state, train_state_pspecs
        from repro.train.step import make_train_step
        from repro.launch.inputs import to_named, train_batch_specs
        from repro.launch.mesh import make_mesh_compat

        cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")),
                                  compute_dtype="float32",
                                  vocab_pad_multiple=64)
        rc = RunConfig(q_chunk=8, kv_chunk=8, loss_chunk=8)
        rng = np.random.default_rng(0)
        t = jnp.asarray(rng.integers(0, 64, (8, 17)), jnp.int32)
        batch = {"tokens": t[:, :-1], "labels": t[:, 1:]}

        # single device
        step0 = jax.jit(make_train_step(cfg, None, rc, AdamWConfig(lr=1e-3)))
        s0 = init_train_state(cfg, jax.random.PRNGKey(0))
        s0n, m0 = step0(s0, batch)

        # 4x2 mesh
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        rules = ShardingRules.for_mesh(mesh)
        ps = train_state_pspecs(cfg, rules)
        stepd = jax.jit(make_train_step(cfg, rules, rc, AdamWConfig(lr=1e-3)),
                        in_shardings=(to_named(rules, ps), None))
        s1 = init_train_state(cfg, jax.random.PRNGKey(0))
        s1n, m1 = stepd(s1, batch)
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-3, \
            (float(m0["loss"]), float(m1["loss"]))
        for a, b in zip(jax.tree.leaves(s0n["params"]),
                        jax.tree.leaves(s1n["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
        print("sharded train OK", float(m0["loss"]), float(m1["loss"]))
    """)
    assert "sharded train OK" in out


def test_elastic_reshard_4_to_8():
    out = _run("""
        import dataclasses, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.checkpoint.ckpt import save_checkpoint
        from repro.checkpoint.elastic import reshard_checkpoint
        from repro.train.state import init_train_state, abstract_train_state
        from repro.launch.mesh import make_mesh_compat

        cfg = dataclasses.replace(reduced(get_config("smollm-135m")),
                                  vocab_pad_multiple=64)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 42, state)

        mesh4 = make_mesh_compat((2, 2), ("data", "model"))
        mesh8 = make_mesh_compat((4, 2), ("data", "model"))
        restored, manifest = reshard_checkpoint(
            d, cfg, mesh4, mesh8, abstract_train_state(cfg))
        assert manifest["step"] == 42
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # model-axis change must be rejected
        mesh_bad = make_mesh_compat((2, 4), ("data", "model"))
        try:
            reshard_checkpoint(d, cfg, mesh4, mesh_bad,
                               abstract_train_state(cfg))
            raise SystemExit("should have raised")
        except ValueError:
            pass
        print("elastic OK")
    """)
    assert "elastic OK" in out


def test_dryrun_cell_on_debug_mesh():
    """The dry-run path itself (lower+compile+parse) on an 8-device mesh."""
    out = _run("""
        import json
        import repro.launch.dryrun as dr
        import jax

        # monkeypatch the production mesh to the 8 fake devices
        import repro.launch.mesh as mesh_mod
        def small_mesh(*, multi_pod=False):
            return mesh_mod.make_mesh_compat((4, 2), ("data", "model"))
        dr.make_production_mesh = small_mesh
        rec = dr.dryrun_cell("smollm-135m", "train_4k", multi_pod=False)
        assert rec["status"] == "ok", rec
        assert rec["hlo_flops_per_dev"] > 0
        assert rec["collectives"]["count"] > 0
        print("dryrun cell OK",
              rec["hlo_flops_per_dev"], rec["collectives"]["all-reduce"])
    """)
    assert "dryrun cell OK" in out


def test_dryrun_optimized_serve_on_debug_mesh():
    """The §Perf OPTIMIZED_SERVE configuration must keep compiling."""
    out = _run("""
        import jax
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_mod

        def small_mesh(*, multi_pod=False):
            return mesh_mod.make_mesh_compat((4, 2), ("data", "model"))
        dr.make_production_mesh = small_mesh
        kw = dict(dr.OPTIMIZED_SERVE)
        kw["rules_overrides"] = dict(kw["rules_overrides"], moe_groups=4)
        rec = dr.dryrun_cell("olmoe-1b-7b", "decode_32k", multi_pod=False,
                             variant="serve_optimized", **kw)
        assert rec["status"] == "ok", rec
        rec2 = dr.dryrun_cell("gemma3-12b", "decode_32k", multi_pod=False,
                              variant="serve_optimized", **kw)
        assert rec2["status"] == "ok", rec2
        print("optimized serve OK")
    """)
    assert "optimized serve OK" in out
