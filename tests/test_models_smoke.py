"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED same-family config and runs one forward/train step on CPU,
asserting output shapes and absence of NaNs; decode consistency is
checked against the full forward pass."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, reduced
from repro.models import build_model
from repro.models.transformer import RunConfig

RC = RunConfig(q_chunk=8, kv_chunk=8, mamba_chunk=8, rwkv_chunk=8,
               loss_chunk=8)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    s_text = S - cfg.prefix_len
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, s_text)),
        jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.prefix_len:
        batch["prefix_embed"] = 0.01 * jnp.ones(
            (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    if cfg.is_enc_dec:
        batch["encoder_frames"] = 0.01 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_reduced_forward_and_loss(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, rc=RC)
    params = model.init(KEY)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_params == cfg.param_counts()[0], \
        "analytical param counter drifted from the real tree"
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    x, aux = model.hidden_states(params, batch)
    S = batch["tokens"].shape[1] + cfg.prefix_len
    assert x.shape == (2, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x)))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_reduced_train_step_updates(arch):
    from repro.optim.adamw import AdamWConfig
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step
    cfg = reduced(get_config(arch))
    step_fn = jax.jit(make_train_step(cfg, None, RC, AdamWConfig(lr=1e-3)))
    state = init_train_state(cfg, KEY)
    batch = _batch(cfg)
    new_state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # parameters actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(state["params"]),
                                jax.tree.leaves(new_state["params"])))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_decode_consistency_with_forward(arch):
    """prefill(t[:k]) + decode(t[k:]) must reproduce the forward pass's
    next-token logits (f32 compute for tight comparison)."""
    # capacity_factor -> huge so MoE never drops tokens: capacity dropping
    # is batch-dependent and legitimately breaks train/decode equivalence
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32",
                              capacity_factor=64.0)
    model = build_model(cfg, rc=dataclasses.replace(RC, prefill_pad=48))
    params = model.init(KEY)
    B, S, k = 2, 16, 12
    batch = _batch(cfg, B=B, S=S)
    toks = batch["tokens"]

    logits_full, _ = model.logits(params, batch)      # (B, S_tot, V)

    pre = dict(batch)
    pre["tokens"] = toks[:, :k]
    logits_pre, cache = jax.jit(model.prefill)(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits_pre),
        np.asarray(logits_full[:, cfg.prefix_len + k - 1]),
        rtol=2e-3, atol=2e-3)

    decode = jax.jit(model.decode_step)
    for i in range(k, toks.shape[1]):
        logits_i, cache = decode(params, cache, toks[:, i:i + 1])
        np.testing.assert_allclose(
            np.asarray(logits_i),
            np.asarray(logits_full[:, cfg.prefix_len + i]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode diverges at position {i}")


def test_gemma3_sliding_window_mask_effect():
    """A windowed layer must ignore tokens beyond the window."""
    cfg = reduced(get_config("gemma3-12b"))
    # window=2: each layer sees (self, prev) only, so the stacked local
    # receptive field after 5 layers is 5 — strictly less than the 15-step
    # distance probed below
    pattern = tuple(
        dataclasses.replace(s, window=2 if s.window else None)
        for s in cfg.pattern)
    cfg = dataclasses.replace(cfg, pattern=pattern,
                              compute_dtype="float32")
    model = build_model(cfg, rc=RC)
    params = model.init(KEY)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 1) % cfg.vocab_size     # perturb far-past token
    x1, _ = model.logits(params, {"tokens": jnp.asarray(t1)})
    x2, _ = model.logits(params, {"tokens": jnp.asarray(t2)})
    # gemma3 pattern has one GLOBAL layer, so late positions may differ;
    # but a pure-local stack must not see position 0 from position 15.
    local_only = tuple(s for s in pattern if s.window is not None)
    cfg_local = dataclasses.replace(cfg, pattern=local_only,
                                    n_layers=len(local_only))
    model_l = build_model(cfg_local, rc=RC)
    params_l = model_l.init(KEY)
    y1, _ = model_l.logits(params_l, {"tokens": jnp.asarray(t1)})
    y2, _ = model_l.logits(params_l, {"tokens": jnp.asarray(t2)})
    np.testing.assert_allclose(np.asarray(y1[0, -1]), np.asarray(y2[0, -1]),
                               rtol=1e-4, atol=1e-4)


def test_prefix_lm_bidirectional_attention():
    """paligemma: a change in a LATER prefix position must influence an
    EARLIER prefix position's hidden state (bidirectional prefix)."""
    cfg = dataclasses.replace(reduced(get_config("paligemma-3b")),
                              compute_dtype="float32")
    model = build_model(cfg, rc=RC)
    params = model.init(KEY)
    B, P = 1, cfg.prefix_len
    rng = np.random.default_rng(1)
    pe1 = jnp.asarray(rng.normal(size=(B, P, cfg.d_model)), jnp.float32)
    pe2 = pe1.at[0, -1].add(1.0)            # change the LAST prefix token
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)
    h1, _ = model.hidden_states(params, {"tokens": toks, "prefix_embed": pe1})
    h2, _ = model.hidden_states(params, {"tokens": toks, "prefix_embed": pe2})
    assert float(jnp.max(jnp.abs(h1[0, 0] - h2[0, 0]))) > 1e-6


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "rwkv6-7b"])
def test_state_space_chunk_invariance(arch):
    """Chunked scan must equal single-chunk scan (mamba/rwkv)."""
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    batch = _batch(cfg, B=2, S=32)
    params = build_model(cfg, rc=RC).init(KEY)
    h_small, _ = build_model(
        cfg, rc=dataclasses.replace(RC, mamba_chunk=4, rwkv_chunk=4)
    ).hidden_states(params, batch)
    h_big, _ = build_model(
        cfg, rc=dataclasses.replace(RC, mamba_chunk=32, rwkv_chunk=32)
    ).hidden_states(params, batch)
    np.testing.assert_allclose(np.asarray(h_small), np.asarray(h_big),
                               rtol=1e-3, atol=1e-3)


def test_causal_skip_flash_matches_dense():
    """Static causal block skipping (§Perf lever) is numerics-identical."""
    from repro.models.layers import MaskSpec, flash_attention
    rng = np.random.default_rng(4)
    B, S, K, G, Dh = 2, 64, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, K, G, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, Dh)), jnp.float32)
    for window in (None, 8):
        mask = MaskSpec(causal=True, window=window)
        o0 = flash_attention(q, k, v, mask, q_chunk=16, kv_chunk=16,
                             causal_skip=False)
        o1 = flash_attention(q, k, v, mask, q_chunk=16, kv_chunk=16,
                             causal_skip=True)
        np.testing.assert_allclose(np.asarray(o0), np.asarray(o1),
                                   rtol=1e-5, atol=1e-5)


def test_causal_skip_end_to_end():
    cfg = dataclasses.replace(reduced(get_config("gemma3-12b")),
                              compute_dtype="float32")
    params = build_model(cfg, rc=RC).init(KEY)
    batch = _batch(cfg, B=2, S=32)
    h0, _ = build_model(cfg, rc=RC).hidden_states(params, batch)
    rc_skip = dataclasses.replace(RC, causal_skip=True)
    h1, _ = build_model(cfg, rc=rc_skip).hidden_states(params, batch)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                               rtol=1e-4, atol=1e-4)
