"""Matching-engine behaviour: exact matching returns the true NN, pruning
accounting is correct, approximate matching follows the paper's
tie-breaking, and the I/O cost model orders HDD > SSD > HBM."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SAX, SSAX, exact_match, approximate_match
from repro.core.matching import (
    RawStore, pairwise_euclidean, pruning_power, tightness_of_lower_bound)
from repro.data.synthetic import season_dataset


@pytest.fixture(scope="module")
def season_setup():
    X = season_dataset(n=400, T=480, L=10, strength=0.7, seed=11)
    Q, D = X[:10], X[10:]
    ss = SSAX(T=480, W=24, L=10, A_seas=64, A_res=64, r2_season=0.7)
    rq = ss.encode(jnp.asarray(Q))
    rx = ss.encode(jnp.asarray(D))
    dists = np.asarray(ss.pairwise_distance(rq, rx))
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    return Q, D, dists, ed


def test_exact_match_equals_bruteforce(season_setup):
    Q, D, dists, ed = season_setup
    for qi in range(len(Q)):
        store = RawStore.hdd(D)
        res = exact_match(Q[qi], dists[qi], store, batch_size=16)
        assert res.index == int(np.argmin(ed[qi]))
        assert np.isclose(res.distance, ed[qi].min(), rtol=1e-5)
        assert res.raw_accesses == store.accesses


def test_exact_match_batch_size_invariance(season_setup):
    Q, D, dists, ed = season_setup
    r1 = exact_match(Q[0], dists[0], RawStore.hdd(D), batch_size=1)
    r64 = exact_match(Q[0], dists[0], RawStore.hdd(D), batch_size=64)
    assert r1.index == r64.index
    # batched verification can only over-fetch by < one batch
    assert r64.raw_accesses <= r1.raw_accesses + 64


def test_pruning_monotone_in_accuracy(season_setup):
    """The better lower bound (sSAX) must prune at least as well as SAX
    on strong-season data — the paper's central matching claim."""
    Q, D, dss, ed = season_setup
    sax = SAX(T=480, W=24, A=4096)       # same 288-bit budget as the sSAX
    dsax = np.asarray(sax.pairwise_distance(
        sax.encode(jnp.asarray(Q)), sax.encode(jnp.asarray(D))))
    pp_s = np.mean([pruning_power(Q[i], dss[i], D) for i in range(len(Q))])
    pp_x = np.mean([pruning_power(Q[i], dsax[i], D) for i in range(len(Q))])
    assert pp_s > pp_x


def test_approximate_match_tie_breaking():
    rng = np.random.default_rng(3)
    D = rng.normal(size=(50, 32)).astype(np.float32)
    q = rng.normal(size=(32,)).astype(np.float32)
    dists = np.ones(50)
    dists[[7, 20]] = 0.25                  # two tied minima
    store = RawStore.ssd(D)
    res = approximate_match(q, dists, store)
    ed = np.sqrt(np.sum((D - q) ** 2, -1))
    assert res.index in (7, 20)
    assert res.index == (7 if ed[7] <= ed[20] else 20)
    assert store.accesses == 2


def test_raw_store_empty_fetch_charges_nothing():
    """Regression: an all-pruned round (empty index array) must return a
    (0, T) block and bill neither a seek nor a row access."""
    D = np.arange(12, dtype=np.float32).reshape(3, 4)
    store = RawStore.ssd(D)
    out = store.fetch(np.empty(0, np.int64))
    assert out.shape == (0, 4) and out.dtype == np.float32
    out = store.fetch([])                  # plain empty list, too
    assert out.shape == (0, 4)
    assert store.accesses == 0 and store.fetches == 0
    assert store.modeled_io_seconds() == 0.0
    # non-empty fetch still bills exactly one seek
    store.fetch([0, 2])
    assert store.accesses == 2 and store.fetches == 1
    # boolean masks keep selecting rows (not coerced to indices 0/1)
    np.testing.assert_array_equal(
        store.fetch(np.asarray([False, True, True])), D[1:])


def test_raw_store_cost_model_ordering():
    D = np.zeros((10, 960), np.float32)
    n = 1000
    t_hdd = RawStore.hdd(D).modeled_io_seconds(n)
    t_ssd = RawStore.ssd(D).modeled_io_seconds(n)
    t_hbm = RawStore.hbm(D).modeled_io_seconds(n)
    assert t_hdd > t_ssd > t_hbm
    assert t_hdd / t_hbm > 1e3           # the 3-orders-of-magnitude regime


def test_tlb_bounds(season_setup):
    Q, D, dss, ed = season_setup
    tlb = tightness_of_lower_bound(dss, ed)
    assert 0.0 <= tlb <= 1.0 + 1e-6
    assert tlb > 0.5                      # strong season => tight bound
