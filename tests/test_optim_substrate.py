"""Optimizer, schedule, and gradient-compression substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compression import (
    compress_grads, error_feedback_update, init_error_feedback,
    quantize_dequantize)
from repro.optim.schedule import cosine_schedule


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0]), "b": jnp.asarray([[2.0, 2.0]])}
    target = {"w": jnp.asarray([1.0, 1.0]), "b": jnp.zeros((1, 2))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    step = jnp.zeros((), jnp.int32)

    def loss_fn(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    for i in range(300):
        grads = jax.grad(loss_fn)(params)
        params, opt, m = adamw_update(cfg, params, grads, opt, step + i)
    assert float(loss_fn(params)) < 1e-3


def test_grad_clip_limits_global_norm():
    params = {"w": jnp.ones((4,))}
    grads = {"w": 100.0 * jnp.ones((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(cfg, params, grads, opt,
                                 jnp.zeros((), jnp.int32))
    assert float(metrics["grad_norm"]) == 200.0
    # effective update uses clipped grads: m after one step = (1-b1)*g_clip
    # indirectly verified via the step magnitude being bounded
    new_p, _, _ = adamw_update(cfg, params, grads, opt,
                               jnp.zeros((), jnp.int32))


def test_cosine_schedule_shape():
    s = lambda t: float(cosine_schedule(jnp.asarray(t, jnp.float32),
                                        warmup=10, total=100))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-5
    assert s(50) < 1.0
    assert abs(s(100) - 0.1) < 1e-2     # floor


def test_quantize_dequantize_error_small():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    dq = quantize_dequantize(g)
    rel = float(jnp.linalg.norm(g - dq) / jnp.linalg.norm(g))
    assert rel < 0.01                   # int8 block quant ~0.4% typical


def test_compression_metrics_and_skip_small():
    grads = {"mat": jnp.ones((32, 32)), "bias": jnp.ones((32,))}
    out, metrics = compress_grads(grads)
    assert "compress_rel_err" in metrics
    np.testing.assert_array_equal(np.asarray(out["bias"]),
                                  np.asarray(grads["bias"]))


def test_error_feedback_reduces_bias():
    """With EF, the *accumulated* applied update converges to the true
    accumulated gradient (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    ef = init_error_feedback({"w": g_true})["w"]
    applied = jnp.zeros_like(g_true)
    for _ in range(20):
        comp, ef_new = error_feedback_update({"w": g_true}, {"w": ef})
        applied = applied + comp["w"]
        ef = ef_new["w"]
    target = 20 * g_true
    rel = float(jnp.linalg.norm(applied - target) / jnp.linalg.norm(target))
    assert rel < 1e-3


def test_training_with_compression_converges():
    """End-to-end: tiny LM trains with int8 grad compression."""
    from repro.configs import get_config, reduced
    from repro.models.transformer import RunConfig
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step
    from repro.optim.adamw import AdamWConfig

    cfg = reduced(get_config("smollm-135m"))
    rc = RunConfig(q_chunk=8, kv_chunk=8, loss_chunk=8)
    step = jax.jit(make_train_step(cfg, None, rc, AdamWConfig(lr=3e-3),
                                   compression="int8"))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 32, (4, 33)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8
    assert all(np.isfinite(losses))
