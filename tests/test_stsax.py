"""Property + behaviour tests for stSAX (the paper's future-work
extension: combined season+trend awareness, core/stsax.py)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SAX, SSAX, TSAX, znormalize
from repro.core.matching import pairwise_euclidean, tightness_of_lower_bound
from repro.core.stsax import STSAX
from repro.data.synthetic import _znorm_np, random_walk


def season_trend_dataset(n=200, T=960, L=8, s_seas=0.4, s_tr=0.4, seed=0):
    """Series with BOTH a season and a trend of controlled strengths."""
    rng = np.random.default_rng(seed)
    base = _znorm_np(random_walk(rng, n, T))
    mask = rng.normal(size=(n, L)).astype(np.float32)
    mask -= mask.mean(1, keepdims=True)
    seas = _znorm_np(np.tile(mask, (1, T // L)))
    t = np.arange(T, dtype=np.float32)
    tc = (t - t.mean()) / t.std()
    tr = np.sign(rng.normal(size=(n, 1))).astype(np.float32) * tc[None]
    noise = max(0.0, 1 - s_seas - s_tr)
    x = (np.sqrt(s_seas) * seas + np.sqrt(s_tr) * tr
         + np.sqrt(noise) * base)
    return _znorm_np(x)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_stsax_lower_bounds_euclidean(data):
    T = data.draw(st.sampled_from([64, 128, 256]))
    L = 8
    W = data.draw(st.sampled_from([4, 8]))
    A_t = data.draw(st.sampled_from([8, 64]))
    A_s = data.draw(st.sampled_from([4, 32]))
    A_r = data.draw(st.sampled_from([4, 32]))
    s_seas = data.draw(st.floats(0.05, 0.6))
    s_tr = data.draw(st.floats(0.05, 0.35))
    seed = data.draw(st.integers(0, 2 ** 16))
    x = season_trend_dataset(12, T, L, s_seas, s_tr, seed)
    stx = STSAX(T=T, W=W, L=L, A_tr=A_t, A_seas=A_s, A_res=A_r,
                r2_trend=s_tr, r2_season=s_seas / max(1 - s_tr, 1e-6))
    rep = stx.encode(jnp.asarray(x))
    d_rep = np.asarray(stx.pairwise_distance(rep, rep))
    d_ed = np.asarray(pairwise_euclidean(jnp.asarray(x), jnp.asarray(x)))
    assert np.all(d_rep <= d_ed + 1e-2), (d_rep - d_ed).max()


def test_stsax_beats_single_component_techniques():
    """On data with BOTH components, stSAX should out-bound SAX, sSAX and
    tSAX at a comparable representation budget — the future-work claim."""
    X = season_trend_dataset(300, 960, 8, s_seas=0.45, s_tr=0.35, seed=7)
    Q, D = X[:20], X[20:]
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))

    def tlb(tech):
        d = np.asarray(tech.pairwise_distance(
            tech.encode(jnp.asarray(Q)), tech.encode(jnp.asarray(D))))
        return tightness_of_lower_bound(d, ed)

    sax = SAX(T=960, W=48, A=64)                             # 288 bits
    ssax = SSAX(T=960, W=24, L=8, A_seas=64, A_res=256,      # 240 bits
                r2_season=0.45)
    tsax = TSAX(T=960, W=48, A_tr=64, A_res=32, r2_trend=0.35)
    stsax = STSAX(T=960, W=24, L=8, A_tr=64, A_seas=64,      # 246 bits
                  A_res=256, r2_trend=0.35, r2_season=0.69)
    t_sax, t_ss, t_ts, t_st = tlb(sax), tlb(ssax), tlb(tsax), tlb(stsax)
    assert t_st > t_sax
    assert t_st > t_ts
    assert t_st >= t_ss - 1e-3      # season part dominates; stSAX adds trend
    # and the combination must beat the best single-component technique
    assert t_st > max(t_sax, t_ss, t_ts) - 1e-3


def test_stsax_exact_matching_correct():
    from repro.core.matching import RawStore
    from repro.core import exact_match
    X = season_trend_dataset(250, 480, 8, s_seas=0.4, s_tr=0.4, seed=11)
    Q, D = X[:5], X[5:]
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    stx = STSAX(T=480, W=12, L=8, A_tr=32, A_seas=32, A_res=64,
                r2_trend=0.4, r2_season=0.67)
    d = np.asarray(stx.pairwise_distance(
        stx.encode(jnp.asarray(Q)), stx.encode(jnp.asarray(D))))
    for qi in range(len(Q)):
        r = exact_match(Q[qi], d[qi], RawStore.ssd(D))
        assert r.index == int(np.argmin(ed[qi]))
