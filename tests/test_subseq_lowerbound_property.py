"""Property-based tests (hypothesis) for the subsequence analogue of the
central invariant: for EVERY encoder, the representation distance between
an encoded z-normalized query and any encoded z-normalized window
lower-bounds the true z-normalized Euclidean distance — for arbitrary
window length, stride, and series shape.  This is what makes the pruned
windowed scan (``repro.subseq.SubseqEngine``) exact (paper §4.1 /
Appendix A applied to the window set)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import SAX, SSAX, STSAX, TSAX
from repro.subseq import SubseqEngine, WindowView
from repro.subseq.windows import znorm_windows

TOL = 1e-2     # f32 + normalization slack on distances O(10)

L = 10         # season length; window lengths below are multiples


def _corpus(seed, n, T):
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        x = np.cumsum(rng.normal(size=(n, T)), axis=1)
    elif kind == 1:
        mask = rng.normal(size=(n, L))
        x = np.tile(mask, (1, T // L + 1))[:, :T] \
            + 0.5 * rng.normal(size=(n, T))
    else:
        x = rng.normal(size=(n, 1)) * np.arange(T)[None, :] \
            + rng.normal(size=(n, T))
    return x.astype(np.float32)


def _encoder(name, m):
    return {
        "sax": lambda: SAX(T=m, W=m // L, A=16),
        "ssax": lambda: SSAX(T=m, W=m // L, L=L, A_seas=8, A_res=16,
                             r2_season=0.5),
        "tsax": lambda: TSAX(T=m, W=m // L, A_tr=16, A_res=16,
                             r2_trend=0.4),
        "stsax": lambda: STSAX(T=m, W=m // L, L=L, A_tr=8, A_seas=8,
                               A_res=16, r2_trend=0.2, r2_season=0.4),
    }[name]()


@settings(max_examples=20, deadline=None)
@given(st.data())
@pytest.mark.parametrize("tech", ["sax", "ssax", "tsax", "stsax"])
def test_windowed_repr_distance_lower_bounds_znormalized_ed(tech, data):
    m = data.draw(st.sampled_from([60, 120, 200]))
    stride = data.draw(st.sampled_from([1, 3, 11]))
    extra = data.draw(st.integers(0, 37))      # ragged tail beyond m
    seed = data.draw(st.integers(0, 2**16))
    T = m + m // 2 + extra
    X = _corpus(seed, 4, T)
    q_raw = _corpus(seed + 1, 2, m)

    view = WindowView(_encoder(tech, m), X, stride=stride)
    eng = SubseqEngine(view, verify="numpy")
    zq = eng.normalize_queries(q_raw)
    d_rep = eng.repr_distances(zq)             # (2, n_windows)

    W = np.lib.stride_tricks.sliding_window_view(
        X, m, axis=1)[:, ::stride].reshape(-1, m)
    Wz = znorm_windows(W)
    d_true = np.stack([
        np.sqrt(np.sum(np.square(Wz - q[None]), -1)) for q in zq])
    assert d_rep.shape == d_true.shape
    assert np.all(d_rep <= d_true + TOL), \
        (tech, stride, (d_rep - d_true).max())
