"""SymbolicStore (repro/store): incremental append must be bit-identical
to one-shot encoding for every encoder, the RawStore protocol must hold,
snapshots must round-trip engine and index results exactly, and
engine/service consumers must serve appended rows immediately."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SAX, SSAX, STSAX, TSAX, MatchEngine, OneDSAX
from repro.core.matching import RawStore
from repro.data.synthetic import season_dataset
from repro.store import SymbolicStore, rep_leaves

N, N_Q, T, L = 300, 4, 480, 10


@pytest.fixture(scope="module")
def season():
    X = season_dataset(n=N + N_Q, T=T, L=L, strength=0.7, seed=21)
    return X[:N_Q], X[N_Q:]


ENCODERS = {
    "sax": SAX(T=T, W=24, A=64),
    "ssax": SSAX(T=T, W=24, L=L, A_seas=32, A_res=32, r2_season=0.7),
    "tsax": TSAX(T=T, W=24, A_tr=32, A_res=32, r2_trend=0.5),
    "stsax": STSAX(T=T, W=24, L=10, A_tr=16, A_seas=16, A_res=32,
                   r2_trend=0.3, r2_season=0.4),
    "onedsax": OneDSAX(T=T, W=24, A_a=16, A_s=16),
}


@pytest.mark.parametrize("tech", sorted(ENCODERS))
def test_append_chunked_bit_identical_to_oneshot(season, tech):
    _, D = season
    enc = ENCODERS[tech]
    oneshot = [np.asarray(l)
               for l in rep_leaves(enc.encode(jnp.asarray(D, jnp.float32)))]
    # deliberate arbitrary split pattern incl. single rows
    store2 = SymbolicStore(enc)
    splits = [0, 1, 2, 130, 131, 258, N]
    for lo, hi in zip(splits[:-1], splits[1:]):
        store2.append(D[lo:hi])
    assert store2.n == N
    for got, want in zip(rep_leaves(store2.rep_view()), oneshot):
        np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(store2.data, D.astype(np.float32))


def test_store_rawstore_protocol(season):
    _, D = season
    store = SymbolicStore.from_rows(ENCODERS["ssax"], D, media="hdd")
    ref = RawStore.hdd(D)
    rows = store.fetch([3, 5, 7])
    np.testing.assert_array_equal(rows, D[[3, 5, 7]])
    assert store.accesses == 3 and store.fetches == 1
    assert store.modeled_io_seconds() == \
        pytest.approx(ref.modeled_io_seconds(3, 1))
    # empty fetch: no rows, no modeled seek
    empty = store.fetch(np.empty(0, np.int64))
    assert empty.shape == (0, T)
    assert store.fetches == 1
    store.reset()
    assert store.accesses == 0 and store.fetches == 0


def test_engine_over_store_matches_rawstore_engine(season):
    Q, D = season
    enc = ENCODERS["ssax"]
    res_store = MatchEngine(enc, SymbolicStore.from_rows(enc, D),
                            verify="numpy").topk(Q, k=5)
    res_raw = MatchEngine(enc, RawStore.ssd(D), verify="numpy").topk(Q, k=5)
    np.testing.assert_array_equal(res_store.indices, res_raw.indices)
    np.testing.assert_array_equal(res_store.distances, res_raw.distances)


def test_engine_serves_appended_rows_immediately(season):
    Q, D = season
    enc = ENCODERS["ssax"]
    engine = MatchEngine(enc, SymbolicStore.from_rows(enc, D),
                         verify="numpy")
    ids = engine.append(Q)               # ingest the queries themselves
    res = engine.topk(Q, k=1)
    np.testing.assert_array_equal(res.indices[:, 0], ids)
    assert np.allclose(res.distances, 0.0, atol=1e-5)
    # a RawStore-backed engine cannot ingest
    with pytest.raises(TypeError):
        MatchEngine(enc, RawStore.ssd(D), verify="numpy").append(Q)


def test_engine_empty_store_returns_empty_result(season):
    """Querying before the first ingest must return an empty, well-formed
    result (0-width frontier), not crash — exact and approximate."""
    Q, _ = season
    enc = ENCODERS["ssax"]
    engine = MatchEngine(enc, SymbolicStore(enc), verify="numpy")
    for exact in (True, False):
        res = engine.topk(Q, k=4, exact=exact)
        assert res.indices.shape == (N_Q, 0)
        assert res.store_fetches == 0 and (res.raw_accesses == 0).all()


def test_engine_rejects_mismatched_store_encoder(season):
    _, D = season
    store = SymbolicStore.from_rows(ENCODERS["ssax"], D)
    with pytest.raises(ValueError):
        MatchEngine(SSAX(T=T, W=24, L=L, A_seas=16, A_res=16,
                         r2_season=0.3), store)


@pytest.mark.parametrize("tech", ["sax", "ssax", "tsax"])
def test_snapshot_roundtrip_bitwise(tmp_path, season, tech):
    Q, D = season
    enc = ENCODERS[tech]
    store = SymbolicStore.from_rows(enc, D, media="hdd")
    store.save(str(tmp_path))
    reopened = SymbolicStore.open(str(tmp_path))
    assert reopened.n == store.n
    assert reopened.encoder == enc
    assert reopened.seek_s == store.seek_s
    np.testing.assert_array_equal(reopened.data, store.data)
    for got, want in zip(rep_leaves(reopened.rep_view()),
                         rep_leaves(store.rep_view())):
        np.testing.assert_array_equal(got, want)
    # engine answers are reproduced exactly
    r0 = MatchEngine(enc, store, verify="numpy").topk(Q, k=7)
    r1 = MatchEngine(enc, reopened, verify="numpy").topk(Q, k=7)
    np.testing.assert_array_equal(r0.indices, r1.indices)
    np.testing.assert_array_equal(r0.distances, r1.distances)
    # reopened store keeps ingesting
    reopened.append(Q)
    assert reopened.n == store.n + N_Q


def test_snapshot_roundtrip_index(tmp_path, season):
    Q, D = season
    enc = ENCODERS["ssax"]
    store = SymbolicStore.from_rows(enc, D)
    store.build_index(max_bits=5, leaf_capacity=16)
    store.save(str(tmp_path))
    reopened = SymbolicStore.open(str(tmp_path))
    assert reopened.index is not None
    assert reopened.index.n_nodes == store.index.n_nodes
    r0 = store.index.topk(Q, store, k=3)
    r1 = reopened.index.topk(Q, reopened, k=3)
    np.testing.assert_array_equal(r0.indices, r1.indices)
    np.testing.assert_array_equal(r0.distances, r1.distances)


def test_snapshot_latest_pointer_and_gc(tmp_path, season):
    _, D = season
    store = SymbolicStore.from_rows(ENCODERS["sax"], D)
    for _ in range(4):                   # keep=3 -> oldest GC'd
        store.append(D[:1])
        store.save(str(tmp_path))
    snaps = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("snap_"))
    assert len(snaps) == 3
    assert (tmp_path / "LATEST").read_text() == snaps[-1]
    reopened = SymbolicStore.open(str(tmp_path))
    assert reopened.n == store.n


def test_append_maintains_index_incrementally(season):
    """Appends route new rows into the split tree through the bulk-build
    code path — the index keeps full coverage with no rebuild and the
    indexed engine stays bit-identical to the linear sweep."""
    Q, D = season
    enc = ENCODERS["ssax"]
    store = SymbolicStore.from_rows(enc, D[:-2])
    store.build_index(max_bits=4, leaf_capacity=32)
    assert store.index is not None
    store.append(D[-2:])
    assert store.index is not None       # maintained, not invalidated
    assert store.index.n == store.n == N
    engine = MatchEngine(enc, store, verify="numpy")
    res_idx = engine.topk(Q, k=3, source="index")
    res_lin = engine.topk(Q, k=3)
    np.testing.assert_array_equal(res_idx.indices, res_lin.indices)
    np.testing.assert_array_equal(res_idx.distances, res_lin.distances)


def test_open_rejects_corruption_and_drifted_breakpoints(tmp_path, season):
    """Tampered arrays fail the content hash; a snapshot whose stored
    breakpoint tables disagree with the rebuilt encoder (hash intact,
    library drifted) must also refuse to open — symbols would be
    re-interpreted."""
    import json
    import os
    from repro.store.snapshot import _content_hash
    _, D = season
    store = SymbolicStore.from_rows(ENCODERS["ssax"], D)
    path = store.save(str(tmp_path))
    arrays = dict(np.load(os.path.join(path, "shard_h000.npz")))
    arrays["bp_b_res"] = arrays["bp_b_res"] + 0.25
    np.savez(os.path.join(path, "shard_h000.npz"), **arrays)
    with pytest.raises(ValueError, match="hash mismatch"):
        SymbolicStore.open(str(tmp_path))
    # consistent hash but drifted tables: the breakpoint check fires
    mpath = os.path.join(path, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["hash"] = _content_hash(arrays)
    json.dump(manifest, open(mpath, "w"))
    with pytest.raises(ValueError, match="drifted"):
        SymbolicStore.open(str(tmp_path))
