"""MetricsRegistry under concurrent service use.

The serving front-end records from client threads, the dispatcher
thread and the engine simultaneously; this suite pins down the
guarantees the service relies on:

* recording is atomic under threads — no lost counter increments or
  histogram observations;
* ``merge_snapshots`` over per-phase registries equals one shared
  registry that saw the same traffic (merged histograms == sum of the
  per-phase snapshots, bucket by bucket);
* ``reset_counters()`` at a service-session boundary scopes store I/O
  accounting to the session — no bleed into the next session's
  numbers, and no effect on results.
"""

import threading

import numpy as np

from repro.data.synthetic import season_dataset
from repro.obs import MetricsRegistry
from repro.obs.metrics import merge_snapshots

N_THREADS = 8
N_OPS = 400


def _hammer(reg, tid):
    for i in range(N_OPS):
        reg.counter("c.total").inc()
        reg.counter(f"c.thread{tid}").inc(2)
        reg.histogram("h.lat").observe(1e-5 * (i % 7 + 1))
        reg.gauge(f"g.thread{tid}").set(float(i))


def test_concurrent_recording_loses_nothing():
    reg = MetricsRegistry()
    ts = [threading.Thread(target=_hammer, args=(reg, t))
          for t in range(N_THREADS)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = reg.snapshot()
    assert snap["counters"]["c.total"] == N_THREADS * N_OPS
    for t in range(N_THREADS):
        assert snap["counters"][f"c.thread{t}"] == 2 * N_OPS
    h = snap["histograms"]["h.lat"]
    assert h["count"] == N_THREADS * N_OPS
    assert sum(h["counts"]) == N_THREADS * N_OPS


def test_merged_phase_snapshots_equal_shared_registry():
    """Per-phase registries merged == one shared registry, for the same
    interleaved traffic (the bench runner's per-suite pattern under
    concurrent use)."""
    shared = MetricsRegistry()
    phases = [MetricsRegistry() for _ in range(3)]

    def worker(tid):
        for p, reg in enumerate(phases):
            for i in range(50):
                for r in (reg, shared):
                    r.counter("c.ops").inc()
                    r.histogram("h.lat").observe(1e-4 * (i % 5 + 1 + p))

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    merged = None
    for reg in phases:
        merged = merge_snapshots(merged, reg.snapshot())
    want = shared.snapshot()
    assert merged["counters"] == want["counters"]
    mh, wh = merged["histograms"]["h.lat"], want["histograms"]["h.lat"]
    assert mh["count"] == wh["count"]
    assert mh["counts"] == wh["counts"]
    assert np.isclose(mh["sum"], wh["sum"])
    # and bucket-by-bucket the merge is the sum of the phases
    per_phase = [reg.snapshot()["histograms"]["h.lat"] for reg in phases]
    assert mh["counts"] == [
        sum(p["counts"][b] for p in per_phase)
        for b in range(len(mh["counts"]))]


def test_reset_counters_scopes_io_to_session():
    """Store I/O accounting resets at a session boundary: the second
    session reports only its own traffic, and resetting never perturbs
    results (same engine, same answers)."""
    from repro.core import MatchEngine, make_technique
    from repro.service import MatchSession
    from repro.store import SymbolicStore

    T, n, n_q, k, L = 240, 48, 3, 3, 10
    X = season_dataset(n + n_q, T, L, 0.7, seed=41)
    Q, D = X[:n_q], X[n_q:]
    enc = make_technique("ssax", T=T, W=T // (2 * L), L=L, r2_season=0.7)
    store = SymbolicStore.from_rows(enc, D, media="ssd")
    store.build_index(leaf_fill=16)
    eng = MatchEngine(enc, store, verify="host", batch_size=32)

    with MatchSession(eng, metrics=MetricsRegistry(),
                      window_s=0.0, max_batch=4) as s1:
        r1 = s1.serve(Q, k=k, tier="index")
    after_first = store.accesses
    assert after_first > 0

    # session 2: construction resets the store counters, so its I/O
    # numbers start from zero instead of inheriting session 1's
    with MatchSession(eng, metrics=MetricsRegistry(),
                      window_s=0.0, max_batch=4) as s2:
        assert store.accesses == 0
        r2 = s2.serve(Q, k=k, tier="index")
    assert 0 < store.accesses <= after_first
    for a, b in zip(r1, r2):
        assert np.array_equal(a.indices, b.indices)
        assert np.array_equal(a.distances, b.distances)


def test_snapshot_while_recording_does_not_deadlock():
    """snapshot() runs concurrently with recording (the reporter thread
    pattern) — must terminate and return a consistent shape."""
    reg = MetricsRegistry()
    stop = threading.Event()

    def rec():
        while not stop.is_set():
            reg.counter("c.x").inc()
            reg.histogram("h.x").observe(1e-3)

    ts = [threading.Thread(target=rec) for _ in range(3)]
    for t in ts:
        t.start()
    try:
        for _ in range(200):
            snap = reg.snapshot()
            assert set(snap) == {"counters", "gauges", "histograms"}
    finally:
        stop.set()
        for t in ts:
            t.join()
    assert reg.snapshot()["counters"]["c.x"] > 0
