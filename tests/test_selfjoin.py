"""Matrix-profile self-join (``repro.profile``): the one property the
subsystem exists under is BIT-identity — ``SelfJoinEngine.profile`` must
equal the brute-force oracle ``scan_profile`` exactly (distances AND
neighbors), for every encoder, every candidate source (linear lower-
bound matrix / split-tree index / sharded device stream), and both
verification families.  Families pair with their own oracle: the numpy
verifier and the kernel verifier are distinct bitwise reductions by
design, so numpy engines compare against a numpy oracle and
host/device engines against a ``verify="host"`` oracle — device must
match host bitwise because it runs the identical kernel math.

Plus: trivial-zone geometry, motif/discord purity (non-overlap,
planted-pattern recovery), the device path's zero-host-transfer
invariants, the profile cache, and the service's self-join tier.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from hypcompat import given, settings, st

from repro.core import make_technique
from repro.data.synthetic import season_dataset
from repro.profile import (MatrixProfile, SelfJoinEngine, topk_discords,
                           topk_motifs)
from repro.subseq import SubseqEngine, WindowView

L = 10
TECHS = ["sax", "ssax", "tsax", "stsax"]
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _enc(name, m):
    kw = {"sax": {}, "ssax": {"r2_season": 0.7},
          "tsax": {"r2_trend": 0.3}, "stsax": {"r2_season": 0.5}}[name]
    return make_technique(name, T=m, W=m // L, L=L, **kw)


def _corpus(seed, n, T):
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        x = np.cumsum(rng.normal(size=(n, T)), axis=1)
    elif kind == 1:
        mask = rng.normal(size=(n, L))
        x = np.tile(mask, (1, T // L + 1))[:, :T] \
            + 0.3 * rng.normal(size=(n, T))
    else:
        x = (np.linspace(0, 3, T)[None] * rng.normal(size=(n, 1))
             + 0.5 * rng.normal(size=(n, T)))
    return x.astype(np.float32)


def _view(tech, D, m, stride, index=False):
    view = WindowView(_enc(tech, m), D, stride=stride, media="ssd")
    if index:
        view.build_index(leaf_fill=16)
    return view


def _same(a: MatrixProfile, b: MatrixProfile):
    return (np.array_equal(a.distances, b.distances)
            and np.array_equal(a.neighbors, b.neighbors))


# --------------------------------------------------------------- exactness

@pytest.mark.parametrize("tech", TECHS)
@pytest.mark.parametrize("index", [False, True])
def test_profile_bit_identical_to_oracle(tech, index):
    """Linear and indexed paths, numpy family: profile, motifs and
    discords all equal the brute-force oracle exactly."""
    D = _corpus(3, 5, 300)
    view = _view(tech, D, m=60, stride=6, index=index)
    eng = SelfJoinEngine(view, verify="numpy", batch_size=64)
    prof = eng.profile()
    assert prof.source == ("index" if index else "linear")
    oracle = eng.scan_profile()
    assert _same(prof, oracle), tech
    assert topk_motifs(prof, view.locate, 3) == \
        topk_motifs(oracle, view.locate, 3)
    assert topk_discords(prof, view.locate, 3) == \
        topk_discords(oracle, view.locate, 3)
    # the pruned paths must actually prune relative to the oracle scan
    assert prof.raw_accesses.mean() <= oracle.raw_accesses.mean()


@pytest.mark.parametrize("tech", ["ssax", "stsax"])
def test_profile_kernel_family_matches_its_own_oracle(tech):
    """The kernel-verifier family ("host") is a different bitwise
    reduction from numpy — it must match ITS oracle exactly."""
    D = _corpus(4, 4, 240)
    view = _view(tech, D, m=60, stride=6)
    eng = SelfJoinEngine(view, verify="host", batch_size=64)
    assert _same(eng.profile(), eng.scan_profile())


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_profile_property_engine_equals_oracle(data):
    """Property: for arbitrary corpus shape, stride, exclusion and
    encoder, the engine profile is bit-identical to the oracle and no
    reported neighbor lies in its query's trivial zone."""
    tech = data.draw(st.sampled_from(TECHS))
    seed = data.draw(st.integers(0, 2**16))
    n = data.draw(st.integers(2, 5))
    m = data.draw(st.sampled_from([40, 60]))
    stride = data.draw(st.sampled_from([4, 7, 11]))
    T = m + stride * data.draw(st.integers(4, 12)) \
        + data.draw(st.integers(0, 5))
    excl = data.draw(st.sampled_from([1, m // 4, m // 2, m]))
    index = data.draw(st.booleans())
    view = _view(tech, _corpus(seed, n, T), m, stride, index=index)
    eng = SelfJoinEngine(view, verify="numpy", exclusion=excl,
                         batch_size=32)
    prof = eng.profile()
    assert _same(prof, eng.scan_profile()), (tech, seed, excl)
    for w in range(prof.n):
        nb = prof.neighbors[w]
        if nb >= 0:
            assert nb not in eng.trivial_ids(w), (w, nb)
            assert np.isfinite(prof.distances[w])
        else:
            assert prof.distances[w] == np.inf


# ---------------------------------------------------------------- geometry

def test_trivial_zone_geometry():
    """``trivial_ids``: contains the window itself, stays on the same
    source row, and is exactly the |start - start'| < exclusion band."""
    view = _view("sax", _corpus(0, 3, 240), m=60, stride=6)
    eng = SelfJoinEngine(view, exclusion=20)
    nw = view.windows_per_row
    for wid in [0, 1, nw - 1, nw, 2 * nw + 3, view.n - 1]:
        ids = eng.trivial_ids(wid)
        assert wid in ids
        assert np.all(ids // nw == wid // nw)
        starts = (ids % nw) * view.stride
        s0 = (wid % nw) * view.stride
        assert np.all(np.abs(starts - s0) < eng.exclusion)
        # the band is maximal: one step further is outside
        lo, hi = ids.min(), ids.max()
        if lo % nw > 0:
            assert abs((lo - 1) % nw - wid % nw) * view.stride \
                >= eng.exclusion
        if hi % nw < nw - 1:
            assert abs((hi + 1) % nw - wid % nw) * view.stride \
                >= eng.exclusion


def test_exclusion_validation():
    view = _view("sax", _corpus(0, 2, 120), m=40, stride=4)
    assert SelfJoinEngine(view).exclusion == max(1, 40 // 4)
    with pytest.raises(ValueError, match="exclusion"):
        SelfJoinEngine(view, exclusion=0)
    with pytest.raises(ValueError, match="index"):
        SelfJoinEngine(view).profile(use_index=True)


# ---------------------------------------------------------- motifs/discords

def _plant(n=5, T=300, m=60, seed=13):
    """Corpus with a near-identical snippet in rows 0 and 1 (the motif)
    and a one-off burst in row 2 (the discord)."""
    rng = np.random.default_rng(seed)
    D = np.asarray(season_dataset(n, T, L, strength=0.6,
                                  per_series_strength=True, seed=seed),
                   np.float64).copy()
    o = (T - m) // 2
    snip = np.sin(np.linspace(0, 6 * np.pi, m)) * 2.0
    D[0, o:o + m] = snip + 0.01 * rng.normal(size=m)
    D[1, o:o + m] = snip + 0.01 * rng.normal(size=m)
    D[2, o:o + m] += 6.0 * np.hanning(m)
    return D.astype(np.float32), o


def test_motifs_and_discords_recover_planted_patterns():
    D, o = _plant()
    view = _view("ssax", D, m=60, stride=6)
    eng = SelfJoinEngine(view, verify="numpy")
    motifs = eng.topk_motifs(3)
    a, b, d = motifs[0]
    rows, starts = view.locate(np.asarray([a, b], np.int64))
    assert sorted(rows.tolist()) == [0, 1]
    assert all(abs(int(s) - o) <= 2 * view.stride for s in starts)
    assert d < 1.0
    discords = eng.topk_discords(3)
    r_disc, _ = view.locate(np.asarray([discords[0][0]], np.int64))
    assert int(r_disc[0]) == 2


def test_motif_discord_non_overlap_and_order():
    """Selected motif endpoints and discords never overlap each other
    (same row within exclusion samples); motifs ascend in distance and
    discords descend; nothing non-finite is ever reported."""
    view = _view("tsax", _corpus(7, 5, 300), m=60, stride=6)
    eng = SelfJoinEngine(view, verify="numpy")
    prof = eng.profile()
    motifs = topk_motifs(prof, view.locate, 6)
    discords = topk_discords(prof, view.locate, 6)
    assert [d for *_, d in motifs] == sorted(d for *_, d in motifs)
    assert [d for _, d in discords] == \
        sorted((d for _, d in discords), reverse=True)
    assert all(np.isfinite(d) for *_, d in motifs)
    assert all(np.isfinite(d) for _, d in discords)

    def no_overlap(wids):
        rows, starts = view.locate(np.asarray(wids, np.int64))
        for i in range(len(wids)):
            for j in range(i + 1, len(wids)):
                assert not (rows[i] == rows[j]
                            and abs(int(starts[i]) - int(starts[j]))
                            < prof.exclusion), (wids[i], wids[j])
    no_overlap([w for pair in motifs for w in pair[:2]])
    no_overlap([w for w, _ in discords])


def test_profile_cache_and_refresh():
    view = _view("sax", _corpus(1, 3, 240), m=60, stride=6)
    eng = SelfJoinEngine(view, verify="numpy")
    p1 = eng.profile()
    assert eng.profile() is p1                       # cache hit is free
    assert eng.profile(refresh=True) is not p1       # forced recompute
    p3 = eng.profile(explain=True)                   # EXPLAIN re-measures
    assert p3 is not p1 and p3.trace is not None
    assert _same(p1, p3)


# ------------------------------------------------------------- device path

def test_device_stream_bitwise_and_zero_host_transfers():
    """In-process single-device mesh: the sharded stream path with
    ``verify="device"`` equals the kernel-family host oracle bitwise
    while ordering candidates AND verifying rows entirely on device."""
    from repro.launch.mesh import make_mesh_compat
    from repro.obs import check_trace
    D = _corpus(5, 4, 240)
    view = _view("stsax", D, m=60, stride=6)
    host = SelfJoinEngine(view, verify="host", batch_size=64)
    oracle = host.scan_profile()
    mesh = make_mesh_compat((1,), ("data",))
    dev = SelfJoinEngine(view, verify="device", mesh=mesh, batch_size=64)
    prof = dev.profile(explain=True)
    assert prof.source == "stream"
    assert _same(prof, oracle)
    assert check_trace(prof.trace, device=True) == []
    assert prof.trace.get("host_order_bytes") == 0
    assert prof.trace.get("rows_to_host") == 0


def test_device_stream_multi_shard_subprocess():
    """2 and 4 mocked hosts (XLA device count is process-global, hence
    the subprocess): bit-identity against the host twin plus the
    zero-transfer invariants, every encoder."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent("""
        import numpy as np
        from repro.core import make_technique
        from repro.data.synthetic import season_dataset
        from repro.launch.mesh import make_mesh_compat
        from repro.obs import check_trace
        from repro.profile import SelfJoinEngine
        from repro.subseq import WindowView

        D = season_dataset(4, 240, 10, strength=0.7,
                           per_series_strength=True, seed=21)
        kw = {"sax": {}, "ssax": {"r2_season": 0.7},
              "tsax": {"r2_trend": 0.3}, "stsax": {"r2_season": 0.5}}
        for tech, extra in kw.items():
            enc = make_technique(tech, T=60, W=6, L=10, **extra)
            view = WindowView(enc, D, stride=6, media="ssd")
            oracle = SelfJoinEngine(view, verify="host").scan_profile()
            for shards in (2, 4):
                mesh = make_mesh_compat((shards,), ("data",))
                eng = SelfJoinEngine(view, verify="device", mesh=mesh,
                                     batch_size=64)
                p = eng.profile(explain=True)
                assert np.array_equal(p.distances, oracle.distances), \\
                    (tech, shards)
                assert np.array_equal(p.neighbors, oracle.neighbors), \\
                    (tech, shards)
                assert check_trace(p.trace, device=True) == []
                assert p.trace.get("host_order_bytes") == 0
                assert p.trace.get("rows_to_host") == 0
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


# ----------------------------------------------------------------- service

def test_service_selfjoin_tier():
    """The session's self-join tier: motif/discord requests are served
    from the shared profile, match the oracle, and bad kinds shed with
    a reason instead of hanging."""
    from repro.obs import MetricsRegistry
    from repro.service import MatchSession
    D, _ = _plant()
    view = _view("ssax", D, m=60, stride=6)
    sub = SubseqEngine(view, verify="host", batch_size=64)
    reg = MetricsRegistry()
    sj = SelfJoinEngine(view, verify="host", batch_size=64, metrics=reg)
    oracle = sj.scan_profile()
    sess = MatchSession(sub, selfjoin=sj, metrics=reg, window_s=0.05,
                        max_batch=4)
    r_m = sess.submit_selfjoin("motifs", k=2)
    r_d = sess.submit_selfjoin("discords", k=2)
    r_bad = sess.submit_selfjoin("profiles", k=1)
    sess.start()
    assert r_m.wait(300) and r_m.ok, r_m.error
    assert r_d.wait(300) and r_d.ok, r_d.error
    assert r_bad.wait(300) and not r_bad.ok and r_bad.error
    sess.close()
    assert r_m.tier_served == "selfjoin"
    assert r_m.result == topk_motifs(oracle, view.locate, 2)
    assert r_d.result == topk_discords(oracle, view.locate, 2)
    snap = reg.snapshot()
    assert snap["counters"].get("selfjoin.queries", 0) > 0
