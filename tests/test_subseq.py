"""Subsequence subsystem (repro.subseq): SubseqEngine.topk must be
bit-identical to a brute-force windowed z-normalized scan for every
encoder (ragged T and stride > 1 included), WindowView's incremental
window encoding must equal one-shot encoding for any ingest chunking,
window fetches must bill deduplicated underlying rows through the
RawStore cost model, and non-overlap suppression must drop trivial
matches without losing exactness."""

import numpy as np
import pytest

from repro.core import SAX, SSAX, STSAX, TSAX
from repro.core.matching import RawStore
from repro.data.synthetic import season_dataset
from repro.store import SymbolicStore
from repro.subseq import SubseqEngine, WindowView
from repro.subseq.windows import znorm_windows

M = 120        # window length (the encoders' T)
N_Q = 3


def _encoders():
    return {
        "sax": SAX(T=M, W=12, A=16),
        "ssax": SSAX(T=M, W=12, L=10, A_seas=8, A_res=16, r2_season=0.5),
        "tsax": TSAX(T=M, W=12, A_tr=16, A_res=16, r2_trend=0.3),
        "stsax": STSAX(T=M, W=12, L=10, A_tr=8, A_seas=8, A_res=16,
                       r2_trend=0.2, r2_season=0.4),
    }


@pytest.fixture(scope="module")
def corpus():
    # T deliberately ragged: not a multiple of the stride values below,
    # leaving a dangling tail shorter than one window
    X = season_dataset(n=10, T=610, L=10, strength=0.7, seed=5)
    rng = np.random.default_rng(0)
    Q = np.stack([X[0, 37:37 + M],
                  X[3, 250:250 + M] + 0.1 * rng.normal(size=M)
                  .astype(np.float32),
                  rng.normal(size=M).astype(np.float32)])
    return X, Q


def _bruteforce_windows(X, stride):
    """All z-normalized windows, row-major window ids — the ground truth
    the engine must match bitwise."""
    W = np.lib.stride_tricks.sliding_window_view(
        X, M, axis=1)[:, ::stride].reshape(-1, M)
    return znorm_windows(W)


def _bruteforce_topk(Wz, zq, k):
    idx, dist = [], []
    for q in zq:
        d = np.sqrt(np.sum(np.square(Wz - q[None]), -1))
        o = np.argsort(d, kind="stable")[:k]
        idx.append(o)
        dist.append(d[o].astype(np.float64))
    return np.asarray(idx, np.int64), np.asarray(dist)


@pytest.mark.parametrize("tech", ["sax", "ssax", "tsax", "stsax"])
@pytest.mark.parametrize("stride", [1, 7])
def test_subseq_topk_bitwise_equals_windowed_bruteforce(corpus, tech,
                                                        stride):
    X, Q = corpus
    enc = _encoders()[tech]
    view = WindowView(enc, X, stride=stride)
    eng = SubseqEngine(view, verify="numpy")
    res = eng.topk(Q, k=5)
    zq = eng.normalize_queries(Q)
    want_i, want_d = _bruteforce_topk(_bruteforce_windows(X, stride),
                                      zq, 5)
    np.testing.assert_array_equal(res.window_ids, want_i)
    np.testing.assert_array_equal(res.distances, want_d)
    # id -> (row, start) translation is consistent with the dense layout
    nw = view.windows_per_row
    np.testing.assert_array_equal(res.rows, want_i // nw)
    np.testing.assert_array_equal(res.starts, (want_i % nw) * stride)


def test_subseq_prunes_on_seasonal_corpus(corpus):
    X, Q = corpus
    enc = _encoders()["ssax"]
    eng = SubseqEngine(WindowView(enc, X, stride=1), verify="numpy")
    res = eng.topk(Q[:2], k=1)        # in-corpus(-ish) queries prune hard
    assert (res.raw_accesses < eng.view.n).any()
    assert res.store_accesses > 0 and res.io_seconds > 0


def test_windowview_incremental_equals_oneshot(corpus):
    """Appending the corpus in chunks (and with different encode_chunk
    sizes) must produce bit-identical window representations — the
    store-subsystem chunked-encode property lifted to windows."""
    X, _ = corpus
    enc = _encoders()["ssax"]
    one = WindowView(enc, X, stride=3, encode_chunk=4096)
    for chunks, ec in [((3, 4, 3), 4096), ((5, 5), 57), ((10,), 11)]:
        inc = WindowView(enc, stride=3, encode_chunk=ec)
        ofs = 0
        for c in chunks:
            inc.append(X[ofs:ofs + c])
            ofs += c
        assert inc.n == one.n
        for a, b in zip(_leaves(inc), _leaves(one)):
            np.testing.assert_array_equal(a, b)


def _leaves(view):
    rep = view.rep_view()
    return rep if isinstance(rep, tuple) else (rep,)


def test_windowview_append_serves_new_windows(corpus):
    X, Q = corpus
    enc = _encoders()["sax"]
    view = WindowView(enc, X[:6], stride=2)
    eng = SubseqEngine(view, verify="numpy")
    eng.topk(Q[:1], k=1)                       # warm the rep cache
    new_ids = view.append(X[6:])
    assert new_ids[0] == 6 * view.windows_per_row
    res = eng.topk(Q[:1], k=3)
    zq = eng.normalize_queries(Q[:1])
    want_i, want_d = _bruteforce_topk(_bruteforce_windows(X, 2), zq, 3)
    np.testing.assert_array_equal(res.window_ids, want_i)
    np.testing.assert_array_equal(res.distances, want_d)


def test_windowview_over_symbolic_store_source(corpus):
    """A SymbolicStore can be the corpus: its raw rows are windowed, its
    cost model bills the fetches, and rows appended through the store are
    picked up by sync()."""
    X, Q = corpus
    whole = SAX(T=610, W=61, A=16)             # whole-series encoder
    store = SymbolicStore.from_rows(whole, X[:8], media="hdd")
    enc = _encoders()["sax"]
    view = WindowView(enc, store, stride=2)
    assert view.n == 8 * view.windows_per_row
    store.append(X[8:])                        # out-of-band ingest
    assert view.sync() == 2 * view.windows_per_row
    eng = SubseqEngine(view, verify="numpy")
    res = eng.topk(Q[:1], k=2)
    zq = eng.normalize_queries(Q[:1])
    want_i, _ = _bruteforce_topk(_bruteforce_windows(X, 2), zq, 2)
    np.testing.assert_array_equal(res.window_ids, want_i)
    assert store.accesses > 0                  # billed on the source


def test_window_fetch_bills_dedup_rows(corpus):
    X, _ = corpus
    view = WindowView(_encoders()["sax"], X, stride=1)
    nw = view.windows_per_row
    view.reset()
    # four windows from row 0, two from row 2 -> 2 row reads, 1 seek
    out = view.fetch([0, 1, 5, nw - 1, 2 * nw, 2 * nw + 3])
    assert out.shape == (6, M)
    assert view.accesses == 2
    assert view.fetches == 1
    np.testing.assert_array_equal(
        out[0], znorm_windows(X[0, :M][None])[0])
    # modeled I/O charges long-row bytes, not window bytes
    assert view.modeled_io_seconds(2, 1) == \
        view.source.modeled_io_seconds(2, 1)
    # warm rows come from the buffer pool: no new billing, no seek
    view.fetch([3, nw - 7, 2 * nw + 1])
    assert view.accesses == 2 and view.fetches == 1
    # a cold row in the batch bills only itself
    view.fetch([0, 4 * nw])
    assert view.accesses == 3 and view.fetches == 2
    # reset drops the buffer: everything is cold again
    view.reset()
    view.fetch([0])
    assert view.accesses == 1 and view.fetches == 1


def test_window_fetch_without_row_buffer(corpus):
    X, _ = corpus
    view = WindowView(_encoders()["sax"], X, stride=1, cache_rows=0)
    view.reset()
    view.fetch([0, 1])
    view.fetch([2, 3])
    assert view.accesses == 2            # same row billed cold each round
    assert view.fetches == 2


def test_rawstore_fetch_bills_unique_rows_only():
    """Satellite regression: duplicate/overlapping indices in one fetch
    bill each physical row once."""
    data = np.arange(20, dtype=np.float32).reshape(5, 4)
    store = RawStore.ssd(data)
    out = store.fetch([3, 3, 1, 3, 1])
    assert out.shape == (5, 4)                 # rows still per-request
    np.testing.assert_array_equal(out[0], data[3])
    assert store.accesses == 2                 # ...but billed deduped
    assert store.fetches == 1
    store.fetch([2, 2, 2])
    assert store.accesses == 3
    assert store.fetches == 2


def test_subseq_nonoverlap_suppression(corpus):
    X, Q = corpus
    view = WindowView(_encoders()["sax"], X, stride=1)
    eng = SubseqEngine(view, verify="numpy")
    plain = eng.topk(Q[:1], k=5)
    sup = eng.topk(Q[:1], k=5, exclusion=M // 2)
    # without suppression the best matches crowd around one offset;
    # with it every reported pair is temporally separated
    for a in range(5):
        for b in range(a + 1, 5):
            if sup.rows[0, a] == sup.rows[0, b]:
                assert abs(sup.starts[0, a] - sup.starts[0, b]) >= M // 2
    # the best match is unaffected and results stay sorted
    assert sup.window_ids[0, 0] == plain.window_ids[0, 0]
    assert (np.diff(sup.distances[0]) >= 0).all()
    # suppression is exact: greedy over the full verified ordering
    zq = eng.normalize_queries(Q[:1])
    Wz = _bruteforce_windows(X, 1)
    d = np.sqrt(np.sum(np.square(Wz - zq[0][None]), -1))
    order = np.argsort(d, kind="stable")
    nw = view.windows_per_row
    taken = []
    for wid in order:
        r, s = wid // nw, (wid % nw) * 1
        if any(tr == r and abs(ts - s) < M // 2 for tr, ts in taken):
            continue
        taken.append((r, s))
        if len(taken) == 5:
            break
    want = np.asarray([r * nw + s for r, s in taken], np.int64)
    np.testing.assert_array_equal(sup.window_ids[0], want)


@pytest.mark.parametrize("use_index", [False, True])
def test_exclusion_widening_never_verifies_window_twice(corpus, use_index):
    """Regression (ROADMAP "indexed suppression frontier reuse"): with
    exclusion > 0 the widening rounds must reuse the verified frontier —
    instrumenting WindowView.fetch shows every window id fetched AT MOST
    ONCE over the whole search (single query, so fetch-level counts are
    per-query counts), on the indexed AND the linear path, with results
    still bit-identical to the un-widened reference."""
    from collections import Counter
    X, Q = corpus
    enc = _encoders()["sax"]
    view = WindowView(enc, X, stride=1)
    if use_index:
        view.build_index(leaf_fill=32)
    eng = SubseqEngine(view, verify="numpy", batch_size=64)
    counts = Counter()
    orig = view.fetch
    view.fetch = lambda wids: (counts.update(
        np.asarray(wids, np.int64).tolist()) or orig(wids))
    # k + tight exclusion forces several widening rounds
    res = eng.topk(Q[:1], k=6, exclusion=M // 2, use_index=use_index)
    view.fetch = orig
    assert counts, "nothing was verified?"
    dup = {w: c for w, c in counts.items() if c > 1}
    assert not dup, f"windows fetched more than once: {dup}"
    # exactness: identical to a fresh linear-path run
    ref_eng = SubseqEngine(WindowView(enc, X, stride=1), verify="numpy",
                           batch_size=64)
    ref = ref_eng.topk(Q[:1], k=6, exclusion=M // 2, use_index=False)
    np.testing.assert_array_equal(res.window_ids, ref.window_ids)
    np.testing.assert_array_equal(res.distances, ref.distances)


def test_rep_only_store_guards():
    enc = _encoders()["sax"]
    store = SymbolicStore(enc, store_raw=False)
    store.append(np.zeros((3, M), np.float32))
    assert store.n == 3
    with pytest.raises(TypeError):
        store.fetch([0])
    with pytest.raises(TypeError):
        store.save("/tmp/never-written")


def test_scan_topk_agrees_with_engine_on_indices(corpus):
    """The MASS-style kernel brute force finds the same winners (f32
    kernel numerics, so indices + allclose distances, not bitwise)."""
    X, Q = corpus
    view = WindowView(_encoders()["sax"], X, stride=2)
    eng = SubseqEngine(view, verify="numpy")
    exact = eng.topk(Q, k=3)
    scan = eng.scan_topk(Q, k=3)
    np.testing.assert_array_equal(scan.window_ids, exact.window_ids)
    np.testing.assert_allclose(scan.distances, exact.distances,
                               rtol=1e-3, atol=1e-3)
    # brute force reads the whole corpus; the pruned path cannot read more
    assert scan.store_accesses == view.n_rows
