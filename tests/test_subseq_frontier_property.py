"""Property test (hypothesis): exclusion-widening frontier reuse.

For any (k, exclusion, stride) and either candidate path (linear sweep
or split-tree index), ``SubseqEngine.topk`` with suppression must

* equal the brute-force greedy-suppression oracle bitwise (exactness is
  not allowed to depend on how many widening rounds ran), and
* never fetch the same window id twice (the engine's "never verified
  twice" accounting contract, now shared by both paths).

Guarded by ``pytest.importorskip`` like the other property modules —
hypothesis runs in CI, not in every container.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import SAX  # noqa: E402
from repro.data.synthetic import season_dataset  # noqa: E402
from repro.subseq import SubseqEngine, WindowView  # noqa: E402
from repro.subseq.windows import znorm_windows  # noqa: E402

M = 120
_X = season_dataset(n=6, T=360, L=10, strength=0.7, seed=3)
_Q = _X[0:1, 41:41 + M] + 0.05 * np.random.default_rng(0).normal(
    size=(1, M)).astype(np.float32)
_VIEWS: dict = {}


def _view(stride, indexed):
    key = (stride, indexed)
    if key not in _VIEWS:
        view = WindowView(SAX(T=M, W=12, A=16), _X, stride=stride)
        if indexed:
            view.build_index(leaf_fill=32)
        _VIEWS[key] = view
    return _VIEWS[key]


def _oracle(stride, zq, k, exclusion):
    """Greedy suppression over the full verified ordering — the exact
    semantics ``SubseqEngine._suppress`` promises."""
    W = np.lib.stride_tricks.sliding_window_view(
        _X, M, axis=1)[:, ::stride].reshape(-1, M)
    Wz = znorm_windows(W)
    nw = W.shape[0] // _X.shape[0]
    d = np.sqrt(np.sum(np.square(Wz - zq[0][None]), -1))
    order = np.argsort(d, kind="stable")
    out_i = np.full(k, -1, np.int64)
    out_d = np.full(k, np.inf, np.float64)
    taken = []
    for wid in order:
        r, s = wid // nw, (wid % nw) * stride
        if any(tr == r and abs(ts - s) < exclusion for tr, ts in taken):
            continue
        out_i[len(taken)] = wid
        out_d[len(taken)] = d[wid]
        taken.append((r, s))
        if len(taken) == k:
            break
    return out_i, out_d


@settings(deadline=None, max_examples=15)
@given(k=st.integers(1, 7), exclusion=st.integers(1, M),
       stride=st.sampled_from([1, 3, 7]), indexed=st.booleans())
def test_suppression_widening_exact_and_verifies_once(k, exclusion,
                                                      stride, indexed):
    from collections import Counter
    view = _view(stride, indexed)
    eng = SubseqEngine(view, verify="numpy", batch_size=32)
    counts = Counter()
    orig = view.fetch
    view.fetch = lambda wids: (counts.update(
        np.asarray(wids, np.int64).tolist()) or orig(wids))
    try:
        res = eng.topk(_Q, k=k, exclusion=exclusion, use_index=indexed)
    finally:
        view.fetch = orig
    dup = {w: c for w, c in counts.items() if c > 1}
    assert not dup, f"windows fetched more than once: {dup}"
    zq = eng.normalize_queries(_Q)
    want_i, want_d = _oracle(stride, zq, k, exclusion)
    np.testing.assert_array_equal(res.window_ids[0], want_i)
    np.testing.assert_array_equal(res.distances[0], want_d)
