"""Service subsystem: coalescing front-end + planner exactness.

The two properties the always-on service is allowed to exist under:

1. **Exactness discipline** — a planner-routed exact-tier answer is
   bit-identical to calling ``engine.topk`` directly with that tier's
   source, for every encoder x candidate source x verification path.
2. **Batching neutrality** — a coalesced (Q, T) dispatch answers every
   request identically to dispatching it alone (including the session's
   power-of-two shape bucketing, which pads with duplicate queries).

Plus the front-end contracts: admission control sheds with a reason
and exact ``serve.shed.* == serve.rejected`` accounting (never a
silent drop), deadline-threatened requests downgrade to the anytime
tier with an error-bar certificate, and the planner's routing follows
its estimates.
"""

import numpy as np
import pytest

from repro.core import MatchEngine, make_technique
from repro.data.synthetic import season_dataset
from repro.obs import MetricsRegistry
from repro.service import (TIERS, CoalescingQueue, MatchRequest,
                           MatchSession, QueryPlanner)
from repro.store import SymbolicStore

L = 10
TECHS = ["sax", "ssax", "tsax", "stsax"]


def _enc(name, T):
    kw = {"sax": {}, "ssax": {"r2_season": 0.7},
          "tsax": {"r2_trend": 0.3}, "stsax": {"r2_season": 0.5}}[name]
    return make_technique(name, T=T, W=T // (2 * L), L=L, **kw)


def _mesh1():
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((1,), ("data",))


def _data(tech, T=240, n=64, n_q=5, seed=5):
    X = season_dataset(n + n_q, T, L, 0.7, per_series_strength=True,
                       seed=seed)
    return X[:n_q], X[n_q:]


def _host_engine(tech, Q, D, T):
    enc = _enc(tech, T)
    store = SymbolicStore.from_rows(enc, D, media="ssd")
    store.build_index(leaf_fill=16)
    return MatchEngine(enc, store, verify="host", batch_size=32)


def _device_engine(tech, Q, D, T):
    import jax.numpy as jnp
    from repro.core.distributed import make_engine_service
    dev = make_engine_service(_enc(tech, T), jnp.asarray(D), _mesh1(),
                              batch_size=32, verify="device")
    dev.store.build_index(leaf_fill=16)
    return dev


@pytest.mark.parametrize("tech", TECHS)
@pytest.mark.parametrize("verify", ["host", "device"])
def test_exact_tiers_bit_identical_and_batch_neutral(tech, verify):
    """Coalesced, planner-routed exact answers == direct per-request
    ``topk`` for both exact tiers, all encoders, host and device."""
    T, k = 240, 4
    Q, D = _data(tech, T=T)
    engine = (_host_engine if verify == "host" else _device_engine)(
        tech, Q, D, T)
    src = {"index": "index", "linear": None}
    for tier in ("index", "linear"):
        sess = MatchSession(engine, metrics=MetricsRegistry(),
                            window_s=0.05, max_batch=len(Q))
        # submit before start: deterministically one coalesced batch
        reqs = [sess.submit(q, k=k, tier=tier) for q in Q]
        sess.start()
        for r in reqs:
            assert r.wait(120) and r.ok, (tier, r.error)
        sess.close()
        assert all(r.tier_served == tier for r in reqs)
        batch = engine.topk(Q, k=k, source=src[tier])
        for i, r in enumerate(reqs):
            solo = engine.topk(Q[i][None], k=k, source=src[tier])
            label = (tech, verify, tier, i)
            assert np.array_equal(r.indices, batch.indices[i]), label
            assert np.array_equal(r.distances, batch.distances[i]), label
            assert np.array_equal(r.indices, solo.indices[0]), label
            assert np.array_equal(r.distances, solo.distances[0]), label


def test_batching_neutrality_odd_sizes():
    """Non-power-of-two coalesced batches (exercising the pad bucket)
    answer identically to solo dispatch."""
    T, k = 240, 3
    Q, D = _data("ssax", T=T, n_q=5)
    engine = _host_engine("ssax", Q, D, T)
    for n_sub in (1, 3, 5):
        sess = MatchSession(engine, metrics=MetricsRegistry(),
                            window_s=0.05, max_batch=8)
        reqs = [sess.submit(q, k=k, tier="index") for q in Q[:n_sub]]
        sess.start()
        for r in reqs:
            assert r.wait(120) and r.ok, r.error
        sess.close()
        for i, r in enumerate(reqs):
            solo = engine.topk(Q[i][None], k=k, source="index")
            assert np.array_equal(r.indices, solo.indices[0]), n_sub
            assert np.array_equal(r.distances, solo.distances[0]), n_sub


def test_subseq_session_exact_tiers():
    """The session serves a SubseqEngine too: exact window answers
    bit-identical to direct windowed topk."""
    from repro.subseq import SubseqEngine, WindowView
    n, T, m, stride, k = 6, 360, 120, 6, 3
    rng = np.random.default_rng(9)
    D = season_dataset(n, T, L, 0.7, per_series_strength=True, seed=9)
    rows_ = rng.integers(0, n, size=3)
    offs = rng.integers(0, T - m, size=3)
    Q = np.stack([D[r, o:o + m] for r, o in zip(rows_, offs)])
    view = WindowView(_enc("ssax", m), D, stride=stride, media="ssd")
    view.build_index(leaf_fill=16)
    engine = SubseqEngine(view, verify="host", batch_size=64)
    for tier, use_index in (("index", True), ("linear", False)):
        sess = MatchSession(engine, metrics=MetricsRegistry(),
                            window_s=0.05, max_batch=4)
        reqs = [sess.submit(q, k=k, tier=tier) for q in Q]
        sess.start()
        for r in reqs:
            assert r.wait(120) and r.ok, r.error
        sess.close()
        for i, r in enumerate(reqs):
            solo = engine.topk(Q[i][None], k=k, use_index=use_index)
            assert np.array_equal(r.indices, solo.window_ids[0])
            assert np.array_equal(r.rows, solo.rows[0])
            assert np.array_equal(r.starts, solo.starts[0])
            assert np.array_equal(r.distances, solo.distances[0])


def test_shed_accounting_and_reasons():
    """Every rejected request carries a reason; per-reason counters sum
    exactly to ``serve.rejected``; nothing is silently dropped."""
    T = 240
    Q, D = _data("sax", T=T)
    engine = _host_engine("sax", Q, D, T)
    reg = MetricsRegistry()
    sess = MatchSession(engine, metrics=reg, window_s=0.0,
                        max_batch=2, max_queue=2)
    sheds = []
    sheds.append(sess.submit(np.zeros(7)))               # bad shape
    sheds.append(sess.submit(Q[0], k=0))                 # bad k
    sheds.append(sess.submit(Q[0], tier="nope"))         # bad tier
    sheds.append(sess.submit(Q[0], deadline_s=-1.0))     # dead budget
    bad_vals = Q[0].copy()
    bad_vals[0] = np.nan
    sheds.append(sess.submit(bad_vals))                  # non-finite
    ok1 = sess.submit(Q[0])
    ok2 = sess.submit(Q[1])
    sheds.append(sess.submit(Q[2]))                      # queue full
    for r in sheds:
        assert r.done.is_set() and not r.ok and r.error is not None
        assert r.shed_reason in ("bad_query", "deadline_expired",
                                 "queue_full")
    sess.start()
    sess.close()
    assert ok1.ok and ok2.ok
    sess2 = MatchSession(engine, metrics=reg, window_s=0.0, max_batch=2)
    late = MatchRequest(query=Q[0].astype(np.float32))
    sess2.start()
    sess2.close()
    sess2.queue.submit(late)                             # after shutdown
    assert late.shed_reason == "shutdown"
    c = reg.snapshot()["counters"]
    shed_total = sum(v for name, v in c.items()
                     if name.startswith("serve.shed."))
    assert shed_total == c["serve.rejected"] == len(sheds) + 1
    assert c["serve.requests"] == 2


def test_engine_error_resolves_requests():
    """A dispatch exception sheds the batch with ``engine_error`` —
    callers are never left blocked."""
    def boom(batch):
        raise RuntimeError("kaput")

    reg = MetricsRegistry()
    q = CoalescingQueue(boom, window_s=0.0, max_batch=4, metrics=reg)
    req = MatchRequest(query=np.zeros(4, np.float32))
    q.submit(req)
    q.start()
    assert req.wait(30)
    q.close()
    assert req.shed_reason == "engine_error" and "kaput" in req.error
    c = reg.snapshot()["counters"]
    assert c["serve.shed.engine_error"] == c["serve.rejected"] == 1


def test_deadline_downgrade_serves_approx_with_error_bar():
    """A request whose budget cannot cover the exact tier is downgraded
    (not shed): served from the anytime tier, carrying kth_lb and a
    non-negative error bar."""
    T, k = 240, 4
    Q, D = _data("stsax", T=T)
    engine = _host_engine("stsax", Q, D, T)
    reg = MetricsRegistry()
    sess = MatchSession(engine, metrics=reg, window_s=0.0, max_batch=4)
    sess.calibrate(Q[:1], k=k)
    # pin the exact-tier estimates far beyond the budget: every request
    # is deadline-threatened, but 5s is generous enough that none
    # expires while queued
    sess.planner._est["index"].wall_s = 10.0
    sess.planner._est["linear"].wall_s = 10.0
    sess.start()
    reqs = [sess.submit(q, k=k, deadline_s=5.0) for q in Q]
    for r in reqs:
        assert r.wait(120)
    sess.close()
    exact = engine.topk(Q, k=k, source="index")
    for i, r in enumerate(reqs):
        assert r.ok, r.error
        assert r.tier_served == "approx"
        assert r.plan is not None and r.plan.downgraded
        assert r.kth_lb is not None and r.error_bar is not None
        assert r.error_bar >= 0.0
        # certificate: kth_lb lower-bounds the true k-NN distance
        assert r.kth_lb <= exact.distances[i, -1] + 1e-5
    assert reg.snapshot()["counters"]["serve.downgraded"] == len(Q)


def test_planner_routing_and_learning():
    planner = QueryPlanner(total=10_000, has_index=True)
    d = planner.route(k=1)
    assert d.tier == "index" and d.reason == "cost"
    # learned estimates flip the choice
    class _R:
        raw_accesses = np.array([100.0])
    planner.observe("index", 1, 5.0, _R())
    planner.observe("linear", 1, 0.01, _R())
    assert planner.route(k=1).tier == "linear"
    # deadline downgrade
    d = planner.route(k=1, deadline_left=1e-4)
    assert d.tier == "approx" and d.downgraded
    # forced override wins
    assert planner.route(k=1, tier="linear").reason == "forced"
    # no index -> linear is the only exact tier
    p2 = QueryPlanner(total=100, has_index=False)
    assert p2.route(k=1).tier == "linear"
    assert p2.route(k=1).reason == "only_tier"


def test_planner_seeds_from_registry_history():
    reg = MetricsRegistry()
    for _ in range(8):
        reg.histogram("match.topk_latency_s").observe(0.25)
    planner = QueryPlanner(total=1000, has_index=True)
    planner.seed_from_metrics(reg)
    # adopted the observed p50 (conservative bucket upper bound)
    assert 0.2 <= planner.estimate("index") <= 0.5
    assert 0.2 <= planner.estimate("linear") <= 0.5
