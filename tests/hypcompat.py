"""Hypothesis when installed, seeded deterministic draws otherwise.

The container's tier-1 legs don't ship ``hypothesis``; the established
``pytest.importorskip`` idiom silently drops every property test there.
This shim keeps the property BODIES running everywhere: with hypothesis
installed the real ``given`` / ``settings`` / ``st`` are re-exported
unchanged (shrinking, example database, the works); without it, the
same test runs ``max_examples`` times against seeded ``default_rng``
draws — no shrinking, but the invariant is still exercised on a spread
of cases instead of not at all.

Only the strategy surface these tests use is shimmed: ``st.data()``
draws of ``sampled_from`` / ``integers`` / ``booleans``.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False
    import functools
    import inspect

    import numpy as np

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

    class _StModule:
        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(
                lambda rng: options[int(rng.integers(len(options)))])

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def data():
            return "data"

    st = _StModule()

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy._sample(self._rng)

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(_data_marker):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                # ``settings`` is applied OUTSIDE ``given`` and tags the
                # wrapper, so the count is read off ``run`` at call time
                for i in range(getattr(run, "_max_examples", 10)):
                    fn(*args, _Data(np.random.default_rng(0xC0FFEE + i)),
                       **kwargs)
            # hide the bound ``data`` param from pytest's fixture
            # resolution (parametrize args before it stay visible)
            sig = inspect.signature(fn)
            run.__signature__ = sig.replace(
                parameters=list(sig.parameters.values())[:-1])
            del run.__wrapped__
            return run
        return deco
