"""Corpus-epoch pinning: snapshot-consistent reads under ingest.

The tentpole contract of the epoch refactor: every mutation publishes
an immutable frontier (``repro.store.CorpusEpoch``) as its LAST step,
and a query pinned to epoch *e* answers **bit-identically to a frozen
copy of the store truncated at e** — regardless of how many rows are
appended between pinning and dispatch, with ZERO index rebuilds (as-of
reads are id filters over the live split tree, never copies).

Covered here:

* the pinning property across all four encoders x linear/index source
  x host/device verification, over interleavings of ``append`` and
  pinned ``topk`` (oracle: a fresh engine built over the truncated
  rows);
* zero rebuilds — the index object survives every append by identity;
* subsequence epochs (``WindowView.current_epoch`` clamps to index
  coverage mid-sync);
* the service satellites: planner-state persistence round-trip,
  per-dispatch deadline re-check, replica placement/failover requeue,
  and the threaded ingest-while-serving stress test with exact
  shed accounting.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import MatchEngine, make_technique
from repro.data.synthetic import season_dataset
from repro.obs import MetricsRegistry
from repro.service import (CoalescingQueue, MatchRequest, MatchSession,
                           QueryPlanner)
from repro.store import CorpusEpoch, SymbolicStore, epoch_rows

L = 10
TECHS = ["sax", "ssax", "tsax", "stsax"]


def _enc(name, T):
    kw = {"sax": {}, "ssax": {"r2_season": 0.7},
          "tsax": {"r2_trend": 0.3}, "stsax": {"r2_season": 0.5}}[name]
    return make_technique(name, T=T, W=T // (2 * L), L=L, **kw)


def _mesh1():
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((1,), ("data",))


def _data(n, T, seed=11):
    return season_dataset(n, T, L, 0.7, per_series_strength=True,
                          seed=seed)


def _build(tech, rows, T, verify):
    """One engine over ``rows``, index built, per verification path."""
    if verify == "host":
        store = SymbolicStore.from_rows(_enc(tech, T), rows, media="ssd")
        store.build_index(leaf_fill=16)
        return MatchEngine(_enc(tech, T), store, verify="host",
                           batch_size=32)
    import jax.numpy as jnp
    from repro.core.distributed import make_engine_service
    eng = make_engine_service(_enc(tech, T), jnp.asarray(rows), _mesh1(),
                              batch_size=32, verify="device")
    eng.store.build_index(leaf_fill=16)
    return eng


def _append(engine, rows, verify):
    if verify == "host":
        engine.store.append(rows)
    else:
        engine.ingest(rows)


# ---------------------------------------------------------------------------
# tentpole property: pinned answers == frozen truncated store
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tech", TECHS)
@pytest.mark.parametrize("source", ["linear", "index"])
@pytest.mark.parametrize("verify", ["host", "device"])
def test_epoch_pinned_topk_equals_frozen_store(tech, source, verify):
    T, k, n0 = 240, 3, 40
    X = _data(n0 + 24 + 3, T)
    Q, D = X[:3], X[3:]
    engine = _build(tech, D[:n0], T, verify)
    idx0 = engine.store.index
    src = "index" if source == "index" else None

    # interleave appends with epoch pins; query every pinned epoch
    # AFTER later appends have already landed
    pins = [engine.store.current_epoch()]
    for lo, hi in ((n0, n0 + 7), (n0 + 7, n0 + 24)):  # odd chunk sizes
        _append(engine, D[lo:hi], verify)
        pins.append(engine.store.current_epoch())
    assert [p.n_rows for p in pins] == [n0, n0 + 7, n0 + 24]

    for ep in pins:
        got = engine.topk(Q, k=k, source=src, epoch=ep)
        frozen = _build(tech, D[:ep.n_rows], T, verify)
        want = frozen.topk(Q, k=k, source=src)
        label = (tech, source, verify, ep.n_rows)
        assert np.array_equal(got.indices, want.indices), label
        assert np.array_equal(got.distances, want.distances), label
        # pinned reads never see past the frontier
        assert got.indices.max() < ep.n_rows, label

    # zero index rebuilds: the SAME tree object served every epoch
    assert engine.store.index is idx0
    # and the live (unpinned) answer reflects the full corpus
    live = engine.topk(Q, k=k, source=src)
    want = engine.topk(Q, k=k, source=src,
                       epoch=engine.store.current_epoch())
    assert np.array_equal(live.indices, want.indices)


def test_epoch_rows_coercion_and_publish_order():
    """``epoch_rows`` accepts CorpusEpoch | int | None; mutations
    publish AFTER the index insert (index_n always covers n_rows)."""
    assert epoch_rows(None) is None
    assert epoch_rows(7) == 7
    assert epoch_rows(CorpusEpoch(epoch=3, n_rows=12, index_n=12)) == 12
    T = 240
    store = SymbolicStore.from_rows(_enc("ssax", T), _data(16, T),
                                    media="ssd")
    store.build_index(leaf_fill=8)
    for m in (1, 5):
        store.append(_data(m, T, seed=m))
        ep = store.current_epoch()
        assert ep.n_rows == store.n
        assert ep.index_n == store.n      # index covered before publish
        assert ep.epoch == store.version
    assert store.epoch_ledger[-1] is store.current_epoch()


def test_subseq_epoch_pinning():
    """Window-level epochs: pinned subsequence answers equal a frozen
    view truncated at the pin, for linear and indexed candidates."""
    from repro.subseq import SubseqEngine, WindowView
    n0, T, m, stride, k = 5, 360, 120, 6, 3
    rows = _data(n0 + 4, T, seed=9)
    q = rows[0, 40:40 + m][None]

    def _view(upto):
        v = WindowView(_enc("ssax", m), rows[:upto], stride=stride)
        v.build_index(leaf_fill=16)
        return v

    view = _view(n0)
    eng = SubseqEngine(view, verify="host")
    pins = [view.current_epoch()]
    view.append(rows[n0:n0 + 4])
    pins.append(view.current_epoch())
    for use_index in (False, True):
        for ep, n_src in zip(pins, (n0, n0 + 4)):
            got = eng.topk(q, k=k, use_index=use_index, epoch=ep)
            frozen = SubseqEngine(_view(n_src), verify="host")
            want = frozen.topk(q, k=k, use_index=use_index)
            assert np.array_equal(got.window_ids, want.window_ids)
            assert np.array_equal(got.distances, want.distances)


# ---------------------------------------------------------------------------
# satellite: planner-state persistence round-trip
# ---------------------------------------------------------------------------
def test_planner_state_roundtrip(tmp_path):
    T, k = 240, 3
    X = _data(40 + 4, T)
    Q, D = X[:4], X[4:]
    engine = _build("ssax", D, T, "host")
    sd = str(tmp_path / "svc")
    sess = MatchSession(engine, metrics=MetricsRegistry(),
                        window_s=0.01, max_batch=8, state_dir=sd)
    sess.start()
    for r in sess.serve(Q, k=k):
        assert r.ok, r.error
    before = sess.planner.snapshot()
    sess.close()                         # close persists planner.json
    assert (tmp_path / "svc" / "planner.json").exists()
    assert any(e["n_obs"] > 0 for e in before.values())

    # a fresh session seeds from the persisted estimates
    sess2 = MatchSession(engine, metrics=MetricsRegistry(),
                         window_s=0.01, max_batch=8, state_dir=sd)
    after = sess2.planner.snapshot()
    for tier, e in before.items():
        assert after[tier]["wall_s"] == pytest.approx(e["wall_s"])
        assert after[tier]["n_obs"] == e["n_obs"]
    # live observations are never clobbered by history
    p = QueryPlanner(total=100, has_index=False)
    p.observe("linear", 1, 0.5, type("R", (), {
        "raw_accesses": np.array([3.0])})())
    p.seed_from_snapshot({"linear": {"wall_s": 9.0, "cands": 1,
                                     "n_obs": 50}})
    assert p.estimate("linear") == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# satellite: deadlines re-checked per dispatch, not only at coalesce
# ---------------------------------------------------------------------------
def test_deadline_rechecked_at_dispatch():
    T = 240
    X = _data(32 + 2, T)
    Q, D = X[:2], X[2:]
    engine = _build("ssax", D, T, "host")
    reg = MetricsRegistry()
    sess = MatchSession(engine, metrics=reg, window_s=0.01, max_batch=8)
    # a request whose deadline died between routing and its group's
    # engine call must be shed as deadline_expired, not served late
    req = MatchRequest(query=Q[0], k=1)
    req.t_submit = time.monotonic() - 1.0
    req.t_deadline = time.monotonic() - 0.5      # already expired
    sess._run_group("linear", 1, [req])
    assert req.done.is_set() and not req.ok
    assert req.shed_reason == "deadline_expired"
    snap = reg.snapshot()["counters"]
    assert snap.get("serve.shed.deadline_expired") == 1
    assert snap.get("serve.rejected") == 1
    # a live-deadline request in the same group still gets served
    ok_req = MatchRequest(query=Q[1], k=1)
    ok_req.t_submit = time.monotonic()
    ok_req.t_deadline = time.monotonic() + 60.0
    sess._run_group("linear", 1, [ok_req])
    assert ok_req.ok and ok_req.tier_served == "linear"


# ---------------------------------------------------------------------------
# satellite: replicas — shared store, EWMA placement, failover requeue
# ---------------------------------------------------------------------------
def test_replicated_session_exact_and_failover():
    T, k = 240, 3
    X = _data(48 + 6, T)
    Q, D = X[:6], X[6:]
    engine = _build("ssax", D, T, "host")
    enc = _enc("ssax", T)
    replica = MatchEngine(enc, engine.store, verify="host",
                          batch_size=32)
    with pytest.raises(ValueError):
        MatchSession(engine, replicas=[
            MatchEngine(enc, SymbolicStore.from_rows(enc, D[:8]),
                        verify="host")])
    reg = MetricsRegistry()
    sess = MatchSession(engine, replicas=[replica], metrics=reg,
                        window_s=0.005, max_batch=4)
    sess.start()
    oracle = engine.topk(Q, k=k, source="index")
    reqs = [sess.submit(q, k=k, tier="index") for q in Q]
    for i, r in enumerate(reqs):
        assert r.wait(120) and r.ok, r.error
        assert r.replica in (0, 1)
        assert np.array_equal(r.indices, oracle.indices[i])
    # kill a replica mid-flight: requests are requeued, never shed
    sess.kill_replica(1)
    assert sess.queue.live_replicas() == [0]
    reqs2 = [sess.submit(q, k=k, tier="index") for q in Q]
    for i, r in enumerate(reqs2):
        assert r.wait(120) and r.ok, r.error
        assert r.replica == 0
        assert np.array_equal(r.indices, oracle.indices[i])
    sess.close()
    snap = reg.snapshot()["counters"]
    assert snap.get("serve.rejected", 0) == 0
    assert snap.get("serve.replica_killed") == 1


def test_queue_requeues_batch_on_replica_failure():
    """A replica dispatch failure reroutes the batch's unresolved
    requests to a surviving replica (serve.requeued), shedding only
    when every live replica has failed it."""
    reg = MetricsRegistry()
    served_on = []

    def dispatch(batch, rid):
        if rid == 0:
            raise RuntimeError("replica 0 crashed")
        for r in batch:
            served_on.append(rid)
            r.done.set()

    q = CoalescingQueue(dispatch, n_replicas=2, metrics=reg,
                        window_s=0.0, max_batch=4,
                        place=lambda live, depths: 0 if 0 in live
                        else live[0])
    reqs = [MatchRequest(query=np.zeros(4, np.float32))
            for _ in range(3)]
    for r in reqs:
        q.submit(r)
    q.start()
    for r in reqs:
        assert r.wait(30)
        assert r.error is None, r.error
        assert r.requeues == 1
    q.close()
    assert served_on and all(rid == 1 for rid in served_on)
    snap = reg.snapshot()["counters"]
    assert snap.get("serve.requeued") == 3
    assert snap.get("serve.rejected", 0) == 0


# ---------------------------------------------------------------------------
# satellite: threaded ingest + query stress — no torn reads, exact
# epoch-pinned answers, exact shed accounting
# ---------------------------------------------------------------------------
def test_threaded_ingest_while_serving_stress():
    T, k, n0, n_chunks, chunk = 240, 3, 40, 6, 5
    X = _data(n0 + n_chunks * chunk + 4, T)
    Q, D = X[:4], X[4:]
    engine = _build("ssax", D[:n0], T, "host")
    reg = MetricsRegistry()
    sess = MatchSession(engine, metrics=reg, window_s=0.001,
                        max_batch=16, max_queue=512)
    sess.start()
    stop = threading.Event()
    served = []
    served_lock = threading.Lock()

    def writer():
        for c in range(n_chunks):
            lo = n0 + c * chunk
            engine.store.append(D[lo:lo + chunk])
            time.sleep(0.002)
        stop.set()

    def reader(tier):
        while not stop.is_set():
            reqs = [sess.submit(q, k=k, tier=tier) for q in Q]
            for r in reqs:
                assert r.wait(120)
                if r.ok:
                    with served_lock:
                        served.append(r)

    wt = threading.Thread(target=writer)
    rts = [threading.Thread(target=reader, args=(t,))
           for t in ("index", "linear")]
    wt.start()
    [t.start() for t in rts]
    wt.join()
    [t.join() for t in rts]
    sess.close()

    assert served, "stress loop served nothing"
    # every served answer is tagged with its admission epoch and equals
    # a frozen store truncated there (oracle cached per frontier)
    oracles = {}
    n_final = n0 + n_chunks * chunk
    qkey = {q.tobytes(): i for i, q in enumerate(Q)}
    for r in served:
        assert r.epoch is not None
        n_e = r.epoch.n_rows
        assert n0 <= n_e <= n_final
        src = "index" if r.tier_served == "index" else None
        if (n_e, src) not in oracles:
            frozen = _build("ssax", D[:n_e], T, "host")
            oracles[(n_e, src)] = frozen.topk(Q, k=k, source=src)
        want = oracles[(n_e, src)]
        qi = qkey[r.query.tobytes()]
        assert np.array_equal(r.indices, want.indices[qi]), \
            (n_e, r.tier_served)
        assert np.array_equal(r.distances, want.distances[qi])
    # exact shed accounting survives concurrency
    snap = reg.snapshot()["counters"]
    sheds = sum(v for n, v in snap.items()
                if n.startswith("serve.shed."))
    assert sheds == snap.get("serve.rejected", 0)
