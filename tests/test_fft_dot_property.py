"""Property tests (hypothesis) for the MASS-style FFT sliding dot
product (``repro.kernels.fft_dot``): for arbitrary (m, stride, ragged
T) the rfft/irfft path must agree with the m-step accumulation twin,
the explicit-window oracle (``ref.sliding_dot_ref``) and — through the
rolling-statistics distance expansion — the windowed kernel
(``ops.windowed_euclid``) within the DOCUMENTED tolerance contract
``fft_dot.fft_tolerance(m)``.  The contract is the whole point: the
FFT path is fast but not bitwise, so exact top-k verification never
consumes it — these tests pin down exactly how far it may drift."""

import numpy as np
import pytest

from hypcompat import given, settings, st  # noqa: E402 — shim or real

import jax.numpy as jnp  # noqa: E402

from repro.kernels import ops  # noqa: E402
from repro.kernels.fft_dot import (fft_tolerance, sliding_dot_accum,  # noqa: E402
                                   sliding_dot_fft, windowed_euclid_fft)
from repro.kernels.ref import sliding_dot_ref, windowed_euclid_ref  # noqa: E402


def _case(data):
    """One (x, q, stride) draw: bounded-range data (the contract is
    relative to operand scale; unbounded draws test overflow, not the
    transform), arbitrary stride, ragged T beyond the window grid."""
    m = data.draw(st.sampled_from([8, 24, 33, 64]))
    stride = data.draw(st.integers(1, 5))
    extra = data.draw(st.integers(0, 17))
    n = data.draw(st.integers(1, 4))
    q_n = data.draw(st.integers(1, 3))
    seed = data.draw(st.integers(0, 2**16))
    T = m + 2 * stride + extra
    rng = np.random.default_rng(seed)
    scale = data.draw(st.sampled_from([1.0, 7.0]))
    shift = data.draw(st.sampled_from([0.0, 3.0]))
    x = (scale * rng.normal(size=(n, T)) + shift).astype(np.float32)
    q = rng.normal(size=(q_n, m)).astype(np.float32)
    q = (q - q.mean(1, keepdims=True)) \
        / np.maximum(q.std(1, keepdims=True), 1e-6)
    return x, q, m, stride


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fft_dot_matches_accumulation_and_oracle(data):
    x, q, m, stride = _case(data)
    d_fft = np.asarray(sliding_dot_fft(x, q, stride=stride))
    d_acc = np.asarray(sliding_dot_accum(x, q, stride=stride))
    d_ref = np.asarray(sliding_dot_ref(jnp.asarray(x), jnp.asarray(q),
                                       stride))
    tol = fft_tolerance(m)
    # the dot products themselves scale with m * |x| — widen atol by
    # the operand scale the same way the contract widens with m
    scale = max(1.0, float(np.abs(x).max()))
    tol = dict(rtol=tol["rtol"], atol=tol["atol"] * scale)
    assert d_fft.shape == d_acc.shape == d_ref.shape
    np.testing.assert_allclose(d_fft, d_acc, **tol)
    np.testing.assert_allclose(d_fft, d_ref, **tol)
    # the accumulation twin is near-bitwise to the explicit oracle
    np.testing.assert_allclose(d_acc, d_ref, rtol=1e-5,
                               atol=1e-4 * scale * m)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_fft_distance_matches_kernel_within_contract(data):
    """The full distance expansion: FFT path vs the windowed kernel
    (interpret mode) and the explicit-window reference, within the
    documented ``fft_tolerance(m)`` squared-distance contract."""
    x, q, m, stride = _case(data)
    d_fft = np.asarray(windowed_euclid_fft(x, q, stride=stride))
    d_ref = np.asarray(windowed_euclid_ref(jnp.asarray(x),
                                           jnp.asarray(q), stride))
    d_ker = np.asarray(ops.windowed_euclid(jnp.asarray(x),
                                           jnp.asarray(q),
                                           stride=stride))
    tol = fft_tolerance(m)
    np.testing.assert_allclose(d_fft, d_ref, **tol)
    np.testing.assert_allclose(d_fft, d_ker, **tol)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_ops_method_dispatch(data):
    """``ops.windowed_euclid(method="fft")`` routes to the FFT path and
    agrees with ``method="accum"`` within the contract; ``ops.
    sliding_dot`` dispatches both dot formulations; 1-D queries keep
    the (N, S) shape contract; unknown methods raise."""
    x, q, m, stride = _case(data)
    d_fft = np.asarray(ops.windowed_euclid(jnp.asarray(x),
                                           jnp.asarray(q),
                                           stride=stride, method="fft"))
    d_acc = np.asarray(ops.windowed_euclid(jnp.asarray(x),
                                           jnp.asarray(q),
                                           stride=stride,
                                           method="accum"))
    np.testing.assert_allclose(d_fft, d_acc, **fft_tolerance(m))
    one = np.asarray(ops.windowed_euclid(jnp.asarray(x),
                                         jnp.asarray(q[0]),
                                         stride=stride, method="fft"))
    np.testing.assert_array_equal(one, d_fft[0])
    s_fft = np.asarray(ops.sliding_dot(jnp.asarray(x), jnp.asarray(q),
                                       stride=stride, method="fft"))
    s_acc = np.asarray(ops.sliding_dot(jnp.asarray(x), jnp.asarray(q),
                                       stride=stride, method="accum"))
    scale = max(1.0, float(np.abs(x).max()))
    np.testing.assert_allclose(
        s_fft, s_acc, rtol=fft_tolerance(m)["rtol"],
        atol=fft_tolerance(m)["atol"] * scale)


def test_unknown_method_raises():
    x = jnp.zeros((2, 50), jnp.float32)
    q = jnp.zeros((1, 10), jnp.float32)
    with pytest.raises(ValueError, match="method"):
        ops.windowed_euclid(x, q, method="nope")
    with pytest.raises(ValueError, match="method"):
        ops.sliding_dot(x, q, method="nope")


def test_zero_variance_windows_follow_kernel_convention():
    """Constant windows z-normalize to zero: the FFT expansion must
    reproduce the kernel's d2 = sum(q^2) convention exactly there."""
    x = np.ones((2, 60), np.float32)
    x[1, 30:] = np.linspace(0, 1, 30)
    q = np.random.default_rng(0).normal(size=(2, 12)).astype(np.float32)
    q = (q - q.mean(1, keepdims=True)) / q.std(1, keepdims=True)
    d_fft = np.asarray(windowed_euclid_fft(x, q, stride=1))
    d_ref = np.asarray(windowed_euclid_ref(jnp.asarray(x),
                                           jnp.asarray(q), 1))
    q_ss = np.sum(q * q, axis=1)
    # row 0 of x is constant everywhere: every window collapses to q_ss
    np.testing.assert_allclose(
        d_fft[:, 0, :],
        np.broadcast_to(q_ss[:, None], d_fft[:, 0, :].shape),
        rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(d_fft, d_ref, **fft_tolerance(12))
