"""MoE routing substrate: capacity accounting, gate renormalization,
load-balance signal, and dispatch == dense-equivalent compute."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.moe import capacity, moe_mlp
from repro.models.transformer import init_params
from repro.sharding.specs import ShardingRules


def _moe_params(key, d, E, f, shared=0, d_ff=None):
    ks = jax.random.split(key, 8)
    p = {
        "router": jax.random.normal(ks[0], (d, E)) * 0.02,
        "w_gate": jax.random.normal(ks[1], (E, d, f)) / np.sqrt(d),
        "w_up": jax.random.normal(ks[2], (E, d, f)) / np.sqrt(d),
        "w_down": jax.random.normal(ks[3], (E, f, d)) / np.sqrt(f),
    }
    if shared:
        df = d_ff or f
        p["shared_w_gate"] = jax.random.normal(ks[4], (d, df)) / np.sqrt(d)
        p["shared_w_up"] = jax.random.normal(ks[5], (d, df)) / np.sqrt(d)
        p["shared_w_down"] = jax.random.normal(ks[6], (df, d)) / np.sqrt(df)
    return p


class Cfg:
    n_experts = 8
    moe_top_k = 2
    capacity_factor = 8.0       # generous default; tests override
    n_shared_experts = 0
    router_aux_weight = 0.01


def test_capacity_formula():
    assert capacity(1024, 8, 2, 1.25) == 320
    assert capacity(8, 8, 1, 1.0) >= 8        # floor


def test_moe_matches_dense_reference():
    """With infinite capacity, scatter-dispatch MoE must equal the direct
    per-token top-k expert sum."""
    cfg = Cfg()
    cfg.capacity_factor = 100.0
    key = jax.random.PRNGKey(0)
    d, E, f = 16, 8, 32
    p = _moe_params(key, d, E, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
    y = moe_mlp(p, x, cfg, None)

    # reference: explicit per-token loop
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, 2)
    vals = vals / vals.sum(-1, keepdims=True)
    y_ref = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(2):
            e = int(idx[t, j])
            h = np.asarray(jax.nn.silu(xt[t] @ p["w_gate"][e])
                           * (xt[t] @ p["w_up"][e]))
            y_ref[t] += float(vals[t, j]) * (h @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d), y_ref,
                               rtol=2e-2, atol=2e-3)


def test_capacity_drops_tokens():
    cfg = Cfg()
    cfg.capacity_factor = 0.25           # force drops
    key = jax.random.PRNGKey(0)
    p = _moe_params(key, 16, 8, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 16))
    aux = {}
    y = moe_mlp(p, x, cfg, None, aux=aux)
    assert float(aux["dropped_frac"]) > 0.0
    assert np.all(np.isfinite(np.asarray(y)))


def test_load_balance_loss_prefers_uniform():
    cfg = Cfg()
    key = jax.random.PRNGKey(2)
    p = _moe_params(key, 16, 8, 32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 16))
    aux = {}
    moe_mlp(p, x, cfg, None, aux=aux)
    lb_uniformish = float(aux["load_balance"])

    # force a collapsed router: all tokens to expert 0
    p_bad = dict(p, router=jnp.zeros((16, 8)).at[:, 0].set(5.0))
    aux_bad = {}
    moe_mlp(p_bad, x, cfg, None, aux=aux_bad)
    assert float(aux_bad["load_balance"]) > lb_uniformish


def test_shared_expert_path():
    cfg = Cfg()
    cfg.n_shared_experts = 1
    p = _moe_params(jax.random.PRNGKey(4), 16, 8, 32, shared=1)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 16))
    y = moe_mlp(p, x, cfg, None)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))


def test_grouped_dispatch_matches_ungrouped():
    """Group-local dispatch (the §Perf olmoe lever) must match the
    ungrouped path when capacity is generous."""
    import dataclasses
    from repro.sharding.specs import ShardingRules

    class GCfg(Cfg):
        capacity_factor = 64.0

    cfg = GCfg()
    p = _moe_params(jax.random.PRNGKey(7), 16, 8, 32)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 16, 16))

    class FakeRules:
        mesh = None
        moe_groups = 4
        def pspec(self, dims, shape):
            from jax.sharding import PartitionSpec
            return PartitionSpec()

    y0 = moe_mlp(p, x, cfg, None)
    y1 = moe_mlp(p, x, cfg, FakeRules())
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-2, atol=2e-3)
