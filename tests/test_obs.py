"""Observability subsystem unit tests (``repro.obs``): metric
primitives, deterministic snapshot merges, trace span nesting /
accumulation, the rendered EXPLAIN report, and the trace checker that
gates CI (missing spans, device-path transfer invariants)."""

import json
import math

import numpy as np
import pytest

from repro.obs import (LATENCY_BUCKETS, Histogram, MetricsRegistry, Trace,
                       check_trace, maybe_span, merge_snapshots,
                       render_trace)


# -- metrics ---------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.5)
    reg.gauge("g").set(0.25)
    reg.gauge("g").set(0.75)            # last-wins
    h = reg.histogram("h")
    for v in (1e-5, 1e-5, 2.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.5
    assert snap["gauges"]["g"] == 0.75
    assert snap["histograms"]["h"]["count"] == 3
    assert sum(snap["histograms"]["h"]["counts"]) == 3
    # the accessor returns the SAME object every time (no reset on read)
    assert reg.histogram("h") is h
    json.dumps(snap)                     # plain JSON, embeddable as-is


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_registry_reset_is_suite_boundary():
    reg = MetricsRegistry()
    reg.counter("c").inc(7)
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}
    assert reg.counter("c").value == 0.0


def test_histogram_quantile_fixed_bounds():
    h = Histogram()
    assert h.bounds == LATENCY_BUCKETS
    assert math.isnan(h.quantile(0.5))
    for _ in range(99):
        h.observe(1e-4)
    h.observe(1e6)                       # overflow slot
    # p50 lands in the 1e-4 bucket: the reported bound covers the value
    assert 1e-4 <= h.quantile(0.5) < 2e-4
    assert h.quantile(1.0) == float("inf")


def test_histogram_merge_requires_identical_bounds():
    with pytest.raises(ValueError):
        Histogram((1.0, 2.0)).merge(Histogram((1.0, 3.0)))


def test_merge_snapshots_deterministic():
    """Recording split across two registries then merged must equal one
    registry recording everything — the property fixed bucket bounds
    buy (multi-host / multi-suite aggregation with no re-binning)."""
    obs_a = [1e-5, 3e-3, 0.2]
    obs_b = [4e-6, 0.2, 50.0, 1e9]
    split = []
    for obs in (obs_a, obs_b):
        reg = MetricsRegistry()
        reg.counter("n").inc(len(obs))
        for v in obs:
            reg.histogram("lat").observe(v)
        split.append(reg.snapshot())
    merged = merge_snapshots(split[0], split[1])

    ref = MetricsRegistry()
    ref.counter("n").inc(len(obs_a) + len(obs_b))
    for v in obs_a + obs_b:
        ref.histogram("lat").observe(v)
    assert merged == ref.snapshot()
    # merge is associative with empty/None
    assert merge_snapshots(merged, None) == merged


# -- traces ----------------------------------------------------------------

def test_trace_span_nesting_paths():
    tr = Trace("t")
    with tr.span("order"):
        with tr.span("seed"):
            pass
    with tr.span("verify"):
        pass
    assert tr.span_names() == ["order", "order/seed", "verify"]
    assert tr.has_span("order") and tr.has_span("verify")
    assert tr.has_span("seed")           # suffix match on the nested path
    assert not tr.has_span("nope")
    assert tr.span_seconds("order") >= tr.span_seconds("seed") >= 0.0


def test_trace_add_accumulates_and_copies():
    tr = Trace("t")
    live = np.array([1, 2], np.int64)
    tr.add("examined", live)
    live[:] = 99                         # engine buffer mutates afterwards
    tr.add("examined", np.array([10, 20], np.int64))
    np.testing.assert_array_equal(tr.get("examined"), [11, 22])
    tr.add("rows", 5)
    tr.add("rows", 7)
    assert tr.get("rows") == 12


def test_trace_to_dict_is_json():
    tr = Trace("t", engine="match")
    with tr.span("verify", k=np.int64(4)):
        pass
    tr.add("generated", np.array([3, 4]))
    tr.record_round(phase="scan", active=2,
                    kth=np.array([1.5, 2.5], np.float32))
    d = tr.to_dict()
    json.dumps(d)
    assert d["meta"]["generated"] == [3, 4]
    assert d["rounds"][0]["kth"] == [1.5, 2.5]


def test_maybe_span_off_is_shared_noop():
    a = maybe_span(None, "order")
    b = maybe_span(None, "verify")
    assert a is b                        # one shared nullcontext object
    with a as sp:
        assert sp is None


# -- explain / checker -----------------------------------------------------

def _fake_trace(**overrides):
    tr = Trace("match.topk")
    tr.meta.update(engine="match", k=4, q_n=2, total=100,
                   source="linear", verify="host")
    with tr.span("order"):
        pass
    with tr.span("verify"):
        pass
    tr.add("generated", np.array([100, 100], np.int64))
    tr.add("examined", np.array([20, 30], np.int64))
    tr.add("verified", np.array([20, 30], np.int64))
    tr.set("pruning_power", np.array([0.8, 0.7]))
    tr.add("rows_fetched", 50)
    tr.add("seeks", 2)
    tr.add("modeled_io_s", 0.01)
    tr.record_round(phase="scan", active=2, examined=50,
                    kth=np.array([1.0, 2.0]), wall_s=0.001)
    tr.meta.update(overrides)
    return tr


def test_render_trace_report_fields():
    out = render_trace(_fake_trace())
    assert "match.topk" in out and "k=4" in out
    assert "order" in out and "verify" in out
    assert "pruning" in out and "50 rows in 2 seeks" in out


def test_check_trace_passes_on_complete_trace():
    assert check_trace(_fake_trace()) == []


def test_check_trace_flags_missing_spans_and_rounds():
    empty = Trace("match.topk")
    problems = check_trace(empty)
    joined = " ".join(problems)
    assert problems
    assert "order" in joined and "verify" in joined


def test_check_trace_device_invariants():
    # device path without transfer accounting at all -> flagged
    assert check_trace(_fake_trace(), device=True)
    ok = _fake_trace(host_order_bytes=0, rows_to_host=0)
    assert check_trace(ok, device=True) == []
    bad = _fake_trace(host_order_bytes=4096, rows_to_host=3)
    problems = check_trace(bad, device=True)
    assert len(problems) == 2
