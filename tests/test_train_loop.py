"""Fault-tolerance behaviours of the training loop: checkpoint/restart on
injected device loss, straggler policy, crash-only restart semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.train.loop import (
    FailureInjector, SimulatedDeviceLoss, StragglerPolicy, train_loop)


def _toy_setup():
    """A 1-parameter 'model' whose loss history is easy to reason about."""
    def init_state():
        return {"params": {"w": jnp.asarray(4.0)},
                "step": jnp.asarray(0, jnp.int32)}

    @jax.jit
    def step(state, batch):
        w = state["params"]["w"]
        loss = (w - batch["target"]) ** 2
        w = w - 0.1 * 2 * (w - batch["target"])
        return ({"params": {"w": w}, "step": state["step"] + 1},
                {"loss": loss})

    def batch_fn(i):
        return {"target": jnp.asarray(1.0)}

    return init_state, step, batch_fn


def test_loop_runs_to_completion(tmp_path):
    init, step, batch = _toy_setup()
    state, hist = train_loop(init_state_fn=init, train_step=step,
                             batch_fn=batch, n_steps=30,
                             log_every=0)
    assert len(hist["loss"]) == 30
    assert hist["loss"][-1] < hist["loss"][0]


def test_failure_triggers_restore_and_replay(tmp_path):
    init, step, batch = _toy_setup()
    ck = Checkpointer(str(tmp_path), every=5)
    inj = FailureInjector(fail_at=(7, 13))
    state, hist = train_loop(init_state_fn=init, train_step=step,
                             batch_fn=batch, n_steps=20,
                             checkpointer=ck, failure_injector=inj,
                             log_every=0)
    assert hist["restarts"] == 2
    # loop replays from the checkpoint: more recorded steps than n_steps
    assert len(hist["loss"]) > 20
    # and still converges
    assert hist["loss"][-1] < 1e-2


def test_restart_budget_enforced(tmp_path):
    init, step, batch = _toy_setup()

    class AlwaysFail(FailureInjector):
        def check(self, step):
            raise SimulatedDeviceLoss("boom")

    with pytest.raises(RuntimeError, match="restart budget"):
        train_loop(init_state_fn=init, train_step=step, batch_fn=batch,
                   n_steps=5, failure_injector=AlwaysFail(),
                   checkpointer=Checkpointer(str(tmp_path), every=100),
                   max_restarts=2, log_every=0)


def test_straggler_policy_detects_slow_steps():
    pol = StragglerPolicy(slack=2.0, patience=2, window=16)
    fired = []
    for i in range(20):
        dt = 1.0
        if i in (12, 13):
            dt = 10.0
        if pol.observe(i, dt):
            fired.append(i)
    assert fired == [13]
    assert len(pol.events) == 2


def test_straggler_mitigation_checkpoints(tmp_path):
    init, step, batch = _toy_setup()
    ck = Checkpointer(str(tmp_path), every=10_000)   # cadence never fires

    class FakeStraggler(StragglerPolicy):
        def observe(self, step, dt):
            return step == 9

    state, hist = train_loop(init_state_fn=init, train_step=step,
                             batch_fn=batch, n_steps=12,
                             checkpointer=ck, straggler=FakeStraggler(),
                             log_every=0)
    assert hist["straggler_events"] == 1
    assert hist["checkpoints"] >= 2     # mitigation save + final save
