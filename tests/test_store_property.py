"""Property tests (hypothesis) for the streaming symbolic store: append
under ARBITRARY chunk splits must be bit-identical to one-shot encoding,
and save -> open -> topk must reproduce in-memory results exactly."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import SSAX, MatchEngine  # noqa: E402
from repro.data.synthetic import season_dataset  # noqa: E402
from repro.store import SymbolicStore, rep_leaves  # noqa: E402

N, N_Q, T, L = 160, 3, 480, 10
ENC = SSAX(T=T, W=24, L=L, A_seas=32, A_res=32, r2_season=0.7)
_X = season_dataset(n=N + N_Q, T=T, L=L, strength=0.7, seed=29)
Q, D = _X[:N_Q], _X[N_Q:]
_ONESHOT = [np.asarray(l)
            for l in rep_leaves(ENC.encode(jnp.asarray(D, jnp.float32)))]


@st.composite
def chunk_splits(draw):
    """An arbitrary ordered partition of [0, N) into append chunks."""
    cuts = draw(st.lists(st.integers(min_value=1, max_value=N - 1),
                         unique=True, max_size=12))
    return [0] + sorted(cuts) + [N]


@settings(max_examples=20, deadline=None)
@given(chunk_splits())
def test_append_any_chunking_bit_identical(splits):
    store = SymbolicStore(ENC)
    for lo, hi in zip(splits[:-1], splits[1:]):
        store.append(D[lo:hi])
    assert store.n == N
    for got, want in zip(rep_leaves(store.rep_view()), _ONESHOT):
        np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(store.data, D.astype(np.float32))


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=N - 1))
def test_save_open_topk_reproduces_exactly(k, cut):
    import tempfile
    store = SymbolicStore(ENC)
    store.append(D[:cut])
    store.append(D[cut:])
    with tempfile.TemporaryDirectory() as tmp:
        store.save(tmp)
        reopened = SymbolicStore.open(tmp)
    r0 = MatchEngine(ENC, store, verify="numpy").topk(Q, k=k)
    r1 = MatchEngine(ENC, reopened, verify="numpy").topk(Q, k=k)
    np.testing.assert_array_equal(r0.indices, r1.indices)
    np.testing.assert_array_equal(r0.distances, r1.distances)
    np.testing.assert_array_equal(r0.raw_accesses, r1.raw_accesses)
