"""End-to-end behaviour tests for the paper's system: the full
encode -> symbolic sweep -> pruned exact match pipeline reproduces the
paper's qualitative results on each dataset family."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SAX, SSAX, TSAX, exact_match, approximate_match)
from repro.core.matching import (
    RawStore, pairwise_euclidean, tightness_of_lower_bound)
from repro.data.synthetic import season_dataset, trend_dataset
from repro.kernels import ops


@pytest.fixture(scope="module")
def strong_season():
    X = season_dataset(n=500, T=960, L=10, strength=0.9, seed=42)
    return X[:8], X[8:]


def test_e2e_ssax_beats_sax_on_strong_season(strong_season):
    """The paper's headline: with a strong season, sSAX gives a much
    tighter bound, much higher pruning, and far fewer raw accesses than
    SAX at the SAME representation budget."""
    Q, D = strong_season
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))

    sax = SAX(T=960, W=48, A=64)                       # 288 bits
    ss = SSAX(T=960, W=48, L=10, A_seas=9, A_res=32,   # ~272 bits
              r2_season=0.9)
    d_sax = np.asarray(sax.pairwise_distance(
        sax.encode(jnp.asarray(Q)), sax.encode(jnp.asarray(D))))
    d_ss = np.asarray(ss.pairwise_distance(
        ss.encode(jnp.asarray(Q)), ss.encode(jnp.asarray(D))))

    tlb_sax = tightness_of_lower_bound(d_sax, ed)
    tlb_ss = tightness_of_lower_bound(d_ss, ed)
    assert tlb_ss > tlb_sax + 0.2, (tlb_ss, tlb_sax)

    acc_sax = acc_ss = 0
    for qi in range(len(Q)):
        r_sax = exact_match(Q[qi], d_sax[qi], RawStore.hdd(D))
        r_ss = exact_match(Q[qi], d_ss[qi], RawStore.hdd(D))
        assert r_sax.index == r_ss.index == int(np.argmin(ed[qi]))
        acc_sax += r_sax.raw_accesses
        acc_ss += r_ss.raw_accesses
    assert acc_ss < acc_sax


def test_e2e_kernel_path_equals_class_path(strong_season):
    """The Pallas sweep and the reference class produce the same matches."""
    Q, D = strong_season
    ss = SSAX(T=960, W=48, L=10, A_seas=16, A_res=32, r2_season=0.9)
    s_syms, r_syms = ss.encode(jnp.asarray(D))
    sq, rq = ss.encode(jnp.asarray(Q))
    scale = 960 / (48 * 10)
    for qi in range(4):
        tabs = ops.make_ssax_query_tables(sq[qi], rq[qi],
                                          ss.b_seas, ss.b_res)
        d_kernel = np.sqrt(np.asarray(
            ops.ssax_dist(s_syms, r_syms, *tabs)) * scale)
        d_class = np.asarray(ss.pairwise_distance(
            (sq[qi:qi+1], rq[qi:qi+1]), (s_syms, r_syms)))[0]
        np.testing.assert_allclose(d_kernel, d_class, rtol=1e-4, atol=1e-4)


def test_e2e_tsax_on_trend_data():
    X = trend_dataset(n=300, T=960, strength=0.7, seed=9)
    Q, D = X[:6], X[6:]
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    ts = TSAX(T=960, W=40, A_tr=128, A_res=128, r2_trend=0.7)
    d_ts = np.asarray(ts.pairwise_distance(
        ts.encode(jnp.asarray(Q)), ts.encode(jnp.asarray(D))))
    assert np.all(d_ts <= ed + 1e-2)
    for qi in range(len(Q)):
        r = exact_match(Q[qi], d_ts[qi], RawStore.ssd(D))
        assert r.index == int(np.argmin(ed[qi]))


def test_e2e_approximate_matching_accuracy(strong_season):
    """Approximate accuracy (paper §5.4): sSAX's approximate match is
    closer to the exact match than SAX's on strong seasons."""
    Q, D = strong_season
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    sax = SAX(T=960, W=48, A=64)
    ss = SSAX(T=960, W=48, L=10, A_seas=9, A_res=32, r2_season=0.9)
    d_sax = np.asarray(sax.pairwise_distance(
        sax.encode(jnp.asarray(Q)), sax.encode(jnp.asarray(D))))
    d_ss = np.asarray(ss.pairwise_distance(
        ss.encode(jnp.asarray(Q)), ss.encode(jnp.asarray(D))))

    def aa(dists):
        vals = []
        for qi in range(len(Q)):
            r = approximate_match(Q[qi], dists[qi], RawStore.ssd(D))
            vals.append(ed[qi].min() / max(r.distance, 1e-12))
        return float(np.mean(vals))

    assert aa(d_ss) >= aa(d_sax) - 1e-6
