"""Entry-point smoke tests: every launcher runs end-to-end in a
subprocess (reduced scale) — the CLIs are part of the deployable surface."""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(args, extra_env=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if extra_env:
        env.update(extra_env)
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_train_launcher(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "qwen3-0.6b",
                "--steps", "12", "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"])
    assert "final loss" in out
    assert os.path.exists(os.path.join(str(tmp_path), "LATEST"))


def test_serve_launcher():
    out = _run(["-m", "repro.launch.serve", "--requests", "2",
                "--max-new", "4", "--d-model", "64"])
    assert "tok/s" in out


def test_match_launcher():
    out = _run(["-m", "repro.launch.match", "--n", "4000", "--queries",
                "2", "--technique", "ssax", "--T", "480", "--k", "8"],
               extra_env={"XLA_FLAGS":
                          "--xla_force_host_platform_device_count=4"})
    # engine-backed exact top-k is provably identical to brute force
    assert "exact k=1: 2/2" in out
    assert "exact k=8: 2/2" in out
    assert "approx k=8: 1-NN hit" in out


def test_dryrun_launcher_single_cell(tmp_path):
    out = _run(["-m", "repro.launch.dryrun", "--arch", "smollm-135m",
                "--shape", "decode_32k", "--multi-pod", "single",
                "--out", str(tmp_path / "d.json")])
    assert "1 ok" in out
