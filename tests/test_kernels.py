"""Per-kernel allclose sweeps: every Pallas kernel (interpret mode on CPU)
against its pure-jnp oracle across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.euclid import euclid_pallas
from repro.kernels.paa import paa_pallas
from repro.kernels.sax_dist import sax_dist_pallas
from repro.kernels.ssax_dist import ssax_dist_pallas
from repro.kernels.windowed_euclid import windowed_euclid_pallas

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("N", [256, 512, 1024])
@pytest.mark.parametrize("W,A", [(8, 4), (48, 64), (96, 256), (32, 1024)])
def test_sax_dist_shapes(N, W, A):
    # (32, 1024) exercises the paper's 4 MB LUT limit: the (W, A) table is
    # 128 KB here but the full A^2 cell table upstream is 4 MB — the VMEM
    # budget case from DESIGN.md §3.
    syms = jnp.asarray(RNG.integers(0, A, size=(N, W)), jnp.int32)
    table = jnp.asarray(RNG.normal(size=(W, A)) ** 2, jnp.float32)
    out = sax_dist_pallas(syms, table, interpret=True)
    want = ref.sax_dist_ref(syms, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N", [128, 384])
@pytest.mark.parametrize("L,W,As,Ar", [(8, 16, 16, 8), (10, 48, 64, 32)])
def test_ssax_dist_shapes(N, L, W, As, Ar):
    seas = jnp.asarray(RNG.integers(0, As, size=(N, L)), jnp.int32)
    res = jnp.asarray(RNG.integers(0, Ar, size=(N, W)), jnp.int32)
    t1 = jnp.asarray(RNG.normal(size=(L, As)), jnp.float32)
    t2 = jnp.asarray(RNG.normal(size=(L, As)), jnp.float32)
    u1 = jnp.asarray(RNG.normal(size=(W, Ar)), jnp.float32)
    u2 = jnp.asarray(RNG.normal(size=(W, Ar)), jnp.float32)
    out = ssax_dist_pallas(seas, res, t1, t2, u1, u2, interpret=True)
    want = ref.ssax_dist_ref(seas, res, t1, t2, u1, u2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,T,W", [(128, 512, 32), (256, 960, 48),
                                   (128, 1920, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paa_shapes_dtypes(N, T, W, dtype):
    x = jnp.asarray(RNG.normal(size=(N, T)), dtype)
    out = paa_pallas(x, W, interpret=True)
    want = ref.paa_ref(x.astype(jnp.float32), W)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("N,T", [(128, 512), (256, 2048), (128, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_euclid_shapes_dtypes(N, T, dtype):
    x = jnp.asarray(RNG.normal(size=(N, T)), dtype)
    q = jnp.asarray(RNG.normal(size=(T,)), dtype)
    out = euclid_pallas(x, q, interpret=True)
    want = ref.euclid_ref(x.astype(jnp.float32), q.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("Q,N,T", [(2, 37, 480), (5, 300, 1000),
                                   (13, 130, 3000), (9, 1, 17),
                                   (31, 257, 129)])
def test_euclid_query_tiling_ragged(Q, N, T):
    """BLK_Q tiling: ragged query batches (not block multiples) must pad
    internally and match the per-query reference."""
    x = jnp.asarray(RNG.normal(size=(N, T)), jnp.float32)
    q = jnp.asarray(RNG.normal(size=(Q, T)), jnp.float32)
    out = np.asarray(euclid_pallas(x, q, interpret=True))
    want = np.stack([np.asarray(ref.euclid_ref(x, qi)) for qi in q])
    assert out.shape == (Q, N)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Q,N,T,m,stride", [
    (1, 4, 256, 64, 1),      # single query
    (3, 5, 300, 32, 3),      # stride > 1, ragged tail
    (2, 9, 1111, 64, 7),     # ragged everything
    (2, 2, 100, 100, 1),     # exactly one window per row
    (4, 24, 960, 120, 5),    # more rows than BLK_N
])
def test_windowed_euclid_shapes(Q, N, T, m, stride):
    x = jnp.asarray(RNG.normal(size=(N, T)), jnp.float32)
    q = RNG.normal(size=(Q, m)).astype(np.float32)
    q = (q - q.mean(-1, keepdims=True)) / q.std(-1, keepdims=True)
    out = np.asarray(windowed_euclid_pallas(
        x, jnp.asarray(q), stride=stride, interpret=True))
    want = np.asarray(ref.windowed_euclid_ref(x, jnp.asarray(q), stride))
    S = (T - m) // stride + 1
    assert out.shape == (Q, N, S)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-3)


def test_windowed_euclid_constant_window_matches_znorm_semantics():
    """A zero-variance window z-normalizes to the zero vector (the
    znormalize eps guard); its distance must be sum(q^2), not inf."""
    x = jnp.ones((1, 64), jnp.float32)
    q = RNG.normal(size=(1, 16)).astype(np.float32)
    q = (q - q.mean()) / q.std()
    out = np.asarray(windowed_euclid_pallas(x, jnp.asarray(q),
                                            interpret=True))
    np.testing.assert_allclose(out, np.full_like(out, (q * q).sum()),
                               rtol=1e-4)


def test_ops_wrappers_pad_ragged():
    """Public ops pad ragged candidate counts transparently."""
    N, W, A = 300, 16, 32          # not a multiple of any block
    syms = jnp.asarray(RNG.integers(0, A, size=(N, W)), jnp.int32)
    table = jnp.asarray(RNG.normal(size=(W, A)) ** 2, jnp.float32)
    out = ops.sax_dist(syms, table)
    want = ref.sax_dist_ref(syms, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    x = jnp.asarray(RNG.normal(size=(300, 960)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.paa_segments(x, 48)),
        np.asarray(ref.paa_ref(x, 48)), rtol=1e-5, atol=1e-5)
    q = jnp.asarray(RNG.normal(size=(960,)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.euclid_batch(x, q)),
        np.asarray(ref.euclid_ref(x, q)), rtol=1e-4, atol=1e-4)
    qm = jnp.asarray(RNG.normal(size=(5, 960)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.euclid_batch(x, qm)),
        np.stack([np.asarray(ref.euclid_ref(x, qi)) for qi in qm]),
        rtol=1e-4, atol=1e-4)
    qw = jnp.asarray(RNG.normal(size=(2, 96)), jnp.float32)
    qw = (qw - qw.mean(-1, keepdims=True)) / qw.std(-1, keepdims=True)
    np.testing.assert_allclose(
        np.asarray(ops.windowed_euclid(x[:7], qw, stride=5)),
        np.asarray(ref.windowed_euclid_ref(x[:7], qw, 5)),
        rtol=1e-3, atol=1e-3)


def test_kernel_matches_encoder_distance():
    """End-to-end: kernel sweep == SSAX class distances on real data."""
    from repro.core import SSAX
    from repro.data.synthetic import season_dataset
    X = season_dataset(n=256, T=480, L=10, strength=0.7, seed=3)
    ss = SSAX(T=480, W=24, L=10, A_seas=64, A_res=32, r2_season=0.7)
    s_syms, r_syms = ss.encode(jnp.asarray(X))
    tabs = ops.make_ssax_query_tables(s_syms[0], r_syms[0],
                                      ss.b_seas, ss.b_res)
    d2 = np.asarray(ops.ssax_dist(s_syms, r_syms, *tabs))
    d_class = np.asarray(ss.pairwise_distance(
        (s_syms[:1], r_syms[:1]), (s_syms, r_syms)))[0]
    scale = 480 / (24 * 10)
    np.testing.assert_allclose(np.sqrt(d2 * scale), d_class,
                               rtol=1e-4, atol=1e-4)
