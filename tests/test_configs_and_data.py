"""Config registry exactness (assigned dims), representation-size
accounting (Table 1 / Table 4), and synthetic-data strength control."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, SHAPES, get_config, shape_for
from repro.core import SAX, SSAX, TSAX, season_strength, trend_strength
from repro.core.onedsax import OneDSAX
from repro.data.synthetic import season_dataset, trend_dataset
from repro.data.datasets import economy_like, metering_like

ASSIGNED = {
    "smollm-135m": (30, 576, 9, 3, 1536, 49152),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    "rwkv6-7b": (32, 4096, 32, 32, 14336, 65536),
}


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_assigned_dims_exact(arch):
    cfg = get_config(arch)
    L, d, H, kv, ff, V = ASSIGNED[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == kv
    assert (cfg.d_ff_e if cfg.n_experts else cfg.d_ff) == ff
    assert cfg.vocab_size == V


def test_moe_configs():
    j = get_config("jamba-1.5-large-398b")
    assert j.n_experts == 16 and j.moe_top_k == 2
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.n_experts == 16 and l4.moe_top_k == 1
    ol = get_config("olmoe-1b-7b")
    assert ol.n_experts == 64 and ol.moe_top_k == 8


def test_jamba_interleave_is_1_to_7():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [s.kind for s in cfg.pattern]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum(s.moe for s in cfg.pattern) == 4      # every other layer


def test_gemma3_local_global_5_to_1():
    cfg = get_config("gemma3-12b")
    wins = [s.window for s in cfg.pattern]
    assert wins.count(None) == 1 and len(wins) == 6


def test_long500k_skip_policy():
    runs = [a for a in ARCHITECTURES
            if shape_for(get_config(a), "long_500k") is not None]
    assert sorted(runs) == ["gemma3-12b", "jamba-1.5-large-398b", "rwkv6-7b"]


def test_param_counts_match_published():
    expect = {"smollm-135m": 0.135e9, "phi4-mini-3.8b": 3.8e9,
              "qwen3-0.6b": 0.6e9, "gemma3-12b": 11.8e9,
              "jamba-1.5-large-398b": 398e9,
              "llama4-scout-17b-a16e": 109e9, "olmoe-1b-7b": 6.9e9,
              "rwkv6-7b": 7.6e9}
    for a, want in expect.items():
        tot, _ = get_config(a).param_counts()
        assert abs(tot - want) / want < 0.06, (a, tot, want)
    _, act = get_config("llama4-scout-17b-a16e").param_counts()
    assert abs(act - 17e9) / 17e9 < 0.06


# -- representation sizes (paper Table 1 / Table 4) -----------------------

def test_representation_sizes_equal_sax_budget():
    """Paper Table 4 synthetic row: all techniques at 320 bits."""
    assert float(SAX(T=960, W=32, A=1024).bits) == 320
    assert float(SAX(T=960, W=40, A=256).bits) == 320
    s = SSAX(T=960, W=24, L=10, A_seas=256, A_res=1024, r2_season=0.5)
    # L*ld(A_seas) + W*ld(A_res) = 10*8 + 24*10 = 320
    assert float(s.bits) == 320
    t = TSAX(T=960, W=32, A_tr=32, A_res=2 ** ((320 - 5) // 32),
             r2_trend=0.5)
    assert float(t.bits) <= 320
    o = OneDSAX(T=300, W=10, A_a=2 ** 5, A_s=8)
    assert float(o.bits) == 10 * (5 + 3)


def test_ssax_requires_wl_divides_t():
    with pytest.raises(AssertionError):
        SSAX(T=960, W=7, L=10, A_seas=4, A_res=4)


# -- synthetic data ---------------------------------------------------------

@pytest.mark.parametrize("target", [0.1, 0.5, 0.9])
def test_season_strength_control(target):
    X = season_dataset(n=64, T=480, L=10, strength=target, seed=1)
    s = np.asarray(season_strength(jnp.asarray(X), 10))
    assert abs(s.mean() - target) < 0.005          # paper's +-0.5pp
    assert np.allclose(X.mean(-1), 0, atol=1e-4)
    assert np.allclose(X.std(-1), 1, atol=1e-3)


@pytest.mark.parametrize("target", [0.2, 0.7])
def test_trend_strength_control(target):
    X = trend_dataset(n=64, T=480, strength=target, seed=2)
    s = np.asarray(trend_strength(jnp.asarray(X)))
    assert abs(s.mean() - target) < 0.005


def test_metering_like_daily_strength():
    X = metering_like(n=256, days=20)
    s = np.asarray(season_strength(jnp.asarray(X), 48))
    assert 0.1 < s.mean() < 0.3           # paper: 18.3% mean daily season


def test_economy_like_is_trendy():
    X = economy_like(n=256)
    s = np.asarray(trend_strength(jnp.asarray(X)))
    assert s.mean() > 0.3
