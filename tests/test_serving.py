"""Serving engine: batched continuous decoding must reproduce the naive
one-request-at-a-time greedy loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.transformer import RunConfig
from repro.serving.engine import Request, ServeEngine

RC = RunConfig(q_chunk=8, kv_chunk=8, mamba_chunk=8, rwkv_chunk=8,
               loss_chunk=8)


def _naive_greedy(model, params, prompt, n_new):
    import repro.models.model as MM
    padded = dataclasses.replace(model.rc, prefill_pad=64)
    model = MM.Model(cfg=model.cfg, rules=model.rules, rc=padded)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    out = [int(jnp.argmax(logits[0]))]
    decode = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        logits, cache = decode(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-7b"])
def test_engine_matches_naive_greedy_single(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              compute_dtype="float32")
    model = build_model(cfg, rc=RC)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    want = _naive_greedy(model, params, prompt, 6)

    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    done = eng.run([req])
    assert done[0].out_tokens == want


def test_engine_rejects_prompt_exceeding_max_len():
    """A prompt longer than max_len used to splice nothing into the slot
    cache and decode garbage; it must now be rejected with an error."""
    cfg = dataclasses.replace(reduced(get_config("smollm-135m")),
                              compute_dtype="float32")
    model = build_model(cfg, rc=RC)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    too_long = Request(rid=0,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           48).astype(np.int32),
                       max_new_tokens=4)
    ok = Request(rid=1,
                 prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=4)
    assert not eng.admit(too_long)
    assert too_long.done and too_long.error is not None
    assert too_long.out_tokens == []
    # run() must drain a mixed batch without hanging on the rejected one
    reject2 = Request(rid=2,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          40).astype(np.int32),
                      max_new_tokens=4)
    done = eng.run([reject2, ok])
    assert len(done) == 2
    assert reject2.error is not None and reject2.out_tokens == []
    assert ok.error is None and len(ok.out_tokens) == 4


def test_engine_serves_batch_of_requests():
    cfg = dataclasses.replace(reduced(get_config("smollm-135m")),
                              compute_dtype="float32")
    model = build_model(cfg, rc=RC)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(4, 10)).astype(np.int32),
                    max_new_tokens=5)
            for i in range(5)]
    eng = ServeEngine(model, params, n_slots=2, max_len=64)
    done = eng.run(list(reqs))
    assert len(done) == 5
    for r in reqs:
        assert len(r.out_tokens) == 5
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)
