"""Index subsystem (repro/index): exactness vs the linear sweep for all
four encoders (whole-series and windowed), incremental == bulk tree
structure, snapshot round-trips, sharded snapshot layout."""

import numpy as np
import pytest

from repro.core import MatchEngine, make_technique
from repro.data.synthetic import season_dataset
from repro.store import SymbolicStore
from repro.subseq import SubseqEngine, WindowView

N, N_Q, T, W, L = 260, 4, 240, 12, 10
TECHS = ("sax", "ssax", "tsax", "stsax")


@pytest.fixture(scope="module")
def season():
    X = season_dataset(n=N + N_Q, T=T, L=L, strength=0.7, seed=41)
    return X[:N_Q], X[N_Q:]


def _enc(tech):
    return make_technique(tech, T=T, W=W, L=L)


@pytest.mark.parametrize("tech", TECHS)
@pytest.mark.parametrize("k", [1, 6])
def test_indexed_topk_bitwise_equals_linear(season, tech, k):
    """MatchEngine.topk from the tree candidate source is bit-identical
    to the linear lower-bound sweep — same verification path, same
    (distance, index) tie-break (acceptance criterion)."""
    Q, D = season
    enc = _enc(tech)
    store = SymbolicStore.from_rows(enc, D)
    store.build_index(leaf_fill=16, max_bits=5)
    engine = MatchEngine(enc, store, verify="numpy")
    lin = engine.topk(Q, k=k)
    idx = engine.topk(Q, k=k, source="index")
    np.testing.assert_array_equal(idx.indices, lin.indices)
    np.testing.assert_array_equal(idx.distances, lin.distances)


def test_indexed_examines_fewer_candidates_ssax(season):
    """On strong-season data the season-aware tree must examine fewer
    candidates than the linear pruned scan (sSAX)."""
    Q, D = season
    store = SymbolicStore.from_rows(_enc("ssax"), D)
    store.build_index(leaf_fill=16, max_bits=5)
    engine = MatchEngine(_enc("ssax"), store, verify="numpy")
    lin = engine.topk(Q, k=4)
    idx = engine.topk(Q, k=4, source="index")
    assert idx.raw_accesses.mean() < lin.raw_accesses.mean()


@pytest.mark.parametrize("tech", TECHS)
def test_incremental_insert_equals_bulk_rebuild(season, tech):
    """The satellite-fix regression: appends maintain the index through
    the SAME code path as bulk construction — the incremental tree and a
    bulk-rebuilt tree agree on leaf membership exactly, and answer
    queries bit-identically (no silent re-split drift)."""
    Q, D = season
    enc = _enc(tech)
    inc = SymbolicStore(enc)
    inc.append(D[:60])
    inc.build_index(leaf_fill=16, max_bits=5)
    for lo, hi in ((60, 61), (61, 150), (150, 151), (151, N)):
        inc.append(D[lo:hi])
    assert inc.index is not None and inc.index.n == inc.n == N
    bulk = SymbolicStore.from_rows(enc, D)
    bulk.build_index(leaf_fill=16, max_bits=5)
    assert inc.index.n_nodes == bulk.index.n_nodes
    assert inc.index.tree.leaf_membership() == \
        bulk.index.tree.leaf_membership()
    r_inc = MatchEngine(enc, inc, verify="numpy").topk(Q, k=5,
                                                      source="index")
    r_blk = MatchEngine(enc, bulk, verify="numpy").topk(Q, k=5,
                                                       source="index")
    np.testing.assert_array_equal(r_inc.indices, r_blk.indices)
    np.testing.assert_array_equal(r_inc.distances, r_blk.distances)


@pytest.mark.parametrize("tech", TECHS)
def test_windowed_indexed_equals_linear_and_scan(tech):
    """SubseqEngine over an indexed WindowView: bit-identical to the
    linear window sweep and the brute-force scan, with stride > 1 and
    ragged T (T - m not divisible by the stride), including after an
    append with no rebuild (acceptance criterion)."""
    T_long, m, stride = 250, 120, 3
    D = season_dataset(10, T_long, L, strength=0.7,
                       per_series_strength=True, seed=43)
    rng = np.random.default_rng(2)
    Q = np.stack([D[2, 40:40 + m], D[7, 100:100 + m]]) \
        + 0.05 * rng.normal(size=(2, m)).astype(np.float32)
    enc = make_technique(tech, T=m, W=m // L, L=L)
    view = WindowView(enc, D, stride=stride, media="ssd")
    eng = SubseqEngine(view, verify="numpy")
    lin = eng.topk(Q, k=5, use_index=False)
    view.build_index(leaf_fill=12, max_bits=5)
    idx = eng.topk(Q, k=5)
    np.testing.assert_array_equal(idx.window_ids, lin.window_ids)
    np.testing.assert_array_equal(idx.distances, lin.distances)
    scan = eng.scan_topk(Q, k=5, use_kernel=False)
    np.testing.assert_array_equal(idx.window_ids, scan.window_ids)
    # append: the index follows incrementally, answers stay identical
    view.append(season_dataset(2, T_long, L, 0.7, seed=44))
    assert view.index.n == view.n
    lin2 = eng.topk(Q, k=5, use_index=False)
    idx2 = eng.topk(Q, k=5)
    np.testing.assert_array_equal(idx2.window_ids, lin2.window_ids)
    np.testing.assert_array_equal(idx2.distances, lin2.distances)
    # suppression routes through the index too, still exact
    s_lin = eng.topk(Q, k=3, exclusion=m // 2, use_index=False)
    s_idx = eng.topk(Q, k=3, exclusion=m // 2)
    np.testing.assert_array_equal(s_idx.window_ids, s_lin.window_ids)


def test_windowed_index_requires_sync_coverage():
    D = season_dataset(4, 250, L, 0.7, seed=45)
    enc = _enc("ssax")
    view = WindowView(enc, D[:3], stride=2)
    view.build_index(leaf_fill=8)
    eng = SubseqEngine(view, verify="numpy")
    with pytest.raises(ValueError, match="no index"):
        SubseqEngine(WindowView(enc, D, stride=2),
                     verify="numpy").topk(D[0, :T], k=1, use_index=True)
    # out-of-band source growth is caught (WindowView.append syncs, so
    # only manual misuse can desynchronize)
    view.index.tree.insert(np.zeros((1, view.index.adapter.D), np.float32))
    with pytest.raises(ValueError, match="covers"):
        eng.topk(D[0, :enc.T], k=1)


def test_snapshot_roundtrip_incremental_index(tmp_path, season):
    """open(save(store)) restores an incrementally-built tree that
    answers queries identically and KEEPS accepting inserts (acceptance
    criterion)."""
    Q, D = season
    enc = _enc("stsax")
    store = SymbolicStore(enc)
    store.append(D[:90])
    store.build_index(leaf_fill=16, max_bits=5)
    store.append(D[90:])
    store.save(str(tmp_path))
    reopened = SymbolicStore.open(str(tmp_path))
    assert reopened.index is not None
    assert reopened.index.n_nodes == store.index.n_nodes
    r0 = MatchEngine(enc, store, verify="numpy").topk(Q, k=3,
                                                     source="index")
    r1 = MatchEngine(enc, reopened, verify="numpy").topk(Q, k=3,
                                                        source="index")
    np.testing.assert_array_equal(r0.indices, r1.indices)
    np.testing.assert_array_equal(r0.distances, r1.distances)
    # the reopened tree continues inserting exactly like the original
    store.append(Q)
    reopened.append(Q)
    assert reopened.index.tree.leaf_membership() == \
        store.index.tree.leaf_membership()


def test_sharded_snapshot_two_host_roundtrip(tmp_path, season):
    """save(n_hosts=2) writes per-host shard_hNNN.npz files (ckpt.py
    conventions) that reassemble into the identical store + index."""
    import os
    Q, D = season
    enc = _enc("ssax")
    store = SymbolicStore.from_rows(enc, D, media="hdd")
    store.build_index(leaf_fill=16, max_bits=5)
    path = store.save(str(tmp_path), n_hosts=2)
    shards = sorted(f for f in os.listdir(path) if f.startswith("shard_"))
    assert shards == ["shard_h000.npz", "shard_h001.npz"]
    with np.load(os.path.join(path, "shard_h000.npz")) as z0, \
            np.load(os.path.join(path, "shard_h001.npz")) as z1:
        assert z0["raw"].shape[0] + z1["raw"].shape[0] == N
        assert "bp_b_seas" in z0.files       # host 0 owns the globals
        assert "bp_b_seas" not in z1.files
    reopened = SymbolicStore.open(str(tmp_path))
    np.testing.assert_array_equal(reopened.data, store.data)
    assert reopened.seek_s == store.seek_s
    r0 = MatchEngine(enc, store, verify="numpy").topk(Q, k=5,
                                                     source="index")
    r1 = MatchEngine(enc, reopened, verify="numpy").topk(Q, k=5,
                                                        source="index")
    np.testing.assert_array_equal(r0.indices, r1.indices)
    np.testing.assert_array_equal(r0.distances, r1.distances)


def test_build_index_rejects_rep_only_store():
    enc = _enc("ssax")
    store = SymbolicStore(enc, store_raw=False)
    store.append(np.zeros((4, T), np.float32))
    with pytest.raises(TypeError, match="store_raw"):
        store.build_index()


def test_adapter_for_rejects_unknown_encoder():
    from repro.core import OneDSAX
    from repro.index import adapter_for
    with pytest.raises(TypeError, match="adapter"):
        adapter_for(OneDSAX(T=T, W=W, A_a=16, A_s=16))
