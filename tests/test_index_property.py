"""Property tests (hypothesis) for the index subsystem: for EVERY
encoder and ANY append chunking, incremental ``insert`` must yield a
tree whose top-k is bit-identical to a bulk-rebuilt tree — the index
analogue of test_store_property.py's chunked-encode property.  The
structural claim is stronger and also checked: leaf membership itself is
chunking-invariant (the split dimension is a function of node bit-state
only, so bulk build and incremental maintenance are the same code
path)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import MatchEngine, make_technique  # noqa: E402
from repro.data.synthetic import season_dataset  # noqa: E402
from repro.store import SymbolicStore  # noqa: E402

N, N_Q, T, W, L = 120, 3, 240, 12, 10
_X = season_dataset(n=N + N_Q, T=T, L=L, strength=0.7, seed=47)
Q, D = _X[:N_Q], _X[N_Q:]
ENCODERS = {tech: make_technique(tech, T=T, W=W, L=L)
            for tech in ("sax", "ssax", "tsax", "stsax")}


@st.composite
def chunk_splits(draw):
    """An arbitrary ordered partition of [0, N) into append chunks."""
    cuts = draw(st.lists(st.integers(min_value=1, max_value=N - 1),
                         unique=True, max_size=10))
    return [0] + sorted(cuts) + [N]


@pytest.mark.parametrize("tech", sorted(ENCODERS))
@settings(max_examples=6, deadline=None)
@given(chunk_splits(), st.integers(min_value=1, max_value=6))
def test_incremental_insert_topk_bit_identical_to_bulk(tech, splits, k):
    enc = ENCODERS[tech]
    inc = SymbolicStore(enc)
    inc.append(D[:splits[1]])
    inc.build_index(leaf_fill=12, max_bits=4)    # index from chunk 1 on
    for lo, hi in zip(splits[1:-1], splits[2:]):
        inc.append(D[lo:hi])
    assert inc.index is not None and inc.index.n == N

    bulk = SymbolicStore.from_rows(enc, D)
    bulk.build_index(leaf_fill=12, max_bits=4)

    # structural invariance: same split history, same leaf membership
    assert inc.index.n_nodes == bulk.index.n_nodes
    assert inc.index.tree.leaf_membership() == \
        bulk.index.tree.leaf_membership()

    # behavioral invariance: bit-identical top-k (and both == linear)
    r_inc = MatchEngine(enc, inc, verify="numpy").topk(Q, k=k,
                                                      source="index")
    r_blk = MatchEngine(enc, bulk, verify="numpy").topk(Q, k=k,
                                                       source="index")
    r_lin = MatchEngine(enc, bulk, verify="numpy").topk(Q, k=k)
    np.testing.assert_array_equal(r_inc.indices, r_blk.indices)
    np.testing.assert_array_equal(r_inc.distances, r_blk.distances)
    np.testing.assert_array_equal(r_inc.indices, r_lin.indices)
    np.testing.assert_array_equal(r_inc.distances, r_lin.distances)


@pytest.mark.parametrize("tech", sorted(ENCODERS))
@settings(max_examples=4, deadline=None)
@given(chunk_splits(), st.sampled_from([1, 2, 4]))
def test_grouped_bulk_build_equals_incremental(tech, splits, n_groups):
    """The sharded build path — root-subtree grouped routing
    (``SplitTree.insert_grouped`` keyed by ``insert.root_addresses``) —
    must equal BOTH the single-host bulk build and the incremental
    chunked insert on node count and leaf membership, for every
    encoder, arbitrary chunkings and 1/2/4 mocked hosts."""
    from repro.index import SeriesIndex

    enc = ENCODERS[tech]
    inc = SymbolicStore(enc)
    inc.append(D[:splits[1]])
    inc.build_index(leaf_fill=12, max_bits=4)   # incremental reference
    for lo, hi in zip(splits[1:-1], splits[2:]):
        inc.append(D[lo:hi])
    ref = inc.index.tree

    # grouped bulk build through the store-facing entry point
    bulk = SymbolicStore.from_rows(enc, D)
    bulk.build_index(leaf_fill=12, max_bits=4, n_shards=n_groups)
    assert bulk.index.n_nodes == inc.index.n_nodes
    assert bulk.index.tree.leaf_membership() == ref.leaf_membership()

    # grouped insert under the SAME arbitrary chunking: every chunk is
    # partitioned by root address and routed group-by-group
    grp = SeriesIndex(enc, leaf_fill=12, max_bits=4)
    for lo, hi in zip(splits[:-1], splits[1:]):
        grp.tree.insert_grouped(grp.adapter.features(D[lo:hi]), n_groups)
    assert grp.tree.n_nodes == ref.n_nodes
    assert grp.tree.leaf_membership() == ref.leaf_membership()
