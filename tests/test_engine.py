"""Unified k-NN engine (core/engine.py): batched multi-query exact top-k
must be bit-identical to a numpy brute-force scan for every technique,
the kernel verification path must agree, pruning must actually prune,
and the ragged Pallas euclid kernel must match numpy."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SAX, SSAX, STSAX, TSAX, MatchEngine
from repro.core.engine import (
    merge_topk_device, merge_topk_numpy, topk_verify, verify_candidates)
from repro.core.matching import RawStore
from repro.data.synthetic import _znorm_np, season_dataset, trend_dataset

N_Q = 6


def _bruteforce_topk(Q, D, k):
    """Stable numpy scan in the dataset's native dtype; ties broken by
    lower index."""
    idx, dist = [], []
    for q in Q:
        d = np.sqrt(np.sum((D - q[None]) ** 2, axis=-1))
        o = np.argsort(d, kind="stable")[:k]
        idx.append(o)
        dist.append(d[o])
    return np.asarray(idx, np.int64), np.asarray(dist)


def _season_trend(n, T=480, L=8, seed=0):
    rng = np.random.default_rng(seed)
    mask = rng.normal(size=(n, L)).astype(np.float32)
    seas = np.tile(mask - mask.mean(1, keepdims=True), (1, T // L))
    t = np.arange(T, dtype=np.float32)
    tr = np.sign(rng.normal(size=(n, 1))).astype(np.float32) * \
        ((t - t.mean()) / t.std())[None]
    x = (np.sqrt(0.4) * _znorm_np(seas) + np.sqrt(0.3) * tr
         + np.sqrt(0.3) * rng.normal(size=(n, T)).astype(np.float32))
    return _znorm_np(x)


@pytest.fixture(scope="module")
def datasets():
    Xs = season_dataset(n=400 + N_Q, T=480, L=10, strength=0.7, seed=11)
    Xt = trend_dataset(400 + N_Q, 480, 0.6, seed=7)
    Xst = _season_trend(400 + N_Q, T=480, L=8, seed=3)
    return {
        "sax": (SAX(T=480, W=24, A=64), Xs),
        "ssax": (SSAX(T=480, W=24, L=10, A_seas=64, A_res=64,
                      r2_season=0.7), Xs),
        "tsax": (TSAX(T=480, W=24, A_tr=64, A_res=64, r2_trend=0.6), Xt),
        "stsax": (STSAX(T=480, W=20, L=8, A_tr=16, A_seas=16, A_res=32,
                        r2_trend=0.3, r2_season=0.4), Xst),
    }


@pytest.mark.parametrize("tech", ["sax", "ssax", "tsax", "stsax"])
@pytest.mark.parametrize("k", [1, 5, 32])
def test_engine_topk_bitwise_equals_bruteforce(datasets, tech, k):
    enc, X = datasets[tech]
    Q, D = X[:N_Q], X[N_Q:]
    engine = MatchEngine(enc, RawStore.ssd(D), verify="numpy")
    res = engine.topk(Q, k=k)
    want_i, want_d = _bruteforce_topk(Q, D, k)
    np.testing.assert_array_equal(res.indices, want_i)
    np.testing.assert_array_equal(res.distances, want_d)
    assert res.raw_accesses.shape == (N_Q,)
    assert (res.raw_accesses <= D.shape[0]).all()


def test_engine_prunes_ssax_strength07(datasets):
    enc, X = datasets["ssax"]
    Q, D = X[:N_Q], X[N_Q:]
    for k in (1, 32):
        engine = MatchEngine(enc, RawStore.ssd(D), verify="numpy")
        res = engine.topk(Q, k=k)
        assert (res.raw_accesses < D.shape[0]).all(), k
        np.testing.assert_allclose(res.pruned_fraction,
                                   1.0 - res.raw_accesses / D.shape[0])


def test_engine_kernel_path_matches_numpy_path(datasets):
    enc, X = datasets["ssax"]
    Q, D = X[:N_Q], X[N_Q:]
    res_k = MatchEngine(enc, RawStore.ssd(D), verify="kernel").topk(Q, k=5)
    want_i, want_d = _bruteforce_topk(Q, D, 5)
    np.testing.assert_array_equal(res_k.indices, want_i)
    np.testing.assert_allclose(res_k.distances, want_d,
                               rtol=1e-5, atol=1e-5)


def test_engine_batch_size_invariance(datasets):
    enc, X = datasets["ssax"]
    Q, D = X[:N_Q], X[N_Q:]
    engine = MatchEngine(enc, RawStore.ssd(D), verify="numpy")
    r8 = engine.topk(Q, k=5, batch_size=8)
    r256 = engine.topk(Q, k=5, batch_size=256)
    np.testing.assert_array_equal(r8.indices, r256.indices)
    # batched verification can only over-fetch by < one batch per query
    assert (r256.raw_accesses <= r8.raw_accesses + 256).all()


def test_engine_approximate_topk(datasets):
    enc, X = datasets["ssax"]
    Q, D = X[:N_Q], X[N_Q:]
    engine = MatchEngine(enc, RawStore.ssd(D), verify="numpy")
    res = engine.topk(Q, k=5, exact=False, expand=4)
    # verifies exactly the candidate frontier, one batched fetch
    assert (res.raw_accesses == 20).all()
    assert res.store_fetches == 1
    # candidates are ranked by true distance and are genuine rows
    d_all = np.stack([np.sqrt(np.sum((D - q[None]) ** 2, -1)) for q in Q])
    for qi in range(N_Q):
        np.testing.assert_array_equal(
            res.distances[qi], np.sort(res.distances[qi]))
        np.testing.assert_allclose(
            d_all[qi][res.indices[qi]], res.distances[qi], rtol=1e-6)


def test_verify_candidates_padding_and_k():
    rng = np.random.default_rng(5)
    D = rng.normal(size=(64, 96)).astype(np.float32)
    Q = rng.normal(size=(2, 96)).astype(np.float32)
    cand = np.asarray([[3, 9, 17, -1, -1], [0, 1, 2, 3, 4]])
    store = RawStore.ssd(D)
    res = verify_candidates(Q, cand, store, k=3)
    assert res.indices.shape == (2, 3)
    assert (res.indices[0] >= 0).all() and res.raw_accesses[0] == 3
    d0 = np.sqrt(np.sum((D[[3, 9, 17]] - Q[0][None]) ** 2, -1))
    np.testing.assert_array_equal(res.indices[0],
                                  np.asarray([3, 9, 17])[np.argsort(d0)])


def test_merge_device_equals_numpy_no_ties():
    rng = np.random.default_rng(9)
    d = rng.uniform(1.0, 2.0, size=(4, 40)).astype(np.float32)
    i = np.argsort(rng.normal(size=(4, 40)), axis=1).astype(np.int64)
    nd, ni = merge_topk_numpy(d, i, 7)
    dd, di = merge_topk_device(d, i, 7)
    np.testing.assert_allclose(nd, dd, rtol=1e-6)
    np.testing.assert_array_equal(ni, di)


def test_merge_device_tie_break_by_dataset_index():
    """Regression: the device merge must share the host tie-break contract
    — equal distances resolve to the smaller dataset index regardless of
    candidate position, and -1 padding sorts last."""
    d = np.asarray([[1.0, 0.5, 0.5, 0.5, np.inf],
                    [2.0, 2.0, 2.0, 2.0, 2.0]], np.float32)
    i = np.asarray([[4, 9, 2, 7, -1],
                    [30, 10, 50, 20, 40]], np.int64)
    nd, ni = merge_topk_numpy(d, i, 3)
    dd, di = merge_topk_device(d, i, 3)
    np.testing.assert_array_equal(ni, [[2, 7, 9], [10, 20, 30]])
    np.testing.assert_array_equal(di, ni)
    np.testing.assert_allclose(dd, nd, rtol=1e-6)


def test_engine_device_merge_bitwise_on_duplicated_rows(datasets):
    """With duplicated dataset rows (exact distance ties) the
    device-merge engine must still match the stable brute force."""
    enc, X = datasets["ssax"]
    Q, D = X[:N_Q], X[N_Q:N_Q + 150]
    D = np.concatenate([D, D[:40]])          # 40 exact duplicates
    res = MatchEngine(enc, RawStore.ssd(D), verify="numpy",
                      device_merge=True).topk(Q, k=8)
    want_i, want_d = _bruteforce_topk(Q, D, 8)
    np.testing.assert_array_equal(res.indices, want_i)
    np.testing.assert_allclose(res.distances, want_d, rtol=1e-6)


def test_topk_verify_seeded_never_reverifies_inf_columns():
    """Regression: with a seeded frontier, +inf-bound columns (seeded or
    other-query candidates in a sparse sweep) must never be verified —
    over-fetching one used to duplicate a seeded member in the merge."""
    rng = np.random.default_rng(7)
    D = rng.normal(size=(30, 16)).astype(np.float32)
    q = rng.normal(size=(16,)).astype(np.float32)
    d_true = np.sqrt(np.sum((D - q[None]) ** 2, -1))
    seed_ids = np.argsort(d_true, kind="stable")[:2]
    init_d = d_true[seed_ids][None]
    rd = np.where(np.isin(np.arange(30), seed_ids), np.inf,
                  d_true * 0.5)[None]
    store = RawStore.ssd(D)
    res = topk_verify(q[None], rd, store, k=4, batch_size=64,
                      init_d=init_d, init_i=seed_ids[None])
    want = np.argsort(d_true, kind="stable")[:4]
    np.testing.assert_array_equal(res.indices[0], want)
    assert len(np.unique(res.indices[0])) == 4


def test_topk_verify_single_query_1d_inputs():
    rng = np.random.default_rng(2)
    D = rng.normal(size=(50, 64)).astype(np.float32)
    q = rng.normal(size=(64,)).astype(np.float32)
    d_true = np.sqrt(np.sum((D - q[None]) ** 2, -1))
    store = RawStore.ssd(D)
    res = topk_verify(q, d_true * 0.5, store, k=3)   # any valid lower bound
    np.testing.assert_array_equal(
        res.indices[0], np.argsort(d_true, kind="stable")[:3])


def test_euclid_pallas_ragged_matches_numpy():
    """Regression: ragged (non-block-multiple) verification batches used
    to hard-assert; now they pad internally and match numpy."""
    from repro.kernels.euclid import euclid_pallas
    rng = np.random.default_rng(21)
    for (n, t) in [(37, 480), (300, 1000), (130, 3000), (1, 17)]:
        x = rng.normal(size=(n, t)).astype(np.float32)
        q = rng.normal(size=(t,)).astype(np.float32)
        out = np.asarray(euclid_pallas(jnp.asarray(x), jnp.asarray(q),
                                       interpret=True))
        want = np.sum((x - q[None]) ** 2, -1)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
        # multi-query form
        qm = rng.normal(size=(3, t)).astype(np.float32)
        outm = np.asarray(euclid_pallas(jnp.asarray(x), jnp.asarray(qm),
                                        interpret=True))
        wantm = np.stack([np.sum((x - qi[None]) ** 2, -1) for qi in qm])
        assert outm.shape == (3, n)
        np.testing.assert_allclose(outm, wantm, rtol=1e-4, atol=1e-4)
