"""sSAX iSAX-style index (core/index.py): exactness, pruning, and the
nested-interval bound invariant."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSAX
from repro.core.index import SSaxIndex, ndtri_np
from repro.core.matching import RawStore, pairwise_euclidean
from repro.data.synthetic import season_dataset


def test_ndtri_matches_jax():
    from jax.scipy.special import ndtri
    qs = np.linspace(0.001, 0.999, 97)
    np.testing.assert_allclose(ndtri_np(qs),
                               np.asarray(ndtri(jnp.asarray(qs))),
                               atol=2e-5)


@pytest.fixture(scope="module")
def built_index():
    X = season_dataset(n=3000, T=480, L=8, strength=0.7, seed=33)
    Q, D = X[:16], X[16:]
    ss = SSAX(T=480, W=20, L=8, A_seas=64, A_res=64, r2_season=0.7)
    sigma, resbar = ss.features(jnp.asarray(D))
    idx = SSaxIndex(np.asarray(sigma), np.asarray(resbar), T=480,
                    sd_seas=ss.sd_seas, sd_res=ss.sd_res,
                    max_bits=6, leaf_capacity=32)
    return Q, D, ss, idx


def test_index_structure(built_index):
    Q, D, ss, idx = built_index
    assert idx.n_nodes > 1
    # every id appears exactly once across the leaves
    seen = []

    def walk(node):
        if node.is_leaf:
            seen.extend(node.ids.tolist())
        else:
            for c in node.children.values():
                walk(c)

    walk(idx.root)
    assert sorted(seen) == list(range(D.shape[0] - 0))


def test_index_exact_and_pruning(built_index):
    Q, D, ss, idx = built_index
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    sigma_q, resbar_q = ss.features(jnp.asarray(Q))
    total_pruned = []
    for qi in range(len(Q)):
        store = RawStore.ssd(D)
        res = idx.query(np.asarray(sigma_q[qi]), np.asarray(resbar_q[qi]),
                        store, Q[qi])
        assert res.index == int(np.argmin(ed[qi])), qi
        assert np.isclose(res.distance, ed[qi].min(), rtol=1e-5)
        total_pruned.append(res.pruned_fraction)
    # the index must actually prune on strong-season data
    assert np.mean(total_pruned) > 0.5


@pytest.mark.parametrize("k", [1, 8])
def test_index_batched_topk_bitwise_equals_bruteforce(built_index, k):
    """The engine-routed index path (ROADMAP "Engine over the index"):
    batched multi-query top-k with the engine's tie-break contract."""
    Q, D, ss, idx = built_index
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    sq, rq = ss.features(jnp.asarray(Q))
    store = RawStore.ssd(D)
    res = idx.topk(np.asarray(sq), np.asarray(rq), store, Q, k=k)
    ed64 = np.stack([np.sqrt(np.sum((D - q[None]) ** 2, -1)) for q in Q])
    want = np.argsort(ed64, axis=1, kind="stable")[:, :k]
    np.testing.assert_array_equal(res.indices, want)
    np.testing.assert_array_equal(
        res.distances, np.take_along_axis(ed64, want, axis=1))
    # indexed search must not degenerate into a full scan
    assert (res.raw_accesses < D.shape[0]).all()
    assert res.store_fetches == store.fetches


def test_index_topk_matches_engine_accounting(built_index):
    """Index top-k and linear-engine top-k agree bitwise (both route
    through topk_verify with the same verifier + merge)."""
    from repro.core import MatchEngine
    Q, D, ss, idx = built_index
    sq, rq = ss.features(jnp.asarray(Q))
    res_idx = idx.topk(np.asarray(sq), np.asarray(rq), RawStore.ssd(D), Q,
                       k=5)
    res_lin = MatchEngine(ss, RawStore.ssd(D), verify="numpy").topk(Q, k=5)
    np.testing.assert_array_equal(res_idx.indices, res_lin.indices)
    np.testing.assert_array_equal(res_idx.distances, res_lin.distances)


def test_index_beats_linear_scan_accesses(built_index):
    """Index accesses <= linear pruned-scan accesses on average (it visits
    leaves in bound order instead of sorting all N distances)."""
    from repro.core import exact_match
    Q, D, ss, idx = built_index
    rep_q = ss.encode(jnp.asarray(Q))
    rep_d = ss.encode(jnp.asarray(D))
    dists = np.asarray(ss.pairwise_distance(rep_q, rep_d))
    sigma_q, resbar_q = ss.features(jnp.asarray(Q))
    acc_idx = acc_lin = 0
    for qi in range(len(Q)):
        store = RawStore.ssd(D)
        acc_idx += idx.query(np.asarray(sigma_q[qi]),
                             np.asarray(resbar_q[qi]), store,
                             Q[qi]).raw_accesses
        acc_lin += exact_match(Q[qi], dists[qi],
                               RawStore.ssd(D)).raw_accesses
    # both exact; the index should be in the same ballpark or better
    assert acc_idx <= acc_lin * 3
