"""Unit tests for the SAX substrate: breakpoints, PAA, MINDIST tables,
and the paper's worked example (Fig. 1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.breakpoints import (
    discretize, gaussian_breakpoints, lower_bounds, uniform_breakpoints,
    upper_bounds)
from repro.core.paa import paa, paa_distance
from repro.core.sax import SAX, cell_table


def test_gaussian_breakpoints_equiprobable():
    bp = np.asarray(gaussian_breakpoints(4, 1.0))
    # A=4 quartile breakpoints of N(0,1): -0.6745, 0, 0.6745
    assert np.allclose(bp, [-0.6745, 0.0, 0.6745], atol=1e-3)


def test_gaussian_breakpoints_scaled():
    bp1 = np.asarray(gaussian_breakpoints(8, 1.0))
    bp2 = np.asarray(gaussian_breakpoints(8, 0.5))
    assert np.allclose(bp2, 0.5 * bp1, atol=1e-6)


def test_uniform_breakpoints():
    bp = np.asarray(uniform_breakpoints(4, -1.0, 1.0))
    assert np.allclose(bp, [-0.5, 0.0, 0.5])


def test_discretize_bins():
    bp = jnp.asarray([-0.5, 0.5])
    x = jnp.asarray([-1.0, 0.0, 1.0, -0.5, 0.5])
    syms = np.asarray(discretize(x, bp))
    # [b_{a-1}, b_a) intervals, 0-based symbols
    assert list(syms) == [0, 1, 2, 1, 2]


def test_paa_means():
    x = jnp.arange(12.0)
    assert np.allclose(np.asarray(paa(x, 3)), [1.5, 5.5, 9.5])


def test_paa_distance_lower_bounds_euclid():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(50, 64)).astype(np.float32)
    b = rng.normal(size=(50, 64)).astype(np.float32)
    d_ed = np.sqrt(np.sum((a - b) ** 2, -1))
    d_paa = np.asarray(paa_distance(paa(jnp.asarray(a), 8),
                                    paa(jnp.asarray(b), 8), 64))
    assert np.all(d_paa <= d_ed + 1e-4)


def test_cell_table_properties():
    bp = gaussian_breakpoints(8, 1.0)
    tab = np.asarray(cell_table(bp))
    assert tab.shape == (8, 8)
    assert np.allclose(tab, tab.T)
    # adjacent symbols have distance 0 (Eq. 11)
    for i in range(8):
        for j in range(8):
            if abs(i - j) <= 1:
                assert tab[i, j] == 0.0
            else:
                lo, hi = min(i, j), max(i, j)
                assert np.isclose(tab[i, j], float(bp[hi - 1] - bp[lo]))
    assert np.all(tab >= 0)


def test_paper_figure1_example():
    """PAA (-0.70, -0.81, 0.08, 1.50) with A=4 breakpoints (-.67, 0, .67)
    must encode to (a, a, c, d) = (0, 0, 2, 3)."""
    sax = SAX(T=16, W=4, A=4)
    paa_vals = jnp.asarray([-0.70, -0.81, 0.08, 1.50])
    syms = np.asarray(discretize(paa_vals, sax.breakpoints))
    assert list(syms) == [0, 0, 2, 3]
    other = np.asarray(discretize(
        jnp.asarray([1.72, 0.34, 1.55, 0.49]), sax.breakpoints))
    assert list(other) == [3, 2, 3, 2]          # (d, c, d, c)
    d = float(sax.distance(jnp.asarray(syms), jnp.asarray(other)))
    # paper: d_SAX approx 3.02 for these two series
    assert abs(d - 3.02) < 0.02


def test_sax_distance_symmetry_and_identity():
    rng = np.random.default_rng(1)
    sax = SAX(T=128, W=16, A=16)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    s = sax.encode(x)
    d = np.asarray(sax.pairwise_distance(s, s))
    assert np.allclose(d, d.T, atol=1e-5)
    assert np.allclose(np.diag(d), 0.0, atol=1e-6)
