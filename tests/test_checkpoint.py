"""Checkpoint substrate: atomic save/restore, LATEST pointer, GC, restart
equivalence, and elastic re-shard semantics."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    Checkpointer, latest_step, restore_checkpoint, save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"m": {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))},
                    "v": {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    st = _state()
    save_checkpoint(d, 7, st)
    assert latest_step(d) == 7
    restored, manifest = restore_checkpoint(d, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_follows_newest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _state(1))
    save_checkpoint(d, 5, _state(5))
    assert latest_step(d) == 5


def test_gc_keeps_k(tmp_path):
    d = str(tmp_path)
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(d, s, _state(s), keep=2)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_torn_write_invisible(tmp_path):
    """A .tmp directory (simulated crash mid-write) is never restored."""
    d = str(tmp_path)
    save_checkpoint(d, 3, _state(3))
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert latest_step(d) == 3


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _state())
    bad = _state()
    bad["params"]["w"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(d, bad)


def test_checkpointer_cadence(tmp_path):
    ck = Checkpointer(str(tmp_path), every=10)
    assert ck.maybe_save(0, _state()) is None       # step 0 skipped
    assert ck.maybe_save(5, _state()) is None
    assert ck.maybe_save(10, _state()) is not None
    assert ck.maybe_save(11, _state(), force=True) is not None


def test_restart_training_equivalence(tmp_path):
    """Training S steps straight == training with a save/restore at S/2."""
    from repro.configs import get_config, reduced
    from repro.models.transformer import RunConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = reduced(get_config("qwen3-0.6b"))
    rc = RunConfig(q_chunk=8, kv_chunk=8, loss_chunk=8)
    step = jax.jit(make_train_step(cfg, None, rc, AdamWConfig(lr=1e-3)))
    rng = np.random.default_rng(0)
    batches = []
    for i in range(6):
        t = jnp.asarray(rng.integers(0, 64, (2, 17)), jnp.int32)
        batches.append({"tokens": t[:, :-1], "labels": t[:, 1:]})

    s_a = init_train_state(cfg, jax.random.PRNGKey(0))
    for b in batches:
        s_a, _ = step(s_a, b)

    s_b = init_train_state(cfg, jax.random.PRNGKey(0))
    for b in batches[:3]:
        s_b, _ = step(s_b, b)
    save_checkpoint(str(tmp_path), 3, s_b)
    s_b2, _ = restore_checkpoint(str(tmp_path), jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s_b))
    for b in batches[3:]:
        s_b2, _ = step(s_b2, b)

    for a, b in zip(jax.tree.leaves(s_a["params"]),
                    jax.tree.leaves(s_b2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
