"""Observability neutrality: tracing MUST be a pure observer.

For every encoder (SAX / sSAX / tSAX / stSAX), candidate source (linear
sweep / split-tree index) and verification path (host / device), running
the same query batch with ``explain=True`` must be bit-identical to the
untraced run — identical result ids AND distances, identical per-query
raw-access counts, identical store accounting (accesses / fetches /
modeled I/O).  Whole-series (``MatchEngine``) and subsequence
(``SubseqEngine``) stacks are both covered.

This is the property the zero-overhead-when-off design rests on: every
instrumentation site only *reads* engine state after the computation,
so turning tracing on cannot change what the engine does — only what it
reports.  The traced run must additionally produce a well-formed trace
(required spans present, rounds recorded, device transfer invariants
zero) and a JSON-serializable export.
"""

import json

import numpy as np
import pytest

from repro.core import MatchEngine, make_technique
from repro.data.synthetic import season_dataset
from repro.obs import check_trace
from repro.store import SymbolicStore

L = 10
TECHS = ["sax", "ssax", "tsax", "stsax"]


def _enc(name, T):
    kw = {"sax": {}, "ssax": {"r2_season": 0.7},
          "tsax": {"r2_trend": 0.3}, "stsax": {"r2_season": 0.5}}[name]
    return make_technique(name, T=T, W=T // (2 * L), L=L, **kw)


def _mesh1():
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((1,), ("data",))


def _fingerprint(res, store):
    ids = res.indices if hasattr(res, "indices") else res.window_ids
    return {
        "ids": np.asarray(ids).copy(),
        "distances": np.asarray(res.distances).copy(),
        "raw_accesses": np.asarray(res.raw_accesses).copy(),
        "store_accesses": int(res.store_accesses),
        "store_fetches": int(res.store_fetches),
        "io_seconds": float(res.io_seconds),
        "accesses": int(store.accesses),
        "fetches": int(store.fetches),
    }


def _assert_identical(base, traced, label):
    for key in base:
        a, b = base[key], traced[key]
        assert np.array_equal(a, b), (
            f"{label}: tracing changed {key}: {a!r} != {b!r}")


def _check(trace, *, device):
    problems = check_trace(trace, device=device)
    assert problems == [], problems
    json.dumps(trace.to_dict())


@pytest.mark.parametrize("tech", TECHS)
def test_match_engine_neutral_all_paths(tech):
    T, n, n_q, k = 240, 64, 3, 4
    X = season_dataset(n + n_q, T, L, 0.7, per_series_strength=True,
                       seed=5)
    Q, D = X[:n_q], X[n_q:]
    enc = _enc(tech, T)

    store = SymbolicStore.from_rows(enc, D, media="ssd")
    store.build_index(leaf_fill=16)
    host = MatchEngine(enc, store, verify="host", batch_size=32)

    import jax.numpy as jnp
    from repro.core.distributed import make_engine_service
    dev = make_engine_service(_enc(tech, T), jnp.asarray(D), _mesh1(),
                              batch_size=32, verify="device")
    dev.store.build_index(leaf_fill=16)

    for engine, verify in ((host, "host"), (dev, "device")):
        for source in (None, "index"):
            label = f"{tech}/{verify}/{source or 'linear'}"
            engine.store.reset()
            base = _fingerprint(engine.topk(Q, k=k, source=source),
                                engine.store)
            engine.store.reset()
            res = engine.topk(Q, k=k, source=source, explain=True)
            _assert_identical(base, _fingerprint(res, engine.store),
                              label)
            _check(res.trace, device=(verify == "device"))
            # replaying untraced after the traced run is unchanged too
            engine.store.reset()
            again = _fingerprint(engine.topk(Q, k=k, source=source),
                                 engine.store)
            _assert_identical(base, again, label + "/replay")


@pytest.mark.parametrize("tech", TECHS)
def test_subseq_engine_neutral_all_paths(tech):
    from repro.subseq import SubseqEngine, WindowView
    n, T, m, stride, k, n_q = 6, 360, 120, 6, 3, 2
    rng = np.random.default_rng(9)
    D = season_dataset(n, T, L, 0.7, per_series_strength=True, seed=9)
    q_rows = rng.integers(0, n, size=n_q)
    offs = rng.integers(0, T - m, size=n_q)
    Q = np.stack([D[r, o:o + m] for r, o in zip(q_rows, offs)])
    Q = Q + 0.05 * rng.normal(size=Q.shape).astype(np.float32)
    enc = _enc(tech, m)

    view = WindowView(enc, D, stride=stride, media="ssd")
    view.build_index(leaf_fill=16)
    engines = {"host": SubseqEngine(view, verify="host", batch_size=64),
               "device": SubseqEngine(view, mesh=_mesh1(),
                                      verify="device", batch_size=64)}

    for verify, eng in engines.items():
        for use_index in (False, True):
            label = f"{tech}/{verify}/{'index' if use_index else 'linear'}"
            view.reset()
            base = _fingerprint(eng.topk(Q, k=k, use_index=use_index),
                                view)
            view.reset()
            res = eng.topk(Q, k=k, use_index=use_index, explain=True)
            _assert_identical(base, _fingerprint(res, view), label)
            _check(res.trace, device=(verify == "device"))


def test_metrics_registry_is_neutral_too():
    """Attaching a MetricsRegistry (without tracing) must not change
    results or store accounting either — metrics recording reads the
    same post-hoc state traces do."""
    from repro.obs import MetricsRegistry
    T, n, n_q, k = 240, 48, 2, 3
    X = season_dataset(n + n_q, T, L, 0.7, seed=11)
    Q, D = X[:n_q], X[n_q:]
    enc = _enc("ssax", T)
    store = SymbolicStore.from_rows(enc, D, media="ssd")
    plain = MatchEngine(enc, store, verify="host", batch_size=32)
    store.reset()
    base = _fingerprint(plain.topk(Q, k=k), store)

    reg = MetricsRegistry()
    observed = MatchEngine(enc, store, verify="host", batch_size=32,
                           metrics=reg)
    store.reset()
    _assert_identical(base, _fingerprint(observed.topk(Q, k=k), store),
                      "metrics-attached")
    snap = reg.snapshot()
    assert snap["counters"]["match.queries"] == n_q
    assert snap["counters"]["match.rows_fetched"] == base["accesses"]
    assert snap["histograms"]["match.topk_latency_s"]["count"] == 1
