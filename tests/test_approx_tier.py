"""Anytime/approximate tier + generated-count dedup regression.

Covers the two observability/index changes this PR rides on:

* ``generated_unique``: exclusion-widening re-runs ``topk_verify`` on
  the same trace, so the summed per-round ``generated`` over-counts
  candidates that reappear across rounds.  The trace now also reports
  the deduplicated per-query union (``generated_unique``) — equal to
  the accumulated total for single-round calls, strictly <= (and
  bounded by the corpus) for widening calls.

* ``TreeCandidates`` approximate mode: stop after the exact seed walk
  plus a bounded collect.  The dropped candidates' lower bounds join
  the verified distances to form ``kth_lb`` — a certified lower bound
  on the true k-NN distance — and ``error_bar = d_k - kth_lb >= 0``,
  with zero proving the answer exact.
"""

import numpy as np
import pytest

from repro.core import MatchEngine, make_technique
from repro.data.synthetic import season_dataset
from repro.obs import Trace
from repro.store import SymbolicStore

L = 10
TECHS = ["sax", "ssax", "tsax", "stsax"]


def _enc(name, T):
    kw = {"sax": {}, "ssax": {"r2_season": 0.7},
          "tsax": {"r2_trend": 0.3}, "stsax": {"r2_season": 0.5}}[name]
    return make_technique(name, T=T, W=T // (2 * L), L=L, **kw)


def _engine(tech, D, T):
    store = SymbolicStore.from_rows(_enc(tech, T), D, media="ssd")
    store.build_index(leaf_fill=16)
    return MatchEngine(_enc(tech, T), store, verify="host",
                       batch_size=32)


# -- generated_unique regression ----------------------------------------

def test_generated_unique_equals_total_single_round():
    """Without widening there is exactly one topk_verify call per query
    batch: the dedup union must equal the accumulated total."""
    T, n, n_q = 240, 48, 3
    X = season_dataset(n + n_q, T, L, 0.7, seed=3)
    Q, D = X[:n_q], X[n_q:]
    eng = _engine("ssax", D, T)
    for source in (None, "index"):
        res = eng.topk(Q, k=4, source=source, explain=True)
        gen = np.atleast_1d(res.trace.get("generated"))
        gu = np.atleast_1d(res.trace.get("generated_unique"))
        assert np.array_equal(gen, gu), (source, gen, gu)


@pytest.mark.parametrize("tech", TECHS)
def test_generated_unique_dedups_widening_rounds(tech):
    """Exclusion widening re-generates candidates across rounds on one
    trace: the summed total over-counts, the union must not — and must
    never exceed the corpus size."""
    from repro.subseq import SubseqEngine, WindowView
    n, T, m, stride, k = 5, 360, 120, 3, 4
    rng = np.random.default_rng(13)
    D = season_dataset(n, T, L, 0.7, per_series_strength=True, seed=13)
    rows_ = rng.integers(0, n, size=3)
    offs = rng.integers(0, T - m, size=3)
    Q = np.stack([D[r, o:o + m] for r, o in zip(rows_, offs)])
    Q = Q + 0.02 * rng.normal(size=Q.shape).astype(np.float32)
    view = WindowView(_enc(tech, m), D, stride=stride, media="ssd")
    eng = SubseqEngine(view, verify="numpy", batch_size=64)
    # heavy exclusion forces widening: every reported match suppresses
    # a neighborhood, so the engine re-runs verification rounds
    res = eng.topk(Q, k=k, exclusion=m, explain=True)
    gen = np.atleast_1d(res.trace.get("generated")).astype(np.int64)
    gu = np.atleast_1d(res.trace.get("generated_unique")).astype(np.int64)
    assert gu.shape == gen.shape
    assert np.all(gu <= gen)
    assert np.all(gu <= view.n), (gu, view.n)
    # the over-count is the regression: widening re-hands the full
    # sweep, so the accumulated total exceeds the corpus while the
    # dedup union cannot
    if res.trace.rounds and len(
            [r for r in res.trace.rounds if r.get("phase") == "widen"]):
        assert gen.sum() > gu.sum()


def test_trace_unique_counts_unit():
    t = Trace("t")
    t.note_ids("generated", 0, np.array([1, 2, 3]))
    t.note_ids("generated", 0, np.array([2, 3, 4]))
    t.note_ids("generated", 1, np.array([7]))
    t.note_counts("generated", np.array([0, 2]))
    out = t.unique_counts("generated", 3)
    assert np.array_equal(out, [4, 3, 0])
    assert t.unique_counts("nope", 2) is None


# -- approximate tier ----------------------------------------------------

@pytest.mark.parametrize("tech", TECHS)
def test_topk_approx_certificate(tech):
    """kth_lb lower-bounds the true k-NN distance, error_bar >= 0, and
    the approximate frontier's distances are >= the exact ones."""
    T, n, n_q, k = 240, 96, 4, 4
    X = season_dataset(n + n_q, T, L, 0.7, per_series_strength=True,
                       seed=7)
    Q, D = X[:n_q], X[n_q:]
    eng = _engine(tech, D, T)
    exact = eng.topk(Q, k=k, source="index")
    res = eng.topk_approx(Q, k=k, collect=k, explain=True)
    assert res.kth_lb.shape == (n_q,)
    assert res.error_bar.shape == (n_q,)
    assert np.all(res.error_bar >= 0.0)
    for qi in range(n_q):
        true_dk = exact.distances[qi, -1]
        assert res.kth_lb[qi] <= true_dk + 1e-5, tech
        # approximate distances can only be >= exact (same metric,
        # subset of candidates verified)
        assert np.all(res.distances[qi] >= exact.distances[qi] - 1e-5)
    # trace labels the source as approximate
    assert res.trace.get("exact") is False
    assert res.trace.get("source") == "index-approx"
    assert res.trace.get("error_bar") is not None


def test_topk_approx_large_collect_is_exact():
    """With a collect budget >= the corpus nothing is dropped: the
    answer equals exact topk and the error bar certifies it (0)."""
    T, n, n_q, k = 240, 64, 3, 4
    X = season_dataset(n + n_q, T, L, 0.7, seed=19)
    Q, D = X[:n_q], X[n_q:]
    eng = _engine("ssax", D, T)
    exact = eng.topk(Q, k=k, source="index")
    res = eng.topk_approx(Q, k=k, collect=n)
    assert np.array_equal(res.indices, exact.indices)
    assert np.array_equal(res.distances, exact.distances)
    assert np.all(res.error_bar == 0.0)


def test_topk_approx_recall_improves_with_collect():
    """Recall vs the exact oracle is monotone-ish in the collect budget
    and bounded by 1; the bounded run examines fewer candidates."""
    T, n, n_q, k = 240, 128, 6, 4
    X = season_dataset(n + n_q, T, L, 0.5, per_series_strength=True,
                       seed=23)
    Q, D = X[:n_q], X[n_q:]
    eng = _engine("ssax", D, T)
    exact = eng.topk(Q, k=k, source="index")

    def recall(res):
        return np.mean([np.intersect1d(a, e).size / k for a, e in
                        zip(res.indices, exact.indices)])

    small = eng.topk_approx(Q, k=k, collect=k)
    large = eng.topk_approx(Q, k=k, collect=n)
    assert 0.0 <= recall(small) <= 1.0
    assert recall(large) == 1.0
    assert small.raw_accesses.sum() <= large.raw_accesses.sum()


def test_topk_approx_without_index_falls_back():
    """No index: topk_approx degrades to representation-top-k (the
    paper's approximate matching) without a certificate."""
    T, n, n_q, k = 240, 48, 2, 3
    X = season_dataset(n + n_q, T, L, 0.7, seed=29)
    Q, D = X[:n_q], X[n_q:]
    enc = _enc("sax", T)
    store = SymbolicStore.from_rows(enc, D, media="ssd")  # no index
    eng = MatchEngine(enc, store, verify="host", batch_size=32)
    res = eng.topk_approx(Q, k=k)
    ref = eng.topk(Q, k=k, exact=False)
    assert np.array_equal(res.indices, ref.indices)
    assert not hasattr(res, "kth_lb")


def test_subseq_topk_approx_certificate():
    from repro.subseq import SubseqEngine, WindowView
    n, T, m, stride, k = 6, 360, 120, 6, 3
    rng = np.random.default_rng(31)
    D = season_dataset(n, T, L, 0.7, per_series_strength=True, seed=31)
    rows_ = rng.integers(0, n, size=3)
    offs = rng.integers(0, T - m, size=3)
    Q = np.stack([D[r, o:o + m] for r, o in zip(rows_, offs)])
    view = WindowView(_enc("ssax", m), D, stride=stride, media="ssd")
    view.build_index(leaf_fill=16)
    eng = SubseqEngine(view, verify="host", batch_size=64)
    exact = eng.topk(Q, k=k, use_index=True)
    res = eng.topk_approx(Q, k=k, collect=k, explain=True)
    assert np.all(res.error_bar >= 0.0)
    for qi in range(len(Q)):
        assert res.kth_lb[qi] <= exact.distances[qi, -1] + 1e-5
    big = eng.topk_approx(Q, k=k, collect=view.n)
    assert np.array_equal(big.window_ids, exact.window_ids)
    assert np.all(big.error_bar == 0.0)
    # unindexed subseq engines cannot serve the anytime tier
    view2 = WindowView(_enc("ssax", m), D, stride=stride, media="ssd")
    with pytest.raises(ValueError):
        SubseqEngine(view2, verify="numpy").topk_approx(Q, k=k)


def test_tree_candidates_rejects_bad_collect():
    T, n = 240, 48
    X = season_dataset(n, T, L, 0.7, seed=37)
    eng = _engine("ssax", X, T)
    with pytest.raises(ValueError):
        eng.store.index.source(approx_collect=-1)
