"""Property-based tests (hypothesis) for the system's central invariant:
every representation distance LOWER-BOUNDS the Euclidean distance
(Appendix A.1-A.5) — on arbitrary normalized series, arbitrary alphabet
sizes, arbitrary component strengths.  Also the chain
d_sSAX <= d_sPAA <= d_ED and d_tSAX <= d_tPAA(features) <= d_ED."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    SAX, SSAX, TSAX, znormalize)
from repro.core.matching import euclidean


def _series(draw, n, T, seed):
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["walk", "noise", "season", "trend"]))
    if kind == "walk":
        x = np.cumsum(rng.normal(size=(n, T)), axis=1)
    elif kind == "noise":
        x = rng.normal(size=(n, T))
    elif kind == "season":
        L = 8
        mask = rng.normal(size=(n, L))
        x = np.tile(mask, (1, T // L)) + 0.5 * rng.normal(size=(n, T))
    else:
        slope = rng.normal(size=(n, 1))
        x = slope * np.arange(T)[None, :] + rng.normal(size=(n, T))
    return np.asarray(znormalize(jnp.asarray(x, jnp.float32)))


TOL = 1e-2     # f32 + normalization slack on distances O(10)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_sax_lower_bounds_euclidean(data):
    T = data.draw(st.sampled_from([64, 128, 256]))
    W = data.draw(st.sampled_from([8, 16, 32]))
    A = data.draw(st.sampled_from([4, 16, 64, 256]))
    seed = data.draw(st.integers(0, 2**16))
    x = _series(data.draw, 8, T, seed)
    sax = SAX(T=T, W=W, A=A)
    s = sax.encode(jnp.asarray(x))
    d_rep = np.asarray(sax.pairwise_distance(s, s))
    d_ed = np.sqrt(np.maximum(
        np.sum(x**2, -1)[:, None] + np.sum(x**2, -1)[None]
        - 2 * x @ x.T, 0))
    assert np.all(d_rep <= d_ed + TOL), (d_rep - d_ed).max()


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_ssax_chain_lower_bounds(data):
    T = data.draw(st.sampled_from([64, 128, 256]))
    L = 8
    W = data.draw(st.sampled_from([4, 8]))
    A_s = data.draw(st.sampled_from([4, 16, 64]))
    A_r = data.draw(st.sampled_from([4, 16, 64]))
    r2 = data.draw(st.floats(0.05, 0.95))
    seed = data.draw(st.integers(0, 2**16))
    x = _series(data.draw, 8, T, seed)
    ss = SSAX(T=T, W=W, L=L, A_seas=A_s, A_res=A_r, r2_season=r2)
    rep = ss.encode(jnp.asarray(x))
    feats = ss.features(jnp.asarray(x))
    d_sax = np.asarray(ss.pairwise_distance(rep, rep))
    d_paa = np.asarray(ss.spaa_distance(
        (feats[0][:, None], feats[1][:, None]),
        (feats[0][None, :], feats[1][None, :])))
    d_ed = np.sqrt(np.maximum(
        np.sum(x**2, -1)[:, None] + np.sum(x**2, -1)[None]
        - 2 * x @ x.T, 0))
    # the chain: symbolic <= feature-level <= true (Appendix A.1/A.2)
    assert np.all(d_sax <= d_paa + TOL), (d_sax - d_paa).max()
    assert np.all(d_paa <= d_ed + TOL), (d_paa - d_ed).max()


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_tsax_lower_bounds(data):
    T = data.draw(st.sampled_from([64, 128, 240]))
    W = data.draw(st.sampled_from([8, 16]))
    A_t = data.draw(st.sampled_from([8, 32, 128]))
    A_r = data.draw(st.sampled_from([4, 16, 64]))
    r2 = data.draw(st.floats(0.05, 0.95))
    seed = data.draw(st.integers(0, 2**16))
    x = _series(data.draw, 8, T, seed)
    ts = TSAX(T=T, W=W, A_tr=A_t, A_res=A_r, r2_trend=r2)
    rep = ts.encode(jnp.asarray(x))
    d_rep = np.asarray(ts.pairwise_distance(rep, rep))
    d_ed = np.sqrt(np.maximum(
        np.sum(x**2, -1)[:, None] + np.sum(x**2, -1)[None]
        - 2 * x @ x.T, 0))
    assert np.all(d_rep <= d_ed + TOL), (d_rep - d_ed).max()


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_phi_bounded_by_phi_max(data):
    """Eq. 29: |phi| <= phi_max for any normalized series."""
    T = data.draw(st.sampled_from([32, 64, 128]))
    seed = data.draw(st.integers(0, 2**16))
    x = _series(data.draw, 16, T, seed)
    ts = TSAX(T=T, W=8, A_tr=16, A_res=16)
    phi, _ = ts.features(jnp.asarray(x))
    assert np.all(np.abs(np.asarray(phi)) <= ts.phi_max + 1e-5)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_trend_residual_invariants(data):
    """Eqs. 23/24: residual sum == 0 and trend-residual orthogonality."""
    from repro.core.tsax import remove_trend
    T = data.draw(st.sampled_from([32, 64, 128]))
    seed = data.draw(st.integers(0, 2**16))
    x = _series(data.draw, 8, T, seed)
    res, t1, t2 = remove_trend(jnp.asarray(x))
    res = np.asarray(res)
    s = np.arange(T)
    tr = np.asarray(t1)[:, None] + np.asarray(t2)[:, None] * s[None]
    assert np.allclose(res.sum(-1), 0.0, atol=1e-3)
    assert np.allclose((tr * res).sum(-1), 0.0, atol=1e-2)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_theta_interdependence_eq25(data):
    """Eq. 25: theta2 == -2 theta1 / (T-1) on normalized series."""
    from repro.core.tsax import trend_features
    T = data.draw(st.sampled_from([32, 64, 128]))
    seed = data.draw(st.integers(0, 2**16))
    x = _series(data.draw, 8, T, seed)
    t1, t2 = trend_features(jnp.asarray(x))
    assert np.allclose(np.asarray(t2),
                       -2.0 * np.asarray(t1) / (T - 1), atol=1e-4)
