"""Async request queue with coalescing dispatch and graceful shedding.

The front half of the always-on matching service: callers ``submit``
single-query requests from any thread; a dispatcher thread coalesces
whatever is waiting (up to ``max_batch``, after at most ``window_s`` of
batching delay anchored at the first queued request) into ONE engine
dispatch — the kernels and ``core.engine.topk_verify`` are already
multi-query, so a coalesced (Q, T) batch costs one encode, one
candidate ordering and one sharded verification round-trip instead of
Q of each.

Admission control mirrors ``repro.serving.engine.ServeEngine.admit``'s
shape and the ``serve.*`` metric names: a request that cannot be served
is REJECTED WITH A REASON (``req.error`` set, ``req.done`` event set,
``serve.rejected`` incremented) — never silently dropped.  Every shed
is additionally counted under ``serve.shed.<reason>``, so the shed
accounting always sums to the rejected count (a CI gate in
``benchmarks/bench_serving.py``).

Shed reasons:

* ``queue_full``        — backlog at ``max_queue`` (admission time).
* ``deadline_expired``  — the per-request deadline passed while queued
  (dispatch time) or was non-positive at submit.
* ``bad_query``         — malformed request (wrong length, bad k, an
  unservable tier override); admission time, via the session's
  validator.
* ``shutdown``          — the service stopped before dispatch and was
  closed without draining.
* ``engine_error``      — the dispatch callback raised; every request
  of the failed batch is shed with the exception text.

The queue itself never looks inside a result: the ``dispatch(batch)``
callback (``repro.service.session.MatchSession``) owns planning,
engine calls and response fill-in.  Deadline-expiry shedding at
dispatch time also lives in the session (it holds the clock) through
:meth:`CoalescingQueue.shed`.

Epoch pinning: when the queue is built with ``epoch_fn`` (the store's
``current_epoch``), every request is stamped with the corpus epoch
current AT ADMISSION (``req.epoch``) — the downstream dispatch answers
as of that frontier, so an answer is consistent with the corpus the
caller saw when it submitted, regardless of concurrent ingest.

Replicated dispatch: with ``n_replicas > 1`` the coalescer no longer
dispatches inline; it routes each coalesced batch to one of N replica
inboxes (placement by the injected ``place(live, depths)`` — the
planner's EWMA arbiter — falling back to least-depth) and a worker
thread per replica drains its inbox through ``dispatch(batch,
replica)``.  A replica dispatch failure REQUEUES the batch's
unresolved requests on another live replica (``serve.requeued``)
instead of shedding, as does :meth:`kill` (``serve.replica_killed``);
only a batch that has failed on every live replica is shed with
``engine_error``.  With ``n_replicas == 1`` the dispatch path is
byte-identical to the unreplicated queue.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

SHED_QUEUE_FULL = "queue_full"
SHED_DEADLINE = "deadline_expired"
SHED_BAD_QUERY = "bad_query"
SHED_SHUTDOWN = "shutdown"
SHED_ENGINE_ERROR = "engine_error"

_RID = itertools.count()


@dataclass
class MatchRequest:
    """One single-query matching request and its response slot.

    Callers fill the top block at ``submit`` time; the service fills
    the rest and fires ``done``.  ``error`` follows the
    ``ServeEngine.admit`` contract: None means the request was served;
    a string is the reject/shed explanation (``shed_reason`` carries
    the machine-readable reason code)."""

    query: np.ndarray                   # (T,) raw query
    k: int = 1
    deadline_s: Optional[float] = None  # latency budget from submit
    tier: Optional[str] = None          # explicit tier override
    explain: bool = False               # attach a repro.obs trace
    kind: str = "topk"                  # "topk" | "motifs" | "discords"
    #   corpus self-join kinds carry no query of their own (the corpus
    #   is both sides); the session routes them to the SelfJoinEngine
    #   tier and fills ``result`` with the (window, ...) tuple list

    rid: int = field(default_factory=lambda: next(_RID))
    t_submit: float = 0.0
    t_deadline: Optional[float] = None
    t_done: float = 0.0
    epoch: Optional[object] = None      # corpus frontier pinned at
    #   admission (``repro.store.CorpusEpoch``); the answer is exact as
    #   of this frontier regardless of concurrent ingest
    replica: Optional[int] = None       # replica that served it
    requeues: int = 0                   # replica-failover reroutes

    indices: Optional[np.ndarray] = None    # (k,) best ids
    distances: Optional[np.ndarray] = None  # (k,) true d_ED
    rows: Optional[np.ndarray] = None       # subsequence mode only
    starts: Optional[np.ndarray] = None
    kth_lb: Optional[float] = None          # approx tier certificate
    error_bar: Optional[float] = None
    tier_served: Optional[str] = None
    plan: Optional[object] = None           # planner.PlanDecision
    trace: Optional[object] = None
    result: Optional[object] = None         # self-join kinds: the
    #   topk_motifs / topk_discords tuple list of ``repro.profile``

    error: Optional[str] = None
    shed_reason: Optional[str] = None
    done: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until served or shed; True when the request finished."""
        return self.done.wait(timeout)

    @property
    def ok(self) -> bool:
        return self.done.is_set() and self.error is None

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class CoalescingQueue:
    """Thread-safe coalescing request queue (see module docstring).

    Parameters
    ----------
    dispatch:   ``dispatch(batch: list[MatchRequest]) -> None`` — runs
                on the dispatcher thread, must fill every request and
                set its ``done`` event (or shed it via :meth:`shed`).
    validate:   optional ``validate(req) -> Optional[str]`` admission
                hook; a returned message rejects with ``bad_query``.
    window_s:   coalescing window — after the first request of a batch
                arrives, wait at most this long for more before
                dispatching (0: dispatch whatever is queued
                immediately; coalescing then only captures requests
                that raced in together).
    max_batch:  dispatch at most this many requests per engine call
                (1: serial dispatch, the bench baseline).
    max_queue:  admission backlog bound; beyond it submits shed with
                ``queue_full``.
    metrics:    optional ``repro.obs.MetricsRegistry`` (``serve.*``).
    clock:      injectable monotonic clock (tests).
    n_replicas: engine replicas behind ``dispatch``.  1 (default):
                inline dispatch on the coalescer thread,
                ``dispatch(batch)``.  > 1: per-replica inboxes + worker
                threads, ``dispatch(batch, replica)``; failures requeue
                on surviving replicas (see module docstring).
    place:      optional ``place(live, depths) -> replica`` arbiter
                (the planner's EWMA placement); default least-depth.
    epoch_fn:   optional zero-arg frontier supplier (the store's
                ``current_epoch``); stamped onto ``req.epoch`` at
                admission.
    """

    def __init__(self, dispatch: Callable, *,
                 validate: Optional[Callable] = None,
                 window_s: float = 0.002, max_batch: int = 64,
                 max_queue: int = 256, metrics=None,
                 clock: Callable[[], float] = time.monotonic,
                 n_replicas: int = 1,
                 place: Optional[Callable] = None,
                 epoch_fn: Optional[Callable] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self._dispatch = dispatch
        self._validate = validate
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.metrics = metrics
        self._clock = clock
        self._q: List[MatchRequest] = []
        self._cond = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.n_replicas = int(n_replicas)
        self._place = place
        self._epoch_fn = epoch_fn
        # replicated-dispatch state (used only when n_replicas > 1):
        # per-replica batch inboxes + busy flags under one condition,
        # the dead set, and one worker thread per replica
        self._rcond = threading.Condition()
        self._inbox = {r: [] for r in range(self.n_replicas)}
        self._busy = {r: False for r in range(self.n_replicas)}
        self._dead: set = set()
        self._workers: List[threading.Thread] = []
        self._wstop = False

    # -- admission ---------------------------------------------------------
    def shed(self, req: MatchRequest, reason: str, msg: str) -> None:
        """Reject/shed one request with a reason — the never-silent-drop
        primitive.  Mirrors ``ServeEngine.admit``'s reject shape (error
        string, done flag, ``serve.rejected``) and adds the per-reason
        ``serve.shed.<reason>`` counter the accounting gate sums."""
        req.error = msg
        req.shed_reason = reason
        req.t_done = self._clock()
        if self.metrics is not None:
            self.metrics.counter("serve.rejected").inc()
            self.metrics.counter(f"serve.shed.{reason}").inc()
        req.done.set()

    def submit(self, req: MatchRequest) -> bool:
        """Admit a request (thread-safe).  Returns False when the
        request was rejected — ``req.error`` / ``req.shed_reason`` say
        why; the request is always resolved, never silently dropped."""
        now = self._clock()
        if self._stop:
            self.shed(req, SHED_SHUTDOWN, "service is shut down")
            return False
        if self._validate is not None:
            msg = self._validate(req)
            if msg is not None:
                self.shed(req, SHED_BAD_QUERY, msg)
                return False
        if req.deadline_s is not None and req.deadline_s <= 0:
            self.shed(req, SHED_DEADLINE,
                      f"deadline budget {req.deadline_s}s is not positive")
            return False
        with self._cond:
            if len(self._q) >= self.max_queue:
                self.shed(req, SHED_QUEUE_FULL,
                          f"queue at capacity ({self.max_queue})")
                return False
            req.t_submit = now
            if req.deadline_s is not None:
                req.t_deadline = now + req.deadline_s
            if req.epoch is None and self._epoch_fn is not None:
                # pin the corpus frontier AT ADMISSION: the answer is
                # exact as of what the caller could observe now, not as
                # of whenever dispatch happens to run
                req.epoch = self._epoch_fn()
            self._q.append(req)
            self._cond.notify_all()
        if self.metrics is not None:
            self.metrics.counter("serve.requests").inc()
        return True

    def depth(self) -> int:
        with self._cond:
            return len(self._q)

    # -- dispatcher --------------------------------------------------------
    def start(self) -> "CoalescingQueue":
        if self._thread is not None:
            return self
        self._stop = False
        if self.n_replicas > 1 and not self._workers:
            self._wstop = False
            for r in range(self.n_replicas):
                t = threading.Thread(target=self._worker, args=(r,),
                                     name=f"match-replica-{r}",
                                     daemon=True)
                t.start()
                self._workers.append(t)
        self._thread = threading.Thread(target=self._loop,
                                        name="match-dispatch", daemon=True)
        self._thread.start()
        return self

    def close(self, *, drain: bool = True) -> None:
        """Stop the dispatcher.  ``drain=True`` serves everything still
        queued (one final coalesced dispatch per ``max_batch``, routed
        through the replicas when replicated); ``drain=False`` sheds
        the backlog (and any replica-inbox pending) with
        ``shutdown``."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        while True:
            with self._cond:
                batch = self._q[:self.max_batch]
                del self._q[:self.max_batch]
            if not batch:
                break
            if drain:
                if self.n_replicas > 1:
                    self._route_batch(batch)
                else:
                    self._run_batch(batch)
            else:
                for r in batch:
                    self.shed(r, SHED_SHUTDOWN,
                              "service shut down before dispatch")
        if self.n_replicas > 1:
            with self._rcond:
                if not drain:
                    for inbox in self._inbox.values():
                        for batch, _ in inbox:
                            for r in batch:
                                self.shed(r, SHED_SHUTDOWN,
                                          "service shut down before "
                                          "dispatch")
                        inbox.clear()
                else:       # wait for the workers to drain their inboxes
                    while any(self._inbox[r] or self._busy[r]
                              for r in self._inbox
                              if r not in self._dead):
                        self._rcond.wait()
                self._wstop = True
                self._rcond.notify_all()
            for t in self._workers:
                t.join()
            self._workers = []

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._stop:
                    self._cond.wait()
                if self._stop:
                    return               # close() drains or sheds the rest
                # coalescing window, anchored at the first queued request
                # this batch: wait (briefly) for more traffic to batch
                t_close = self._clock() + self.window_s
                while len(self._q) < self.max_batch and not self._stop:
                    left = t_close - self._clock()
                    if left <= 0:
                        break
                    self._cond.wait(timeout=left)
                batch = self._q[:self.max_batch]
                del self._q[:self.max_batch]
            if batch:
                if self.n_replicas > 1:
                    self._route_batch(batch)
                else:
                    self._run_batch(batch)

    def _run_batch(self, batch: List[MatchRequest]) -> None:
        """Unreplicated dispatch (n_replicas == 1): inline on the
        coalescer thread — byte-identical to the pre-replica queue."""
        if self.metrics is not None:
            self.metrics.counter("serve.batches").inc()
            self.metrics.counter("serve.batched_requests").inc(len(batch))
        try:
            self._dispatch(batch)
        except Exception as e:  # noqa: BLE001 — resolve, never hang callers
            for r in batch:
                if not r.done.is_set():
                    self.shed(r, SHED_ENGINE_ERROR,
                              f"{type(e).__name__}: {e}")
        for r in batch:          # belt-and-braces: a dispatch must never
            if not r.done.is_set():      # leave a caller blocked forever
                self.shed(r, SHED_ENGINE_ERROR,
                          "dispatch returned without resolving request")

    # -- replicated dispatch ----------------------------------------------
    def _route_batch(self, batch: List[MatchRequest],
                     attempts: int = 0, exclude: Optional[int] = None
                     ) -> None:
        """Place one coalesced batch on a live replica's inbox.
        ``attempts`` counts replicas that already failed this batch;
        ``exclude`` avoids re-placing on the replica that just failed
        (it stays eligible for FUTURE batches — one poisoned batch must
        not mark every replica it visits dead)."""
        with self._rcond:
            live = [r for r in range(self.n_replicas)
                    if r not in self._dead and r != exclude]
            if not live:
                live = [r for r in range(self.n_replicas)
                        if r not in self._dead]
            if not live:
                for r in batch:
                    if not r.done.is_set():
                        self.shed(r, SHED_ENGINE_ERROR,
                                  "no live replicas")
                return
            depths = {r: len(self._inbox[r]) + int(self._busy[r])
                      for r in live}
            if self._place is not None:
                rid = int(self._place(live, depths))
                if rid not in depths:
                    rid = min(live, key=lambda r: (depths[r], r))
            else:
                rid = min(live, key=lambda r: (depths[r], r))
            self._inbox[rid].append((batch, attempts))
            self._rcond.notify_all()

    def _worker(self, rid: int) -> None:
        while True:
            with self._rcond:
                while not self._inbox[rid] and not self._wstop \
                        and rid not in self._dead:
                    self._rcond.wait()
                if self._wstop or rid in self._dead:
                    return       # kill() / close() reroute or shed pending
                batch, attempts = self._inbox[rid].pop(0)
                self._busy[rid] = True
            try:
                self._run_replica_batch(batch, rid, attempts)
            finally:
                with self._rcond:
                    self._busy[rid] = False
                    self._rcond.notify_all()

    def _run_replica_batch(self, batch: List[MatchRequest], rid: int,
                           attempts: int) -> None:
        if self.metrics is not None:
            self.metrics.counter("serve.batches").inc()
            self.metrics.counter("serve.batched_requests").inc(len(batch))
        try:
            self._dispatch(batch, rid)
        except Exception as e:  # noqa: BLE001 — requeue, then shed
            pending = [r for r in batch if not r.done.is_set()]
            if pending and attempts + 1 < self.n_replicas and any(
                    r != rid and r not in self._dead
                    for r in range(self.n_replicas)):
                # replica failure: the batch survives — requeue the
                # unresolved requests on another live replica
                for r in pending:
                    r.requeues += 1
                if self.metrics is not None:
                    self.metrics.counter("serve.requeued").inc(
                        len(pending))
                self._route_batch(pending, attempts + 1, exclude=rid)
                return
            for r in pending:
                self.shed(r, SHED_ENGINE_ERROR,
                          f"{type(e).__name__}: {e}")
        for r in batch:          # belt-and-braces: a dispatch must never
            if not r.done.is_set():      # leave a caller blocked forever
                self.shed(r, SHED_ENGINE_ERROR,
                          "dispatch returned without resolving request")

    def kill(self, rid: int) -> int:
        """Simulate/handle replica death: mark ``rid`` dead (no future
        placements; its worker exits) and REQUEUE its pending inbox
        batches on the surviving replicas — death sheds nothing.
        Returns the number of requests rerouted."""
        if not 0 <= rid < self.n_replicas:
            raise ValueError(f"no replica {rid}")
        with self._rcond:
            self._dead.add(rid)
            pending = list(self._inbox[rid])
            self._inbox[rid].clear()
            self._rcond.notify_all()
        if self.metrics is not None:
            self.metrics.counter("serve.replica_killed").inc()
        moved = 0
        for batch, attempts in pending:
            alive = [r for r in batch if not r.done.is_set()]
            if not alive:
                continue
            for r in alive:
                r.requeues += 1
            moved += len(alive)
            self._route_batch(alive, attempts)
        if moved and self.metrics is not None:
            self.metrics.counter("serve.requeued").inc(moved)
        return moved

    def live_replicas(self) -> List[int]:
        with self._rcond:
            return [r for r in range(self.n_replicas)
                    if r not in self._dead]
