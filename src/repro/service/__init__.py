"""Always-on matching service: coalescing front-end + query planner.

* :mod:`repro.service.queue`   — async request queue; waiting requests
  coalesce into one (Q, T) engine dispatch; admission control sheds
  with a reason, never silently.
* :mod:`repro.service.planner` — telemetry-driven tier router
  (index / linear / approx) with deadline downgrade to the anytime
  tier and its error-bar certificate.
* :mod:`repro.service.session` — the servable façade wiring store +
  index + sharded device verify + obs tracing together.
"""

from repro.service.planner import TIERS, PlanDecision, QueryPlanner
from repro.service.queue import (SHED_BAD_QUERY, SHED_DEADLINE,
                                 SHED_ENGINE_ERROR, SHED_QUEUE_FULL,
                                 SHED_SHUTDOWN, CoalescingQueue,
                                 MatchRequest)
from repro.service.session import MatchSession

__all__ = [
    "TIERS", "PlanDecision", "QueryPlanner", "CoalescingQueue",
    "MatchRequest", "MatchSession", "SHED_QUEUE_FULL", "SHED_DEADLINE",
    "SHED_BAD_QUERY", "SHED_SHUTDOWN", "SHED_ENGINE_ERROR",
]
