"""Session façade: store + index + sharded verify + obs in one object.

``MatchSession`` wires an existing engine (``core.engine.MatchEngine``
— typically built device-resident via
``core.distributed.make_engine_service`` — or
``subseq.search.SubseqEngine``) behind the coalescing queue
(``service.queue``) and the telemetry-driven planner
(``service.planner``), producing the one servable object the launcher
(``launch/serve_match.py``) and the serving benchmark talk to:

* ``submit`` / ``serve`` — async single-query requests; waiting
  requests coalesce into one (Q, T) engine dispatch per batch.
* exact tiers stay EXACT: a planner-routed "index" or "linear" answer
  is bit-identical to calling ``engine.topk`` directly with that
  source, and a coalesced batch answers every request identically to
  dispatching it alone (batching neutrality) — both property-tested.
* deadline-threatened requests downgrade to the anytime "approx" tier
  and carry back ``kth_lb`` / ``error_bar`` (the certificate from
  ``index.candidates``), never a silent miss.
* every dispatch feeds the planner (``planner.observe``) and the obs
  registry (``serve.*`` metrics + optional per-request EXPLAIN trace).

Store I/O accounting is session-scoped: construction calls
``store.reset_counters()`` so a session's ``io`` numbers never bleed
in from whatever ran before it (and resetting never perturbs results
— covered by the metrics-concurrency tests).

Epoch-pinned serving: when the engine's store publishes corpus epochs
(``current_epoch`` — ``repro.store.SymbolicStore`` and
``subseq.WindowView`` both do), every request is pinned to the epoch
current at ADMISSION and the dispatch answers as of that frontier
(``engine.topk(..., epoch=req.epoch)``) — bit-identical to a store
frozen at the pin, no matter how much is ingested between admission
and dispatch.  ``req.epoch`` reports the pin back to the caller.

Replicated dispatch: ``replicas=[engine2, ...]`` adds engines sharing
the primary's store behind the queue's per-replica workers; the
planner's per-replica EWMAs arbitrate placement and a replica failure
requeues (never sheds) — see ``service.queue``.  ``state_dir=``
persists the planner's learned estimates across restarts
(``save_state`` / seeded on construction).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.service.planner import TIERS, QueryPlanner
from repro.service.queue import (SHED_DEADLINE, CoalescingQueue,
                                 MatchRequest)

#: File name of the persisted planner state inside ``state_dir``.
PLANNER_STATE = "planner.json"


class MatchSession:
    """One always-on matching service over one engine (see module doc).

    Parameters
    ----------
    engine:      ``MatchEngine`` or ``SubseqEngine`` (auto-detected by
                 the presence of ``engine.view``).
    metrics:     ``repro.obs.MetricsRegistry`` for ``serve.*`` metrics;
                 defaults to the engine's registry when it has one.
    planner:     inject a preconfigured ``QueryPlanner`` (tests); by
                 default one is built from the engine's store/index and
                 seeded from the registry's existing latency history.
    window_s / max_batch / max_queue: coalescing queue knobs.
    approx_collect: bounded-collect size for the approx tier (default
                 ``max(4k, 32)`` per request, the engine's own default).
    safety:      planner deadline-downgrade margin.
    replicas:    additional engines over the SAME store (same object —
                 validated) served behind per-replica dispatch workers;
                 the primary stays replica 0 and the oracle for
                 ``topk``/exactness tests.
    state_dir:   directory for persisted planner state; when it holds
                 a ``planner.json`` from a previous ``save_state`` the
                 planner starts from those learned estimates.
    """

    def __init__(self, engine, *, selfjoin=None, metrics=None,
                 planner=None,
                 window_s: float = 0.002, max_batch: int = 64,
                 max_queue: int = 256,
                 approx_collect: Optional[int] = None,
                 safety: float = 2.0,
                 replicas: Optional[Sequence] = None,
                 state_dir: Optional[str] = None):
        self.engine = engine
        self.engines = [engine] + list(replicas or [])
        self._subseq = hasattr(engine, "view")
        for i, eng in enumerate(self.engines[1:], start=1):
            shared = (getattr(eng, "view", None) is engine.view
                      if self._subseq
                      else getattr(eng, "store", None) is engine.store)
            if not shared:
                raise ValueError(
                    f"replica {i} does not share the primary engine's "
                    "store — replicas answer over ONE corpus")
        # optional repro.profile.SelfJoinEngine: enables the corpus-
        # level "selfjoin" tier (kind="motifs"/"discords" requests)
        self._selfjoin = selfjoin
        if selfjoin is not None and self._subseq \
                and selfjoin.view is not engine.view:
            raise ValueError("selfjoin engine must share the session "
                             "engine's WindowView")
        self.metrics = metrics if metrics is not None \
            else getattr(engine, "metrics", None)
        self._approx_collect = approx_collect
        if self._subseq:
            view = engine.view
            self.query_len = int(view.m)
            self._store = view
            has_index = getattr(view, "index", None) is not None
            # the subsequence anytime tier routes through the window
            # index; without one there is no approx tier to downgrade to
            has_approx = has_index
            total = int(view.n)
        else:
            store = engine.store
            self.query_len = int(engine.encoder.T)
            self._store = store
            has_index = getattr(store, "index", None) is not None
            has_approx = True
            total = int(getattr(store, "n", None)
                        or store.data.shape[0])
        self.planner = planner if planner is not None else QueryPlanner(
            total=total, has_index=has_index, has_approx=has_approx,
            has_selfjoin=selfjoin is not None,
            store=self._store, safety=safety,
            approx_collect=approx_collect or 32)
        if planner is None:
            self.planner.seed_from_metrics(self.metrics)
        self.state_dir = state_dir
        if state_dir is not None:
            self._load_state(state_dir)
        # session-scoped I/O accounting (never perturbs results)
        if hasattr(self._store, "reset_counters"):
            self._store.reset_counters()
        self._plan_lock = threading.Lock()
        # epoch pinning: stamped at admission when the store publishes
        # a frontier (SymbolicStore / WindowView); legacy stores serve
        # unpinned, exactly as before
        epoch_fn = getattr(self._store, "current_epoch", None)
        n_rep = len(self.engines)
        self.queue = CoalescingQueue(
            self._dispatch, validate=self._validate, window_s=window_s,
            max_batch=max_batch, max_queue=max_queue,
            metrics=self.metrics, n_replicas=n_rep,
            place=self._place if n_rep > 1 else None,
            epoch_fn=epoch_fn)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "MatchSession":
        self.queue.start()
        return self

    def close(self, *, drain: bool = True) -> None:
        self.queue.close(drain=drain)
        if self.state_dir is not None:
            self.save_state()

    def kill_replica(self, replica: int) -> int:
        """Take one replica out of service (failure injection / drain):
        pending batches on it are REQUEUED on the survivors, never
        shed.  Returns the number of rerouted requests."""
        return self.queue.kill(replica)

    # -- planner persistence -----------------------------------------------
    def save_state(self, directory: Optional[str] = None) -> str:
        """Persist the planner's learned estimates (tier EWMAs + per-
        replica placement EWMAs) as ``planner.json`` under
        ``directory`` (default: the session's ``state_dir``).  A later
        session built with ``state_dir=`` starts from them instead of
        the modeled priors.  Atomic: written to a temp file, then
        renamed."""
        d = directory or self.state_dir
        if d is None:
            raise ValueError("no directory given and the session has "
                             "no state_dir")
        os.makedirs(d, exist_ok=True)
        with self._plan_lock:
            state = {"planner": self.planner.snapshot(),
                     "replicas": self.planner.replicas_snapshot()}
        path = os.path.join(d, PLANNER_STATE)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f, indent=1)
        os.replace(tmp, path)
        return path

    def _load_state(self, directory: str) -> None:
        path = os.path.join(directory, PLANNER_STATE)
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                state = json.load(f)
        except (OSError, ValueError):
            return                      # unreadable state: start fresh
        self.planner.seed_from_snapshot(state.get("planner") or {},
                                        state.get("replicas") or {})

    def __enter__(self) -> "MatchSession":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=not any(exc))

    # -- client surface ----------------------------------------------------
    def submit(self, query, *, k: int = 1,
               deadline_s: Optional[float] = None,
               tier: Optional[str] = None,
               explain: bool = False) -> MatchRequest:
        """Enqueue one single-query request; returns immediately.  The
        request resolves (served or shed-with-reason) via ``req.wait()``
        — it is never silently dropped."""
        req = MatchRequest(query=np.asarray(query, np.float32), k=int(k),
                           deadline_s=deadline_s, tier=tier,
                           explain=explain)
        self.queue.submit(req)
        return req

    def submit_selfjoin(self, kind: str = "motifs", *, k: int = 1,
                        deadline_s: Optional[float] = None,
                        explain: bool = False) -> MatchRequest:
        """Enqueue one corpus-level self-join request
        (``kind="motifs"`` or ``"discords"``); requires the session to
        have been built with a ``selfjoin=`` engine.  The resolved
        request carries the ``repro.profile.topk_motifs`` /
        ``topk_discords`` tuple list in ``req.result`` — exact (bit-
        identical to the brute-force profile oracle), served from the
        engine's cached matrix profile after the first dispatch."""
        req = MatchRequest(query=np.empty(0, np.float32), k=int(k),
                           deadline_s=deadline_s, tier="selfjoin",
                           explain=explain, kind=kind)
        self.queue.submit(req)
        return req

    def serve(self, queries, *, k: int = 1,
              deadline_s: Optional[float] = None,
              tier: Optional[str] = None,
              timeout: Optional[float] = 60.0) -> List[MatchRequest]:
        """Convenience closed-loop batch: submit every query, wait for
        all of them, return the resolved requests in submit order."""
        reqs = [self.submit(q, k=k, deadline_s=deadline_s, tier=tier)
                for q in np.atleast_2d(np.asarray(queries, np.float32))]
        for r in reqs:
            r.wait(timeout)
        return reqs

    def topk(self, queries, k: int = 1, **kw):
        """Direct synchronous engine passthrough (the oracle the
        service's exactness property tests compare against)."""
        return self.engine.topk(queries, k=k, **kw)

    def calibrate(self, sample=None, *, k: int = 1) -> dict:
        """Prime the planner's rolling estimates by running each
        servable tier once, directly, over ``sample`` (default: one
        median query of zeros — enough for a latency observation).
        Returns the planner snapshot."""
        if sample is None:
            sample = np.zeros((1, self.query_len), np.float32)
        qs = np.atleast_2d(np.asarray(sample, np.float32))
        for tier in TIERS:
            if not self.planner.servable(tier):
                continue
            t0 = time.perf_counter()
            res = self._run_tier(qs, k, tier, None)
            with self._plan_lock:
                self.planner.observe(tier, qs.shape[0],
                                     time.perf_counter() - t0, res)
        return self.planner.snapshot()

    # -- admission ---------------------------------------------------------
    def _validate(self, req: MatchRequest) -> Optional[str]:
        if req.kind != "topk":
            if req.kind not in ("motifs", "discords"):
                return (f"unknown request kind {req.kind!r} "
                        "(kinds: topk, motifs, discords)")
            if self._selfjoin is None:
                return "self-join tier is not configured on this session"
            if req.k < 1:
                return f"k must be >= 1, got {req.k}"
            return None
        q = np.asarray(req.query)
        if q.ndim != 1 or q.shape[0] != self.query_len:
            return (f"query shape {q.shape} does not match service "
                    f"query length ({self.query_len},)")
        if not np.all(np.isfinite(q)):
            return "query contains non-finite values"
        if req.k < 1:
            return f"k must be >= 1, got {req.k}"
        if req.tier is not None:
            if req.tier not in TIERS:
                return f"unknown tier {req.tier!r} (tiers: {TIERS})"
            if not self.planner.servable(req.tier):
                return f"tier {req.tier!r} is not servable here"
        return None

    # -- placement ---------------------------------------------------------
    def _place(self, live, depths) -> int:
        """Queue placement hook (replicated sessions): the planner's
        EWMA arbiter under the plan lock."""
        with self._plan_lock:
            return self.planner.place(live, depths)

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, batch: List[MatchRequest],
                  replica: int = 0) -> None:
        """One coalesced engine round: shed the already-expired, route
        the rest, run one engine call per (tier, k, epoch) group,
        scatter the per-request slices back.  Runs on the dispatcher
        thread (or a replica worker when replicated — ``replica`` says
        which engine serves this batch).

        Requests carrying different pinned epochs never share an
        engine call: the group key includes the epoch's visible row
        count, so each call answers exactly as of its own frontier."""
        now = time.monotonic()
        groups: dict = {}
        selfjoin: List[MatchRequest] = []
        for req in batch:
            if req.t_deadline is not None and now >= req.t_deadline:
                self.queue.shed(req, SHED_DEADLINE,
                                "deadline expired while queued")
                continue
            left = (req.t_deadline - now
                    if req.t_deadline is not None else None)
            if req.kind != "topk":
                # corpus-level requests are forced onto the selfjoin
                # tier (the planner carries its estimate but never
                # routes per-query traffic there)
                with self._plan_lock:
                    req.plan = self.planner.route(k=req.k,
                                                  deadline_left=left,
                                                  tier="selfjoin")
                selfjoin.append(req)
                continue
            with self._plan_lock:
                plan = self.planner.route(k=req.k, deadline_left=left,
                                          tier=req.tier)
            req.plan = plan
            if plan.downgraded and self.metrics is not None:
                self.metrics.counter("serve.downgraded").inc()
            ep_key = (None if req.epoch is None
                      else int(getattr(req.epoch, "n_rows", req.epoch)))
            groups.setdefault((plan.tier, req.k, ep_key),
                              []).append(req)
        for (tier, k, _), reqs in groups.items():
            self._run_group(tier, k, reqs, replica=replica)
        if selfjoin:
            self._run_selfjoin(selfjoin, replica=replica)

    @staticmethod
    def _bucket(qs: np.ndarray) -> np.ndarray:
        """Pad a coalesced batch up to the next power-of-two row count
        (repeating the last query).  Coalescing produces arbitrary batch
        sizes; without bucketing every new size is a fresh XLA compile,
        which serial dispatch never pays — bucketing caps the shape set
        at log2(max_batch) compiles.  Pad rows are real duplicate
        queries, answered independently and sliced off, so per-request
        results are untouched (covered by the batching-neutrality
        property test)."""
        q_n = qs.shape[0]
        pow2 = 1 << (q_n - 1).bit_length()
        if pow2 == q_n:
            return qs
        return np.concatenate(
            [qs, np.repeat(qs[-1:], pow2 - q_n, axis=0)])

    def _run_group(self, tier: str, k: int,
                   reqs: Sequence[MatchRequest], *,
                   replica: int = 0) -> None:
        # re-check deadlines PER DISPATCH, immediately before the
        # engine call: earlier groups of the same coalesced batch take
        # real wall time, so a deadline alive at routing can be dead by
        # now — serving it anyway would bill an expired request as met
        now = time.monotonic()
        live = []
        for req in reqs:
            if req.t_deadline is not None and now >= req.t_deadline:
                self.queue.shed(req, SHED_DEADLINE,
                                "deadline expired before dispatch")
            else:
                live.append(req)
        reqs = live
        if not reqs:
            return
        epoch = reqs[0].epoch           # group key pins one frontier
        qs = self._bucket(np.stack([r.query for r in reqs])
                          .astype(np.float32))
        trace = None
        if any(r.explain for r in reqs):
            from repro.obs import Trace
            trace = Trace("serve.dispatch")
        t0 = time.perf_counter()
        res = self._run_tier(qs, k, tier, trace, epoch=epoch,
                             replica=replica)
        wall = time.perf_counter() - t0
        with self._plan_lock:
            self.planner.observe(tier, qs.shape[0], wall, res)
            if len(self.engines) > 1:
                self.planner.observe_replica(replica, wall)
        ids = getattr(res, "window_ids", None)
        if ids is None:
            ids = res.indices
        kth_lb = getattr(res, "kth_lb", None)
        error_bar = getattr(res, "error_bar", None)
        for i, req in enumerate(reqs):
            req.indices = np.asarray(ids[i]).copy()
            req.distances = np.asarray(res.distances[i]).copy()
            if self._subseq:
                req.rows = np.asarray(res.rows[i]).copy()
                req.starts = np.asarray(res.starts[i]).copy()
            if kth_lb is not None:
                req.kth_lb = float(np.atleast_1d(kth_lb)[i])
            if error_bar is not None:
                req.error_bar = float(np.atleast_1d(error_bar)[i])
            req.tier_served = tier
            req.replica = replica
            req.trace = trace
            req.t_done = time.monotonic()
            if self.metrics is not None:
                self.metrics.histogram(
                    "serve.request_latency_s").observe(req.latency_s)
                self.metrics.counter(f"serve.tier.{tier}").inc()
            req.done.set()

    def _run_selfjoin(self, reqs: Sequence[MatchRequest],
                      replica: int = 0) -> None:
        """One self-join dispatch: compute (or reuse) the engine's
        cached matrix profile, then answer every request from it —
        motifs and discords are pure functions of the profile
        (``repro.profile``), so every coalesced request sees the same
        exact profile.

        Self-join requests are the one kind NOT answered at the
        admission epoch: the profile is a whole-corpus artifact and its
        cache keys on the live corpus, so the answer is as of the
        DISPATCH-time frontier — ``req.epoch`` is re-pinned here to
        report the frontier actually answered."""
        from repro.profile import topk_discords, topk_motifs
        eng = self._selfjoin
        ep_fn = getattr(self._store, "current_epoch", None)
        trace = None
        if any(r.explain for r in reqs):
            from repro.obs import Trace
            trace = Trace("serve.selfjoin")
        dispatch_epoch = ep_fn() if ep_fn is not None else None
        t0 = time.perf_counter()
        prof = eng.profile(trace=trace)
        wall = time.perf_counter() - t0
        with self._plan_lock:
            self.planner.observe("selfjoin", len(reqs), wall, prof)
        for req in reqs:
            if req.kind == "motifs":
                req.result = topk_motifs(prof, eng.view.locate, req.k)
            else:
                req.result = topk_discords(prof, eng.view.locate, req.k)
            req.tier_served = "selfjoin"
            req.replica = replica
            req.epoch = dispatch_epoch
            req.trace = trace
            req.t_done = time.monotonic()
            if self.metrics is not None:
                self.metrics.histogram(
                    "serve.request_latency_s").observe(req.latency_s)
                self.metrics.counter("serve.tier.selfjoin").inc()
            req.done.set()

    def _run_tier(self, qs: np.ndarray, k: int, tier: str, trace, *,
                  epoch=None, replica: int = 0):
        """One engine call for one (tier, k, epoch) group on one
        replica.  Exact tiers call ``engine.topk`` with exactly the
        source (and epoch) a direct caller would pass — the
        bit-identity contract depends on adding nothing else."""
        collect = (self._approx_collect
                   if self._approx_collect is not None else None)
        eng = self.engines[replica]
        if self._subseq:
            if tier == "approx":
                return eng.topk_approx(qs, k=k, collect=collect,
                                       trace=trace, epoch=epoch)
            return eng.topk(qs, k=k,
                            use_index=(tier == "index"),
                            trace=trace, epoch=epoch)
        if tier == "approx":
            return eng.topk_approx(qs, k=k, collect=collect,
                                   trace=trace, epoch=epoch)
        return eng.topk(qs, k=k,
                        source="index" if tier == "index"
                        else None, trace=trace, epoch=epoch)

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> dict:
        """Service-level JSON view: planner estimates + queue depth."""
        return {"planner": self.planner.snapshot(),
                "replica_wall_s": self.planner.replicas_snapshot(),
                "n_replicas": len(self.engines),
                "live_replicas": self.queue.live_replicas(),
                "queue_depth": self.queue.depth(),
                "window_s": self.queue.window_s,
                "max_batch": self.queue.max_batch}
