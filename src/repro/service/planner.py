"""Telemetry-driven query planner: route each request to a tier.

The service front-end (``repro.service.session``) serves three tiers
over one engine:

* ``"index"``  — exact top-k through the split-tree candidate source
  (sublinear candidates examined; requires ``store.build_index()``).
* ``"linear"`` — exact top-k through the full lower-bound sweep.
* ``"approx"`` — the anytime tier: bounded-collect indexed matching
  (``TreeCandidates`` approximate mode) whose k-th-best lower bound is
  reported back as a per-query error bar; without an index it falls
  back to representation-top-k verification (no certificate).

A session configured with a ``repro.profile.SelfJoinEngine``
additionally carries the corpus-level ``"selfjoin"`` tier (exact
motif/discord requests over the matrix profile).  It is deliberately
NOT in ``TIERS`` — per-query routing never lands there; only
``kind="motifs"`` / ``"discords"`` requests are forced onto it.

Routing combines two signals:

* a **modeled cost** per tier — candidate-count priors scaled by the
  corpus size, billed through the store's I/O cost model
  (``RawStore.modeled_io_seconds``) plus a per-candidate verification
  rate.  This is what the planner answers with before it has seen any
  traffic.
* a **rolling estimate** learned from observation — the obs registry's
  per-call latency and candidate counts (``observe`` after every
  dispatch, plus ``seed_from_metrics`` to adopt a registry's existing
  ``match.topk_latency_s`` history at startup) folded in as an EWMA.
  After a few dispatches the learned estimate dominates the prior.

Deadline handling: a request whose remaining deadline cannot cover the
chosen exact tier's estimated latency (times a safety factor) is
DOWNGRADED to the approximate tier rather than shed — the anytime
tier's error bar makes the degradation measurable, which is the
contract that lets the service keep its never-silently-drop promise
while staying inside latency budgets.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

#: The routable tiers, in the order the planner prefers them when
#: estimates tie ("index" first: it never examines more than linear).
TIERS = ("index", "linear", "approx")

#: Candidate-count priors as a fraction of the corpus, used until real
#: observations replace them.  Linear's prior reflects the paper's
#: pruned-scan behaviour (a few percent of rows examined); the index
#: prior is an order of magnitude tighter; approx is O(k).
_CAND_FRACTION = {"index": 0.005, "linear": 0.05}

#: Per-candidate verification cost prior (seconds/row) and fixed
#: per-dispatch overhead prior — replaced by EWMAs as traffic arrives.
_VERIFY_S_PER_ROW = 2e-6
_DISPATCH_OVERHEAD_S = 2e-3


@dataclass
class PlanDecision:
    """One routing decision, attached to the request as ``req.plan``."""

    tier: str      # one of TIERS
    reason: str    # "cost" | "deadline" | "forced" | "only_tier"
    est_s: float   # planner's latency estimate for this dispatch

    @property
    def downgraded(self) -> bool:
        return self.reason == "deadline"


class _TierEstimate:
    """EWMA of observed per-dispatch wall time and per-query candidate
    count for one tier, seeded from the modeled prior."""

    __slots__ = ("wall_s", "cands", "n_obs")

    def __init__(self, wall_s: float, cands: float):
        self.wall_s = float(wall_s)
        self.cands = float(cands)
        self.n_obs = 0

    def observe(self, wall_s: float, cands: float, alpha: float) -> None:
        if self.n_obs == 0:          # first observation replaces the prior
            self.wall_s = float(wall_s)
            self.cands = float(cands)
        else:
            self.wall_s += alpha * (float(wall_s) - self.wall_s)
            self.cands += alpha * (float(cands) - self.cands)
        self.n_obs += 1


class QueryPlanner:
    """Cost-model + rolling-estimate router (see module docstring).

    Parameters
    ----------
    total:       corpus size (rows / windows) for the modeled priors.
    has_index:   whether the exact "index" tier is servable.
    has_approx:  whether the "approx" tier is servable (the subsequence
                 engine's anytime tier needs the window index).
    store:       optional ``RawStore``-protocol object; its
                 ``modeled_io_seconds`` prices the candidate priors.
    safety:      deadline downgrade margin: an exact tier is considered
                 deadline-threatened when ``est * safety`` exceeds the
                 remaining budget.
    alpha:       EWMA smoothing factor for observations.
    """

    def __init__(self, *, total: int = 0, has_index: bool = False,
                 has_approx: bool = True, has_selfjoin: bool = False,
                 store=None, safety: float = 2.0,
                 alpha: float = 0.3, approx_collect: int = 32):
        self.total = int(total)
        self.has_index = bool(has_index)
        self.has_approx = bool(has_approx)
        self.has_selfjoin = bool(has_selfjoin)
        self.safety = float(safety)
        self.alpha = float(alpha)
        self._store = store
        self._est = {
            "index": _TierEstimate(*self._prior("index", approx_collect)),
            "linear": _TierEstimate(*self._prior("linear", approx_collect)),
            "approx": _TierEstimate(*self._prior("approx", approx_collect)),
        }
        if self.has_selfjoin:
            # the self-join tier answers corpus-level requests (motifs /
            # discords): its prior is a full-corpus candidate sweep, and
            # the session's profile cache makes repeat requests all but
            # free — the EWMA learns that after the first dispatch.  It
            # is NOT in TIERS: per-query requests never route to it.
            self._est["selfjoin"] = _TierEstimate(
                self.modeled_cost(float(self.total)), float(self.total))
        # per-replica dispatch-wall EWMAs (replicated sessions): the
        # placement signal behind ``place`` — learned, not configured
        self._replica_wall: dict = {}

    # -- modeled cost ------------------------------------------------------
    def _prior(self, tier: str, approx_collect: int):
        if tier == "approx":
            cands = float(approx_collect)
        else:
            cands = max(32.0, _CAND_FRACTION[tier] * self.total)
        return self.modeled_cost(cands), cands

    def modeled_cost(self, cands: float) -> float:
        """Seconds to verify ``cands`` candidates under the store's I/O
        model plus the verification-rate and dispatch-overhead priors."""
        io_s = 0.0
        if self._store is not None and hasattr(self._store,
                                               "modeled_io_seconds"):
            io_s = float(self._store.modeled_io_seconds(int(cands), 1))
        return _DISPATCH_OVERHEAD_S + cands * _VERIFY_S_PER_ROW + io_s

    # -- telemetry in ------------------------------------------------------
    def estimate(self, tier: str) -> float:
        """Current per-dispatch latency estimate for ``tier``."""
        return self._est[tier].wall_s

    def observe(self, tier: str, q_n: int, wall_s: float, res) -> None:
        """Fold one dispatch into the tier's rolling estimate.  ``res``
        is the engine result (its ``raw_accesses`` are the observed
        candidate counts the cost model learns from)."""
        cands = float(res.raw_accesses.mean()) if q_n else 0.0
        self._est[tier].observe(wall_s, cands, self.alpha)

    def observe_replica(self, replica: int, wall_s: float) -> None:
        """Fold one dispatch's wall time into the replica's EWMA (the
        placement signal for replicated sessions)."""
        rid = int(replica)
        prev = self._replica_wall.get(rid)
        if prev is None:
            self._replica_wall[rid] = float(wall_s)
        else:
            self._replica_wall[rid] = \
                prev + self.alpha * (float(wall_s) - prev)

    def place(self, live, depths) -> int:
        """Pick a replica for one batch: minimize (queued batches + 1)
        × the replica's EWMA dispatch wall — i.e. expected time until
        the batch would finish there.  Replicas never observed use the
        mean of the observed EWMAs (or the exact-tier estimate when
        none exist), so a fresh replica is neither shunned nor
        blindly preferred.  Ties break on the lowest replica id —
        deterministic placement under equal load."""
        if not live:
            raise ValueError("place() needs at least one live replica")
        known = [w for r, w in self._replica_wall.items() if r in live]
        default = (sum(known) / len(known)) if known else max(
            self.estimate("index") if self.has_index else 0.0,
            self.estimate("linear"))
        return min(live, key=lambda r: (
            (depths.get(r, 0) + 1)
            * self._replica_wall.get(r, default), r))

    def seed_from_metrics(self, metrics) -> None:
        """Adopt an obs registry's existing latency history as the
        exact-tier prior (``match.topk_latency_s`` / the subsequence
        twin) — the service then starts from observed reality instead
        of the modeled prior when the registry has seen traffic."""
        if metrics is None:
            return
        for name in ("match.topk_latency_s", "subseq.topk_latency_s"):
            snap = metrics.snapshot().get("histograms", {}).get(name)
            if not snap or not snap.get("count"):
                continue
            from repro.obs.metrics import Histogram
            p50 = Histogram.from_dict(snap).quantile(0.5)
            if p50 == p50 and p50 != float("inf"):     # not NaN/inf
                for tier in ("index", "linear"):
                    if self._est[tier].n_obs == 0:
                        self._est[tier].wall_s = float(p50)
            return

    # -- routing -----------------------------------------------------------
    def servable(self, tier: str) -> bool:
        if tier == "index":
            return self.has_index
        if tier == "approx":
            return self.has_approx
        if tier == "selfjoin":
            return self.has_selfjoin
        return tier == "linear"

    def route(self, *, k: int = 1,
              deadline_left: Optional[float] = None,
              tier: Optional[str] = None) -> PlanDecision:
        """Pick the tier for one request.

        ``tier``: explicit caller override (validated upstream by the
        session's admission check).  ``deadline_left``: remaining
        latency budget in seconds; when the cheapest exact tier cannot
        meet it (with the safety margin), the request is downgraded to
        the approximate tier with ``reason="deadline"``.
        """
        if tier is not None:
            return PlanDecision(tier, "forced", self.estimate(tier))
        if self.has_index and \
                self.estimate("index") <= self.estimate("linear"):
            exact = "index"
        else:
            exact = "linear"
        est = self.estimate(exact)
        if deadline_left is not None and self.has_approx \
                and est * self.safety > deadline_left:
            return PlanDecision("approx", "deadline",
                                self.estimate("approx"))
        reason = "cost" if self.has_index else "only_tier"
        return PlanDecision(exact, reason, est)

    # -- reporting / persistence -------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON view of the rolling tier estimates (launcher /
        bench reporting, and the persisted half of the planner state —
        see ``seed_from_snapshot``)."""
        return {tier: {"wall_s": e.wall_s, "cands": e.cands,
                       "n_obs": e.n_obs}
                for tier, e in self._est.items()}

    def replicas_snapshot(self) -> dict:
        """Plain-JSON view of the per-replica EWMAs (persisted next to
        ``snapshot()`` by the session's save path)."""
        return {str(r): float(w) for r, w in self._replica_wall.items()}

    def seed_from_snapshot(self, snap: dict,
                           replicas: Optional[dict] = None) -> None:
        """Adopt a persisted ``snapshot()`` as this planner's starting
        estimates — a restarted service plans from the traffic the
        previous process observed instead of the modeled priors.  Only
        tiers this planner has NOT yet observed are seeded (live
        observations always beat history); unknown tiers in the
        snapshot are ignored.  ``replicas`` seeds the per-replica
        placement EWMAs the same way."""
        for tier, e in (snap or {}).items():
            est = self._est.get(tier)
            if est is None or est.n_obs:
                continue
            try:
                est.wall_s = float(e["wall_s"])
                est.cands = float(e["cands"])
                est.n_obs = int(e.get("n_obs", 0))
            except (KeyError, TypeError, ValueError):
                continue
        for r, w in (replicas or {}).items():
            try:
                rid = int(r)
            except (TypeError, ValueError):
                continue
            if rid not in self._replica_wall:
                self._replica_wall[rid] = float(w)
