"""Architecture registry.

``get_config(name)`` resolves any assigned architecture (and the reduced
smoke-test variants via ``reduced``).  ``ARCHITECTURES`` lists the 10
assigned IDs in assignment order.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeSpec, LayerSpec, SHAPES, shape_for, reduced,
    attn, mamba, rwkv, ATTN, MAMBA, RWKV,
)

_MODULES = {
    "smollm-135m": "smollm_135m",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "qwen3-0.6b": "qwen3_0p6b",
    "gemma3-12b": "gemma3_12b",
    "paligemma-3b": "paligemma_3b",
    "jamba-1.5-large-398b": "jamba_1p5_large",
    "llama4-scout-17b-a16e": "llama4_scout",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-medium": "whisper_medium",
    "rwkv6-7b": "rwkv6_7b",
}

ARCHITECTURES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(
            f"unknown arch {name!r}; available: {', '.join(ARCHITECTURES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
