"""paligemma-3b — VLM: SigLIP frontend (STUB) + gemma decoder backbone.

18L d_model=2048 8H (GQA kv=1) head_dim=256 d_ff=16384 vocab=257216
[arXiv:2407.07726]

The modality frontend is a stub: ``input_specs()`` supplies 256 precomputed
patch embeddings (B, 256, d_model); the backbone applies prefix-LM masking
(bidirectional over image + prompt prefix).
"""

from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    pattern=(attn(),),
    rope_base=10_000.0,
    prefix_lm=True,
    prefix_len=256,                  # SigLIP patch embeddings (stubbed)
    tie_embeddings=True,
)
