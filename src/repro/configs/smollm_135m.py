"""smollm-135m — llama-arch small dense LM.

30L d_model=576 9H (GQA kv=3) head_dim=64 d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M]
"""

from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49_152,
    pattern=(attn(),),
    rope_base=10_000.0,
    tie_embeddings=True,
)
