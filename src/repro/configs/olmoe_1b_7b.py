"""olmoe-1b-7b — MoE with 64 experts, top-8 routing, MHA.

16L d_model=2048 16H (kv=16, i.e. MHA) head_dim=128 d_ff=1024/expert
vocab=50304, 64 experts top-8 [arXiv:2409.02060]
"""

from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50_304,
    pattern=(attn(moe=True),),
    n_experts=64,
    moe_top_k=8,
    d_ff_expert=1024,
    rope_base=10_000.0,
    qk_norm=True,
    tie_embeddings=False,
)
