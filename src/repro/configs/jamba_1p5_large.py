"""jamba-1.5-large-398b — hybrid Mamba + attention 1:7 interleave with MoE.

72L d_model=8192 64H (GQA kv=8) head_dim=128 d_ff=24576 vocab=65536,
MoE 16 experts top-2 on every other layer. [arXiv:2403.19887]

Pattern (one Jamba block, repeated 9x): [m, m*, m, a*, m, m*, m, m*]
where * marks MoE layers (every 2nd) and `a` is the single attention layer.
SSM state is O(1) => long_500k decode cell runs.
"""

from repro.configs.base import ModelConfig, attn, mamba

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    pattern=(
        mamba(),
        mamba(moe=True),
        mamba(),
        attn(moe=True),
        mamba(),
        mamba(moe=True),
        mamba(),
        mamba(moe=True),
    ),
    n_experts=16,
    moe_top_k=2,
    d_ff_expert=24_576,
    rope_base=10_000.0,
    use_rope=False,                  # jamba uses no positional encoding
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    tie_embeddings=False,
)
