"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.

32L d_model=4096 (64 heads x 64 head_dim) d_ff=14336 vocab=65536
[arXiv:2404.05892]

O(1) recurrent state => decode and long_500k cells are state-carrying
recurrent steps; no KV cache exists.
"""

from repro.configs.base import ModelConfig, rwkv

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=32,                      # unused by rwkv mixing (kept for shape API)
    n_kv_heads=32,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    pattern=(rwkv(),),
    use_rope=False,
    rwkv_head_dim=64,
    rwkv_lora_dim=64,
    tie_embeddings=False,
)
