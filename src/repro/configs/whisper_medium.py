"""whisper-medium — encoder-decoder audio model; conv frontend is a STUB.

24L (enc) + 24L (dec) d_model=1024 16H (MHA) head_dim=64 d_ff=4096
vocab=51865 [arXiv:2212.04356]

``input_specs()`` supplies 1500 precomputed mel-frame embeddings
(B, 1500, d_model) in place of the conv1d frontend.  Decoder layers carry
cross-attention against the encoder output.  Absolute (sinusoidal)
positions, no RoPE.  Full attention both sides => long_500k skipped.
"""

from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,                     # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    pattern=(attn(cross_attn=True),),
    use_rope=False,
    n_encoder_layers=24,
    encoder_seq=1500,
    tie_embeddings=True,
)
