"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early fusion.

48L d_model=5120 40H (GQA kv=8) head_dim=128 d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E]

Simplifications noted in DESIGN.md: interleaved NoPE layers kept as plain
RoPE; every layer is MoE (Scout's interleave step is 1) with one shared
expert.  Full attention => long_500k skipped.
"""

from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    pattern=(attn(moe=True),),
    n_experts=16,
    moe_top_k=1,
    n_shared_experts=1,
    d_ff_expert=8192,
    rope_base=500_000.0,
    tie_embeddings=False,
)
