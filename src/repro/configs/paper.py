"""The paper's own experimental configurations (Tables 3 & 4) as data.

``DATASETS`` mirrors Table 3 (dimensions) and §4.2 (construction);
``CONFIGS`` mirrors Table 4: the equal-bit-budget configuration grids per
technique and dataset family.  benchmarks/bench_tlb.py and friends draw
from these; keeping them here makes the reproduction surface auditable in
one place.
"""

from __future__ import annotations

# --- Table 3: dataset dimensions -------------------------------------------
DATASETS = {
    "season": dict(n=1000, lengths=[480, 960, 1440, 1920], season_len=10,
                   strengths="1-99% (+-0.5pp)"),
    "trend": dict(n=1000, lengths=[480, 960, 1440, 1920],
                  strengths="1-99% (+-0.5pp)"),
    "metering": dict(n=5958, length=21_840, season_len=48,
                     mean_daily_strength=0.183, surrogate="metering_like"),
    "economy": dict(n=6400, length=300, interval="monthly",
                    surrogate="economy_like"),
    "season_large": dict(n=[6_510_417, 13_020_833], length=960,
                         strengths=[0.10, 0.50, 0.90],
                         note="50/100 Gb efficiency sets; container-scale "
                              "surrogate uses n=20,000 (EXPERIMENTS.md)"),
}

# --- Table 4: equal-budget technique configurations -------------------------
# synthetic: 320-bit budget
SYNTH_SAX = [dict(W=32, A=1024), dict(W=40, A=256), dict(W=48, A=101),
             dict(W=96, A=10)]
SYNTH_SSAX = [dict(W=24, A_res=1024, A_seas=256),
              dict(W=48, A_res=32, A_seas=256),
              dict(W=48, A_res=64, A_seas=9)]
SYNTH_TSAX_ATR = [32, 128, 1024]     # A_res = 2**((320 - ld(A_tr)) // W)

# metering: 3640-bit budget
METERING_SAX = [dict(W=455, A=256), dict(W=520, A=128),
                dict(W=728, A=32), dict(W=910, A=16)]
METERING_SSAX_ASEAS = [16, 64, 256, 1024]   # W=455; A_res from the budget

# economy: 80-bit budget
ECONOMY_SAX = [dict(W=10, A=256), dict(W=12, A=101), dict(W=15, A=40),
               dict(W=20, A=16), dict(W=30, A=6)]
ECONOMY_1DSAX_AS = [8, 16, 32]               # A_a = 2**((80/W) - ld(A_s))
ECONOMY_TSAX_ATR = [16, 64, 256, 1024]

LOOKUP_TABLE_LIMIT_BYTES = 4 * 1024 * 1024   # paper: <= 4 Mb => A <= 1024
