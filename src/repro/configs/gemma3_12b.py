"""gemma3-12b — dense with 5 local (sliding-window 1024) : 1 global interleave.

48L d_model=3840 16H (GQA kv=8) head_dim=256 d_ff=15360 vocab=262144, 128k ctx
[hf:google/gemma-3 family]

Sub-quadratic in the 5/6 local layers => long_500k decode cell runs; local
layers keep a ring-buffer KV cache of the window only.
"""

from repro.configs.base import ModelConfig, attn

_LOCAL_WINDOW = 1024

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    pattern=(
        attn(window=_LOCAL_WINDOW),
        attn(window=_LOCAL_WINDOW),
        attn(window=_LOCAL_WINDOW),
        attn(window=_LOCAL_WINDOW),
        attn(window=_LOCAL_WINDOW),
        attn(),                       # global layer
    ),
    rope_base=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
)
