"""Config system for the LM substrate.

A ``ModelConfig`` fully describes one architecture: geometry, the repeating
per-layer block ``pattern`` (attention / mamba / rwkv, sliding windows, MoE),
modality stubs, and serving metadata.  One module per assigned architecture
lives next to this file; ``repro.configs.get_config(name)`` resolves them.

Input shapes are the four assigned cells (train_4k / prefill_32k / decode_32k /
long_500k); ``shape_for`` returns the concrete ``ShapeSpec`` and knows which
cells an architecture must skip (``long_500k`` on pure full-attention archs).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

ATTN = "attn"
MAMBA = "mamba"
RWKV = "rwkv"


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating block pattern."""

    kind: str = ATTN            # attn | mamba | rwkv
    window: Optional[int] = None  # sliding-window size (attn only); None = global
    moe: bool = False           # MoE MLP instead of dense MLP
    cross_attn: bool = False    # decoder cross-attention (enc-dec models)

    def __post_init__(self):
        assert self.kind in (ATTN, MAMBA, RWKV), self.kind


def attn(window: Optional[int] = None, moe: bool = False,
         cross_attn: bool = False) -> LayerSpec:
    return LayerSpec(kind=ATTN, window=window, moe=moe, cross_attn=cross_attn)


def mamba(moe: bool = False) -> LayerSpec:
    return LayerSpec(kind=MAMBA, moe=moe)


def rwkv() -> LayerSpec:
    return LayerSpec(kind=RWKV)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple = (LayerSpec(),)   # repeating unit; len divides n_layers

    # attention details
    rope_base: float = 10_000.0
    use_rope: bool = True       # False => sinusoidal absolute positions
    qk_norm: bool = False
    prefix_lm: bool = False     # bidirectional attention over the prefix

    # embeddings / head
    tie_embeddings: bool = True
    vocab_pad_multiple: int = 256

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0        # defaults to d_ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # Mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0      # 0 => d_model // 16

    # RWKV-6
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64     # low-rank dim of the data-dependent decay MLPs

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0        # stub frontend sequence length (whisper: 1500)

    # multimodal prefix stub (paligemma: 256 patch embeddings)
    prefix_len: int = 0

    norm_eps: float = 1e-6
    # numerics
    param_dtype: str = "float32"     # master weights
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: pattern of len {len(self.pattern)} does not divide "
            f"{self.n_layers} layers")
        if self.n_experts:
            assert self.moe_top_k > 0

    # -- derived geometry ------------------------------------------------
    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def d_ff_e(self) -> int:
        return self.d_ff_expert or self.d_ff

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_enc_dec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_sub_quadratic(self) -> bool:
        """True when the arch can serve very long contexts: every layer is
        either attention-free (mamba / rwkv) or sliding-window attention,
        except for a bounded number of global-attention layers whose decode
        cost is O(S) per token (gemma3-style interleave counts; a pure
        full-attention stack does not)."""
        kinds = [l.kind for l in self.pattern]
        if all(k in (MAMBA, RWKV) for k in kinds):
            return True
        n_global_attn = sum(
            1 for l in self.pattern if l.kind == ATTN and l.window is None)
        n_local = sum(
            1 for l in self.pattern
            if l.kind != ATTN or l.window is not None)
        # hybrid / local-global interleaves: most layers must be cheap
        return n_local > 0 and n_global_attn * 2 <= len(self.pattern)

    # -- parameter counting (analytical; used for 6ND and roofline) ------
    def layer_specs(self):
        """All ``n_layers`` layer specs, pattern expanded."""
        return list(self.pattern) * self.pattern_repeats

    def attn_params(self, cross: bool = False) -> int:
        p = self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim
        p += self.q_dim * self.d_model
        if self.qk_norm:
            p += 2 * self.head_dim
        if cross:  # a full second attention stack against encoder states
            p += self.attn_params(cross=False)
        return p

    def dense_mlp_params(self) -> int:
        return 3 * self.d_model * self.d_ff      # swiglu: gate, up, down

    def moe_mlp_params(self) -> tuple[int, int]:
        """(total, active) MoE MLP params per layer."""
        per_exp = 3 * self.d_model * self.d_ff_e
        router = self.d_model * self.n_experts
        shared = self.n_shared_experts * 3 * self.d_model * self.d_ff
        total = self.n_experts * per_exp + router + shared
        active = self.moe_top_k * per_exp + router + shared
        return total, active

    def mamba_params(self) -> int:
        di, n, r = self.d_inner, self.mamba_d_state, self.dt_rank
        p = self.d_model * 2 * di                  # in_proj (x & gate)
        p += di * self.mamba_d_conv + di           # depthwise conv (+ bias)
        p += di * (r + 2 * n)                      # x_proj -> dt, B, C
        p += r * di + di                           # dt_proj
        p += di * n + di                           # A_log, D
        p += di * self.d_model                     # out_proj
        return p

    def rwkv_params(self) -> int:
        d, r = self.d_model, self.rwkv_lora_dim
        tm = 4 * d * d                              # r, k, v, out projections
        tm += d * d                                 # gate
        tm += 5 * (d * r + r * d)                   # ddlerp low-rank (w,k,v,r,g)
        tm += d * r + r * d                         # decay lora
        tm += 7 * d                                 # mu_x, mu_rkvwg(5d), w_base
        tm += 3 * d                                 # u bonus, group-ln w/b
        cm = 2 * d * self.d_ff                      # rwkv channel-mix: k, v
        cm += d * d + 2 * d                         # receptance, mu_k, mu_r
        return tm + cm

    def params_per_layer(self, spec: LayerSpec) -> tuple[int, int]:
        """(total, active) params of one layer, norms included."""
        norms = 2 * self.d_model
        if spec.kind == ATTN:
            mix = self.attn_params(cross=spec.cross_attn)
            if spec.cross_attn:
                norms += self.d_model
        elif spec.kind == MAMBA:
            mix = self.mamba_params()
        else:
            mix = self.rwkv_params()
        if spec.kind == RWKV:
            return mix + norms, mix + norms
        if spec.moe:
            tot, act = self.moe_mlp_params()
            return mix + tot + norms, mix + act + norms
        mlp = self.dense_mlp_params()
        return mix + mlp + norms, mix + mlp + norms

    def param_counts(self) -> tuple[int, int]:
        """(total, active) parameters of the full model."""
        tot = act = 0
        for spec in self.layer_specs():
            t, a = self.params_per_layer(spec)
            tot, act = tot + t, act + a
        emb = self.padded_vocab * self.d_model
        tot += emb
        act += emb
        if not self.tie_embeddings:
            tot += emb
            act += emb
        if self.is_enc_dec:
            enc = self.n_encoder_layers * (
                self.attn_params() + self.dense_mlp_params() + 2 * self.d_model)
            enc += self.d_model                  # encoder final norm
            tot += enc
            act += enc
        tot += self.d_model  # final norm
        act += self.d_model
        return tot, act


# ---------------------------------------------------------------------------
# Input shapes (the four assigned cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def shape_for(cfg: ModelConfig, shape_name: str) -> Optional[ShapeSpec]:
    """Resolve a shape cell for an arch; None => documented skip."""
    spec = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.is_sub_quadratic:
        return None             # pure full-attention arch: skip (DESIGN.md)
    return spec


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    # keep one pattern repetition, shrink every width
    small = dict(
        n_layers=len(cfg.pattern),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vocab_pad_multiple=32,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.n_experts else 0,
        d_ff_expert=64 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        mamba_d_state=8,
        mamba_dt_rank=8,
        rwkv_head_dim=16,
        rwkv_lora_dim=8,
        n_encoder_layers=2 if cfg.is_enc_dec else 0,
        encoder_seq=16 if cfg.is_enc_dec else 0,
        prefix_len=4 if cfg.prefix_len else 0,
        name=cfg.name + "-reduced",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
