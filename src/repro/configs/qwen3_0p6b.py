"""qwen3-0.6b — dense with qk-norm, GQA.

28L d_model=1024 16H (GQA kv=8) head_dim=128 d_ff=3072 vocab=151936
[hf:Qwen/Qwen3-8B family]
"""

from repro.configs.base import ModelConfig, attn

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151_936,
    pattern=(attn(),),
    rope_base=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
)
