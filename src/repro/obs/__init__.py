"""Observability subsystem: per-query traces, process metrics, EXPLAIN.

Three pieces (ROADMAP.md §Observability documents the schema):

* :mod:`repro.obs.trace` — ``Trace`` / ``Span``: per-engine-call query
  traces (phase wall-clocks with ``block_until_ready`` fencing,
  verification-round telemetry, candidate / I/O / transfer counters).
  Engines take ``trace=None`` and record nothing unless one is passed
  (zero-overhead-when-off; neutrality is property-tested).
* :mod:`repro.obs.metrics` — ``MetricsRegistry`` (+ the process-wide
  ``REGISTRY``): named counters / gauges / fixed-log-bucket histograms
  with deterministic snapshot merges and plain-JSON export, embedded in
  ``results/BENCH_<suite>.json``.
* :mod:`repro.obs.explain` — ``render_trace`` (the ``--explain``
  per-query plan report) and ``check_trace`` (the CI gate's span /
  device-invariant validation).
"""

from repro.obs.explain import REQUIRED_SPANS, check_trace, render_trace
from repro.obs.metrics import (LATENCY_BUCKETS, REGISTRY, Counter, Gauge,
                               Histogram, MetricsRegistry, merge_snapshots)
from repro.obs.trace import Span, Trace, block_until_ready, maybe_span

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS", "MetricsRegistry",
    "REGISTRY", "REQUIRED_SPANS", "Span", "Trace", "block_until_ready",
    "check_trace", "maybe_span", "merge_snapshots", "render_trace",
]
