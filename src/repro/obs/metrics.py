"""Process-wide metrics: counters, gauges, and fixed-bucket histograms.

The registry is the aggregation side of the observability subsystem
(``repro.obs``): engines and benchmarks increment named metrics, and a
``snapshot()`` is a plain-JSON dict embeddable in
``results/BENCH_<suite>.json`` payloads.

Histograms use FIXED log-spaced bucket bounds (``LATENCY_BUCKETS``:
quarter-decade steps from 1 µs to ~178 s) rather than per-instance
adaptive bounds, so two snapshots taken on different hosts / suites /
processes merge deterministically by adding bucket counts
(:func:`merge_snapshots`) — no re-binning, no bound negotiation.

Everything here is plain Python (no jax, no numpy required at import
time): recording a metric is a dict lookup + float add, cheap enough to
leave on in benchmarks, and absent entirely from the matching hot loops
unless a caller opted in (engines take ``metrics=None`` by default).

Thread safety: the matching service (``repro.service``) records from
submitter threads concurrently with its dispatcher thread, so every
read-modify-write (``inc`` / ``observe`` / ``merge`` / registry
get-or-create) holds one shared module lock — a float add under a lock
is still cheap, and exact totals under concurrency are what the
merged-snapshot determinism contract promises.
"""

from __future__ import annotations

from bisect import bisect_left
from threading import Lock
from typing import Dict, Optional, Tuple

# One lock for all metric mutation: contention is negligible (recording
# is nanoseconds) and a shared lock avoids a per-metric slot.
_REC_LOCK = Lock()

# Quarter-decade log-spaced latency bounds, 1e-6 s .. ~1.78e2 s.  The
# tuple is a module-level constant on purpose: every histogram in every
# process uses the SAME bounds, which is what makes snapshot merges a
# pure bucket-count addition.
LATENCY_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (i / 4.0 - 6.0) for i in range(34))


class Counter:
    """Monotonic accumulator (float so byte / second totals fit)."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        with _REC_LOCK:
            self.value += float(v)


class Gauge:
    """Last-written value (e.g. a per-run pruning power)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        with _REC_LOCK:
            self.value = float(v)


class Histogram:
    """Fixed-bound histogram; bucket ``i`` counts observations ``v <=
    bounds[i]`` (first such bound), the final slot is overflow."""

    kind = "histogram"
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Tuple[float, ...] = LATENCY_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        with _REC_LOCK:
            self.counts[bisect_left(self.bounds, v)] += 1
            self.sum += v
            self.count += 1

    def merge(self, other: "Histogram") -> None:
        if self.bounds != other.bounds:
            raise ValueError("histogram bounds differ; merges are only "
                             "deterministic over identical fixed buckets")
        with _REC_LOCK:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self.sum += other.sum
            self.count += other.count

    def quantile(self, q: float) -> float:
        """Upper bucket bound at quantile ``q`` (conservative estimate;
        NaN when empty, +inf when the quantile lands in overflow)."""
        if self.count == 0:
            return float("nan")
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                return (self.bounds[i] if i < len(self.bounds)
                        else float("inf"))
        return float("inf")

    def to_dict(self) -> dict:
        with _REC_LOCK:                  # consistent (counts, sum, count)
            return {"bounds": list(self.bounds),
                    "counts": list(self.counts),
                    "sum": self.sum, "count": self.count}

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(tuple(d["bounds"]))
        h.counts = [int(c) for c in d["counts"]]
        h.sum = float(d["sum"])
        h.count = int(d["count"])
        return h


class MetricsRegistry:
    """Named metric store with get-or-create accessors.

    A name is bound to one metric kind for the registry's lifetime;
    asking for the same name as a different kind is a programming error
    and raises immediately rather than silently shadowing.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            with _REC_LOCK:
                m = self._metrics.setdefault(name, cls(*args))
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not a "
                            f"{cls.kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = LATENCY_BUCKETS) -> Histogram:
        return self._get(name, Histogram, bounds)

    def reset(self) -> None:
        """Drop every metric — the between-suite boundary in
        ``benchmarks/run.py`` (each ``BENCH_<suite>.json`` snapshot then
        covers exactly one suite, no bleed)."""
        self._metrics.clear()

    def snapshot(self) -> dict:
        """Plain-JSON view: ``{"counters": {name: value}, "gauges":
        {...}, "histograms": {name: {bounds, counts, sum, count}}}``."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        # list() snapshots the key set atomically; per-metric reads are
        # consistent (Histogram.to_dict holds the recording lock)
        for name, m in sorted(list(self._metrics.items())):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.to_dict()
        return out


def merge_snapshots(a: Optional[dict], b: Optional[dict]) -> dict:
    """Deterministic snapshot merge: counters add, gauges last-wins,
    histograms add bucket counts (fixed shared bounds make this exact
    regardless of which process observed what)."""
    out = {"counters": {}, "gauges": {}, "histograms": {}}
    for snap in (a, b):
        if not snap:
            continue
        for n, v in snap.get("counters", {}).items():
            out["counters"][n] = out["counters"].get(n, 0.0) + v
        for n, v in snap.get("gauges", {}).items():
            out["gauges"][n] = v
        for n, d in snap.get("histograms", {}).items():
            if n in out["histograms"]:
                h = Histogram.from_dict(out["histograms"][n])
                h.merge(Histogram.from_dict(d))
                out["histograms"][n] = h.to_dict()
            else:
                out["histograms"][n] = {k: (list(v) if isinstance(v, list)
                                            else v) for k, v in d.items()}
    return out


#: Process-wide default registry — what ``benchmarks/run.py`` snapshots
#: per suite and ``launch/serve.py`` / ``launch/match.py`` report from.
REGISTRY = MetricsRegistry()
