"""EXPLAIN rendering + trace validation.

``render_trace`` turns an engine query trace (``repro.obs.Trace``) into
the human-readable per-query plan report behind
``launch/match.py --explain`` and ``MatchEngine.topk(explain=True)``:
phase wall-clocks, candidates generated / examined / verified per
query, pruning power, modeled I/O, transfer byte counters, and the
round-by-round k-th-best bound evolution.

``check_trace`` is the machine side of the same report — the CI gate
(``launch/match.py --explain --dryrun``) fails the build when a trace
is missing required spans or, on the device-verify path, reports
nonzero ``host_order_bytes`` / rows moved to the host (the PR-5/PR-6
invariants, now asserted as metrics instead of bench-local gates).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.obs.trace import Trace

#: Spans every exact engine trace must contain: candidate generation
#: ("order") and the pruned verification scan ("verify").
REQUIRED_SPANS = ("order", "verify")


def _arr(trace: Trace, key: str, q_n: int) -> np.ndarray:
    v = trace.get(key)
    if v is None:
        return np.zeros(q_n)
    return np.atleast_1d(np.asarray(v))


def _fmt_s(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def render_trace(trace: Trace) -> str:
    """Readable per-query plan report for one engine call."""
    m = trace.meta
    q_n = int(m.get("q_n", 1))
    total = int(m.get("total", 0))
    lines = []
    head = [f"k={m.get('k')}", f"queries={q_n}",
            f"source={m.get('source', 'linear')}",
            f"verify={m.get('verify', '?')}"]
    if total:
        head.append(f"corpus={total}")
    if not m.get("exact", True):
        head.append("approximate")
    lines.append(f"== {trace.name} ({', '.join(head)}) ==")

    # phase wall-clocks from the span tree (top-level spans only; nested
    # child time — e.g. order/seed — is included in its parent)
    tops = [s for s in trace.spans if "/" not in s.name]
    if tops:
        phases = " | ".join(f"{s.name} {_fmt_s(s.seconds)}" for s in tops)
        lines.append(f"phases: {phases}"
                     + (f"  (total {_fmt_s(m['wall_s'])})"
                        if "wall_s" in m else ""))
        nested = [s for s in trace.spans if "/" in s.name]
        for s in nested:
            lines.append(f"  .. {s.name} {_fmt_s(s.seconds)}")

    gen = _arr(trace, "generated", q_n)
    exa = _arr(trace, "examined", q_n)
    ver = _arr(trace, "verified", q_n)
    pp = trace.get("pruning_power")
    gu = trace.get("generated_unique")
    uniq = ""
    if gu is not None and not np.array_equal(np.atleast_1d(gu), gen):
        # widening rounds re-hand candidates: the accumulated total
        # over-counts, the union size is the honest per-query number
        uniq = f" ({np.atleast_1d(gu).mean():.0f} unique)"
    lines.append("candidates/query: generated "
                 f"{gen.mean():.0f}{uniq}, examined {exa.mean():.0f}, "
                 f"verified {ver.mean():.0f}"
                 + (f"; pruning power {np.mean(pp):.2%}"
                    if pp is not None else ""))
    bar = trace.get("error_bar")
    if bar is not None:
        bar = np.atleast_1d(np.asarray(bar, np.float64))
        fin = bar[np.isfinite(bar)]
        lines.append("approx certificate: error bar mean "
                     f"{fin.mean() if fin.size else float('inf'):.4f}, "
                     f"max {fin.max() if fin.size else float('inf'):.4f}"
                     f" ({int((bar == 0).sum())}/{bar.size} provably "
                     "exact)")

    rows = m.get("rows_fetched")
    if rows is not None:
        lines.append(f"io: {int(rows)} rows in {int(m.get('seeks', 0))} "
                     f"seeks, modeled {_fmt_s(float(m.get('modeled_io_s', 0.0)))}")
    if "host_order_bytes" in m or "rows_to_host" in m:
        parts = []
        for key in ("host_order_bytes", "h2d_bytes", "rows_to_host"):
            if key in m:
                parts.append(f"{key}={int(m[key])}")
        lines.append("transfers: " + " ".join(parts))

    # per-query plan table
    if q_n > 1 or total:
        lines.append("  q  generated  examined  pruning")
        for qi in range(q_n):
            p = (float(np.atleast_1d(pp)[qi]) if pp is not None
                 else (1.0 - exa[qi] / total if total else 0.0))
            lines.append(f"  {qi:>2}  {int(gen[qi]):>9}  {int(exa[qi]):>8}"
                         f"  {p:>7.2%}")

    # round-by-round k-th-best evolution (the pruning threshold)
    if trace.rounds:
        lines.append("  round  phase  active  examined  kth-best"
                     "(min..max)  wall")
        for i, r in enumerate(trace.rounds):
            kth = np.asarray(r.get("kth", []), np.float64)
            fin = kth[np.isfinite(kth)]
            if fin.size:
                kbs = f"{fin.min():>8.4f}..{fin.max():<8.4f}"
            else:
                kbs = f"{'inf':>8}..{'inf':<8}"
            lines.append(f"  {i:>5}  {r.get('phase', '?'):>5}  "
                         f"{r.get('active', 0):>6}  "
                         f"{r.get('examined', 0):>8}  {kbs}  "
                         f"{_fmt_s(float(r.get('wall_s', 0.0)))}")
    return "\n".join(lines)


def check_trace(trace: Optional[Trace], *,
                required: Sequence[str] = REQUIRED_SPANS,
                device: bool = False) -> List[str]:
    """Validate a trace; returns a list of problems (empty == pass).

    ``device=True`` additionally enforces the device-path invariants as
    metrics: zero candidate-order bytes assembled on the host and zero
    raw rows moved device->host.
    """
    if trace is None:
        return ["no trace recorded"]
    problems = [f"missing required span {name!r}" for name in required
                if not trace.has_span(name)]
    if not trace.rounds:
        problems.append("no verification rounds recorded")
    if device:
        hob = trace.get("host_order_bytes")
        if hob is None:
            problems.append("device path recorded no host_order_bytes "
                            "metric")
        elif int(hob) != 0:
            problems.append(f"host_order_bytes={int(hob)} on the device "
                            "path (candidate order left the device)")
        rth = trace.get("rows_to_host")
        if rth is None:
            problems.append("device path recorded no rows_to_host metric")
        elif int(rth) != 0:
            problems.append(f"rows_to_host={int(rth)} on the device path "
                            "(raw rows moved device->host)")
    return problems
