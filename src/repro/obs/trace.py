"""Per-query tracing: the ``Trace`` / ``Span`` API.

A ``Trace`` is created per engine call (``MatchEngine.topk(trace=...)``
/ ``explain=True``) and carries three layers of telemetry:

* **Spans** — wall-clocked phases.  ``with trace.span("verify"):``
  records a ``Span`` whose name is the '/'-joined path of the open span
  stack (``"order/seed"`` for the tree seed verification nested inside
  candidate generation).  When the traced region ends in device work,
  pass ``fence=arrays`` so the span blocks on ``jax.block_until_ready``
  before closing — kernel timings are then honest rather than dispatch
  timings.  Fencing only runs when a trace is active, and only *after*
  the traced computation, so it can never change results or store
  accounting (observability neutrality).
* **Rounds** — one dict per verification round
  (``core.engine.topk_verify`` / ``verify_candidates``): phase
  (seed/scan), active query count, candidates examined, the per-query
  k-th-best bound after the merge (the pruning threshold's evolution),
  and per-round wall clock.
* **Meta** — accumulated scalars and per-query arrays
  (``trace.add``): candidates generated / examined / verified, rows
  fetched, modeled seeks, modeled I/O seconds, device<->host byte
  counters.  ``add`` sums numerics and numpy arrays elementwise, so
  multi-round paths (exclusion widening, seed + scan) accumulate
  instead of overwriting.  Because ``add`` sums, a candidate handed to
  two widening rounds counts once per round — a per-round total, not a
  dedup count.  The engines therefore also record the id sets behind
  ``generated`` (``note_ids`` / ``note_counts``) and finalize a
  deduplicated ``generated_unique`` per-query array into meta next to
  the accumulated total (equal on single-round paths, strictly smaller
  under exclusion widening).

Zero-overhead-when-off contract: every instrumentation site in the
matching stack is guarded by ``trace is None`` (or uses
:func:`maybe_span`, which returns a shared null context) — with no
trace the hot loops execute exactly the pre-observability instruction
stream.

``to_dict()`` is plain JSON (numpy converted), schema documented in
ROADMAP.md §Observability.
"""

from __future__ import annotations

import time
from contextlib import contextmanager, nullcontext
from typing import List, Optional

import numpy as np


def _jsonable(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class Span:
    """One wall-clocked phase; ``name`` is the full '/'-joined path."""

    __slots__ = ("name", "t0", "t1", "meta")

    def __init__(self, name: str, t0: float, meta: Optional[dict] = None):
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.meta = meta or {}

    @property
    def seconds(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "seconds": self.seconds,
                **({"meta": _jsonable(self.meta)} if self.meta else {})}


class Trace:
    """Per-call query trace (see module docstring for the layers)."""

    __slots__ = ("name", "meta", "spans", "rounds", "_stack",
                 "_ids", "_id_counts")

    def __init__(self, name: str = "query", **meta):
        self.name = name
        self.meta = dict(meta)
        self.spans: List[Span] = []
        self.rounds: List[dict] = []
        self._stack: List[str] = []
        # deduplicated-id layer behind the accumulated meta counts:
        # key -> {query index -> [id arrays handed so far]} plus a
        # count-only fallback for sources that cannot expose ids (a
        # device-ordered stream never re-hands an id, so counting it
        # once is already deduplicated)
        self._ids: dict = {}
        self._id_counts: dict = {}

    # -- spans ------------------------------------------------------------
    @contextmanager
    def span(self, name: str, *, fence=None, **meta):
        """Wall-clock a phase.  ``fence``: device array(s) (or a pytree)
        to ``block_until_ready`` before the span closes."""
        path = "/".join(self._stack + [name])
        sp = Span(path, time.perf_counter(), meta or None)
        self.spans.append(sp)
        self._stack.append(name)
        try:
            yield sp
        finally:
            self._stack.pop()
            if fence is not None:
                block_until_ready(fence)
            sp.t1 = time.perf_counter()

    def span_names(self) -> List[str]:
        return [s.name for s in self.spans]

    def has_span(self, name: str) -> bool:
        """True if any span's path equals ``name`` or ends in it (so
        ``"seed"`` matches the nested ``"order/seed"``)."""
        return any(s.name == name or s.name.endswith("/" + name)
                   for s in self.spans)

    def span_seconds(self, name: str) -> float:
        return sum(s.seconds for s in self.spans
                   if s.name == name or s.name.endswith("/" + name))

    # -- meta -------------------------------------------------------------
    def set(self, key: str, value) -> None:
        self.meta[key] = value

    def get(self, key: str, default=None):
        return self.meta.get(key, default)

    def add(self, key: str, value) -> None:
        """Accumulate: numerics sum, numpy arrays sum elementwise (a
        copy is stored, never a live engine buffer)."""
        cur = self.meta.get(key)
        if isinstance(value, np.ndarray):
            value = value.copy()
        if cur is None:
            self.meta[key] = value
        else:
            self.meta[key] = cur + value

    # -- deduplicated id tracking ------------------------------------------
    def note_ids(self, key: str, qi: int, ids) -> None:
        """Record the candidate ids behind one ``add(key, ...)`` round for
        query ``qi``; :meth:`unique_counts` later reports the union size
        (the dedup count the accumulated meta total over-counts under
        exclusion widening)."""
        arr = np.asarray(ids, np.int64)
        if arr.size:
            self._ids.setdefault(key, {}).setdefault(int(qi),
                                                     []).append(arr.copy())

    def note_counts(self, key: str, counts) -> None:
        """Count-only fallback of :meth:`note_ids` for sources whose ids
        stay on device (a candidate stream) — valid as a dedup count
        because such a source never re-hands an id."""
        counts = np.atleast_1d(np.asarray(counts, np.int64))
        cur = self._id_counts.get(key)
        self._id_counts[key] = counts.copy() if cur is None \
            else cur + counts

    def unique_counts(self, key: str, q_n: int):
        """(q_n,) deduplicated per-query count for ``key``: |union of
        noted id arrays| plus the count-only stream contribution.
        None when nothing was noted under ``key``."""
        per_q = self._ids.get(key)
        counted = self._id_counts.get(key)
        if per_q is None and counted is None:
            return None
        out = np.zeros(q_n, np.int64)
        if counted is not None:
            out[:len(counted)] += counted
        if per_q is not None:
            for qi, chunks in per_q.items():
                if qi < q_n:
                    out[qi] += np.unique(np.concatenate(chunks)).size
        return out

    # -- rounds -----------------------------------------------------------
    def record_round(self, **fields) -> None:
        self.rounds.append(fields)

    # -- export -----------------------------------------------------------
    def to_dict(self) -> dict:
        return {"name": self.name,
                "meta": _jsonable(self.meta),
                "spans": [s.to_dict() for s in self.spans],
                "rounds": _jsonable(self.rounds)}


_NULL = nullcontext()


def maybe_span(trace: Optional[Trace], name: str, **kw):
    """``trace.span(name)`` or a no-op context — the one-liner guard the
    engine call sites use so the untraced path allocates nothing."""
    return _NULL if trace is None else trace.span(name, **kw)


def block_until_ready(x) -> None:
    """Fence helper: block on any jax array / pytree; silently ignore
    plain host values (numpy arrays, None, tuples of either)."""
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass
