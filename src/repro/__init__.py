"""repro — season- and trend-aware symbolic approximation (sSAX/tSAX/stSAX)
as a multi-pod JAX framework.  See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
