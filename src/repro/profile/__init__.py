"""Matrix-profile self-join subsystem (exact motifs and discords).

``SelfJoinEngine`` computes, for every window of an (N, T) corpus, its
nearest NON-TRIVIAL neighbor — exactly — by treating each corpus window
as a query against the corpus's own window set and routing candidates
through the same lower-bound-ordered verification machinery as
``repro.subseq`` (``core.engine.topk_verify``), with the trivial-match
zone (same source row, starts closer than ``exclusion`` samples —
``SubseqEngine``'s suppression predicate) excluded a priori.  The
profile then yields ``topk_motifs`` (closest non-overlapping window
pairs) and ``topk_discords`` (windows whose nearest neighbor is
farthest) — bit-identical to the brute-force profile oracle
(``SelfJoinEngine.scan_profile``) on every candidate path.

The FFT sliding-dot-product half of the subsystem lives in
``repro.kernels.fft_dot`` (MASS rfft/irfft, O(T log T) per row) behind
``kernels.ops.windowed_euclid(..., method="fft")`` /
``kernels.ops.sliding_dot`` with a documented tolerance contract —
exact verification stays on the bitwise accumulation paths.
"""

from repro.profile.selfjoin import (MatrixProfile, SelfJoinEngine,
                                    topk_discords, topk_motifs)

__all__ = ["MatrixProfile", "SelfJoinEngine", "topk_discords",
           "topk_motifs"]
