"""Exact matrix-profile self-join over a ``WindowView``.

Every window of the corpus is queried against the corpus's own window
set; the nearest neighbor OUTSIDE the trivial-match zone (same source
row, start samples closer than ``exclusion`` — the same non-overlap
predicate ``SubseqEngine._suppress`` applies between reported matches)
is found exactly through ``core.engine.topk_verify``:

* linear path — the (chunk, n_windows) lower-bound matrix with the
  trivial zone masked to +inf before the k-th-best early-stop scan;
* indexed path — the split tree's seed/collect walk with the trivial
  zone handed over as the already-``seen`` id set (the exclusion-
  widening contract of ``repro.index.candidates.TreeCandidates``);
* sharded path — ``ShardedWindowSweep.candidate_stream`` with a device
  ``mask_fn`` lifting trivial bounds to +inf BEFORE the on-device
  (bound, id) lexsort, so candidate order never touches the host; with
  ``verify="device"`` the verification closure keeps raw rows sharded
  on device too (``rows_to_host == 0``).

All paths verify through the same bitwise f32 reduction and (distance,
window id) tie-break, so the profile — and therefore ``topk_motifs`` /
``topk_discords``, which are pure functions of it — is bit-identical
to the brute-force oracle ``scan_profile``.  The FFT dot-product path
(``kernels.fft_dot``) never feeds verification; it exists for profile-
scale sweeps and the crossover benchmark.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.engine import topk_verify
from repro.subseq.search import SubseqEngine
from repro.subseq.windows import WindowView, znorm_windows


@dataclass
class MatrixProfile:
    """Per-window nearest non-trivial neighbor of a corpus self-join.

    ``neighbors[i] == -1`` / ``distances[i] == inf`` when window ``i``
    has no candidate outside its trivial zone (single short row)."""

    distances: np.ndarray        # (n,) f64 true z-normalized d_ED
    neighbors: np.ndarray        # (n,) int64 window id of the NN
    exclusion: int               # trivial-zone half-width in samples
    source: str                  # "linear" | "index" | "stream"
    raw_accesses: np.ndarray     # (n,) windows verified per query window
    pruned_fraction: np.ndarray  # (n,) 1 - verified / n
    store_accesses: int          # deduplicated underlying-row reads
    store_fetches: int           # batched fetch rounds (modeled seeks)
    io_seconds: float            # modeled I/O incl. the query-side pass
    trace: object = field(default=None, repr=False)

    @property
    def n(self) -> int:
        return int(self.distances.shape[0])


def topk_motifs(profile: MatrixProfile, locate, k: int = 1):
    """Top-k motif pairs: the closest mutually non-trivial window pairs
    in ascending (distance, window id) order, greedily filtered so no
    selected window overlaps an already-selected one (same source row,
    starts closer than ``profile.exclusion`` — the suppression predicate
    of ``SubseqEngine``).  The mirror entry of a selected pair clashes
    with the pair itself, so each motif is reported once.

    Pure function of the profile (``locate`` is
    ``WindowView.locate``) — the oracle and the engine share it, so
    bit-identity of motifs reduces to bit-identity of profiles.
    Returns a list of ``(window_a, window_b, distance)`` tuples.
    """
    d, nb = profile.distances, profile.neighbors
    n = d.shape[0]
    rows, starts = locate(np.arange(n, dtype=np.int64))
    order = np.lexsort((np.arange(n), d))
    excl = profile.exclusion
    taken_rows, taken_starts = [], []

    def clash(wid) -> bool:
        r, s = rows[wid], starts[wid]
        return any(tr == r and abs(ts - s) < excl
                   for tr, ts in zip(taken_rows, taken_starts))

    out = []
    for a in order:
        b = nb[a]
        if b < 0 or not np.isfinite(d[a]):
            continue
        if clash(a) or clash(b):
            continue
        out.append((int(a), int(b), float(d[a])))
        for w in (a, b):
            taken_rows.append(rows[w])
            taken_starts.append(starts[w])
        if len(out) == k:
            break
    return out


def topk_discords(profile: MatrixProfile, locate, k: int = 1):
    """Top-k discords: windows whose nearest non-trivial neighbor is
    FARTHEST, in descending distance order (ties to the smaller window
    id), greedily filtered by the same non-overlap predicate as
    :func:`topk_motifs`.  Windows with no non-trivial candidate at all
    (distance +inf) are skipped — an empty neighborhood is a geometry
    artifact, not an anomaly.  Returns ``(window, distance)`` tuples.
    """
    d = profile.distances
    n = d.shape[0]
    rows, starts = locate(np.arange(n, dtype=np.int64))
    order = np.lexsort((np.arange(n), -d))
    excl = profile.exclusion
    taken_rows, taken_starts = [], []
    out = []
    for w in order:
        if profile.neighbors[w] < 0 or not np.isfinite(d[w]):
            continue
        r, s = rows[w], starts[w]
        if any(tr == r and abs(ts - s) < excl
               for tr, ts in zip(taken_rows, taken_starts)):
            continue
        out.append((int(w), float(d[w])))
        taken_rows.append(r)
        taken_starts.append(s)
        if len(out) == k:
            break
    return out


class _ChunkTrace:
    """Per-chunk adapter handed to ``topk_verify`` in place of the real
    trace: the parent ``Trace.add`` accumulates ndarray values
    ELEMENTWISE (same-shape contract), but self-join chunks have
    different query counts, so per-query vectors are collapsed to
    scalar totals before forwarding.  ``note_ids`` / ``note_counts`` /
    ``unique_counts`` are deliberately absent — ``topk_verify`` probes
    them with ``getattr(..., None)`` and skips the per-id layer, whose
    query axis is also chunk-local."""

    def __init__(self, parent):
        self._p = parent

    def add(self, key, value):
        if isinstance(value, np.ndarray):
            value = int(value.sum())
        self._p.add(key, value)

    def set(self, key, value):
        self._p.set(key, value)

    def get(self, key, default=None):
        return self._p.get(key, default)

    def record_round(self, **fields):
        self._p.record_round(**fields)

    def span(self, name, **meta):
        return self._p.span(name, **meta)

    @property
    def meta(self):
        return self._p.meta


class SelfJoinEngine:
    """Exact matrix-profile self-join over a :class:`WindowView`.

    Parameters
    ----------
    view:       the window view (encoder + corpus) to self-join.
    batch_size: verification batch per query window per round.
    verify:     "numpy" | "host" | "kernel" | "device" — the same
                contract as :class:`SubseqEngine` (an inner engine
                supplies verifier, merge, and the sharded sweep).
    mesh:       optional jax mesh; shards the representation sweep and,
                with ``verify="device"``, keeps candidate ordering AND
                raw verification device-resident.
    exclusion:  trivial-zone half-width in SAMPLES (two windows of the
                same source row with |start - start'| < exclusion are
                trivial matches of each other).  Defaults to
                ``max(1, m // 4)`` — the standard quarter-window zone;
                must be >= 1 so a window never matches itself.
    chunk:      query windows per verification round (bounds the
                transient (chunk, n_windows) structures).
    metrics:    opt-in ``repro.obs.MetricsRegistry``.
    """

    def __init__(self, view: WindowView, *, batch_size: int = 64,
                 verify: str = "numpy", mesh=None,
                 exclusion: Optional[int] = None, chunk: int = 32,
                 metrics=None):
        if exclusion is None:
            exclusion = max(1, view.m // 4)
        if exclusion < 1:
            raise ValueError(f"exclusion must be >= 1 (a window is "
                             f"always its own trivial match), got "
                             f"{exclusion}")
        self.view = view
        self.exclusion = int(exclusion)
        self.chunk = int(chunk)
        self.metrics = metrics
        # the inner engine supplies verifier / merge / sharded sweep /
        # device dist_fn — the single source of the exclusion +
        # verification semantics this engine reuses
        self._sub = SubseqEngine(view, batch_size=batch_size,
                                 verify=verify, mesh=mesh)
        self._cache = None               # (key, MatrixProfile)

    # -- delegated machinery ----------------------------------------------
    @property
    def verify_mode(self) -> str:
        return self._sub.verify_mode

    @property
    def verifier(self):
        return self._sub.verifier

    @property
    def merge(self):
        return self._sub.merge

    @property
    def _device(self) -> bool:
        return self._sub._device

    @property
    def _sweep(self):
        return self._sub._sweep

    # -- trivial-match geometry -------------------------------------------
    def trivial_ids(self, wid: int) -> np.ndarray:
        """Window ids in ``wid``'s trivial zone (same source row,
        |start - start'| < exclusion), ``wid`` itself included."""
        nw = self.view.windows_per_row
        stride = self.view.stride
        r, j0 = int(wid) // nw, int(wid) % nw
        half = (self.exclusion - 1) // stride
        lo, hi = max(0, j0 - half), min(nw - 1, j0 + half)
        return np.arange(r * nw + lo, r * nw + hi + 1, dtype=np.int64)

    def _mask_fn(self, wids: np.ndarray):
        """Device mask closure for ``candidate_stream``: (C,) candidate
        ids -> (Q, C) True where the candidate is a trivial match of the
        chunk's query windows — computed from window-id arithmetic on
        device (ids never come to the host; dead-slot ids >= n map to
        out-of-range rows and are already +inf)."""
        import jax.numpy as jnp
        nw = self.view.windows_per_row
        stride = self.view.stride
        excl = self.exclusion
        q_r = jnp.asarray(wids // nw)[:, None]
        q_j = jnp.asarray(wids % nw)[:, None]

        def mask(ids):
            same = (ids[None, :] // nw) == q_r
            near = jnp.abs(ids[None, :] % nw - q_j) * stride < excl
            return same & near

        return mask

    def _query_windows(self, wids: np.ndarray) -> np.ndarray:
        """Z-normalized query windows extracted straight from the host
        source array — NOT through ``view.fetch``: the query side of the
        self-join is one streaming pass over the corpus, billed once in
        :meth:`profile` (fetch billing here would double-count rows and
        break the device path's ``rows_to_host == 0`` invariant)."""
        nw, stride, m = (self.view.windows_per_row, self.view.stride,
                         self.view.m)
        rows = wids // nw
        starts = (wids % nw) * stride
        data = self.view.source.data
        w = data[rows[:, None],
                 starts[:, None] + np.arange(m, dtype=np.int64)[None, :]]
        return znorm_windows(np.asarray(w, np.float32))

    # -- profile -----------------------------------------------------------
    def profile(self, *, use_index: object = "auto",
                batch_size: Optional[int] = None, trace=None,
                explain: bool = False,
                refresh: bool = False) -> MatrixProfile:
        """The full matrix profile — nearest non-trivial neighbor (true
        z-normalized d_ED, (distance, window id) tie-break) of every
        window.  Cached per (corpus version, exclusion, source); any
        append invalidates it.  ``use_index`` follows ``SubseqEngine``:
        "auto" uses ``view.index`` when built, True requires it, False
        forces the linear sweep (sharded when a mesh was given)."""
        if explain and trace is None:
            from repro.obs import Trace
            trace = Trace("selfjoin.profile")
        idx = self.view.index if use_index in ("auto", True) else None
        if use_index is True and idx is None:
            raise ValueError("use_index=True but the view has no index; "
                             "call view.build_index() first")
        if idx is not None and idx.n != self.view.n:
            raise ValueError(f"window index covers {idx.n} of "
                             f"{self.view.n} windows; call view.sync()")
        source = ("index" if idx is not None
                  else "stream" if self._sweep is not None else "linear")
        key = (self.view.version, self.exclusion, source,
               self.verify_mode)
        # a cache hit is free — only a trace request (EXPLAIN measures
        # the real run) or an explicit refresh forces recomputation;
        # metrics record computed profiles, not cache reads
        if (not refresh and trace is None
                and self._cache is not None and self._cache[0] == key):
            return self._cache[1]
        observing = trace is not None or self.metrics is not None
        t0 = time.perf_counter()
        rows0 = self.view.accesses
        hob0 = self._sweep.host_order_bytes if self._sweep is not None \
            else 0
        h2d0 = self._sweep.h2d_bytes if self._sweep is not None else 0
        prof = self._profile(idx, source, batch_size or self._sub.
                             batch_size, trace)
        if observing:
            self._observe(trace, prof, time.perf_counter() - t0,
                          self.view.accesses - rows0, hob0, h2d0)
        if trace is not None:
            prof.trace = trace
        self._cache = (key, prof)
        return prof

    def _profile(self, idx, source: str, bs: int, trace) -> MatrixProfile:
        from repro.obs.trace import maybe_span
        view = self.view
        n, nw = view.n, view.windows_per_row
        n_rows = view.n_rows
        dist = np.full(n, np.inf, np.float64)
        nbr = np.full(n, -1, np.int64)
        raw = np.zeros(n, np.int64)
        acc = {"rows": 0, "fetches": 0, "io": 0.0}
        dfn_maker = (self._sweep.make_dist_fn if self._device else None)
        ct = _ChunkTrace(trace) if trace is not None else None
        for c0 in range(0, n, self.chunk):
            wids = np.arange(c0, min(c0 + self.chunk, n), dtype=np.int64)
            zq = self._query_windows(wids)
            dfn = dfn_maker(zq) if dfn_maker is not None else None
            if idx is not None:
                res = self._chunk_indexed(idx, zq, wids, bs, dfn, ct)
            elif self._sweep is not None:
                res = self._chunk_stream(zq, wids, bs, dfn, ct, trace)
            else:
                res = self._chunk_linear(zq, wids, bs, dfn, ct, trace)
            dist[wids] = res.distances[:, 0]
            nbr[wids] = res.indices[:, 0]
            raw[wids] = res.raw_accesses
            acc["rows"] += res.store_accesses
            acc["fetches"] += res.store_fetches
            acc["io"] += res.io_seconds
        # the query side reads every corpus row once — one modeled
        # streaming pass, accounted explicitly (the windows were taken
        # from the host array, not fetched)
        acc["rows"] += n_rows
        acc["fetches"] += 1
        acc["io"] += view.modeled_io_seconds(n_rows, 1)
        return MatrixProfile(
            distances=dist, neighbors=nbr, exclusion=self.exclusion,
            source=source, raw_accesses=raw,
            pruned_fraction=1.0 - raw / max(n, 1),
            store_accesses=acc["rows"], store_fetches=acc["fetches"],
            io_seconds=acc["io"])

    def _chunk_linear(self, zq, wids, bs, dfn, ct, trace):
        """Host lower-bound matrix with the trivial zone masked to +inf
        before the early-stop scan (a masked column can never be
        generated, fetched, or verified)."""
        from repro.obs.trace import maybe_span
        with maybe_span(trace, "order"):
            rd = np.array(self._sub.repr_distances(zq))
        for i, w in enumerate(wids):
            rd[i, self.trivial_ids(w)] = np.inf
        with maybe_span(trace, "verify"):
            return topk_verify(zq, rd, self.view, k=1, batch_size=bs,
                               verifier=self.verifier, merge=self.merge,
                               dist_fn=dfn, trace=ct)

    def _chunk_stream(self, zq, wids, bs, dfn, ct, trace):
        """Device-ordered candidate stream with the trivial zone lifted
        to +inf ON DEVICE before the (bound, id) lexsort — candidate
        order never touches the host."""
        from repro.obs.trace import maybe_span
        with maybe_span(trace, "order") as sp:
            stream = self._sweep.candidate_stream(
                zq, mask_fn=self._mask_fn(wids))
            if trace is not None:
                from repro.obs.trace import block_until_ready
                block_until_ready((stream._b, stream._i))
                sp.meta["stream"] = True
        with maybe_span(trace, "verify"):
            return topk_verify(zq, None, self.view, k=1, batch_size=bs,
                               verifier=self.verifier, merge=self.merge,
                               dist_fn=dfn, stream=stream, trace=ct)

    def _chunk_indexed(self, idx, zq, wids, bs, dfn, ct):
        """Split-tree candidates with the trivial zone handed over as
        the already-``seen`` id set (the exclusion-widening contract of
        ``TreeCandidates``): seeds and collects skip seen ids, and the
        empty (C, 1) +inf/-1 prior frontier keeps the scan exact —
        exactly how ``SubseqEngine`` widens under suppression, minus
        the widening (k=1 needs one round).  ``topk_from_source``
        creates its own order/verify spans."""
        c = zq.shape[0]
        prior_d = np.full((c, 1), np.inf, np.float64)
        prior_i = np.full((c, 1), -1, np.int64)
        seen = [self.trivial_ids(w) for w in wids]
        return idx.topk(zq, self.view, k=1, batch_size=bs,
                        verifier=self.verifier, merge=self.merge,
                        dist_fn=dfn, prior_d=prior_d, prior_i=prior_i,
                        seen=seen, trace=ct)

    # -- motifs / discords -------------------------------------------------
    def topk_motifs(self, k: int = 1, **profile_kw):
        """Top-k non-overlapping motif pairs (see :func:`topk_motifs`);
        computes (or reuses) the cached profile."""
        return topk_motifs(self.profile(**profile_kw), self.view.locate, k)

    def topk_discords(self, k: int = 1, **profile_kw):
        """Top-k non-overlapping discords (see :func:`topk_discords`)."""
        return topk_discords(self.profile(**profile_kw), self.view.locate,
                             k)

    # -- brute-force oracle ------------------------------------------------
    def scan_profile(self, chunk_bytes: float = 2.5e8) -> MatrixProfile:
        """Brute-force matrix profile: every pairwise window distance
        through THE SAME verifier as the engine paths (so bit-identity
        is a property of the candidate machinery, not of floating-point
        luck), trivial zone masked to +inf, nearest neighbor by the
        (distance, window id) tie-break (``np.argmin`` returns the
        first — smallest-id — minimum).  Modeled I/O is one streaming
        pass over the corpus."""
        view = self.view
        n, n_rows = view.n, view.n_rows
        W = np.concatenate(list(view._window_chunks(0, n_rows)), axis=0)
        dist = np.full(n, np.inf, np.float64)
        nbr = np.full(n, -1, np.int64)
        ids = np.arange(n, dtype=np.int64)
        blk = max(1, int(chunk_bytes / (8 * max(n, 1))))
        for c0 in range(0, n, blk):
            wids = ids[c0:c0 + blk]
            gather = np.broadcast_to(ids[None, :],
                                     (wids.shape[0], n)).copy()
            d = np.array(self.verifier(W, W[wids], gather), np.float64)
            for i, w in enumerate(wids):
                d[i, self.trivial_ids(w)] = np.inf
            j = np.argmin(d, axis=1)
            best = d[np.arange(wids.shape[0]), j]
            fin = np.isfinite(best)
            dist[wids[fin]] = best[fin]
            nbr[wids[fin]] = j[fin]
        return MatrixProfile(
            distances=dist, neighbors=nbr, exclusion=self.exclusion,
            source="scan", raw_accesses=np.full(n, n, np.int64),
            pruned_fraction=np.zeros(n),
            store_accesses=n_rows, store_fetches=1,
            io_seconds=view.modeled_io_seconds(n_rows, 1))

    # -- observability -----------------------------------------------------
    def _observe(self, trace, prof: MatrixProfile, wall_s: float,
                 rows_delta: int, hob0: int, h2d0: int) -> None:
        rth = int(rows_delta) if self._device else None
        hob = h2d = None
        if self._sweep is not None:
            hob = int(self._sweep.host_order_bytes - hob0)
            h2d = int(self._sweep.h2d_bytes - h2d0)
        if trace is not None:
            trace.meta.update(engine="selfjoin", n=prof.n,
                              exclusion=self.exclusion,
                              source=prof.source,
                              verify=self.verify_mode)
            trace.set("wall_s", wall_s)
            trace.set("pruning_power", float(prof.pruned_fraction.mean()))
            if hob is not None:
                trace.set("host_order_bytes", hob)
                trace.set("h2d_bytes", h2d)
            if rth is not None:
                trace.set("rows_to_host", rth)
        if self.metrics is not None:
            m = self.metrics
            m.counter("selfjoin.queries").inc(prof.n)
            m.counter("selfjoin.windows_verified").inc(
                int(prof.raw_accesses.sum()))
            m.counter("selfjoin.rows_fetched").inc(
                int(prof.store_accesses))
            m.counter("selfjoin.seeks").inc(int(prof.store_fetches))
            m.counter("selfjoin.modeled_io_s").inc(float(prof.io_seconds))
            m.gauge("selfjoin.pruning_power").set(
                float(prof.pruned_fraction.mean()))
            m.histogram("selfjoin.profile_latency_s").observe(wall_s)
            if hob is not None:
                m.counter("selfjoin.host_order_bytes").inc(hob)
                m.counter("selfjoin.h2d_bytes").inc(h2d)
            if rth is not None:
                m.counter("selfjoin.rows_to_host").inc(rth)
