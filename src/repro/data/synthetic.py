"""Synthetic datasets per the paper's §4.2.

Season / Trend datasets: random-walk base overlaid with a deterministic
component, rescaled so every series hits the target component strength
R^2 within +-0.5pp, then z-normalized.  Construction note: for a target
strength on a *normalized* series it suffices to mix the normalized
deterministic component and the normalized walk with weights sqrt(R^2) /
sqrt(1-R^2) — the extraction estimators then recover R^2 up to estimation
noise, matching the paper's tolerance-based selection.
"""

from __future__ import annotations

import numpy as np

from repro.core.normalize import znormalize


def random_walk(rng: np.random.Generator, n: int, T: int) -> np.ndarray:
    steps = rng.normal(size=(n, T)).astype(np.float32)
    return np.cumsum(steps, axis=1)


def _znorm_np(x, eps=1e-12):
    mu = x.mean(axis=-1, keepdims=True)
    sd = x.std(axis=-1, keepdims=True)
    return (x - mu) / np.maximum(sd, eps)


def season_dataset(n: int = 1000, T: int = 960, L: int = 10,
                   strength: float = 0.5, seed: int = 0,
                   per_series_strength: bool = False) -> np.ndarray:
    """Random walks overlaid with a length-L season mask (paper: L=10).

    ``per_series_strength`` draws each series' strength uniformly around
    the target (the Season (Large) construction where strengths vary).
    """
    rng = np.random.default_rng(seed)
    assert T % L == 0
    base = _znorm_np(random_walk(rng, n, T))
    # one season mask per series, zero-mean, tiled over the length
    mask = rng.normal(size=(n, L)).astype(np.float32)
    mask = mask - mask.mean(axis=1, keepdims=True)
    mask = mask / np.maximum(mask.std(axis=1, keepdims=True), 1e-12)
    seas = np.tile(mask, (1, T // L))
    if per_series_strength:
        s = rng.uniform(max(0.01, strength - 0.09),
                        min(0.99, strength + 0.09), size=(n, 1)).astype(
                            np.float32)
    else:
        s = np.full((n, 1), strength, np.float32)
    # remove the walk's own seasonal content so the target strength is exact
    walk_seas = np.tile(
        base.reshape(n, T // L, L).mean(axis=1), (1, T // L))
    base_clean = _znorm_np(base - walk_seas)
    x = np.sqrt(s) * seas + np.sqrt(1.0 - s) * base_clean
    return _znorm_np(x)


def trend_dataset(n: int = 1000, T: int = 960, strength: float = 0.5,
                  seed: int = 0) -> np.ndarray:
    """Random walks overlaid with a linear trend of target strength."""
    rng = np.random.default_rng(seed)
    base = _znorm_np(random_walk(rng, n, T))
    # detrend the walk so the injected trend fully controls R^2_tr
    s_ax = np.arange(T, dtype=np.float32)
    s_c = s_ax - s_ax.mean()
    den = np.sum(s_c * s_c)
    beta = (base @ s_c) / den
    base_dt = _znorm_np(base - beta[:, None] * s_c[None, :])
    tr = _znorm_np(np.tile(s_c[None, :], (n, 1)))
    sign = rng.choice(np.asarray([-1.0, 1.0], np.float32), size=(n, 1))
    s = np.full((n, 1), strength, np.float32)
    x = np.sqrt(s) * sign * tr + np.sqrt(1.0 - s) * base_dt
    return _znorm_np(x)
