"""Real-world dataset surrogates (DESIGN.md §8.5).

The CER Metering and M4 Economy datasets are not redistributable; these
builders produce statistically matched stand-ins:

* ``metering_like`` — half-hourly consumption series with a daily season
  (L=48) of mean strength 18.3% (the paper's measured figure), weekly
  modulation, and positive-valued load shapes.
* ``economy_like`` — monthly series (T=300: 25 years) with pronounced
  trends of heterogeneous strength, multiplicative noise, and mild yearly
  seasonality — mimicking M4-monthly's trend-dominated behaviour.
"""

from __future__ import annotations

import numpy as np

from repro.data.synthetic import _znorm_np, random_walk


def metering_like(n: int = 1024, days: int = 65, seed: int = 1):
    """(n, days*48) z-normalized consumption-like series."""
    rng = np.random.default_rng(seed)
    T = days * 48
    t = np.arange(T, dtype=np.float32)
    # daily load shape: morning/evening peaks, per-household phase
    phase = rng.uniform(0, 2 * np.pi, size=(n, 1)).astype(np.float32)
    daily = (np.sin(2 * np.pi * t / 48 + phase)
             + 0.6 * np.sin(4 * np.pi * t / 48 + 1.7 * phase))
    weekly = 0.3 * np.sin(2 * np.pi * t / (48 * 7)
                          + rng.uniform(0, 2 * np.pi, (n, 1)))
    noise = _znorm_np(random_walk(rng, n, T))
    # strengths drawn so the dataset mean R^2(daily) is ~0.183
    s = np.clip(rng.beta(2.0, 8.5, size=(n, 1)).astype(np.float32), 0.01, 0.9)
    x = (np.sqrt(s) * _znorm_np(daily + weekly)
         + np.sqrt(1 - s) * noise)
    return _znorm_np(x)


def economy_like(n: int = 1024, T: int = 300, seed: int = 2):
    """(n, 300) z-normalized monthly economic-like series with trends."""
    rng = np.random.default_rng(seed)
    t = np.arange(T, dtype=np.float32)
    tc = (t - t.mean()) / t.std()
    slope = rng.normal(0.0, 1.0, size=(n, 1)).astype(np.float32)
    curv = rng.normal(0.0, 0.3, size=(n, 1)).astype(np.float32)
    trend = slope * tc + curv * (tc ** 2 - 1.0)
    yearly = 0.25 * np.sin(2 * np.pi * t / 12
                           + rng.uniform(0, 2 * np.pi, (n, 1)))
    noise = _znorm_np(random_walk(rng, n, T))
    s = np.clip(rng.beta(5.0, 2.0, size=(n, 1)).astype(np.float32),
                0.05, 0.98)
    x = (np.sqrt(s) * _znorm_np(trend + yearly)
         + np.sqrt(1 - s) * noise)
    return _znorm_np(x)
