from repro.data.synthetic import (  # noqa: F401
    random_walk, season_dataset, trend_dataset)
from repro.data.datasets import (  # noqa: F401
    metering_like, economy_like)
