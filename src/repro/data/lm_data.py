"""Synthetic LM token pipeline: deterministic, step-indexed, shardable.

Batches are a pure function of (step, dp_rank) — the property the
fault-tolerant loop relies on for idempotent replay after restart, and
the elastic restore relies on for re-splitting across a new dp degree.
The stream is a mixture of Zipfian unigrams and a repeated-motif process,
so small models show a real learning curve (loss drops well below the
uniform-entropy floor) in examples/train_lm.py.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 64
    motif_prob: float = 0.7
    seed: int = 17


class SyntheticLM:
    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.unigram = (p / p.sum()).astype(np.float64)
        self.motifs = rng.integers(
            0, V, size=(cfg.n_motifs, cfg.motif_len)).astype(np.int32)

    def batch(self, step: int, *, dp_rank: int = 0, dp_size: int = 1):
        """dict(tokens, labels) for this step/rank; labels = next token."""
        cfg = self.cfg
        assert cfg.global_batch % dp_size == 0
        b_local = cfg.global_batch // dp_size
        rng = np.random.default_rng(
            (cfg.seed, step, dp_rank))
        S = cfg.seq_len + 1
        toks = rng.choice(cfg.vocab_size, size=(b_local, S),
                          p=self.unigram).astype(np.int32)
        # overlay motifs: predictable spans the model can learn
        n_spans = int(cfg.motif_prob * S / cfg.motif_len)
        for i in range(b_local):
            starts = rng.integers(0, S - cfg.motif_len, size=n_spans)
            ids = rng.integers(0, cfg.n_motifs, size=n_spans)
            for s, m in zip(starts, ids):
                toks[i, s:s + cfg.motif_len] = self.motifs[m]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
