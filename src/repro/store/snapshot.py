"""On-disk snapshot format for :class:`repro.store.SymbolicStore`.

Layout follows the checkpoint conventions of ``checkpoint/ckpt.py``
(atomic manifest commit, per-host shards, LATEST pointer, bounded GC):

    <dir>/snap_00000003/
        manifest.json        # row count, encoder class+params, leaf
                             # shapes/dtypes, shard row ranges, cost
                             # model, hash, index meta
        shard_h000.npz       # host 0's row range of raw + rep leaves,
                             # plus the global breakpoint tables
        shard_h001.npz       # further hosts' row ranges (n_hosts > 1)
        index.npz            # optional: flattened split-tree index
                             # (features, node table, split history)
    <dir>/LATEST             # atomically-replaced pointer file

Row-indexed arrays (raw rows, representation leaves) are split into
contiguous per-host row ranges — on a real pod each process writes its
own locally-addressable ``shard_hNNN.npz`` exactly like ``ckpt.py``; in
a single-process container host 0 owns everything, and the layout is
already multi-host shaped.  The content hash is computed over the
LOGICAL (concatenated) arrays, so it is independent of the shard layout
and a re-sharded save of identical data hashes identically.

The on-disk contiguous ranges are a MANIFEST concept only — they are
independent of how a serving process lays rows out on device.  In
particular, ``core.distributed``'s sweeps mirror rows round-robin
(row i on device i % n_shards); a snapshot saved under any ``n_hosts``
opens into a store whose device mirrors answer bit-identically
(tests/test_sharded_verify.py asserts it end to end).

Crash safety: everything is written into ``snap_XXXX.tmp`` and renamed
only after the manifest fsyncs, so a torn write can never produce a
readable-but-wrong snapshot; ``open`` always follows LATEST (or an
explicit snapshot id).

Encoder round-trip: encoders are frozen dataclasses of plain numbers, so
the manifest stores ``{"class": name, "params": asdict}`` and ``open``
rebuilds through a registry.  The *derived* breakpoint tables (the
season/trend components' alphabets) are additionally stored in shard 0
and compared against the rebuilt encoder's tables — a library change
that silently moved the breakpoints (re-interpreting every stored
symbol) fails loudly instead of returning wrong matches.

Index round-trip: ``manifest["index"]["kind"]`` dispatches between the
generic :class:`repro.index.SeriesIndex` (rebuilt against the manifest
encoder, so it keeps accepting incremental inserts after reopen) and a
legacy ``SSaxIndex`` a caller attached by hand before saving.

Format history: format 1 (single ``arrays.npz``, variance-split
``SSaxIndex`` tree) is NOT readable by this version — its index node
semantics predate the deterministic split rule the subsystem's
incremental guarantees rest on.  ``open`` rejects it loudly; re-save
from the source data.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from typing import Optional

import numpy as np

FORMAT = 2


def _encoder_registry() -> dict:
    from repro.core import SAX, SSAX, STSAX, TSAX, OneDSAX
    return {c.__name__: c for c in (SAX, SSAX, TSAX, STSAX, OneDSAX)}


# breakpoint-table properties an encoder may expose, probed generically
_BREAKPOINT_ATTRS = ("breakpoints", "b_seas", "b_res", "b_tr")


def encoder_manifest(encoder) -> dict:
    if not dataclasses.is_dataclass(encoder):
        raise TypeError(f"cannot snapshot non-dataclass encoder "
                        f"{type(encoder).__name__}")
    return {"class": type(encoder).__name__,
            "params": dataclasses.asdict(encoder)}


def encoder_from_manifest(m: dict):
    registry = _encoder_registry()
    if m["class"] not in registry:
        raise ValueError(f"unknown encoder class {m['class']!r} "
                         f"(known: {sorted(registry)})")
    return registry[m["class"]](**m["params"])


def _breakpoint_arrays(encoder) -> dict:
    out = {}
    for attr in _BREAKPOINT_ATTRS:
        if hasattr(type(encoder), attr):
            out[f"bp_{attr}"] = np.asarray(getattr(encoder, attr),
                                           np.float32)
    return out


def _content_hash(arrays: dict) -> str:
    """sha256 over names, shapes, dtypes AND array bytes — verified on
    open, so a corrupted shard cannot open silently.  Computed over the
    logical arrays, independent of the shard layout."""
    h = hashlib.sha256()
    for k in sorted(arrays):
        v = np.ascontiguousarray(arrays[k])
        h.update(f"{k}:{v.shape}:{v.dtype};".encode())
        h.update(v.tobytes())
    return h.hexdigest()[:16]


def _write_manifest(path: str, manifest: dict):
    with open(path, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())


def _snap_ids(directory: str):
    if not os.path.isdir(directory):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                  if d.startswith("snap_") and not d.endswith(".tmp"))


def _shard_ranges(n: int, n_hosts: int):
    """Contiguous per-host row ranges covering [0, n)."""
    bounds = [int(round(h * n / n_hosts)) for h in range(n_hosts + 1)]
    return [(bounds[h], bounds[h + 1]) for h in range(n_hosts)]


def save_store(directory: str, store, *, keep: int = 3,
               n_hosts: int = 1) -> str:
    """Write one snapshot of ``store``; returns its final path.

    ``n_hosts`` mocks the multi-host pod layout: row-indexed arrays are
    split into ``n_hosts`` contiguous row ranges, one ``shard_hNNN.npz``
    each (this single process writes them all; on a real pod each host
    writes its own shard of locally-addressable rows)."""
    from repro.store.symbolic import rep_leaves

    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    os.makedirs(directory, exist_ok=True)
    for leftover in os.listdir(directory):   # crashed saves: never reuse
        if leftover.startswith("snap_") and leftover.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, leftover),
                          ignore_errors=True)
    snap_id = (_snap_ids(directory) or [0])[-1] + 1
    name = f"snap_{snap_id:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)

    leaves = rep_leaves(store.rep_view())
    row_arrays = {"raw": np.ascontiguousarray(store.data)}
    for i, leaf in enumerate(leaves):
        row_arrays[f"rep_{i}"] = np.ascontiguousarray(leaf)
    global_arrays = _breakpoint_arrays(store.encoder)
    arrays = {**row_arrays, **global_arrays}     # logical view (hashed)

    ranges = _shard_ranges(int(store.n), n_hosts)
    for h, (lo, hi) in enumerate(ranges):
        shard = {k: v[lo:hi] for k, v in row_arrays.items()}
        if h == 0:
            shard.update(global_arrays)          # host 0 owns globals
        np.savez(os.path.join(tmp, f"shard_h{h:03d}.npz"), **shard)

    hashed = dict(arrays)                # logical arrays + index contents
    index_meta = None
    if store.index is not None:
        meta, idx_arrays = store.index.to_snapshot()
        np.savez(os.path.join(tmp, "index.npz"), **idx_arrays)
        hashed.update({f"index/{k}": v for k, v in idx_arrays.items()})
        index_meta = meta

    manifest = {
        "format": FORMAT,
        "time": time.time(),
        "n": int(store.n),
        "T": int(store.T),
        "version": int(store.version),
        "hosts": int(n_hosts),
        "shards": [{"file": f"shard_h{h:03d}.npz", "rows": [lo, hi]}
                   for h, (lo, hi) in enumerate(ranges)],
        "row_keys": sorted(row_arrays),
        "encoder": encoder_manifest(store.encoder),
        "rep_tuple": isinstance(store.rep_view(), tuple),
        "media": {"name": store.media, "seek_s": store.seek_s,
                  "read_bps": store.read_bps},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "hash": _content_hash(hashed),
        "index": index_meta,
    }
    _write_manifest(os.path.join(tmp, "manifest.json"), manifest)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    ptr_tmp = os.path.join(directory, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    for old in _snap_ids(directory)[:-keep]:
        shutil.rmtree(os.path.join(directory, f"snap_{old:08d}"),
                      ignore_errors=True)
    return final


def latest_snap(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    name = open(ptr).read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def _load_shards(path: str, manifest: dict) -> dict:
    """Reassemble the logical arrays from the per-host shard files."""
    row_keys = set(manifest["row_keys"])
    parts: dict = {k: [] for k in row_keys}
    arrays: dict = {}
    for shard in manifest["shards"]:
        with np.load(os.path.join(path, shard["file"])) as z:
            for k in z.files:
                if k in row_keys:
                    parts[k].append(z[k])
                else:
                    arrays[k] = z[k]             # global (host-0) arrays
    for k, chunks in parts.items():
        arrays[k] = np.concatenate(chunks, axis=0) if len(chunks) > 1 \
            else chunks[0]
    return arrays


def open_store(directory: str, *, snap: Optional[int] = None):
    """Reopen a snapshot as a live, append-ready ``SymbolicStore``."""
    from repro.index import SeriesIndex
    from repro.index.legacy import SSaxIndex
    from repro.store.symbolic import SymbolicStore

    if snap is None:
        snap = latest_snap(directory)
        if snap is None:
            raise FileNotFoundError(f"no snapshot under {directory}")
    path = os.path.join(directory, f"snap_{snap:08d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    if manifest.get("format") != FORMAT:
        raise ValueError(f"unsupported snapshot format "
                         f"{manifest.get('format')!r} (this build reads "
                         f"format {FORMAT})")
    encoder = encoder_from_manifest(manifest["encoder"])

    arrays = _load_shards(path, manifest)
    idx_arrays = None
    if manifest.get("index") is not None:
        with np.load(os.path.join(path, "index.npz")) as z:
            idx_arrays = {k: z[k] for k in z.files}

    hashed = dict(arrays)
    if idx_arrays is not None:
        hashed.update({f"index/{k}": v for k, v in idx_arrays.items()})
    got_hash = _content_hash(hashed)
    if got_hash != manifest["hash"]:
        raise ValueError(f"snapshot {path} content hash mismatch "
                         f"({got_hash} != {manifest['hash']}); "
                         f"arrays are corrupt or were modified")

    # breakpoint-table validation: the rebuilt encoder must reproduce the
    # alphabets the symbols were written under
    for key, want in _breakpoint_arrays(encoder).items():
        if key not in arrays:
            raise ValueError(f"snapshot missing breakpoint table {key}")
        if not np.allclose(arrays[key], want, rtol=1e-5, atol=1e-6):
            raise ValueError(
                f"breakpoint table {key} drifted between save and open; "
                f"stored symbols would be re-interpreted — refusing")

    n = int(manifest["n"])
    raw = arrays["raw"]
    if raw.shape != (n, int(manifest["T"])):
        raise ValueError(f"raw shape {raw.shape} != manifest "
                         f"({n}, {manifest['T']})")
    rep_keys = sorted((k for k in arrays if k.startswith("rep_")),
                      key=lambda k: int(k.split("_")[1]))
    leaves = tuple(arrays[k] for k in rep_keys)
    for k, leaf in zip(rep_keys, leaves):
        if leaf.shape[0] != n:
            raise ValueError(f"leaf {k} has {leaf.shape[0]} rows, want {n}")

    media = manifest["media"]
    store = SymbolicStore(encoder, media=media.get("name", "ssd"),
                          seek_s=media["seek_s"], read_bps=media["read_bps"])
    rep = leaves if manifest["rep_tuple"] else leaves[0]
    if n:
        store.append(raw, rep=rep)
    store.version = int(manifest["version"])

    if idx_arrays is not None:
        meta = manifest["index"]
        if meta.get("kind", "ssax") == "series":
            store.index = SeriesIndex.from_snapshot(encoder, meta,
                                                    idx_arrays)
        else:
            store.index = SSaxIndex.from_snapshot(meta, idx_arrays,
                                                  encoder=encoder)
    return store
