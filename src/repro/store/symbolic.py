"""Append-only symbolic store: raw rows + the live symbolic representation.

``SymbolicStore`` owns both sides of the paper's matching setup — the raw
(N, T) series that live on cold storage and the symbolic representation
(SAX / sSAX / tSAX / stSAX / 1d-SAX words) the engine sweeps — and keeps
them consistent under streaming ingestion:

* ``append(rows)`` encodes ONLY the new rows (one pass through the
  encoder's existing encode path; on TPU that is the Pallas PAA front-end)
  and writes raw + representation into preallocated capacity-doubled
  arrays.  Nothing previously ingested is ever touched, so ingest cost is
  O(chunk) instead of the O(corpus) full re-encode ``MatchEngine`` used to
  pay at construction.  Encoders are row-wise maps, so chunked encoding is
  bit-identical to one-shot encoding (tests/test_store.py proves it for
  arbitrary chunkings).
* ``rep_view()`` returns the representation trimmed to the live rows as
  zero-copy numpy views — consumers (``core.engine.MatchEngine``,
  ``core.distributed``) read it per query and therefore serve appended
  rows immediately.
* The store itself speaks the ``RawStore`` verification protocol
  (``data`` / ``fetch`` / ``accesses`` / ``fetches`` /
  ``modeled_io_seconds`` / ``reset``) with the same HDD/SSD/HBM cost
  models, so it drops in wherever a bare ``RawStore`` was used.
* ``save(dir)`` / ``SymbolicStore.open(dir)`` persist everything —
  raw manifest, representation arrays, encoder params (breakpoints
  validated on open), and the split-tree index with its split history —
  in the atomic snapshot layout of :mod:`repro.store.snapshot`
  (optionally sharded per host, ckpt.py style).
* ``build_index()`` attaches a :class:`repro.index.SeriesIndex` that
  ``append`` maintains incrementally — engine queries take sublinear
  candidates from it with bit-identical results.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.matching import MEDIA, RawStore

_MIN_CAPACITY = 1024

#: bounded observability window of recently published epochs — the
#: frontier itself is fully determined by ``n_rows`` (append-only
#: prefix stability), so the ledger is a debugging aid, not a lookup
#: table the read path depends on
_LEDGER_LEN = 1024


def rep_leaves(rep):
    """Normalize an encoder representation (array or tuple) to a tuple."""
    return rep if isinstance(rep, tuple) else (rep,)


@dataclass(frozen=True)
class CorpusEpoch:
    """One immutable published corpus frontier.

    Every mutation (``SymbolicStore.append`` — and therefore
    ``make_engine_service.ingest`` and ``WindowView.sync``, which route
    through it) publishes a new epoch as its LAST step, with a single
    attribute assignment: readers racing an append see either the old
    or the new epoch, never a torn one.  Because the store, the split
    tree and the device mirrors are all strictly append-only (prefixes
    are never rewritten), the frontier is cheap — ``n_rows`` alone
    pins everything a reader needs:

    * store arrays: rows ``[0, n_rows)`` are complete and immutable
      (``rep_view(epoch=)`` is a prefix slice, no copy-on-write);
    * split tree: item ids are assigned monotonically, so an as-of
      read is the row-count filter ``id < n_rows`` during traversal
      (``SplitTree.seed_candidates`` / ``collect_bounds``);
    * round-robin mirrors: the shard head/tail split at this frontier
      is ``head = (n_rows // n_shards) * n_shards`` — derived per
      sweep, with ids ``>= n_rows`` masked to +inf on device.

    ``epoch`` is the store version at publication (monotone counter);
    ``index_n`` records how many items the attached index covered when
    the epoch was published (equal to ``n_rows`` while an incremental
    index is maintained; 0 without one).
    """

    epoch: int
    n_rows: int
    index_n: int = 0


def epoch_rows(epoch) -> Optional[int]:
    """Resolve an epoch argument (``CorpusEpoch`` | int | None) to the
    visible row count, or None for "live" — the one coercion every
    layer that accepts ``epoch=`` shares."""
    if epoch is None:
        return None
    return int(getattr(epoch, "n_rows", epoch))


class SymbolicStore:
    """Append-only raw + symbolic store for one encoder.

    Parameters
    ----------
    encoder:  SAX / SSAX / TSAX / STSAX / OneDSAX instance (anything with
              ``T``, ``encode`` and ``pairwise_distance``).
    media:    "hdd" | "ssd" | "hbm" cost-model preset, or pass explicit
              ``seek_s`` / ``read_bps``.
    capacity: initial row capacity (grows by doubling).
    store_raw: when False the store keeps ONLY the representation —
              appended rows are encoded through the same chunked path but
              their raw values are discarded (``fetch`` raises).  This is
              the representation-only mode ``repro.subseq.WindowView``
              uses so N * S sliding windows never materialize as rows.
    """

    def __init__(self, encoder, *, media: str = "ssd",
                 seek_s: Optional[float] = None,
                 read_bps: Optional[float] = None,
                 capacity: int = 0, store_raw: bool = True):
        self.encoder = encoder
        self.store_raw = bool(store_raw)
        if seek_s is None or read_bps is None:
            if media not in MEDIA:
                raise ValueError(
                    f"unknown media {media!r}; options {set(MEDIA)}")
            self.seek_s = MEDIA[media][0] if seek_s is None else float(seek_s)
            self.read_bps = (MEDIA[media][1] if read_bps is None
                             else float(read_bps))
            self.media = media
        else:
            # explicit cost model: label it by the matching preset so the
            # media name never contradicts the numbers
            self.seek_s, self.read_bps = float(seek_s), float(read_bps)
            self.media = next(
                (name for name, v in MEDIA.items()
                 if v == (self.seek_s, self.read_bps)), "custom")
        self.T = int(encoder.T)
        self._n = 0
        self._cap = 0
        self._raw: Optional[np.ndarray] = None
        self._rep: Optional[list] = None   # list of (cap, ...) leaf arrays
        self._rep_is_tuple = True
        self.version = 0                   # bumped on every append
        self.index = None                  # optional SeriesIndex over rows
        # the published corpus frontier: swapped atomically (one
        # attribute assignment) as the LAST step of every mutation, so
        # a concurrent reader pins either the old or the new epoch,
        # never a half-applied one
        self._epoch = CorpusEpoch(epoch=0, n_rows=0, index_n=0)
        self.epoch_ledger = deque([self._epoch], maxlen=_LEDGER_LEN)
        # the verification protocol (fetch accounting + I/O model) is the
        # one RawStore implements — delegated, not duplicated; its .data
        # is re-pointed at the live prefix after every append
        self._io = RawStore(np.empty((0, self.T), np.float32),
                            seek_s=self.seek_s, read_bps=self.read_bps)
        if capacity:
            self._grow(capacity)

    # -- construction -----------------------------------------------------
    @classmethod
    def from_rows(cls, encoder, rows, *, media: str = "ssd",
                  **kwargs) -> "SymbolicStore":
        """One-shot construction: a store holding ``rows`` already encoded."""
        store = cls(encoder, media=media, **kwargs)
        store.append(rows)
        return store

    def _probe_rep_struct(self):
        """Encode one zero row to learn the leaf shapes/dtypes."""
        import jax.numpy as jnp
        rep = self.encoder.encode(jnp.zeros((1, self.T), jnp.float32))
        self._rep_is_tuple = isinstance(rep, tuple)
        return [np.asarray(leaf) for leaf in rep_leaves(rep)]

    def _grow(self, need: int):
        if need <= self._cap and self._rep is not None:
            return
        new_cap = max(need, 2 * self._cap, _MIN_CAPACITY)
        if self._rep is None:
            self._rep = [np.empty((new_cap,) + l.shape[1:], l.dtype)
                         for l in self._probe_rep_struct()]
            if self.store_raw:
                self._raw = np.empty((new_cap, self.T), np.float32)
        else:
            new_rep = []
            for old in self._rep:
                arr = np.empty((new_cap,) + old.shape[1:], old.dtype)
                arr[:self._n] = old[:self._n]
                new_rep.append(arr)
            self._rep = new_rep
            if self.store_raw:
                new_raw = np.empty((new_cap, self.T), np.float32)
                new_raw[:self._n] = self._raw[:self._n]
                self._raw = new_raw
        self._cap = new_cap

    # -- ingest -----------------------------------------------------------
    def _encode(self, rows: np.ndarray) -> tuple:
        import jax.numpy as jnp
        rep = self.encoder.encode(jnp.asarray(rows, jnp.float32))
        return tuple(np.asarray(leaf) for leaf in rep_leaves(rep))

    def append(self, rows, rep=None) -> np.ndarray:
        """Ingest new series; returns their dataset row ids.

        rows: (M, T) or (T,).  ``rep``: optionally the precomputed
        representation of exactly these rows (e.g. from a sharded encode
        pass) — structure must match ``encoder.encode`` output.  Only the
        new rows are encoded; existing rows and their representation are
        never touched.  A ``self.index`` built by ``build_index`` is
        maintained INCREMENTALLY: the new rows are routed into the split
        tree through the same code path bulk construction uses, so
        index-accelerated queries keep serving without a rebuild (an
        index that cannot insert — e.g. a legacy precomputed-feature
        ``SSaxIndex`` — is invalidated instead).
        """
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        if rows.shape[-1] != self.T:
            raise ValueError(f"rows have length {rows.shape[-1]}, "
                             f"encoder expects T={self.T}")
        m = rows.shape[0]
        if m == 0:
            return np.empty(0, np.int64)
        leaves = (tuple(np.asarray(l) for l in rep_leaves(rep))
                  if rep is not None else self._encode(rows))
        self._grow(self._n + m)
        if len(leaves) != len(self._rep):
            raise ValueError("rep structure does not match the encoder")
        if self.store_raw:
            self._raw[self._n:self._n + m] = rows
        for dst, src in zip(self._rep, leaves):
            if src.shape[0] != m or src.shape[1:] != dst.shape[1:]:
                raise ValueError(
                    f"rep leaf shape {src.shape} incompatible with "
                    f"store leaf {dst.shape[1:]} for {m} rows")
            dst[self._n:self._n + m] = src
        ids = np.arange(self._n, self._n + m, dtype=np.int64)
        self._n += m
        if self.store_raw:
            self._io.data = self._raw[:self._n]
        self.version += 1
        if self.index is not None:
            if getattr(self.index, "encoder", None) is None:
                # legacy feature-only index cannot derive features from
                # raw rows: invalidate rather than serve stale coverage
                self.index = None
            else:
                self.index.insert_rows(rows)   # same path as bulk build
        self._publish_epoch()
        return ids

    def _publish_epoch(self) -> "CorpusEpoch":
        """Publish the current frontier as a new epoch — the last step
        of every mutation, after rows, representation AND index are all
        fully applied, so the new epoch is never observable early."""
        ep = CorpusEpoch(
            epoch=self.version, n_rows=self._n,
            index_n=int(self.index.n) if self.index is not None else 0)
        self.epoch_ledger.append(ep)
        self._epoch = ep                     # atomic publish
        return ep

    def current_epoch(self) -> "CorpusEpoch":
        """The latest published frontier.  A query pinned to this epoch
        answers bit-identically to a frozen copy of the store truncated
        to ``epoch.n_rows``, regardless of concurrent appends."""
        return self._epoch

    # -- views ------------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    def __len__(self) -> int:
        return self._n

    @property
    def data(self) -> np.ndarray:
        """(N, T) raw rows — zero-copy view of the live prefix."""
        return self._io.data

    def rep_view(self, epoch=None):
        """Representation in the encoder's structure (zero-copy).

        ``epoch`` (a ``CorpusEpoch`` or a plain row count) bounds the
        view to the rows visible at that frontier — because the store
        is append-only, the as-of view is a prefix slice of the live
        leaves, content-identical to a frozen copy at publish time."""
        if self._rep is None:
            self._grow(0)
        n = self._n
        n_e = epoch_rows(epoch)
        if n_e is not None:
            n = min(n, n_e)
        leaves = tuple(l[:n] for l in self._rep)
        return leaves if self._rep_is_tuple else leaves[0]

    # -- RawStore verification protocol (delegated) ------------------------
    @property
    def accesses(self) -> int:
        return self._io.accesses

    @property
    def fetches(self) -> int:
        return self._io.fetches

    def fetch(self, idx) -> np.ndarray:
        if not self.store_raw:
            raise TypeError("store was built with store_raw=False: raw "
                            "rows were discarded after encoding and "
                            "cannot be fetched")
        return self._io.fetch(idx)

    def modeled_io_seconds(self, n_accesses: Optional[int] = None,
                           n_fetches: Optional[int] = None) -> float:
        return self._io.modeled_io_seconds(n_accesses, n_fetches)

    def reset_counters(self):
        """Zero the I/O accounting between measured phases (delegates to
        the backing ``RawStore``)."""
        self._io.reset_counters()

    def reset(self):
        self._io.reset()

    # -- index ------------------------------------------------------------
    def build_index(self, *, leaf_fill: int = 64, max_bits: int = 8,
                    leaf_capacity: Optional[int] = None,
                    mesh=None, n_shards: Optional[int] = None):
        """Build (and remember) a ``repro.index.SeriesIndex`` over the
        current rows — any of the four techniques.  Subsequent
        ``append`` calls maintain it incrementally (no rebuild); the
        engine consumes it via ``MatchEngine.topk(..., source="index")``.
        ``leaf_capacity`` is a legacy alias for ``leaf_fill``.

        ``mesh`` / ``n_shards`` route the bulk build through the sharded
        path (device feature extraction across the mesh's data axes,
        tree routing partitioned by root subtree) — bit-identical to the
        single-host build; see ``SeriesIndex.from_store``."""
        if not self.store_raw:
            raise TypeError("store was built with store_raw=False: index "
                            "features are derived from raw rows (index "
                            "the view that owns the raw source instead)")
        if leaf_capacity is not None:
            leaf_fill = leaf_capacity
        from repro.index import SeriesIndex
        self.index = SeriesIndex.from_store(self, leaf_fill=leaf_fill,
                                            max_bits=max_bits,
                                            mesh=mesh, n_shards=n_shards)
        self._publish_epoch()        # the index split-state token changed
        return self.index

    # -- persistence -------------------------------------------------------
    def save(self, directory: str, *, keep: int = 3,
             n_hosts: int = 1) -> str:
        """Write an atomic snapshot (see repro.store.snapshot); returns
        its final path.  ``n_hosts`` splits the row-indexed arrays into
        per-host ``shard_hNNN.npz`` files (ckpt.py conventions)."""
        if not self.store_raw:
            raise TypeError("store was built with store_raw=False: the "
                            "snapshot format requires raw rows (re-derive "
                            "the representation from the source instead)")
        from repro.store.snapshot import save_store
        return save_store(directory, self, keep=keep, n_hosts=n_hosts)

    @classmethod
    def open(cls, directory: str, *, snap: Optional[int] = None
             ) -> "SymbolicStore":
        """Reopen the latest (or a specific) snapshot from disk."""
        from repro.store.snapshot import open_store
        return open_store(directory, snap=snap)
