"""Streaming symbolic store: append-only raw + representation ownership
with incremental encoding and atomic on-disk snapshots (ISSUE 2 /
ROADMAP "Streaming ingestion" + "Index persistence")."""

from repro.store.symbolic import (  # noqa: F401
    MEDIA, CorpusEpoch, SymbolicStore, epoch_rows, rep_leaves)
from repro.store.snapshot import (  # noqa: F401
    latest_snap, open_store, save_store)
