"""``SubseqEngine``: batched exact top-k subsequence matching.

Answers "find the k best-matching windows of length m anywhere in the
corpus" for a (Q, m) query batch by routing window candidates through the
whole-matching frontier machinery (``core.engine.topk_verify``):

1. queries are z-normalized and encoded with the view's encoder;
2. the (Q, n_windows) representation-distance matrix against the live
   window representation is the lower-bounding candidate order;
3. ``topk_verify`` visits windows in that order with the k-th-best
   lower-bound early stop, fetching candidate windows through the
   ``WindowView`` — which bills deduplicated *underlying rows* to the
   ``RawStore`` I/O cost model — and verifying true z-normalized d_ED
   on host (or the Pallas euclid kernel).

Because every representation distance lower-bounds the true z-normalized
window distance, the result is bit-identical to a brute-force windowed
scan (the paper's §4.1 exactness argument applied to the window set; see
``repro.subseq.__init__``).

Candidate generation is linear (the (Q, n_windows) sweep) or — when the
view carries a split-tree index (``view.build_index()``) — sublinear
through ``repro.index``: the tree's seed/collect walk hands
``topk_verify`` a compact candidate set instead of all N*S windows, with
bit-identical results (same verifier, same tie-break).

Non-overlap suppression: with ``exclusion > 0``, windows that overlap an
already-selected better match (same source row, |start - start'| <
exclusion samples) are suppressed — the standard guard against trivial
matches one sample apart.  Selection stays exact: candidates are taken
greedily in the verified (distance, window id) order, and the frontier is
widened until k non-overlapping survivors exist or the window set is
exhausted.  Widening reuses the verified frontier on BOTH candidate
paths: every (window id, true distance) pair ever verified is
accumulated (``topk_verify``'s ``on_verified`` hook), the next round is
seeded with the best of them and excludes the rest — so no window id is
ever fetched or verified twice, matching the engine's accounting
contract (the indexed path used to re-run the whole top-k per round).

Sharding: pass ``mesh=`` to route the (Q, n_windows) representation
sweep through ``core.distributed.ShardedWindowSweep`` (the window
representation shards like whole-series matching — stride > 1 and
ragged T included), and ``verify="device"`` to verify candidate windows
device-side: source rows stay sharded in device memory, each shard
slices + z-normalizes its own windows and distances them through the
multi-query euclid kernel — bit-identical to the host ``verify="host"``
fallback (same kernel math through ``WindowView.fetch``), with zero
rows moved to the host.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.engine import (
    DeviceRepCache, kernel_verifier, make_verifier, merge_topk_device,
    merge_topk_numpy, topk_verify)
from repro.subseq.windows import WindowView, znorm_windows


class _VerifiedSet:
    """Per-query accumulator of every (window id, true distance) pair
    verified across exclusion-widening rounds — the source of the next
    round's seeded frontier, and the structure that makes 'no window id
    is ever verified twice' hold across rounds: the best ``k`` verified
    pairs are seeded, ALL verified ids are excluded from the next
    round's candidates, and an excluded-but-unseeded id is dominated by
    >= k verified better ids so it can never re-enter the top-k."""

    def __init__(self, q_n: int):
        self._maps = [dict() for _ in range(q_n)]     # id -> distance

    def add(self, qi: int, ids, dists):
        m = self._maps[qi]
        for i, d in zip(ids.tolist(), dists.tolist()):
            m[int(i)] = float(d)

    def ids(self, qi: int) -> np.ndarray:
        m = self._maps[qi]
        return np.fromiter(m.keys(), np.int64, len(m))

    def empty(self) -> bool:
        return all(not m for m in self._maps)

    def frontier(self, k: int):
        """Best min(k, verified) pairs per query in (distance, id)
        order — the ``init_d`` / ``init_i`` seed of the next round."""
        if self.empty():
            return None, None
        q_n = len(self._maps)
        out_d = np.full((q_n, k), np.inf, np.float64)
        out_i = np.full((q_n, k), -1, np.int64)
        for qi, m in enumerate(self._maps):
            if not m:
                continue
            ids = np.fromiter(m.keys(), np.int64, len(m))
            ds = np.fromiter(m.values(), np.float64, len(m))
            sel = np.lexsort((ids, ds))[:k]
            out_d[qi, :len(sel)] = ds[sel]
            out_i[qi, :len(sel)] = ids[sel]
        return out_d, out_i


@dataclass
class SubseqResult:
    """Batched top-k window matches.  Rows padded with id/row/start -1 and
    distance inf when fewer than k (non-overlapping) windows exist."""

    window_ids: np.ndarray       # (Q, k) int64 dense window ids
    rows: np.ndarray             # (Q, k) source row of each match
    starts: np.ndarray           # (Q, k) start sample of each match
    distances: np.ndarray        # (Q, k) true z-normalized d_ED
    raw_accesses: np.ndarray     # (Q,) windows verified per query
    pruned_fraction: np.ndarray  # (Q,) 1 - verified / n_windows
    store_accesses: int          # deduplicated underlying-row reads
    store_fetches: int           # batched fetch rounds (modeled seeks)
    io_seconds: float            # modeled I/O of the underlying reads


class SubseqEngine:
    """Batched multi-query top-k subsequence matcher over a WindowView.

    Parameters
    ----------
    view:        :class:`repro.subseq.WindowView` (encoder + corpus).
    batch_size:  verification batch per query per round.
    verify:      "auto" | "kernel" | "numpy" (see ``core.engine``);
                 "numpy" is the bit-identical-to-brute-force host path;
                 "host" fetches through the view but distances with the
                 same kernel math as "device" (bit-identical pair);
                 "device" verifies windows device-side (requires
                 ``mesh``), zero rows moved to the host.
    device_merge: merge frontiers on device (lexsort contract).
    mesh:        optional jax mesh — shards the (Q, n_windows)
                 representation sweep (``ShardedWindowSweep``) like
                 whole-series matching; required for verify="device".
    """

    def __init__(self, view: WindowView, *, batch_size: int = 64,
                 verify: str = "numpy", device_merge: bool = False,
                 mesh=None, metrics=None):
        self.view = view
        self.encoder = view.encoder
        self.batch_size = batch_size
        self.mesh = mesh
        self.verify_mode = verify
        # opt-in repro.obs.MetricsRegistry (None: record nothing)
        self.metrics = metrics
        self._device = verify == "device"
        if self._device and mesh is None:
            raise ValueError('verify="device" needs a mesh (the sharded '
                             "window sweep owns the device raw mirror)")
        self._sweep = None
        if mesh is not None:
            from repro.core.distributed import ShardedWindowSweep
            self._sweep = ShardedWindowSweep(view, mesh,
                                             mirror_raw=self._device)
        # the device path's host twin is the kernel verifier (same f32
        # distance definition -> bit-identical results)
        self.verifier = (kernel_verifier if self._device
                         else make_verifier(verify))
        self.merge = (merge_topk_device
                      if device_merge or self._device else merge_topk_numpy)
        self._rep_cache = DeviceRepCache(view)

    # -- representation sweep --------------------------------------------
    @property
    def rep(self):
        """Device copy of the live window representation, refreshed only
        when the view version changes (append-aware)."""
        return self._rep_cache.get()

    def normalize_queries(self, queries_raw) -> np.ndarray:
        """(Q, m) raw queries -> z-normalized f32 (the matching space)."""
        qs = np.asarray(queries_raw, np.float32)
        if qs.ndim == 1:
            qs = qs[None]
        if qs.shape[-1] != self.view.m:
            raise ValueError(f"queries have length {qs.shape[-1]}, "
                             f"window length is m={self.view.m}")
        return znorm_windows(qs)

    def repr_distances(self, queries_z) -> np.ndarray:
        """(Q, n_windows) lower-bounding representation distances for
        already-normalized queries — sharded over the mesh when one was
        given, single-device otherwise."""
        if self._sweep is not None:
            return np.asarray(self._sweep.repr_distances(queries_z))
        import jax.numpy as jnp
        q_rep = self.encoder.encode(jnp.asarray(queries_z, jnp.float32))
        return np.asarray(self.encoder.pairwise_distance(q_rep, self.rep))

    # -- matching ---------------------------------------------------------
    def topk(self, queries_raw, k: int = 1, *, exclusion: int = 0,
             batch_size: Optional[int] = None,
             use_index: object = "auto", trace=None,
             explain: bool = False, epoch=None) -> SubseqResult:
        """Top-k windows for a (Q, m) query batch (or a single (m,)
        query), exact under z-normalized d_ED.

        exclusion: minimum start-sample distance (same source row) between
        two reported matches; 0 disables suppression.

        use_index: "auto" (use ``view.index`` when built), True (require
        it), or False (force the linear window sweep).  Indexed and
        linear candidate generation verify through the same k-th-best
        early-stop scan and return bit-identical results — the index
        only changes how many windows are examined.

        trace / explain: record a per-query ``repro.obs`` query trace
        (``explain=True`` creates one and attaches it as ``res.trace``);
        bit-identical results and accounting either way (observability
        neutrality, property-tested).

        epoch: a ``view.current_epoch()`` frontier (or plain window
        count) pinning the answer to windows visible at that frontier —
        bit-identical to a view truncated there, regardless of windows
        synced concurrently (the snapshot-consistency contract of
        ingest-while-serving).
        """
        import time as _time
        if explain and trace is None:
            from repro.obs import Trace
            trace = Trace("subseq.topk")
        observing = trace is not None or self.metrics is not None
        t0 = _time.perf_counter() if observing else 0.0
        rows0 = self.view.accesses if observing else 0
        hob0 = (self._sweep.host_order_bytes
                if observing and self._sweep is not None else 0)
        h2d0 = (self._sweep.h2d_bytes
                if observing and self._sweep is not None else 0)
        res = self._topk(queries_raw, k, exclusion, batch_size, use_index,
                         trace, epoch)
        if observing:
            self._observe(trace, res, k, _time.perf_counter() - t0,
                          self.view.accesses - rows0, hob0, h2d0)
        if trace is not None:
            res.trace = trace
        return res

    def _observe(self, trace, res: SubseqResult, k: int, wall_s: float,
                 rows_delta: int, hob0: int, h2d0: int) -> None:
        """Post-call trace/registry recording (never perturbs results —
        it only reads the finished result and monotonic counters)."""
        rth = int(rows_delta) if self._device else None
        hob = h2d = None
        if self._sweep is not None:
            hob = int(self._sweep.host_order_bytes - hob0)
            h2d = int(self._sweep.h2d_bytes - h2d0)
        if trace is not None:
            trace.meta.update(engine="subseq", k=int(k),
                              q_n=int(res.window_ids.shape[0]),
                              total=int(self.view.n),
                              verify=self.verify_mode)
            trace.set("wall_s", wall_s)
            trace.set("pruning_power", res.pruned_fraction.copy())
            # deduplicated "generated": the accumulated meta total counts
            # re-handed candidates once per widening round; the noted id
            # layer reports the true union size alongside it
            gu = trace.unique_counts("generated",
                                     res.window_ids.shape[0]) \
                if hasattr(trace, "unique_counts") else None
            if gu is not None:
                trace.set("generated_unique", gu)
            if hob is not None:
                trace.set("host_order_bytes", hob)
                trace.set("h2d_bytes", h2d)
            if rth is not None:
                trace.set("rows_to_host", rth)
        if self.metrics is not None:
            m = self.metrics
            m.counter("subseq.queries").inc(res.window_ids.shape[0])
            m.counter("subseq.windows_verified").inc(
                int(res.raw_accesses.sum()))
            m.counter("subseq.rows_fetched").inc(int(res.store_accesses))
            m.counter("subseq.seeks").inc(int(res.store_fetches))
            m.counter("subseq.modeled_io_s").inc(float(res.io_seconds))
            m.gauge("subseq.pruning_power").set(
                float(res.pruned_fraction.mean()))
            m.histogram("subseq.topk_latency_s").observe(wall_s)
            if hob is not None:
                m.counter("subseq.host_order_bytes").inc(hob)
                m.counter("subseq.h2d_bytes").inc(h2d)
            if rth is not None:
                m.counter("subseq.rows_to_host").inc(rth)

    def _topk(self, queries_raw, k: int, exclusion: int,
              batch_size: Optional[int], use_index: object,
              trace, epoch=None) -> SubseqResult:
        from repro.obs.trace import maybe_span
        from repro.store.symbolic import epoch_rows
        zq = self.normalize_queries(queries_raw)
        bs = batch_size or self.batch_size
        n_e = epoch_rows(epoch)
        idx = self.view.index if use_index in ("auto", True) else None
        if use_index is True and idx is None:
            raise ValueError("use_index=True but the view has no index; "
                             "call view.build_index() first")
        if trace is not None:
            trace.set("source", "index" if idx is not None else "linear")
            if n_e is not None:
                trace.meta["epoch_rows"] = int(n_e)
        acc = {"rows": 0, "fetches": 0, "io": 0.0}
        dfn = self._sweep.make_dist_fn(zq) if self._device else None
        if idx is not None:
            return self._topk_indexed(zq, idx, k, exclusion, bs, acc, dfn,
                                      trace, epoch=n_e)
        if exclusion <= 0 and self._sweep is not None:
            # device-ordered candidate stream: the (Q, n_windows) bound
            # matrix never materializes on host — the suppression loop
            # below masks host columns, so it keeps the matrix path
            with maybe_span(trace, "order") as sp:
                mask_fn = None
                if n_e is not None:
                    # windows past the pinned frontier -> +inf on device
                    def mask_fn(ids, _n=n_e):
                        return ids >= _n
                stream = self._sweep.candidate_stream(zq, mask_fn=mask_fn)
                if trace is not None:
                    from repro.obs.trace import block_until_ready
                    block_until_ready((stream._b, stream._i))
                    sp.meta["stream"] = True
            with maybe_span(trace, "verify"):
                res = topk_verify(zq, None, self.view, k=k, batch_size=bs,
                                  verifier=self.verifier, merge=self.merge,
                                  dist_fn=dfn, stream=stream, trace=trace)
            total = (int(stream.width) if n_e is None
                     else min(int(stream.width), n_e))
            return self._wrap(res.indices, res.distances, res, total, acc)
        with maybe_span(trace, "order"):
            rd = self.repr_distances(zq)
            if n_e is not None:
                rd = rd[:, :n_e]   # prefix-stable: as-of read is a slice
        nw = rd.shape[1]
        if exclusion <= 0:
            with maybe_span(trace, "verify"):
                res = topk_verify(zq, rd, self.view, k=k, batch_size=bs,
                                  verifier=self.verifier, merge=self.merge,
                                  dist_fn=dfn, trace=trace)
            return self._wrap(res.indices, res.distances, res, nw, acc)

        # widen the verified frontier until k non-overlapping survivors
        # exist per query (or every window has been considered): greedy
        # selection over the verified order is exact as long as the
        # frontier was not cut before the k-th survivor.  Every (id,
        # distance) pair ever verified is accumulated; each widening
        # round seeds the best of them (init_d / init_i) and masks ALL
        # of them to +inf in the bound matrix, so no window id is ever
        # fetched or verified twice across rounds.
        ver = _VerifiedSet(zq.shape[0])
        k_fetch = min(nw, max(4 * k, k + 8))
        rd = np.array(rd)                  # writeable: columns get masked
        widen_round = 0
        while True:
            init_d, init_i = ver.frontier(k_fetch)
            with maybe_span(trace, "verify", round=widen_round):
                res = topk_verify(zq, rd, self.view, k=k_fetch,
                                  batch_size=bs,
                                  verifier=self.verifier, merge=self.merge,
                                  init_d=init_d, init_i=init_i,
                                  dist_fn=dfn, on_verified=ver.add,
                                  trace=trace)
            widen_round += 1
            acc["rows"] += res.store_accesses
            acc["fetches"] += res.store_fetches
            acc["io"] += res.io_seconds
            ids, dists, full = self._suppress(res, k, exclusion)
            if full or k_fetch >= nw:
                return self._wrap(ids, dists, res, nw, acc,
                                  accumulated=True)
            for qi in range(zq.shape[0]):
                rd[qi, ver.ids(qi)] = np.inf
            k_fetch = min(nw, 2 * k_fetch)

    def topk_approx(self, queries_raw, k: int = 1, *,
                    collect: Optional[int] = None,
                    batch_size: Optional[int] = None,
                    trace=None, explain: bool = False,
                    epoch=None) -> SubseqResult:
        """Anytime/approximate window top-k through the index's bounded
        collect (requires ``view.build_index()``): exact seed walk, at
        most ``collect`` (default ``max(4 * k, 32)``) collected
        candidates per query.  The result carries ``kth_lb`` /
        ``error_bar`` — the same certificate contract as
        ``MatchEngine.topk_approx``; an error bar of zero proves the
        answer exact despite the cap."""
        import time as _time
        from repro.store.symbolic import epoch_rows
        idx = self.view.index
        if idx is None:
            raise ValueError("topk_approx needs the window index; call "
                             "view.build_index() first")
        n_e = epoch_rows(epoch)
        if n_e is None:
            if idx.n != self.view.n:
                raise ValueError(f"window index covers {idx.n} of "
                                 f"{self.view.n} windows; call "
                                 f"view.sync()")
        elif idx.n < n_e:
            raise ValueError(f"window index covers {idx.n} windows, "
                             f"epoch pins {n_e}; call view.sync()")
        if explain and trace is None:
            from repro.obs import Trace
            trace = Trace("subseq.topk")
        observing = trace is not None or self.metrics is not None
        t0 = _time.perf_counter() if observing else 0.0
        rows0 = self.view.accesses if observing else 0
        hob0 = (self._sweep.host_order_bytes
                if observing and self._sweep is not None else 0)
        h2d0 = (self._sweep.h2d_bytes
                if observing and self._sweep is not None else 0)
        zq = self.normalize_queries(queries_raw)
        if trace is not None:
            trace.set("source", "index-approx")
            trace.set("exact", False)
        dfn = self._sweep.make_dist_fn(zq) if self._device else None
        res = idx.topk(zq, self.view, k=k,
                       batch_size=batch_size or self.batch_size,
                       verifier=self.verifier, merge=self.merge,
                       dist_fn=dfn, trace=trace, epoch=n_e,
                       approx_collect=(collect if collect is not None
                                       else max(4 * k, 32)))
        total = self.view.n if n_e is None else min(self.view.n, n_e)
        out = self._wrap(res.indices, res.distances, res, total,
                         {"rows": 0, "fetches": 0, "io": 0.0})
        out.kth_lb = res.kth_lb
        out.error_bar = res.error_bar
        if observing:
            self._observe(trace, out, k, _time.perf_counter() - t0,
                          self.view.accesses - rows0, hob0, h2d0)
        if trace is not None:
            out.trace = trace
        return out

    def _topk_indexed(self, zq, idx, k: int, exclusion: int, bs: int,
                      acc: dict, dfn, trace=None,
                      epoch=None) -> SubseqResult:
        """Indexed candidate generation: route the tree's compact
        candidate set through the same verification scan
        (``repro.index.candidates.topk_from_source``) — bit-identical to
        the linear sweep.  With suppression, widen at doubled k_fetch,
        handing ``TreeCandidates`` the accumulated verified frontier and
        seen-id set — each round only verifies never-seen windows (same
        contract as the linear path; each round remains an exact
        top-k_fetch, so greedy selection stays exact).

        ``epoch`` (visible window count) relaxes the cover check: the
        index only needs to reach the PINNED frontier, not the live view
        — concurrent syncs past the pin are filtered by the as-of
        traversal, not a staleness error."""
        if epoch is None:
            if idx.n != self.view.n:
                raise ValueError(f"window index covers {idx.n} of "
                                 f"{self.view.n} windows; call "
                                 f"view.sync()")
            nw_total = self.view.n
        else:
            if idx.n < epoch:
                raise ValueError(f"window index covers {idx.n} windows, "
                                 f"epoch pins {epoch}; call view.sync()")
            nw_total = int(epoch)
        common = dict(batch_size=bs, verifier=self.verifier,
                      merge=self.merge, dist_fn=dfn, epoch=epoch)
        if exclusion <= 0:
            res = idx.topk(zq, self.view, k=k, trace=trace, **common)
            return self._wrap(res.indices, res.distances, res, nw_total,
                              acc)
        ver = _VerifiedSet(zq.shape[0])
        k_fetch = min(nw_total, max(4 * k, k + 8))
        while True:
            init_d, init_i = ver.frontier(k_fetch)
            seen = ([ver.ids(qi) for qi in range(zq.shape[0])]
                    if init_d is not None else None)
            res = idx.topk(zq, self.view, k=k_fetch, on_verified=ver.add,
                           prior_d=init_d, prior_i=init_i, seen=seen,
                           trace=trace, **common)
            acc["rows"] += res.store_accesses
            acc["fetches"] += res.store_fetches
            acc["io"] += res.io_seconds
            ids, dists, full = self._suppress(res, k, exclusion)
            if full or k_fetch >= nw_total:
                return self._wrap(ids, dists, res, nw_total, acc,
                                  accumulated=True)
            k_fetch = min(nw_total, 2 * k_fetch)

    def _suppress(self, res, k: int, exclusion: int):
        """Greedy non-overlap filter over the verified frontier; returns
        (ids, dists, every_query_filled_or_exhausted)."""
        q_n, kf = res.indices.shape
        rows_all, starts_all = self.view.locate(res.indices)
        out_i = np.full((q_n, k), -1, np.int64)
        out_d = np.full((q_n, k), np.inf, np.float64)
        full = True
        for qi in range(q_n):
            taken_rows, taken_starts, m_sel = [], [], 0
            for j in range(kf):
                wid = res.indices[qi, j]
                if wid < 0:
                    break
                r, s = rows_all[qi, j], starts_all[qi, j]
                clash = any(tr == r and abs(ts - s) < exclusion
                            for tr, ts in zip(taken_rows, taken_starts))
                if clash:
                    continue
                out_i[qi, m_sel] = wid
                out_d[qi, m_sel] = res.distances[qi, j]
                taken_rows.append(r)
                taken_starts.append(s)
                m_sel += 1
                if m_sel == k:
                    break
            # a query is settled if it filled k slots or its frontier ran
            # out of real candidates (no more windows exist at all)
            if m_sel < k and res.indices[qi, -1] >= 0:
                full = False
        return out_i, out_d, full

    def _wrap(self, ids, dists, res, nw, acc, *,
              accumulated: bool = False) -> SubseqResult:
        rows, starts = self.view.locate(ids)
        return SubseqResult(
            window_ids=ids, rows=rows, starts=starts, distances=dists,
            raw_accesses=res.raw_accesses,
            pruned_fraction=1.0 - res.raw_accesses / nw,
            store_accesses=acc["rows"] if accumulated else
            res.store_accesses,
            store_fetches=acc["fetches"] if accumulated else
            res.store_fetches,
            io_seconds=acc["io"] if accumulated else res.io_seconds)

    # -- brute-force baseline ---------------------------------------------
    def scan_topk(self, queries_raw, k: int = 1, use_kernel: bool = True,
                  chunk_bytes: float = 2.5e8) -> SubseqResult:
        """Brute-force windowed scan through the MASS-style kernel
        (``kernels.windowed_euclid``): computes the full distance profile
        and takes top-k.  The modeled I/O is one streaming pass over the
        whole corpus — the baseline ``topk`` is judged against.

        The corpus is processed in row chunks sized so the (Q, rows, S)
        profile (and the reference path's window intermediates) stay
        under ``chunk_bytes`` — per-chunk top-k survivors are merged at
        the end, so arbitrarily large corpora scan in bounded memory."""
        import jax.numpy as jnp
        from repro.kernels import ops
        zq = self.normalize_queries(queries_raw)
        q_n, m = zq.shape
        nw = self.view.windows_per_row
        n_rows = self.view.n_rows
        k = min(k, nw * n_rows)
        blk = max(1, int(chunk_bytes / (4 * max(q_n, 1) * nw * m)))
        data = self.view.source.data
        cand_i, cand_d = [], []
        for r0 in range(0, n_rows, blk):
            d2 = np.asarray(ops.windowed_euclid(
                jnp.asarray(data[r0:r0 + blk], jnp.float32),
                jnp.asarray(zq, jnp.float32), stride=self.view.stride,
                use_kernel=use_kernel))
            d = np.sqrt(np.maximum(d2.reshape(q_n, -1), 0.0))
            kk = min(k, d.shape[1])
            part = np.argpartition(d, kk - 1, axis=1)[:, :kk]
            cand_i.append(part + r0 * nw)
            cand_d.append(np.take_along_axis(d, part, axis=1))
        all_i = np.concatenate(cand_i, axis=1)
        all_d = np.concatenate(cand_d, axis=1)
        sel = np.lexsort((all_i, all_d), axis=1)[:, :k]
        order = np.take_along_axis(all_i, sel, axis=1).astype(np.int64)
        dists = np.take_along_axis(all_d, sel, axis=1).astype(np.float64)
        rows, starts = self.view.locate(order)
        return SubseqResult(
            window_ids=order, rows=rows, starts=starts, distances=dists,
            raw_accesses=np.full(q_n, nw * n_rows, np.int64),
            pruned_fraction=np.zeros(q_n),
            store_accesses=n_rows, store_fetches=1,
            io_seconds=self.view.modeled_io_seconds(n_rows, 1))
