"""Subsequence matching: sliding-window symbolic search over long series.

The paper evaluates *whole matching* (§4.1): every query is compared
against same-length dataset rows, candidates are visited in
representation-distance order, and the scan stops once the best verified
d_ED is <= the next representation distance — exact because every
symbolic distance LOWER-BOUNDS d_ED (Appendix A.1–A.5).  Nothing in that
argument requires the candidates to be distinct stored rows: it holds for
any candidate set on which the encoder's bound applies.  This package
instantiates it on the set of **z-normalized sliding windows** of long
series, which turns the store + engine stack into a general subsequence
search system:

* :class:`~repro.subseq.windows.WindowView` enumerates the length-m,
  stride-s windows of an (N, T) corpus and maintains their live symbolic
  representation — encoded incrementally through the
  ``repro.store.SymbolicStore`` chunked-encode path (``store_raw=False``,
  so the N * S window matrix never materializes) and therefore
  bit-identical to one-shot window encoding for any ingest chunking.
  The view also speaks the ``RawStore`` verification protocol over
  *window* indices: fetching candidate windows reads (deduplicated)
  underlying rows through the source's I/O cost model and re-normalizes
  the slices on the fly.
* :class:`~repro.subseq.search.SubseqEngine` runs the paper's pruned
  scan over window candidates via ``core.engine.topk_verify`` — same
  representation-distance order, same k-th-best lower-bound early stop,
  same (distance, index) tie-break — so its top-k windows are exactly
  the brute-force windowed scan's, at a fraction of the raw I/O.
  Optional temporal non-overlap suppression discards trivial matches
  (windows overlapping an already-selected better match in the same
  series).
* :mod:`repro.kernels.windowed_euclid` is the brute-force side of the
  bargain: a MASS-style Pallas kernel producing the full z-normalized
  distance profile from rolling window statistics, used as the scan
  baseline and for ``SubseqEngine.scan_topk``.

Why the exactness argument transfers (§4.1): for windows w of the corpus
and query q, both z-normalized, the encoder bound gives
d_rep(enc(q), enc(w)) <= d_ED(q, w).  ``topk_verify`` only ever prunes a
window whose representation distance is STRICTLY above the k-th best
verified true distance, so — exactly as in the paper's proof — no pruned
window can enter the true top-k, independent of how many windows share an
underlying row.
"""

from repro.subseq.windows import WindowView  # noqa: F401
from repro.subseq.search import SubseqEngine, SubseqResult  # noqa: F401
