"""``WindowView``: the symbolic representation of all sliding windows.

A ``WindowView`` sits on top of a long-series corpus — a bare (N, T)
array, a ``RawStore``, or a ``SymbolicStore`` — and maintains the live
symbolic representation of every z-normalized window of length ``m =
encoder.T`` at a configurable ``stride``, without ever materializing the
N * S window matrix:

* **Representation only.**  Window reps live in a
  ``SymbolicStore(encoder, store_raw=False)`` — the store's incremental
  chunked-encode path (capacity-doubled leaf arrays, bit-identical to
  one-shot encoding for any chunking) with the raw side disabled.  A
  window's raw values are always re-derivable from the source row, so
  storing them would duplicate the corpus m/stride times over.
* **Append-aware.**  ``append(rows)`` pushes rows into the source and
  encodes only the new rows' windows; ``sync()`` picks up rows appended
  to a shared source out-of-band.  Windows of previously ingested rows
  are never re-encoded.
* **Indexable.**  ``build_index()`` attaches a
  :class:`repro.index.SeriesIndex` whose tree items are the windows
  themselves (ids = window ids); ``sync`` maintains it incrementally and
  ``SubseqEngine`` takes sublinear candidates from it — bit-identical
  results to the linear window sweep.
* **Verification protocol over window ids.**  ``fetch(window_ids)``
  returns the z-normalized windows themselves, but bills the I/O cost
  model for the *deduplicated underlying rows* the windows live in —
  overlapping candidate windows of one row cost one row read
  (``RawStore`` cost model, one modeled seek per fetch round).  A
  bounded row buffer (``cache_rows``, FIFO) models the matcher's buffer
  pool: candidate windows arrive in representation-distance order and
  therefore cluster in the same hot rows round after round, so a row is
  billed only when it is cold — the scan baseline by contrast always
  streams the entire corpus.  This is what lets
  ``core.engine.topk_verify`` run unchanged over windows.

Window ids are dense row-major: ``wid = row * S + j`` covers
``source.data[row, j*stride : j*stride + m]`` where ``S`` is the
per-row window count; ``locate`` translates back.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.matching import RawStore
from repro.kernels.windowed_euclid import n_windows
from repro.store.symbolic import SymbolicStore


def znorm_windows(w) -> np.ndarray:
    """Z-normalize a (..., m) window batch exactly like the encode path
    (``repro.core.normalize.znormalize`` on f32) — the single definition
    both ``fetch`` and any brute-force baseline must share for
    bit-identical distances."""
    import jax.numpy as jnp
    from repro.core.normalize import znormalize
    return np.asarray(znormalize(jnp.asarray(np.asarray(w), jnp.float32)))


class WindowView:
    """Sliding-window symbolic view of a long-series corpus.

    Parameters
    ----------
    encoder:      SAX / SSAX / TSAX / STSAX / OneDSAX instance whose ``T``
                  is the window length m.
    source:       (N, T) array (wrapped in a ``RawStore`` with ``media``),
                  or an existing ``RawStore`` / ``SymbolicStore`` whose
                  raw rows are the corpus.  May be None and appended into.
    stride:       window hop in samples (>= 1).
    media:        cost-model preset used when ``source`` is a bare array
                  (ignored otherwise — the source keeps its own model).
    encode_chunk: windows per incremental encode call (bounds the
                  transient window materialization).
    cache_rows:   row-buffer capacity (FIFO); rows served from the buffer
                  are not billed again.  0 disables buffering (every
                  fetch round bills its rows cold).
    """

    def __init__(self, encoder, source=None, *, stride: int = 1,
                 media: str = "ssd", encode_chunk: int = 4096,
                 cache_rows: int = 1024):
        self.encoder = encoder
        self.m = int(encoder.T)
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        self.stride = int(stride)
        self.encode_chunk = int(encode_chunk)
        self.cache_rows = int(cache_rows)
        self._cache: dict = {}          # row id -> raw row (FIFO order)
        self._media = media
        self._rows_done = 0
        self._nw: Optional[int] = None     # windows per row, fixed by T
        self._rep = SymbolicStore(encoder, media=media, store_raw=False)
        self.index = None                  # optional SeriesIndex (windows)
        if source is None:
            self.source = None
        elif hasattr(source, "fetch") and hasattr(source, "data"):
            self.source = source
            self.sync()
        else:
            rows = np.asarray(source, np.float32)
            if rows.ndim == 1:
                rows = rows[None]
            self.source = RawStore(np.empty((0, rows.shape[-1]),
                                            np.float32),
                                   *_media_rates(media))
            self.append(rows)

    # -- geometry ---------------------------------------------------------
    @property
    def T(self) -> int:
        """Source series length (available once the first row exists)."""
        if self.source is None:
            raise ValueError("empty WindowView: append rows first")
        return int(self.source.data.shape[-1])

    @property
    def windows_per_row(self) -> int:
        if self._nw is None:
            self._nw = n_windows(self.T, self.m, self.stride)
        return self._nw

    @property
    def n_rows(self) -> int:
        return 0 if self.source is None else int(self.source.data.shape[0])

    @property
    def n(self) -> int:
        """Total windows currently encoded."""
        return self._rep.n

    def __len__(self) -> int:
        return self.n

    @property
    def version(self) -> int:
        return self._rep.version

    def current_epoch(self):
        """Pinnable frontier for subsequence queries (the unit is WINDOW
        ids, not source rows).  Mid-``sync`` a chunk's representation
        append publishes before its index insert, so when an index
        exists the frontier is clamped to the index's row count — a
        pinned epoch is then fully covered by BOTH structures and the
        indexed and linear paths answer it identically."""
        from repro.store.symbolic import CorpusEpoch
        ep = self._rep.current_epoch()
        if self.index is not None and self.index.n < ep.n_rows:
            ep = CorpusEpoch(epoch=ep.epoch, n_rows=int(self.index.n),
                             index_n=int(self.index.n))
        return ep

    def locate(self, window_ids):
        """Window ids -> (source row, start sample); -1 ids pass through."""
        wid = np.asarray(window_ids, np.int64)
        nw = self.windows_per_row
        rows = np.where(wid >= 0, wid // nw, -1)
        starts = np.where(wid >= 0, (wid % nw) * self.stride, -1)
        return rows, starts

    # -- ingest -----------------------------------------------------------
    def append(self, rows) -> np.ndarray:
        """Push long rows into the source and encode only their windows;
        returns the new rows' window ids."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        if self.source is None:
            self.source = RawStore(np.empty((0, rows.shape[-1]),
                                            np.float32),
                                   *_media_rates(self._media))
        if rows.shape[-1] != self.source.data.shape[-1]:
            raise ValueError(
                f"rows have length {rows.shape[-1]}, corpus has "
                f"T={self.source.data.shape[-1]}")
        if hasattr(self.source, "append"):       # SymbolicStore source
            self.source.append(rows)
        else:
            self.source.data = np.concatenate([self.source.data, rows])
        start = self.n
        self.sync()
        return np.arange(start, self.n, dtype=np.int64)

    def sync(self) -> int:
        """Encode windows of any source rows not yet windowed (rows
        appended through a shared source land here); returns the number
        of windows added.  A window index built by ``build_index`` is
        maintained incrementally: each chunk's z-normalized windows are
        routed into the split tree in window-id order — the same code
        path the bulk build uses, so no rebuild is ever needed."""
        added = 0
        n_rows = self.source.data.shape[0]
        for z in self._window_chunks(self._rows_done, n_rows):
            self._rep.append(z)
            if self.index is not None:
                self.index.insert_rows(z)
            added += z.shape[0]
        self._rows_done = n_rows
        return added

    def _window_chunks(self, row_lo: int, row_hi: int):
        """Yield the z-normalized windows of source rows [row_lo, row_hi)
        in window-id order, ``encode_chunk`` windows at a time — the ONE
        extraction path both incremental ``sync`` and the bulk
        ``build_index`` consume, so the two can never drift apart (the
        bulk == incremental invariance the index subsystem rests on)."""
        nw = self.windows_per_row
        for r in range(row_lo, row_hi):
            wv = np.lib.stride_tricks.sliding_window_view(
                self.source.data[r], self.m)[::self.stride]  # (nw, m) view
            for c0 in range(0, nw, self.encode_chunk):
                yield znorm_windows(wv[c0:c0 + self.encode_chunk])

    # -- index ------------------------------------------------------------
    def build_index(self, *, leaf_fill: int = 64, max_bits: int = 8):
        """Build (and remember) a ``repro.index.SeriesIndex`` over every
        window currently encoded — tree item ids ARE window ids (both
        are dense row-major insertion order).  Windows of rows appended
        afterwards are inserted incrementally by ``sync``;
        ``SubseqEngine`` generates candidates from the tree instead of
        sweeping all N*S windows linearly."""
        from repro.index import SeriesIndex
        idx = SeriesIndex(self.encoder, leaf_fill=leaf_fill,
                          max_bits=max_bits)
        for z in self._window_chunks(0, self._rows_done):
            idx.insert_rows(z)
        assert idx.n == self.n, (idx.n, self.n)
        self.index = idx
        return idx

    # -- representation ---------------------------------------------------
    def rep_view(self):
        """Live window representation (encoder structure, zero-copy)."""
        return self._rep.rep_view()

    @property
    def rep_store(self):
        """The representation-only ``SymbolicStore`` backing this view —
        what ``core.distributed.ShardedWindowSweep`` mirrors on device
        for the sharded window sweep."""
        return self._rep

    # -- RawStore verification protocol over WINDOW ids -------------------
    def fetch(self, window_ids) -> np.ndarray:
        """Z-normalized windows for ``window_ids`` (any order, duplicates
        allowed).  Bills the source cost model for the deduplicated
        underlying rows that are not already in the row buffer (one
        modeled seek for a round that reads any cold row)."""
        wid = np.asarray(window_ids, np.int64)
        if wid.size == 0:
            return np.empty((0, self.m), np.float32)
        rows, starts = self.locate(wid)
        uniq, inv = np.unique(rows, return_inverse=True)
        rowmap = {r: self._cache[r] for r in uniq.tolist()
                  if r in self._cache}
        missing = [r for r in uniq.tolist() if r not in rowmap]
        if missing:
            raw = self.source.fetch(np.asarray(missing, np.int64))
            rowmap.update(zip(missing, raw))
            if self.cache_rows > 0:
                self._cache.update(zip(missing, raw))
                while len(self._cache) > self.cache_rows:
                    self._cache.pop(next(iter(self._cache)))
        slab = np.stack([rowmap[r] for r in uniq.tolist()])[inv]  # (K, T)
        gather = starts[:, None] + np.arange(self.m)[None, :]
        return znorm_windows(np.take_along_axis(slab, gather, axis=1))

    @property
    def accesses(self) -> int:
        return self.source.accesses

    @property
    def fetches(self) -> int:
        return self.source.fetches

    def modeled_io_seconds(self, n_accesses: Optional[int] = None,
                           n_fetches: Optional[int] = None) -> float:
        return self.source.modeled_io_seconds(n_accesses, n_fetches)

    def reset_counters(self):
        """Zero the I/O accounting only, KEEPING the row buffer warm —
        the phase boundary for back-to-back measurements over a live
        service (the buffer pool doesn't empty between queries in
        production).  Use :meth:`reset` for a cold-cache measurement."""
        self.source.reset_counters()

    def reset(self):
        """Reset I/O accounting AND drop the row buffer (a fresh-cache
        measurement, like a cold OS page cache)."""
        self._cache.clear()
        self.source.reset()


def _media_rates(media: str):
    from repro.core.matching import MEDIA
    if media not in MEDIA:
        raise ValueError(f"unknown media {media!r}; options {set(MEDIA)}")
    return MEDIA[media]
