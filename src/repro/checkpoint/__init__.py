from repro.checkpoint.ckpt import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, Checkpointer)
from repro.checkpoint.elastic import reshard_checkpoint  # noqa: F401
