"""Sharded checkpointing with atomic manifest commit.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json            # tree structure, leaf shapes/dtypes, hash
        shard_h000.npz           # this host's leaves (flat key -> array)
    <dir>/LATEST                 # atomically-renamed pointer file

Crash safety: everything is written into ``step_XXXX.tmp`` and renamed
only after the manifest fsyncs — a torn write can never produce a
readable-but-wrong checkpoint, and restore always follows LATEST.  On a
real multi-host pod each process writes its own ``shard_hNNN.npz`` of
locally-addressable shards; in this single-process container host 0 owns
everything (the layout is already multi-host shaped, which is what the
elastic re-shard tool consumes).

Leaves are stored *logically unsharded* (host-gathered) so restore can
re-shard onto any mesh — the elastic-scaling contract (DESIGN.md §6).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import time

import jax
import numpy as np


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): v for p, v in leaves}, treedef


def _config_hash(tree) -> str:
    leaves, _ = _flat(tree)
    desc = json.dumps({k: (list(np.shape(v)), str(np.asarray(v).dtype))
                       for k, v in sorted(leaves.items())})
    return hashlib.sha256(desc.encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, state, *, host: int = 0,
                    keep: int = 3) -> str:
    """Write one checkpoint; returns its final path."""
    leaves, _ = _flat(state)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)

    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, f"shard_h{host:03d}.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "hash": _config_hash(state),
        "hosts": 1,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    # pointer file, atomically replaced
    ptr_tmp = os.path.join(directory, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str):
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    name = open(ptr).read().strip()
    path = os.path.join(directory, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, like, *, step=None,
                       shardings=None):
    """Restore into the structure of ``like`` (a state pytree or abstract
    tree).  ``shardings``: optional matching tree of NamedSharding to
    device_put each leaf onto (elastic re-shard on load)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    data = {}
    for fn in sorted(os.listdir(path)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(path, fn)) as z:
                data.update({k: z[k] for k in z.files})

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    flat_sh = None
    if shardings is not None:
        flat_sh = [s for _, s in
                   jax.tree_util.tree_flatten_with_path(shardings)[0]]
    out = []
    for i, (p, leaf) in enumerate(leaves):
        k = jax.tree_util.keystr(p)
        if k not in data:
            raise KeyError(f"checkpoint at step {step} missing leaf {k}")
        arr = data[k]
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {k}: checkpoint shape {arr.shape} != expected {want}")
        if flat_sh is not None:
            arr = jax.device_put(arr, flat_sh[i])
        out.append(arr)
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    return state, manifest


class Checkpointer:
    """Cadence-based checkpointing helper for the training loop."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, state, force: bool = False):
        if force or (self.every and step % self.every == 0 and step > 0):
            return save_checkpoint(self.directory, step, state,
                                   keep=self.keep)
        return None

    def restore_or_init(self, init_fn, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return init_fn(), 0
        like = init_fn()
        state, manifest = restore_checkpoint(
            self.directory, like, step=step, shardings=shardings)
        return state, manifest["step"]
