"""Elastic re-sharding: restore a checkpoint onto a different mesh.

Checkpoints store logically-unsharded leaves (ckpt.py), so scaling the
data-parallel degree up or down is a restore with new NamedShardings.
``reshard_checkpoint`` is the offline tool (old dir -> new dir is not
needed — the same checkpoint serves any mesh); what changes is the
sharding tree handed to ``restore_checkpoint``.  The launcher calls
``elastic_restore`` on boot with whatever mesh it actually got — that,
plus the data pipeline re-splitting by new dp rank, is the whole elastic
story for DP/FSDP axes.  (Changing the *model* axis degree would change
padding of vocab-sharded tables; guarded against below.)
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.transformer import param_pspecs
from repro.sharding.specs import ShardingRules
from repro.train.state import train_state_pspecs


def state_shardings_for_mesh(cfg, mesh: Mesh):
    rules = ShardingRules.for_mesh(mesh)
    ps = train_state_pspecs(cfg, rules)
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), ps,
                        is_leaf=lambda x: hasattr(x, "_normalized_spec")
                        or type(x).__name__ == "PartitionSpec")


def elastic_restore(directory: str, cfg, mesh: Mesh, abstract_state):
    """Restore the latest checkpoint re-sharded onto ``mesh``."""
    from repro.checkpoint.ckpt import restore_checkpoint
    sh = state_shardings_for_mesh(cfg, mesh)
    return restore_checkpoint(directory, abstract_state, shardings=sh)


def reshard_checkpoint(directory: str, cfg, old_mesh: Mesh, new_mesh: Mesh,
                       abstract_state):
    """Validate old->new mesh compatibility and load re-sharded."""
    if old_mesh.shape.get("model", 1) != new_mesh.shape.get("model", 1):
        raise ValueError(
            "elastic scaling changes only data-parallel axes; the model "
            "axis degree is fixed by table padding (DESIGN.md §6)")
    return elastic_restore(directory, cfg, new_mesh, abstract_state)
