"""AdamW over parameter pytrees — dependency-free, sharding-transparent.

State (m, v) mirrors the parameter tree, so the parameter PartitionSpecs
apply verbatim (ZeRO-1/FSDP falls out of sharding the trees, not of the
optimizer code).  Update math runs in f32 regardless of parameter dtype.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, dtype=F32):
    """dtype=bfloat16 gives the low-memory state variant; the update math
    still runs in f32 (moments are up-cast per step)."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step, *,
                 lr_scale=1.0):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    t = (step + 1).astype(F32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        st_dtype = m.dtype
        g = g.astype(F32) * clip
        m = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay, matrices only
            step_ = step_ + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * step_).astype(p.dtype)
        return new_p, m.astype(st_dtype), v.astype(st_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm}
