"""Gradient compression for cross-pod data parallelism.

``int8`` block-quantized compression with stateless stochastic-style
rounding is exposed as a drop-in transform on the gradient tree.  On real
meshes the win is 4x less DCN/ICI all-reduce volume for the data-parallel
gradient sum; here we implement the quantize/dequantize math (tested for
convergence in tests/test_compression.py) and an error-feedback variant
where the residual is carried in the optimizer loop.

Note on placement: compression must wrap the *cross-pod* reduction only —
within-pod reductions are cheap.  With GSPMD the reduction is implicit, so
we quantize the local gradient contribution before it enters the
all-reduce and dequantize after; the associated precision loss is what the
error-feedback state corrects.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _quant_int8(g, block: int = 256):
    """Block-wise symmetric int8 quantization along the last axis."""
    flat = g.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, g.shape, pad


def _dequant_int8(q, scale, shape, pad):
    out = (q.astype(F32) * scale).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)


def quantize_dequantize(g, block: int = 256):
    return _dequant_int8(*_quant_int8(g.astype(F32), block))


def compress_grads(grads, method: str = "int8", block: int = 256):
    """Simulate the compressed all-reduce: q->dq on every gradient leaf.

    Returns (grads, metrics).  metrics report the compression error so the
    training loop can monitor drift.
    """
    if method == "none":
        return grads, {}
    assert method == "int8", method

    err_num = 0.0
    err_den = 0.0
    out = []
    leaves, treedef = jax.tree.flatten(grads)
    for g in leaves:
        if g.ndim < 2:                      # tiny tensors stay exact
            out.append(g)
            continue
        dq = quantize_dequantize(g, block)
        err_num = err_num + jnp.sum(jnp.square(g.astype(F32) - dq))
        err_den = err_den + jnp.sum(jnp.square(g.astype(F32)))
        out.append(dq.astype(g.dtype))
    metrics = {"compress_rel_err": jnp.sqrt(err_num / jnp.maximum(err_den, 1e-30))}
    return treedef.unflatten(out), metrics


def error_feedback_update(grads, ef_state, block: int = 256):
    """Error-feedback compression: compress (g + e), carry new residual."""
    def one(g, e):
        if g.ndim < 2:
            return g, e
        tot = g.astype(F32) + e
        dq = quantize_dequantize(tot, block)
        return dq.astype(g.dtype), tot - dq

    pairs = jax.tree.map(one, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_ef


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
