"""Batched serving engine: continuous-batching-style decode over a fixed
slot grid.

Requests are admitted into B fixed slots; prefill fills a slot's KV cache
(computed right-padded to the slot length), decode steps advance all
active slots together, finished slots (EOS or budget) are recycled.  The
cache is allocated once at (B, max_len) — admission never reallocates,
which is the property that lets the same compiled step serve the whole
trace.  Slot activity is a boolean mask; inactive slots decode garbage
that is masked out of the responses (standard padded-batch serving).

This engine drives the `serve_lm.py` example and the serving tests; the
dry-run's `serve_step` lowers the same ``decode_step``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int = 16
    out_tokens: list = field(default_factory=list)
    done: bool = False
    error: Optional[str] = None


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 256, eos_id: int = -1, metrics=None):
        self.model = model
        self.params = params
        self.B = n_slots
        self.max_len = max_len
        self.eos = eos_id
        self.cache = model.init_cache(n_slots, max_len)
        self.active: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)
        self.slot_budget = np.zeros(n_slots, np.int64)
        self.last_token = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(model.decode_step)
        # single-slot prefill writes one slot's cache lines
        self._prefill_one = jax.jit(self._prefill_impl, static_argnums=(2,))
        # opt-in repro.obs.MetricsRegistry: request/token counters +
        # admit->done latency histogram; None records nothing
        self.metrics = metrics
        self._t_admit: dict[int, float] = {}

    # -- prefill -------------------------------------------------------
    def _prefill_impl(self, params, tokens, slot: int):
        """Prefill one request and splice its cache into slot ``slot``."""
        logits, cache = self.model.prefill(params, {"tokens": tokens})
        return logits, cache

    def _splice(self, slot: int, prefill_cache, prompt_len: int):
        """Copy one request's prefill cache into the engine's slot."""
        def copy(dst, src):
            if dst.ndim < 2 or src.shape[0] != dst.shape[0]:
                return dst
            # leaves: (R, B, S, ...) dst vs (R, 1, P, ...) src
            if dst.ndim != src.ndim:
                return dst
            pad = [(0, 0)] * src.ndim
            if src.shape[2] <= dst.shape[2]:
                pad[2] = (0, dst.shape[2] - src.shape[2])
            else:
                return dst
            src_p = jnp.pad(src, pad).astype(dst.dtype)
            return dst.at[:, slot:slot + 1].set(src_p)

        new_blocks = jax.tree.map(copy, self.cache["blocks"],
                                  prefill_cache["blocks"])
        self.cache = dict(self.cache, blocks=new_blocks)

    def admit(self, req: Request) -> bool:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            # _splice cannot represent a prompt longer than the slot, and
            # decode positions past max_len write out of the cache range;
            # both used to silently produce garbage. Reject up front.
            req.error = (f"prompt length {len(req.prompt)} + "
                         f"max_new_tokens {req.max_new_tokens} exceeds "
                         f"engine max_len {self.max_len}")
            req.done = True
            if self.metrics is not None:
                self.metrics.counter("serve.rejected").inc()
            return False
        for slot in range(self.B):
            if self.active[slot] is None:
                tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, pc = self._prefill_one(self.params, tokens, slot)
                self._splice(slot, pc, len(req.prompt))
                first = int(jnp.argmax(logits[0]))
                req.out_tokens.append(first)
                self.active[slot] = req
                self.slot_pos[slot] = len(req.prompt)
                self.slot_budget[slot] = req.max_new_tokens - 1
                self.last_token[slot, 0] = first
                # global pos counter: engine decodes all slots at a common
                # position; slot caches were right-padded to max prompt
                self.cache = dict(
                    self.cache,
                    pos=jnp.asarray(int(max(self.slot_pos[s]
                                            for s in range(self.B)
                                            if self.active[s] is not None)),
                                    jnp.int32))
                if self.metrics is not None:
                    import time
                    self.metrics.counter("serve.requests").inc()
                    self.metrics.counter("serve.prompt_tokens").inc(
                        len(req.prompt))
                    self._t_admit[req.rid] = time.perf_counter()
                return True
        return False

    def step(self):
        """One decode step for all active slots."""
        if not any(r is not None for r in self.active):
            return
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_token))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        if self.metrics is not None:
            self.metrics.counter("serve.decode_steps").inc()
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.slot_budget[slot] -= 1
            self.last_token[slot, 0] = tok
            if self.metrics is not None:
                self.metrics.counter("serve.tokens").inc()
            if tok == self.eos or self.slot_budget[slot] <= 0:
                req.done = True
                self.active[slot] = None
                if self.metrics is not None:
                    import time
                    t0 = self._t_admit.pop(req.rid, None)
                    if t0 is not None:
                        self.metrics.histogram(
                            "serve.request_latency_s").observe(
                                time.perf_counter() - t0)

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve a request list to completion (simple FCFS admission)."""
        pending = list(requests)
        done: list[Request] = []
        while pending or any(r is not None for r in self.active):
            while pending and (self.admit(pending[0]) or pending[0].done):
                pending.pop(0)          # admitted, or rejected with error
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        return done
