"""Routing and splitting — the ONE construction code path.

Bulk build and incremental maintenance both come through
:func:`route`: new member ids are pushed down the tree, every touched
node's bounding box is widened, and any leaf that ends up over its fill
factor splits.  There is no separate "rebuild" algorithm to drift from
the insert path (the bug class where a post-append rebuild silently
re-splits differently from the original construction).

Chunking invariance (why incremental == bulk, leaf membership and all):

* the split dimension (:func:`split_dim_for`) is a function of the
  node's bit state only — never of its current members;
* a member's child at a split node is a function of its own feature
  value — never of its co-members;
* a node ends up split iff the TOTAL number of members ever routed
  through it exceeds ``leaf_fill`` — a monotone condition on the final
  member multiset, not on arrival order;
* boxes are running min/max — order-free.

So the final tree is a pure function of the inserted feature multiset
(in id order), regardless of how inserts were batched.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.index.tree import SplitTree, TreeNode, _new_node


def split_dim_for(tree: SplitTree, bits: np.ndarray) -> Optional[int]:
    """The dimension a node with ``bits`` splits on: the least-refined
    refinable dimension, ties broken season-first (adapter priority),
    then by dimension order.  Returns None when every dimension is at
    ``max_bits`` (the leaf stays overfull — alphabet exhausted)."""
    refinable = np.nonzero(np.asarray(bits) < tree.max_bits)[0]
    if refinable.size == 0:
        return None
    order = np.lexsort((refinable, tree.adapter.priority[refinable],
                        np.asarray(bits)[refinable]))
    return int(refinable[order[0]])


def root_addresses(tree: SplitTree, feats: np.ndarray,
                   n_groups: int) -> np.ndarray:
    """Deterministic root-subtree address of each feature row after
    ``ceil(log2(n_groups))`` top-level splits — the sharded bulk build's
    partition key (:meth:`SplitTree.insert_grouped`).

    Simulates the split cascade an empty tree would perform at the root:
    the split dimension at each depth is :func:`split_dim_for` of the
    accumulated bit state (a function of the bit state ALONE, never of
    the members), and the branch a row takes is the one new bit its
    symbol gains when that dimension's cardinality doubles.

    Why the branch extraction is exact: the Gaussian quantile breakpoints
    at cardinalities 2^b and 2^(b+1) nest BITWISE.  Break j of the
    2^b-grid is the quantile at j / 2^b, and (2j) / 2^(b+1) == j / 2^b
    exactly in IEEE arithmetic (division by a power of two only shifts
    the exponent), so ``ndtri_np`` — a deterministic elementwise map —
    produces the identical float64, ``gauss_breaks``' scaling by ``sd``
    is the same multiplication, and ``searchsorted(side="right")``
    against the finer grid therefore refines every coarse cell by
    exactly one new (odd-index) breakpoint.  Hence

        symbols(f, dim, b + 1) == 2 * symbols(f, dim, b) + branch,

    with branch in {0, 1} — the subtraction below recovers the branch
    bit exactly, never approximately.
    """
    feats = np.asarray(feats, np.float32)
    depth = max(int(np.ceil(np.log2(max(n_groups, 1)))), 0)
    bits = np.zeros(tree.D, np.int64)
    addr = np.zeros(feats.shape[0], np.int64)
    for _ in range(depth):
        dim = split_dim_for(tree, bits)
        if dim is None:               # alphabet exhausted at the root
            break
        b = int(bits[dim])
        branch = tree.symbols(feats, dim, b + 1) \
            - 2 * tree.symbols(feats, dim, b)
        addr = addr * 2 + branch
        bits[dim] += 1
    return addr


def route(tree: SplitTree, node: TreeNode, ids: np.ndarray):
    """Push member ids into ``node``'s subtree, splitting overfull
    leaves.  ``ids`` must already be present in ``tree.feats``."""
    if ids.size == 0:
        return
    f = tree._feats[ids]
    node.lo = np.minimum(node.lo, f.min(axis=0))
    node.hi = np.maximum(node.hi, f.max(axis=0))
    if node.is_leaf:
        node.ids = np.concatenate([node.ids, ids])
        if node.ids.size > tree.leaf_fill:
            _split_leaf(tree, node)
    else:
        _route_children(tree, node, ids)


def _split_leaf(tree: SplitTree, node: TreeNode):
    """Convert an overfull leaf into an internal node by promoting the
    deterministic split dimension one bit and re-routing its members
    (which recursively splits any still-overfull child)."""
    dim = split_dim_for(tree, node.bits)
    if dim is None:
        return                        # cannot refine further
    node.split_dim = dim
    node.children = {}
    ids, node.ids = node.ids, None
    _route_children(tree, node, ids)


def _route_children(tree: SplitTree, node: TreeNode, ids: np.ndarray):
    """Partition ``ids`` by their symbol on the node's split dimension at
    the promoted cardinality; create children lazily."""
    child_bits = int(node.bits[node.split_dim]) + 1
    syms = tree.symbols(tree._feats[ids], node.split_dim, child_bits)
    for s in np.unique(syms):
        child = node.children.get(int(s))
        if child is None:
            bits = node.bits.copy()
            bits[node.split_dim] += 1
            child = _new_node(bits)
            node.children[int(s)] = child
            tree.n_nodes += 1
        route(tree, child, ids[syms == s])
