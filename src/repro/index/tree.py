"""The adaptive split tree: structure, traversal, and snapshots.

Every indexed item is a D-dimensional real-valued feature vector (one of
:mod:`repro.index.features`' adapters).  A node carries a per-dimension
bit count (its cardinality state); splitting promotes ONE dimension by
one bit and partitions members by their symbol at the new cardinality —
iSAX splitting, generalized to the multi-component feature word.  Leaves
hold item ids; every node keeps the tight bounding box of all members
ever routed through it, so the weighted box distance
(:meth:`SplitTree.bbox_lb`) prunes subtrees DS-tree-style from the very
first split.

The split dimension is a **deterministic function of the node's bit
state alone** (:func:`repro.index.insert.split_dim_for`): refine the
least-refined dimension, season dimensions first.  Because it never
looks at the members, the tree after inserting rows 0..n-1 is the same
no matter how the inserts were chunked — incremental maintenance and
bulk construction are literally the same code path
(:mod:`repro.index.insert`) and produce identical leaf membership.

Traversal (used by :class:`repro.index.candidates.TreeCandidates`):

* ``seed_candidates`` — best-first leaf walk (heap on the box bound)
  until >= k member ids are collected; verifying them yields an upper
  bound U on the true k-th-NN distance.
* ``collect_bounds`` — walk the tree pruning subtrees whose box bound
  exceeds U; surviving leaf members are bounded individually with the
  adapter's exact feature distance.  O(survivors) output, never
  corpus-width.

Children are always iterated in symbol order, so two structurally equal
trees traverse identically regardless of construction history.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.index.features import FeatureAdapter, gauss_breaks

_MIN_CAPACITY = 256


@dataclass
class TreeNode:
    bits: np.ndarray                  # (D,) int8 cardinality bits per dim
    ids: Optional[np.ndarray] = None  # leaf payload (int64 item ids)
    children: Optional[dict] = None   # symbol -> TreeNode
    split_dim: int = -1
    lo: Optional[np.ndarray] = None   # (D,) running member bounding box
    hi: Optional[np.ndarray] = None

    @property
    def is_leaf(self) -> bool:
        return self.children is None


def _new_node(bits: np.ndarray) -> TreeNode:
    d = bits.shape[0]
    return TreeNode(bits=bits, ids=np.empty(0, np.int64),
                    lo=np.full(d, np.inf, np.float32),
                    hi=np.full(d, -np.inf, np.float32))


class SplitTree:
    """Incremental adaptive split tree over one feature adapter.

    Parameters
    ----------
    adapter:   :class:`repro.index.features.FeatureAdapter`.
    leaf_fill: leaf fill factor — a leaf holding more members splits
               (unless every dimension is refined to ``max_bits``).
    max_bits:  maximum cardinality bits per dimension.
    """

    def __init__(self, adapter: FeatureAdapter, *, leaf_fill: int = 64,
                 max_bits: int = 8):
        if leaf_fill < 1:
            raise ValueError(f"leaf_fill must be >= 1, got {leaf_fill}")
        self.adapter = adapter
        self.D = adapter.D
        self.leaf_fill = int(leaf_fill)
        self.max_bits = int(max_bits)
        self._feats = np.empty((0, self.D), np.float32)
        self._n = 0
        self.root = _new_node(np.zeros(self.D, np.int8))
        self.n_nodes = 1
        self._breaks: dict = {}       # (dim, bits) -> breakpoint array
        # structure mutex: a split rewires ``children`` dicts while a
        # traversal iterates them, so inserts and walks are serialized.
        # Walks are O(survivors) numpy work; verification — the
        # dominant cost — runs outside the lock, so concurrent
        # ingest-while-serving contends only on the cheap tree phases.
        self._lock = threading.RLock()

    # -- items -----------------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    def __len__(self) -> int:
        return self._n

    @property
    def feats(self) -> np.ndarray:
        """(n, D) feature matrix of all indexed items (live prefix)."""
        return self._feats[:self._n]

    def _grow(self, need: int):
        if need <= self._feats.shape[0]:
            return
        cap = max(need, 2 * self._feats.shape[0], _MIN_CAPACITY)
        arr = np.empty((cap, self.D), np.float32)
        arr[:self._n] = self._feats[:self._n]
        self._feats = arr

    def insert(self, feats) -> np.ndarray:
        """Index new items; returns their ids (contiguous, in insertion
        order — callers align them with dataset rows / window ids).
        Bulk construction IS this call: inserting everything at once and
        inserting in arbitrary chunks build the same tree."""
        from repro.index.insert import route
        feats = np.asarray(feats, np.float32)
        if feats.ndim == 1:
            feats = feats[None]
        if feats.shape[-1] != self.D:
            raise ValueError(f"features have {feats.shape[-1]} dims, "
                             f"adapter has D={self.D}")
        m = feats.shape[0]
        if m == 0:
            return np.empty(0, np.int64)
        with self._lock:
            self._grow(self._n + m)
            self._feats[self._n:self._n + m] = feats
            ids = np.arange(self._n, self._n + m, dtype=np.int64)
            self._n += m
            route(self, self.root, ids)
        return ids

    def insert_grouped(self, feats, n_groups: int) -> np.ndarray:
        """Bulk insert partitioned by root-subtree address — the sharded
        build path (``insert.root_addresses`` is the partition key each
        host/device would own).  Groups are routed one root subtree at a
        time; because the tree after any insert sequence is a pure
        function of the feature multiset (:mod:`repro.index.insert`),
        the structure equals the in-order bulk build, and sorting each
        leaf's ids afterwards (``_canonicalize_leaves``) restores the
        only order-dependent state — id order within a leaf — to what
        the in-order build produces (ascending).  Returns the ids in
        insertion order, same contract as ``insert``."""
        from repro.index.insert import root_addresses, route
        feats = np.asarray(feats, np.float32)
        if feats.ndim == 1:
            feats = feats[None]
        if feats.shape[-1] != self.D:
            raise ValueError(f"features have {feats.shape[-1]} dims, "
                             f"adapter has D={self.D}")
        m = feats.shape[0]
        if m == 0:
            return np.empty(0, np.int64)
        with self._lock:
            self._grow(self._n + m)
            self._feats[self._n:self._n + m] = feats
            ids = np.arange(self._n, self._n + m, dtype=np.int64)
            self._n += m
            addr = root_addresses(self, feats, n_groups)
            for a in np.unique(addr):
                route(self, self.root, ids[addr == a])
            self._canonicalize_leaves()
        return ids

    def _canonicalize_leaves(self):
        """Sort every leaf's member ids ascending — the canonical order
        the in-order incremental build produces (ids are assigned
        monotonically and appended in arrival order)."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node.is_leaf:
                if node.ids.size:
                    node.ids = np.sort(node.ids)
            else:
                stack.extend(node.children.values())

    # -- symbols ---------------------------------------------------------
    def breaks(self, dim: int, bits: int) -> np.ndarray:
        key = (dim, bits)
        bp = self._breaks.get(key)
        if bp is None:
            bp = gauss_breaks(1 << bits, float(self.adapter.sds[dim]))
            self._breaks[key] = bp
        return bp

    def symbols(self, feats: np.ndarray, dim: int, bits: int) -> np.ndarray:
        """Symbol of each feature row on ``dim`` at cardinality 2**bits."""
        if bits == 0:
            return np.zeros(feats.shape[0], np.int64)
        return np.searchsorted(self.breaks(dim, bits), feats[:, dim],
                               side="right")

    # -- bounds ----------------------------------------------------------
    def bbox_lb(self, qf: np.ndarray, node: TreeNode) -> float:
        """Weighted distance from the query features to the node's tight
        member bounding box — a valid d_ED lower bound by the adapter's
        per-component argument (features module docstring).  +inf for a
        node no member was ever routed through."""
        gap = np.maximum(0.0, np.maximum(node.lo - qf, qf - node.hi))
        return float(np.sqrt(np.sum(self.adapter.weights * gap * gap)))

    def member_lb(self, qf: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Exact per-member feature-distance bound (adapter-defined)."""
        return self.adapter.member_lb(qf, self._feats[ids])

    # -- traversal -------------------------------------------------------
    #
    # As-of reads (``max_id``): item ids are assigned monotonically and
    # inserts only ever EXTEND the tree (new members, expanded boxes,
    # deeper splits) — nothing indexed before id ``max_id`` is ever
    # rewritten.  So a traversal as-of an epoch frontier is just the
    # filter ``id < max_id`` at the leaves: a node's (possibly later,
    # looser) bounding box is still a valid lower bound for the epoch
    # subset of its members, so pruning stays correct, and the final
    # top-k is bit-identical to a tree holding only the first ``max_id``
    # items (exactness of the downstream k-th-best verification holds
    # for ANY valid-bound candidate set).

    def seed_candidates(self, qf: np.ndarray, k: int,
                        max_id: Optional[int] = None) -> list:
        """Best-first leaf walk until >= k member ids are collected — the
        seed set whose verified distances upper-bound the true k-th NN.
        ``max_id`` restricts to items inserted before that id (as-of an
        epoch frontier); the walk keeps descending until k epoch-visible
        members are found or the tree is exhausted."""
        import heapq
        with self._lock:
            heap = [(0.0, 0, self.root)]
            counter = 1
            out: list = []
            while heap and len(out) < k:
                _, _, node = heapq.heappop(heap)
                if node.is_leaf:
                    ids = node.ids
                    if max_id is not None:
                        ids = ids[ids < max_id]
                    out.extend(ids.tolist())
                    continue
                for s in sorted(node.children):
                    child = node.children[s]
                    heapq.heappush(heap, (self.bbox_lb(qf, child), counter,
                                          child))
                    counter += 1
            return out

    def collect_bounds(self, qf: np.ndarray, thresh: float,
                       max_id: Optional[int] = None):
        """Compact (ids, member bounds) of every member that could still
        beat ``thresh`` (subtrees pruned by the box bound, members by the
        exact feature bound) — O(survivors), never corpus-width.
        ``max_id`` filters to the members visible as-of an epoch
        frontier (see the traversal note above)."""
        ids_out, lb_out = [], []
        with self._lock:
            stack = [self.root]
            while stack:
                node = stack.pop()
                if self.bbox_lb(qf, node) > thresh:
                    continue
                if node.is_leaf:
                    ids = node.ids
                    if max_id is not None:
                        ids = ids[ids < max_id]
                    if ids.size:
                        mlb = self.member_lb(qf, ids)
                        keep = mlb <= thresh
                        ids_out.append(ids[keep])
                        lb_out.append(mlb[keep])
                else:
                    for s in sorted(node.children):
                        stack.append(node.children[s])
        if not ids_out:
            return np.empty(0, np.int64), np.empty(0)
        return (np.concatenate(ids_out).astype(np.int64),
                np.concatenate(lb_out))

    def leaf_membership(self) -> list:
        """Canonical structure fingerprint: preorder (symbol-ordered)
        list of (root-to-leaf symbol path, member ids).  Two trees built
        from the same items in any chunking compare equal."""
        out = []

        def walk(node, path):
            if node.is_leaf:
                out.append((path, node.ids.tolist()))
            else:
                for s in sorted(node.children):
                    walk(node.children[s], path + (int(s),))

        walk(self.root, ())
        return out

    # -- snapshot serialization ------------------------------------------
    def to_snapshot(self):
        """Flatten to (meta, arrays): feature matrix + preorder node
        table (bits, parent, split history, boxes) + concatenated leaf
        payloads.  ``from_snapshot`` rebuilds without re-splitting, and
        the rebuilt tree keeps accepting ``insert``."""
        nodes, parents, syms = [], [], []

        def walk(node, parent, sym):
            nid = len(nodes)
            nodes.append(node)
            parents.append(parent)
            syms.append(sym)
            if not node.is_leaf:
                for s in sorted(node.children):
                    walk(node.children[s], nid, s)

        walk(self.root, -1, -1)
        leaf_ids = [nd.ids if nd.is_leaf else np.empty(0, np.int64)
                    for nd in nodes]
        arrays = {
            "feats": np.ascontiguousarray(self.feats),
            "node_bits": np.stack([nd.bits for nd in nodes]),
            "node_parent": np.asarray(parents, np.int32),
            "node_sym": np.asarray(syms, np.int32),
            "node_split_dim": np.asarray([nd.split_dim for nd in nodes],
                                         np.int32),
            "node_lo": np.stack([nd.lo for nd in nodes]),
            "node_hi": np.stack([nd.hi for nd in nodes]),
            "leaf_counts": np.asarray([len(x) for x in leaf_ids], np.int64),
            "leaf_ids": (np.concatenate(leaf_ids) if leaf_ids else
                         np.empty(0, np.int64)).astype(np.int64),
        }
        meta = {"n": int(self._n), "D": int(self.D),
                "leaf_fill": int(self.leaf_fill),
                "max_bits": int(self.max_bits),
                "n_nodes": int(self.n_nodes)}
        return meta, arrays

    @classmethod
    def from_snapshot(cls, adapter: FeatureAdapter, meta: dict,
                      arrays: dict) -> "SplitTree":
        """Rebuild a tree from ``to_snapshot`` output (no re-split)."""
        self = cls(adapter, leaf_fill=int(meta["leaf_fill"]),
                   max_bits=int(meta["max_bits"]))
        n = int(meta["n"])
        feats = np.asarray(arrays["feats"], np.float32)
        if feats.shape != (n, self.D):
            raise ValueError(f"snapshot feats shape {feats.shape} != "
                             f"({n}, {self.D})")
        self._grow(n)
        self._feats[:n] = feats
        self._n = n
        n_nodes = int(meta["n_nodes"])
        counts = arrays["leaf_counts"]
        offsets = np.concatenate([[0], np.cumsum(counts)])
        nodes = []
        for i in range(n_nodes):
            is_leaf = int(arrays["node_split_dim"][i]) < 0
            node = TreeNode(
                bits=np.asarray(arrays["node_bits"][i], np.int8),
                ids=(arrays["leaf_ids"][offsets[i]:offsets[i + 1]]
                     .astype(np.int64) if is_leaf else None),
                children={} if not is_leaf else None,
                split_dim=int(arrays["node_split_dim"][i]),
                lo=np.asarray(arrays["node_lo"][i], np.float32),
                hi=np.asarray(arrays["node_hi"][i], np.float32))
            nodes.append(node)
            parent = int(arrays["node_parent"][i])
            if parent >= 0:
                nodes[parent].children[int(arrays["node_sym"][i])] = node
        self.root = nodes[0]
        self.n_nodes = n_nodes
        return self
