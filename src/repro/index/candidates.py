"""``CandidateSource``: where ``topk_verify`` gets its candidates.

The engine's exactness argument (``core.engine`` docstring) only needs a
set of candidates with valid d_ED lower bounds, consumed in bound order
with the k-th-best early stop.  This module abstracts WHERE that set
comes from:

* :class:`LinearSweep` — the paper's linear scan: the full (Q, N)
  representation-distance matrix (device sweep), every row a candidate.
* :class:`TreeCandidates` — sublinear generation from a
  :class:`repro.index.tree.SplitTree`:

  1. *Seed*: per query, walk leaves best-first until >= k members; the
     engine verifies them in one batched fetch — the k-th verified
     distance U upper-bounds the true k-th NN.
  2. *Collect*: walk the tree pruning subtrees with box bound > U;
     surviving members with feature bound <= U become a COMPACT
     candidate set (everything else provably cannot enter the top-k,
     even on ties, since bound > U >= d_k implies d > d_k).
  3. The engine's ``topk_verify`` consumes the compact bounds in sorted
     order with the same k-th-best early stop (``col_ids`` maps columns
     to dataset rows), seeded with the phase-1 frontier (seed members
     are excluded so no candidate is verified twice).

Both sources flow through :func:`topk_from_source`, so indexed and
linear top-k share one verification path and identical exactness
guarantees — results are bit-identical (same verifier, same (distance,
id) tie-break), only the number of candidates examined differs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Protocol, runtime_checkable

import numpy as np

from repro.index.tree import SplitTree


@dataclass
class CandidateSet:
    """What a source hands the verification scan.

    Either ``bounds`` (host matrix; ``col_ids`` maps columns to dataset
    ids) or ``stream`` (a ``core.distributed.DeviceOrderedStream`` —
    device-ordered global ids, no host matrix) is set, never both."""

    bounds: Optional[np.ndarray]       # (Q, C) d_ED lower bounds
    col_ids: Optional[np.ndarray]      # (C,) dataset id per column
                                       # (None: column j IS row j)
    init_d: Optional[np.ndarray] = None  # (Q, <=k) pre-verified frontier
    init_i: Optional[np.ndarray] = None
    seed_res: Optional[object] = None  # TopKResult of the seed phase
    stream: Optional[object] = None    # device-ordered candidate stream
    # approximate mode only: per-query lower bounds of the candidates
    # the bounded collect DROPPED — the certificate behind the result's
    # ``kth_lb`` / ``error_bar`` (None on exact paths)
    approx_dropped: Optional[list] = None


@runtime_checkable
class CandidateSource(Protocol):
    def candidate_bounds(self, queries_raw, k: int,
                         verify: Callable) -> CandidateSet:
        """Produce the candidate set for a (Q, T) query batch.
        ``verify(cand_idx) -> TopKResult`` verifies a (Q, S) id matrix
        against raw storage (engine-supplied; sources that need a
        verified upper bound — the tree's seed phase — call it)."""
        ...


class LinearSweep:
    """The full lower-bound sweep as a candidate source.

    ``stream_fn`` (queries_raw -> device-ordered stream) replaces the
    host (Q, N) matrix with a ``DeviceOrderedStream`` — same candidates
    in the same (bound, id) order, zero host materialization."""

    def __init__(self, repr_fn: Callable,
                 stream_fn: Optional[Callable] = None):
        self._repr_fn = repr_fn       # queries_raw -> (Q, N) bounds
        self._stream_fn = stream_fn

    def candidate_bounds(self, queries_raw, k: int,
                         verify: Callable) -> CandidateSet:
        if self._stream_fn is not None:
            return CandidateSet(bounds=None, col_ids=None,
                                stream=self._stream_fn(queries_raw))
        return CandidateSet(bounds=np.asarray(self._repr_fn(queries_raw)),
                            col_ids=None)


class TreeCandidates:
    """Sublinear candidate generation from a split tree.

    ``query_features`` maps the engine's query batch to (Q, D) adapter
    features — precomputed-feature callers pass a closure ignoring the
    raw queries.

    Frontier reuse (exclusion widening): ``prior_d`` / ``prior_i`` seed
    an already-verified frontier ((Q, <=k) ascending, -1 / +inf padded)
    and ``seen`` lists EVERY id verified in earlier rounds (a per-query
    superset of the prior ids).  The seed walk then only verifies ids
    never seen before, and the collect phase excludes all seen ids — so
    across widening rounds no id is ever verified twice.  Exactness is
    preserved under the caller's contract that ``prior`` holds the best
    ``min(k, |verified|)`` of the accumulated verified set: a seen id
    outside that frontier is dominated by >= k verified better ids and
    can never re-enter the top-k.

    ``device_order=True`` sorts the compact union bounds by (bound, id)
    on device and hands the scan a ``DeviceOrderedStream`` of dataset
    ids instead of the host (bounds, col_ids) pair — results are
    identical (exactness holds for any valid-bound order; the f64
    bounds are rounded downward to f32, staying valid lower bounds).

    ``approx_collect=C`` is the APPROXIMATE mode (the planner's anytime
    tier): the seed walk still runs exactly, but the collect phase keeps
    only the C best-(bound, id) survivors per query and records the
    dropped candidates' lower bounds in ``CandidateSet.approx_dropped``.
    ``topk_from_source`` turns those into a certified per-query
    ``kth_lb`` (the k-th smallest over verified true distances and
    dropped bounds — every dropped candidate's true distance is >= its
    bound, so the true k-th NN distance is >= ``kth_lb``) and
    ``error_bar = d_k - kth_lb``; an ``error_bar`` of zero proves the
    answer exact despite the cap.
    """

    def __init__(self, tree: SplitTree, query_features: Callable, *,
                 prior_d=None, prior_i=None, seen=None,
                 device_order: bool = False,
                 approx_collect: Optional[int] = None,
                 epoch=None):
        self.tree = tree
        self._query_features = query_features
        self._device_order = bool(device_order)
        # as-of frontier: only items with id < epoch are generated (a
        # ``repro.store.CorpusEpoch`` or plain row count; None = live).
        # Inserts only extend the tree, so the filter happens inside the
        # traversals (tree.seed_candidates / collect_bounds max_id) —
        # no copy-on-write, bit-identical to a tree truncated there.
        from repro.store.symbolic import epoch_rows
        self._epoch = epoch_rows(epoch)
        if approx_collect is not None and approx_collect < 0:
            raise ValueError("approx_collect must be >= 0")
        self._approx_collect = approx_collect
        # prior and seen travel together: seen ids without their verified
        # frontier cannot be excluded exactly (their distances are lost),
        # and a seeded frontier without the seen set would be re-collected
        # and double-merged
        if (seen is None) != (prior_i is None) or \
                (prior_d is None) != (prior_i is None):
            raise ValueError("prior_d, prior_i and seen must be passed "
                             "together (or all omitted)")
        self._prior_d = prior_d
        self._prior_i = prior_i
        self._seen = seen

    @property
    def is_approx(self) -> bool:
        return self._approx_collect is not None

    def _fresh_seeds(self, qf_r, k: int, n_prior: int, seen_r):
        """Best-first seed ids never verified before, walking deeper
        until prior + fresh can pin the k-th-NN upper bound U (or the
        tree is exhausted)."""
        need = k - n_prior
        if need <= 0:
            return np.empty(0, np.int64)
        m = k
        while True:
            s = np.asarray(self.tree.seed_candidates(
                qf_r, m, max_id=self._epoch), np.int64)
            fresh = s[~np.isin(s, seen_r)]
            if len(fresh) >= need or len(s) < m:   # < m: walk exhausted
                return fresh
            m *= 2

    def candidate_bounds(self, queries_raw, k: int,
                         verify: Callable) -> CandidateSet:
        tree = self.tree
        qf = np.asarray(self._query_features(queries_raw), np.float32)
        if qf.ndim == 1:
            qf = qf[None]
        q_n = qf.shape[0]
        n_vis = tree.n if self._epoch is None \
            else min(tree.n, self._epoch)
        if n_vis == 0:
            return CandidateSet(
                bounds=np.empty((q_n, 0)), col_ids=None,
                approx_dropped=([np.empty(0)] * q_n if self.is_approx
                                else None))
        k = min(k, n_vis)

        seen = self._seen if self._seen is not None \
            else [np.empty(0, np.int64)] * q_n
        seen = [np.asarray(s, np.int64) for s in seen]
        if self._prior_i is not None:
            prior_d = np.asarray(self._prior_d, np.float64)
            prior_i = np.asarray(self._prior_i, np.int64)
            n_prior = (prior_i >= 0).sum(axis=1)
        else:
            prior_d = prior_i = None
            n_prior = np.zeros(q_n, np.int64)

        seeds = [self._fresh_seeds(qf[r], k, int(n_prior[r]), seen[r])
                 for r in range(q_n)]
        width = max(len(s) for s in seeds)
        seed_res = None
        if width:
            cand = np.full((q_n, width), -1, np.int64)
            for r, s in enumerate(seeds):
                cand[r, :len(s)] = s
            seed_res = verify(cand)

        # merged frontier: prior rounds + freshly verified seeds — this
        # seeds the scan (init_d/init_i) and pins U per query
        if seed_res is None:
            merged_d = prior_d[:, :k]
            merged_i = prior_i[:, :k]
        elif prior_d is None:
            merged_d, merged_i = seed_res.distances, seed_res.indices
        else:
            from repro.core.engine import merge_topk_numpy
            merged_d, merged_i = merge_topk_numpy(
                np.concatenate([prior_d, seed_res.distances], axis=1),
                np.concatenate([prior_i, seed_res.indices], axis=1), k)

        all_ids, all_lbs = [], []
        dropped = [] if self.is_approx else None
        for r in range(q_n):
            # U upper-bounds the true k-th NN only once k members are
            # verified; a short frontier (corpus < k) collects everything
            u = (float(merged_d[r, k - 1])
                 if merged_d.shape[1] >= k else np.inf)
            ids_r, lb_r = tree.collect_bounds(qf[r], u,
                                              max_id=self._epoch)
            drop = np.concatenate([seen[r], seeds[r]])
            keep = ~np.isin(ids_r, drop)   # verified ids never re-enter
            ids_r, lb_r = ids_r[keep], lb_r[keep]
            if self.is_approx and ids_r.size > self._approx_collect:
                # bounded collect: keep the C best survivors in the scan
                # order (bound, id); the dropped bounds are the error
                # certificate — every dropped true distance >= its bound
                order = np.lexsort((ids_r, lb_r))
                cut = order[self._approx_collect:]
                dropped.append(lb_r[cut].copy())
                sel = np.sort(order[:self._approx_collect])
                ids_r, lb_r = ids_r[sel], lb_r[sel]
            elif self.is_approx:
                dropped.append(np.empty(0))
            all_ids.append(ids_r)
            all_lbs.append(lb_r)
        union = np.unique(np.concatenate(all_ids))     # sorted row ids
        bounds = np.full((q_n, union.size), np.inf, np.float64)
        for r in range(q_n):
            bounds[r, np.searchsorted(union, all_ids[r])] = all_lbs[r]
        if self._device_order and union.size:
            from repro.core.distributed import host_order_stream
            return CandidateSet(bounds=None, col_ids=None,
                                stream=host_order_stream(bounds, union),
                                init_d=merged_d, init_i=merged_i,
                                seed_res=seed_res, approx_dropped=dropped)
        return CandidateSet(bounds=bounds, col_ids=union,
                            init_d=merged_d,
                            init_i=merged_i, seed_res=seed_res,
                            approx_dropped=dropped)


def topk_from_source(queries_raw, source: CandidateSource, store, *,
                     k: int = 1, batch_size: int = 64, verifier=None,
                     merge=None, total: Optional[int] = None,
                     dist_fn=None, on_verified=None, trace=None):
    """Exact top-k through any candidate source — one verification path
    (``core.engine.topk_verify``) for linear and indexed search.

    ``total``: corpus size for access accounting (``pruned_fraction``);
    defaults to the candidate-column count (correct for dense sources).
    Returns ``core.engine.TopKResult`` with combined accounting across
    the source's seed phase and the pruned scan.

    ``dist_fn`` / ``on_verified`` follow the ``core.engine.topk_verify``
    contracts and apply to BOTH phases — with a ``dist_fn`` the seed
    verification is device-resident too.

    ``trace``: optional ``repro.obs.Trace`` — candidate generation is
    recorded as span "order" (the tree's seed verification nests as
    "order/seed") and the pruned scan as span "verify"; off (None) the
    call path is unchanged.
    """
    from repro.core.engine import (
        TopKResult, merge_topk_numpy, numpy_verifier, topk_verify,
        verify_candidates)
    from repro.obs.trace import maybe_span
    verifier = verifier or numpy_verifier
    merge = merge or merge_topk_numpy

    qs = np.asarray(queries_raw)
    if qs.ndim == 1:
        qs = qs[None]

    def verify(cand_idx):
        with maybe_span(trace, "seed"):
            return verify_candidates(qs, cand_idx, store, k=k,
                                     verifier=verifier, merge=merge,
                                     dist_fn=dist_fn,
                                     on_verified=on_verified, trace=trace)

    with maybe_span(trace, "order") as order_span:
        cs = source.candidate_bounds(qs, k, verify)
        if trace is not None and cs.stream is not None:
            # the stream's sort ran on device — fence it so the "order"
            # wall-clock is the kernel time, not the dispatch time
            from repro.obs.trace import block_until_ready
            block_until_ready((getattr(cs.stream, "_b", None),
                               getattr(cs.stream, "_i", None)))
            order_span.meta["stream"] = True
    with maybe_span(trace, "verify"):
        res = topk_verify(qs, cs.bounds, store, k=k, batch_size=batch_size,
                          verifier=verifier, merge=merge,
                          col_ids=cs.col_ids,
                          init_d=cs.init_d, init_i=cs.init_i,
                          dist_fn=dist_fn, on_verified=on_verified,
                          stream=cs.stream, trace=trace)
    width = (int(cs.stream.width) if cs.stream is not None
             else cs.bounds.shape[1])
    n = width if total is None else int(total)
    if cs.seed_res is None:
        if total is not None and n != width and n != 0:
            res = TopKResult(
                indices=res.indices, distances=res.distances,
                raw_accesses=res.raw_accesses,
                pruned_fraction=1.0 - res.raw_accesses / n,
                store_accesses=res.store_accesses,
                store_fetches=res.store_fetches,
                io_seconds=res.io_seconds)
    else:
        seed = cs.seed_res
        acc = res.raw_accesses + seed.raw_accesses
        res = TopKResult(
            indices=res.indices, distances=res.distances,
            raw_accesses=acc,
            pruned_fraction=1.0 - acc / max(n, 1),
            store_accesses=res.store_accesses + seed.store_accesses,
            store_fetches=res.store_fetches + seed.store_fetches,
            io_seconds=res.io_seconds + seed.io_seconds)
    if cs.approx_dropped is not None:
        _attach_error_bar(res, cs.approx_dropped, k, trace)
    return res


def _attach_error_bar(res, dropped: list, k: int, trace=None) -> None:
    """Approximate-mode certificate: ``res.kth_lb[r]`` is the k-th
    smallest over (verified true distances, dropped candidates' lower
    bounds) — a valid lower bound on the true k-th-NN distance because
    every dropped candidate's true distance is >= its bound.
    ``res.error_bar = d_k - kth_lb`` (0 proves exactness; inf when
    fewer than k candidates were verified at all)."""
    q_n = res.distances.shape[0]
    kth_lb = np.full(q_n, np.inf)
    for r in range(q_n):
        row = res.distances[r]
        vals = np.concatenate([row[np.isfinite(row)],
                               np.asarray(dropped[r], np.float64)])
        if vals.size:
            vals.sort()
            kth_lb[r] = vals[min(k, vals.size) - 1]
    dk = res.distances[:, -1].astype(np.float64)
    # dk finite -> kth_lb <= dk (the union includes the verified row);
    # dk inf with a finite dropped bound -> genuinely unbounded error;
    # both inf (empty corpus) -> vacuously exact
    res.kth_lb = kth_lb
    res.error_bar = np.where(
        np.isfinite(dk), np.maximum(dk - kth_lb, 0.0),
        np.where(np.isfinite(kth_lb), np.inf, 0.0))
    if trace is not None:
        trace.set("kth_lb", kth_lb.copy())
        trace.set("error_bar", res.error_bar.copy())
