"""``SSaxIndex`` — the original sSAX-only index API, now a thin wrapper
over the generic subsystem (:mod:`repro.index.tree` +
:mod:`repro.index.candidates`).

Kept for compatibility: the (sigma, resbar) constructor, ``query`` /
``topk`` / ``from_store`` / snapshot round-trip all behave as before,
but construction, incremental insert, and candidate generation are the
shared code paths every encoder uses — there is no sSAX-special split
logic left to drift.
"""

from __future__ import annotations

import numpy as np

from repro.core.matching import MatchResult, RawStore
from repro.index.candidates import TreeCandidates, topk_from_source
from repro.index.features import SSAXFeatures
from repro.index.tree import SplitTree


class SSaxIndex:
    """iSAX-style index over sSAX (sigma, resbar) features.

    features: (sigma (N, L), resbar (N, W)) real-valued sPAA features
    (kept host-side; symbols are derived per cardinality).
    """

    def __init__(self, sigma: np.ndarray, resbar: np.ndarray, *, T: int,
                 sd_seas: float, sd_res: float, max_bits: int = 8,
                 leaf_capacity: int = 64, encoder=None):
        sigma = np.asarray(sigma, np.float32)
        resbar = np.asarray(resbar, np.float32)
        self.T = int(T)
        self.sd_seas = float(sd_seas)
        self.sd_res = float(sd_res)
        self.L = sigma.shape[1]
        self.W = resbar.shape[1]
        self.D = self.L + self.W
        self.encoder = encoder
        self.adapter = SSAXFeatures(self.T, self.L, self.W,
                                    sd_seas=self.sd_seas,
                                    sd_res=self.sd_res, encoder=encoder)
        self.tree = SplitTree(self.adapter, leaf_fill=leaf_capacity,
                              max_bits=max_bits)
        if sigma.shape[0]:
            self.tree.insert(np.concatenate([sigma, resbar], axis=1))

    # -- views ------------------------------------------------------------
    @property
    def root(self):
        return self.tree.root

    @property
    def n_nodes(self) -> int:
        return self.tree.n_nodes

    @property
    def feats(self) -> np.ndarray:
        return self.tree.feats

    @property
    def sigma(self) -> np.ndarray:
        return self.tree.feats[:, :self.L]

    @property
    def resbar(self) -> np.ndarray:
        return self.tree.feats[:, self.L:]

    @property
    def n(self) -> int:
        return self.tree.n

    @property
    def leaf_capacity(self) -> int:
        return self.tree.leaf_fill

    @property
    def max_bits(self) -> int:
        return self.tree.max_bits

    # -- incremental maintenance ------------------------------------------
    def insert_rows(self, rows) -> np.ndarray:
        """Route new RAW rows into the tree (requires the encoder the
        index was built from, i.e. ``from_store`` construction)."""
        if self.encoder is None:
            raise TypeError("this SSaxIndex was built from precomputed "
                            "features; build via from_store to insert "
                            "raw rows incrementally")
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        return self.tree.insert(self.adapter.features(rows))

    # -- search -----------------------------------------------------------
    def topk(self, sigma_q: np.ndarray, resbar_q: np.ndarray, store,
             queries_raw: np.ndarray, *, k: int = 1, batch_size: int = 64,
             verifier=None, merge=None):
        """Batched multi-query exact top-k through the indexed traversal
        (seed-verify, bound-collect, k-th-best pruned verification) —
        see :mod:`repro.index.candidates`.  Returns an
        ``engine.TopKResult`` with combined access accounting."""
        sigma_q = np.asarray(sigma_q, np.float32)
        resbar_q = np.asarray(resbar_q, np.float32)
        if sigma_q.ndim == 1:
            sigma_q, resbar_q = sigma_q[None], resbar_q[None]
        feats_q = np.concatenate([sigma_q, resbar_q], axis=1)
        source = TreeCandidates(self.tree, lambda _qs: feats_q)
        return topk_from_source(queries_raw, source, store, k=k,
                                batch_size=batch_size, verifier=verifier,
                                merge=merge, total=self.tree.n)

    def query(self, q_sigma: np.ndarray, q_resbar: np.ndarray,
              store: RawStore, q_raw: np.ndarray) -> MatchResult:
        """Exact 1-NN — thin wrapper over the batched ``topk`` path, so
        indexed search shares the engine's verification machinery."""
        res = self.topk(q_sigma, q_resbar, store, q_raw, k=1)
        return MatchResult(index=int(res.indices[0, 0]),
                           distance=float(res.distances[0, 0]),
                           raw_accesses=int(res.raw_accesses[0]),
                           pruned_fraction=float(res.pruned_fraction[0]))

    # -- store integration ------------------------------------------------
    @classmethod
    def from_store(cls, store, *, max_bits: int = 8,
                   leaf_capacity: int = 64) -> "SSaxIndex":
        """Build an index over a ``repro.store.SymbolicStore`` whose
        encoder exposes sSAX-style (sigma, resbar) features."""
        import jax.numpy as jnp
        enc = store.encoder
        if not (hasattr(enc, "features") and hasattr(enc, "sd_seas")
                and hasattr(enc, "sd_res")):
            raise TypeError(f"{type(enc).__name__} does not expose "
                            "season-aware (sigma, resbar) features")
        feats = enc.features(jnp.asarray(store.data, jnp.float32))
        if len(feats) != 2:
            raise TypeError(f"{type(enc).__name__}.features returns "
                            f"{len(feats)} components, need (sigma, resbar)")
        sigma, resbar = feats
        return cls(np.asarray(sigma), np.asarray(resbar), T=enc.T,
                   sd_seas=enc.sd_seas, sd_res=enc.sd_res,
                   max_bits=max_bits, leaf_capacity=leaf_capacity,
                   encoder=enc)

    # -- snapshot serialization -------------------------------------------
    def to_snapshot(self):
        """(meta, arrays) via the shared tree flattening — rebuildable
        without re-splitting by ``from_snapshot``."""
        meta, arrays = self.tree.to_snapshot()
        meta.update({"kind": "ssax", "T": int(self.T), "L": int(self.L),
                     "W": int(self.W), "sd_seas": float(self.sd_seas),
                     "sd_res": float(self.sd_res),
                     "leaf_capacity": int(self.leaf_capacity)})
        return meta, arrays

    @classmethod
    def from_snapshot(cls, meta: dict, arrays: dict,
                      encoder=None) -> "SSaxIndex":
        """Rebuild an index from ``to_snapshot`` output (no re-split)."""
        self = cls.__new__(cls)
        self.T = int(meta["T"])
        self.sd_seas = float(meta["sd_seas"])
        self.sd_res = float(meta["sd_res"])
        self.L = int(meta["L"])
        self.W = int(meta["W"])
        self.D = self.L + self.W
        self.encoder = encoder
        self.adapter = SSAXFeatures(self.T, self.L, self.W,
                                    sd_seas=self.sd_seas,
                                    sd_res=self.sd_res, encoder=encoder)
        self.tree = SplitTree.from_snapshot(self.adapter, meta, arrays)
        return self
