"""Per-encoder feature adapters for the split-tree index.

An adapter maps raw series (or z-normalized windows) to a real-valued
feature matrix and defines two lower bounds of d_ED on it:

* the **weighted bounding-box bound** used to prune subtrees — for any
  member x of a node with box [lo, hi] and query features f(q),

      d_ED(q, x)^2  >=  sum_d w_d * gap_d^2,
      gap_d = max(0, lo_d - f(q)_d, f(q)_d - hi_d);

* the **exact member bound** ``member_lb`` (the Table-2 feature
  distance) used to bound individual leaf members.

Why the weighted sum lower-bounds d_ED per encoder (each term is one of
the paper's proofs, Appendix A):

* SAX — PAA segment means, w = T/W (A.1: PAA projection).
* sSAX — the tiled season-mask difference is exactly (T/L)*|d_sigma|^2
  and is orthogonal to the residual difference (residuals have zero mean
  per phase), whose norm the residual PAA bounds by (T/W)*|d_res|^2.
* tSAX — the trend difference lies in span{1, t} while the least-squares
  residual difference is orthogonal to it; with the scaled slope feature
  u = tan(phi) * sqrt(T * var(t)) the trend term is |du|^2 <= |d_tr|^2
  (the mean component is dropped), w_u = 1.
* stSAX — trend orthogonal to the detrended remainder (A.4), season
  orthogonal to residual within it: all three terms add.

``member_lb`` defaults to the same weighted L2; the season-aware
adapters override it with the tighter Table-2 forms (d_sPAA keeps the
season x residual cross term).
"""

from __future__ import annotations

import math

import numpy as np


def ndtri_np(q):
    """Inverse normal CDF (Acklam's rational approximation, |err|<1.2e-8)
    — keeps this host-side module importable without jax/scipy."""
    q = np.asarray(q, np.float64)
    a = [-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    out = np.empty_like(q)
    lo = q < plow
    hi = q > phigh
    mid = ~(lo | hi)
    if lo.any():
        r = np.sqrt(-2 * np.log(q[lo]))
        out[lo] = (((((c[0] * r + c[1]) * r + c[2]) * r + c[3]) * r + c[4])
                   * r + c[5]) / ((((d[0] * r + d[1]) * r + d[2]) * r
                                   + d[3]) * r + 1)
    if hi.any():
        r = np.sqrt(-2 * np.log(1 - q[hi]))
        out[hi] = -((((((c[0] * r + c[1]) * r + c[2]) * r + c[3]) * r
                      + c[4]) * r + c[5]) /
                    ((((d[0] * r + d[1]) * r + d[2]) * r + d[3]) * r + 1))
    if mid.any():
        r = q[mid] - 0.5
        t = r * r
        out[mid] = (((((a[0] * t + a[1]) * t + a[2]) * t + a[3]) * t
                     + a[4]) * t + a[5]) * r / \
            (((((b[0] * t + b[1]) * t + b[2]) * t + b[3]) * t + b[4]) * t + 1)
    return out


def gauss_breaks(card: int, sd: float) -> np.ndarray:
    """card-quantile breakpoints of N(0, sd) (card - 1 interior points)."""
    qs = np.arange(1, card) / card
    return sd * ndtri_np(qs)


class FeatureAdapter:
    """Feature-space contract the split tree consumes.

    Attributes
    ----------
    T:        series length the bounds are scaled to.
    D:        feature dimensionality.
    weights:  (D,) bounding-box weights (see module docstring).
    sds:      (D,) per-dimension scale for the split breakpoints (only
              affects split balance, never correctness).
    priority: (D,) split-order class per dimension; lower splits first
              (0 = season, then trend, then residual).
    encoder:  the bound encoder, when available — required only by
              ``features`` (precomputed-feature paths run without one).
    """

    def __init__(self, T: int, weights, sds, priority, encoder=None):
        self.T = int(T)
        self.weights = np.asarray(weights, np.float64)
        self.sds = np.asarray(sds, np.float64)
        self.priority = np.asarray(priority, np.int32)
        self.D = int(self.weights.size)
        assert self.sds.shape == self.priority.shape == (self.D,)
        self.encoder = encoder

    def _require_encoder(self):
        if self.encoder is None:
            raise TypeError(f"{type(self).__name__} was built without an "
                            "encoder: features must be supplied precomputed")
        return self.encoder

    def features(self, rows) -> np.ndarray:
        """(N, T) raw rows -> (N, D) float32 features (row-wise map, so
        chunked computation is bit-identical to one-shot).

        Split into a pure device map (``_device_features``) and host
        assembly (``_assemble``) so the sharded bulk build
        (``features_sharded``) runs the exact same per-row computation —
        the two paths are bit-identical by construction."""
        return self._assemble(self._device_features(rows))

    def features_sharded(self, rows, mesh) -> np.ndarray:
        """``features`` with the device map sharded row-wise over the
        mesh data axes (``core.distributed.rowwise_sharded``).
        Bit-identical to the host path: the per-row map cannot depend on
        which shard a row landed in, and assembly stays on host."""
        from repro.core.distributed import rowwise_sharded
        return self._assemble(
            rowwise_sharded(self, "_device_features", rows, mesh))

    def _device_features(self, rows):
        """Pure row-wise jax map: (N, T) raw rows -> device feature
        pytree (leaves all lead with the N axis)."""
        raise NotImplementedError

    def _assemble(self, parts) -> np.ndarray:
        """Host assembly of ``_device_features`` output into the (N, D)
        float32 feature matrix (casts / concats / host-f64 transforms
        that must not move onto the device for bit-identity)."""
        raise NotImplementedError

    def member_lb(self, qf: np.ndarray, feats: np.ndarray) -> np.ndarray:
        """Exact feature-distance lower bound of d_ED per member.
        qf: (D,), feats: (M, D) -> (M,) float64."""
        d = np.asarray(feats, np.float64) - np.asarray(qf, np.float64)[None]
        return np.sqrt(np.maximum(np.sum(self.weights * d * d, axis=1), 0.0))


class SAXFeatures(FeatureAdapter):
    """PAA segment means; d_PAA = sqrt(T/W * |d|^2)."""

    def __init__(self, T: int, W: int, *, sd: float = 1.0, encoder=None):
        super().__init__(T, [T / W] * W, [sd] * W, [0] * W, encoder)
        self.W = int(W)

    def _device_features(self, rows):
        import jax.numpy as jnp
        from repro.core.paa import paa
        self._require_encoder()
        return paa(jnp.asarray(rows, jnp.float32), self.W)

    def _assemble(self, parts) -> np.ndarray:
        return np.asarray(parts, np.float32)


class SSAXFeatures(FeatureAdapter):
    """Season mask (L) ++ residual PAA (W); member bound is the exact
    d_sPAA of Table 2 (season x residual cross term kept)."""

    def __init__(self, T: int, L: int, W: int, *, sd_seas: float,
                 sd_res: float, encoder=None):
        super().__init__(T, [T / L] * L + [T / W] * W,
                         [sd_seas] * L + [sd_res] * W,
                         [0] * L + [1] * W, encoder)
        self.L, self.W = int(L), int(W)

    def _device_features(self, rows):
        import jax.numpy as jnp
        enc = self._require_encoder()
        return enc.features(jnp.asarray(rows, jnp.float32))

    def _assemble(self, parts) -> np.ndarray:
        sigma, resbar = parts
        return np.concatenate([np.asarray(sigma, np.float32),
                               np.asarray(resbar, np.float32)], axis=1)

    def member_lb(self, qf, feats):
        """d_sPAA expanded to avoid the L x W cross product:
        T/L*|ds|^2 + T/W*|dr|^2 + 2T/(W*L)*sum(ds)*sum(dr)."""
        feats = np.asarray(feats, np.float64)
        qf = np.asarray(qf, np.float64)
        ds = feats[:, :self.L] - qf[None, :self.L]
        dr = feats[:, self.L:] - qf[None, self.L:]
        t = (self.T / self.L) * np.sum(ds * ds, axis=1) \
            + (self.T / self.W) * np.sum(dr * dr, axis=1) \
            + 2.0 * self.T / (self.W * self.L) * ds.sum(1) * dr.sum(1)
        return np.sqrt(np.maximum(t, 0.0))


def _trend_scale(T: int) -> float:
    from repro.core.tsax import time_variance
    return math.sqrt(T * time_variance(T))


class TSAXFeatures(FeatureAdapter):
    """Scaled trend slope u = tan(phi) * sqrt(T * var(t)) (1 dim, weight
    1) ++ residual PAA (W dims, weight T/W)."""

    def __init__(self, T: int, W: int, *, sd_res: float,
                 r2_trend: float = 0.5, encoder=None):
        sd_u = math.sqrt(max(r2_trend, 0.05) * T)
        super().__init__(T, [1.0] + [T / W] * W, [sd_u] + [sd_res] * W,
                         [0] + [1] * W, encoder)
        self.W = int(W)
        self.scale = _trend_scale(T)

    def _device_features(self, rows):
        import jax.numpy as jnp
        enc = self._require_encoder()
        return enc.features(jnp.asarray(rows, jnp.float32))

    def _assemble(self, parts) -> np.ndarray:
        phi, resbar = parts
        # slope transform stays host-f64: tan in f32 on device would
        # drift the stored features by ulps vs the incremental path
        u = self.scale * np.tan(np.asarray(phi, np.float64))
        return np.concatenate([u[:, None].astype(np.float32),
                               np.asarray(resbar, np.float32)], axis=1)


class STSAXFeatures(FeatureAdapter):
    """Scaled trend slope (1) ++ season mask (L) ++ residual PAA (W);
    the member bound combines |du|^2 with the d_sPAA season/residual part
    (cross term kept) — each term is one of the paper's component
    bounds, summed by orthogonality (stSAX docstring / A.4)."""

    def __init__(self, T: int, L: int, W: int, *, sd_seas: float,
                 sd_res: float, r2_trend: float = 0.3, encoder=None):
        sd_u = math.sqrt(max(r2_trend, 0.05) * T)
        super().__init__(T, [1.0] + [T / L] * L + [T / W] * W,
                         [sd_u] + [sd_seas] * L + [sd_res] * W,
                         [1] + [0] * L + [2] * W, encoder)
        self.L, self.W = int(L), int(W)
        self.scale = _trend_scale(T)

    def _device_features(self, rows):
        import jax.numpy as jnp
        enc = self._require_encoder()
        return enc.features(jnp.asarray(rows, jnp.float32))

    def _assemble(self, parts) -> np.ndarray:
        phi, sigma, resbar = parts
        u = self.scale * np.tan(np.asarray(phi, np.float64))
        return np.concatenate([u[:, None].astype(np.float32),
                               np.asarray(sigma, np.float32),
                               np.asarray(resbar, np.float32)], axis=1)

    def member_lb(self, qf, feats):
        feats = np.asarray(feats, np.float64)
        qf = np.asarray(qf, np.float64)
        du = feats[:, 0] - qf[0]
        ds = feats[:, 1:1 + self.L] - qf[None, 1:1 + self.L]
        dr = feats[:, 1 + self.L:] - qf[None, 1 + self.L:]
        t = du * du \
            + (self.T / self.L) * np.sum(ds * ds, axis=1) \
            + (self.T / self.W) * np.sum(dr * dr, axis=1) \
            + 2.0 * self.T / (self.W * self.L) * ds.sum(1) * dr.sum(1)
        return np.sqrt(np.maximum(t, 0.0))


def adapter_for(encoder) -> FeatureAdapter:
    """The feature adapter matching one of the paper's four techniques."""
    from repro.core import SAX, SSAX, STSAX, TSAX
    if isinstance(encoder, SAX):
        return SAXFeatures(encoder.T, encoder.W, sd=encoder.sd,
                           encoder=encoder)
    if isinstance(encoder, SSAX):
        return SSAXFeatures(encoder.T, encoder.L, encoder.W,
                            sd_seas=encoder.sd_seas, sd_res=encoder.sd_res,
                            encoder=encoder)
    if isinstance(encoder, TSAX):
        return TSAXFeatures(encoder.T, encoder.W, sd_res=encoder.sd_res,
                            r2_trend=encoder.r2_trend, encoder=encoder)
    if isinstance(encoder, STSAX):
        return STSAXFeatures(encoder.T, encoder.L, encoder.W,
                             sd_seas=encoder.sd_seas,
                             sd_res=encoder.sd_res,
                             r2_trend=encoder.r2_trend, encoder=encoder)
    raise TypeError(f"no index feature adapter for "
                    f"{type(encoder).__name__}; the split tree supports "
                    "SAX, sSAX, tSAX and stSAX")
