"""Season-aware index subsystem: an incremental iSAX-style split tree
over any encoder's symbolic feature space.

The paper's headline speedup — matching orders of magnitude faster than
SAX — combines the improved symbolic distribution with an index over the
symbolic space, so indexing is a first-class subsystem here, not an
sSAX-only afterthought:

* :mod:`repro.index.features` — per-encoder *feature adapters* mapping
  raw series to a real-valued feature vector whose weighted distance
  lower-bounds d_ED (SAX: PAA means; sSAX: season mask + residual means;
  tSAX: scaled trend slope + residual means; stSAX: all three), plus the
  exact per-member feature-distance bound of Table 2.
* :mod:`repro.index.tree` / :mod:`repro.index.insert` — the adaptive
  split tree.  Splitting promotes one feature dimension by one bit of
  cardinality, **season-aware**: the split order is a deterministic
  function of the node's bit-state that refines seasonal dimensions
  first (then trend, then residual).  Because the split dimension never
  depends on *which* members a node currently holds, the tree built by
  incremental :meth:`~repro.index.tree.SplitTree.insert` is structurally
  identical to a bulk rebuild for ANY append chunking — leaf membership
  and all — so ``SymbolicStore.append`` maintains the index in place
  instead of invalidating it.
* :mod:`repro.index.candidates` — the ``CandidateSource`` protocol that
  feeds ``core.engine.topk_verify``.  ``LinearSweep`` is the paper's
  full lower-bound sweep; ``TreeCandidates`` generates a *compact*
  candidate set from the tree (best-first seed walk, verified upper
  bound U, then a pruned collect of every member whose bound can still
  beat U).  Both run through the same k-th-best early-stop verification,
  so indexed top-k is bit-identical to the linear sweep.
* :class:`repro.index.series.SeriesIndex` — the store-facing object:
  built from a ``SymbolicStore`` (or raw rows / z-normalized windows),
  incrementally maintained by ``insert_rows``, snapshot-round-trippable,
  and usable as a candidate source by ``MatchEngine`` and
  ``SubseqEngine`` (via ``WindowView.build_index``).
"""

from repro.index.features import (  # noqa: F401
    FeatureAdapter, adapter_for, ndtri_np)
from repro.index.tree import SplitTree, TreeNode  # noqa: F401
from repro.index.candidates import (  # noqa: F401
    CandidateSet, LinearSweep, TreeCandidates, topk_from_source)
from repro.index.series import SeriesIndex  # noqa: F401
