"""``SeriesIndex``: the store-facing incremental index.

One object ties the pieces together for a concrete corpus: the encoder's
feature adapter, the split tree, and the engine protocol.  It indexes
raw rows (``SymbolicStore`` / whole matching) or z-normalized windows
(``subseq.WindowView`` / subsequence matching) — anything whose items
the adapter's ``features`` accepts row-wise.

Contracts:

* ``insert_rows`` is incremental and chunking-invariant: the tree after
  any sequence of inserts equals a bulk build over the same rows
  (:mod:`repro.index.insert`), so ``SymbolicStore.append`` and
  ``WindowView.sync`` maintain it in place.
* ``topk`` routes through ``core.engine.topk_verify`` via
  :class:`repro.index.candidates.TreeCandidates` — bit-identical to the
  linear sweep, sublinear candidates examined.
* ``to_snapshot`` / ``from_snapshot`` round-trip the tree INCLUDING its
  split history, so a reopened incrementally-built index answers
  queries identically and keeps accepting inserts.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.index.candidates import TreeCandidates, topk_from_source
from repro.index.features import FeatureAdapter, adapter_for
from repro.index.tree import SplitTree

_INSERT_CHUNK = 8192


class SeriesIndex:
    """Incremental split-tree index for one encoder's corpus."""

    def __init__(self, encoder, *, leaf_fill: int = 64, max_bits: int = 8,
                 adapter: Optional[FeatureAdapter] = None):
        self.encoder = encoder
        self.adapter = adapter if adapter is not None \
            else adapter_for(encoder)
        self.tree = SplitTree(self.adapter, leaf_fill=leaf_fill,
                              max_bits=max_bits)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_store(cls, store, *, leaf_fill: int = 64, max_bits: int = 8,
                   mesh=None, n_shards: int = None) -> "SeriesIndex":
        """Index every row of a ``SymbolicStore`` (or any object with
        raw ``.data``) — the bulk build is just ``insert_rows`` over the
        existing rows, the same code path appends keep using.

        ``mesh`` shards feature extraction row-wise across its data axes
        (``FeatureAdapter.features_sharded``); ``n_shards`` (default:
        the mesh's data-axis device count) additionally partitions the
        tree routing by root subtree (``SplitTree.insert_grouped``).
        Both paths are bit-identical to the single-host incremental
        build — leaf membership, boxes and split history included."""
        idx = cls(store.encoder, leaf_fill=leaf_fill, max_bits=max_bits)
        if mesh is None and (n_shards is None or n_shards <= 1):
            idx.insert_rows(store.data)
        else:
            idx.bulk_load(store.data, mesh=mesh, n_shards=n_shards)
        return idx

    def bulk_load(self, rows, *, mesh=None, n_shards: int = None
                  ) -> np.ndarray:
        """Sharded bulk build: features on device across ``mesh``'s data
        axes, tree routing partitioned into ``n_shards`` root subtrees.
        Chunked like ``insert_rows`` (row-wise maps make chunking
        bit-identical); returns the new ids in insertion order."""
        if n_shards is None:
            n_shards = 1
            if mesh is not None:
                from repro.core.distributed import _data_axes
                for a in _data_axes(mesh):
                    n_shards *= mesh.shape[a]
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        if rows.shape[0] == 0:
            return np.empty(0, np.int64)
        out = []
        for c0 in range(0, rows.shape[0], _INSERT_CHUNK):
            chunk = rows[c0:c0 + _INSERT_CHUNK]
            feats = (self.adapter.features_sharded(chunk, mesh)
                     if mesh is not None else self.adapter.features(chunk))
            out.append(self.tree.insert_grouped(feats, max(n_shards, 1)))
        return np.concatenate(out)

    def insert_rows(self, rows) -> np.ndarray:
        """Compute features of new rows (chunked — features are row-wise
        maps, so chunking is bit-identical) and route them into the
        tree; returns their item ids (insertion order)."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        if rows.shape[0] == 0:
            return np.empty(0, np.int64)
        out = []
        for c0 in range(0, rows.shape[0], _INSERT_CHUNK):
            chunk = rows[c0:c0 + _INSERT_CHUNK]
            out.append(self.tree.insert(self.adapter.features(chunk)))
        return np.concatenate(out)

    # -- views -----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.tree.n

    def __len__(self) -> int:
        return self.tree.n

    @property
    def n_nodes(self) -> int:
        return self.tree.n_nodes

    @property
    def leaf_fill(self) -> int:
        return self.tree.leaf_fill

    @property
    def max_bits(self) -> int:
        return self.tree.max_bits

    # -- engine integration ----------------------------------------------
    def query_features(self, queries_raw) -> np.ndarray:
        qs = np.asarray(queries_raw, np.float32)
        if qs.ndim == 1:
            qs = qs[None]
        return self.adapter.features(qs)

    def source(self, *, prior_d=None, prior_i=None, seen=None,
               device_order: bool = False,
               approx_collect: Optional[int] = None,
               epoch=None) -> TreeCandidates:
        """This index as a ``CandidateSource`` for the match engine.
        ``prior_d`` / ``prior_i`` / ``seen`` enable frontier reuse across
        exclusion-widening rounds (see ``TreeCandidates``): already
        verified ids are seeded, never verified twice.  ``device_order``
        sorts the compact candidate bounds on device and streams ids to
        the scan instead of handing it a host matrix.  ``approx_collect``
        switches to the APPROXIMATE anytime mode: exact seed walk, then
        at most that many collected survivors per query, with the
        dropped bounds carried as the result's error certificate.
        ``epoch`` (``repro.store.CorpusEpoch`` or row count) restricts
        generation to items indexed before that frontier — the as-of
        read behind snapshot-consistent serving under ingest."""
        return TreeCandidates(self.tree, self.query_features,
                              prior_d=prior_d, prior_i=prior_i, seen=seen,
                              device_order=device_order,
                              approx_collect=approx_collect, epoch=epoch)

    def topk(self, queries_raw, store, *, k: int = 1, batch_size: int = 64,
             verifier=None, merge=None, dist_fn=None, on_verified=None,
             prior_d=None, prior_i=None, seen=None,
             approx_collect: Optional[int] = None, epoch=None, trace=None):
        """Exact top-k over ``store`` through the indexed traversal —
        bit-identical to the linear-sweep engine (same verification
        path, same tie-break).  ``dist_fn`` routes verification through
        a device-resident distance hook; ``prior_d``/``prior_i``/``seen``
        reuse an earlier round's verified frontier; ``trace`` records a
        ``repro.obs`` query trace (seed/collect/scan phases).
        ``approx_collect`` routes through the bounded-collect
        approximate mode — the result then carries ``kth_lb`` /
        ``error_bar`` (see ``TreeCandidates``).  ``epoch`` pins the
        answer to the items visible at that frontier (bit-identical to
        an index truncated there, regardless of concurrent inserts)."""
        from repro.store.symbolic import epoch_rows
        src = self.source(prior_d=prior_d, prior_i=prior_i, seen=seen,
                          approx_collect=approx_collect, epoch=epoch)
        n_e = epoch_rows(epoch)
        total = self.n if n_e is None else min(self.n, n_e)
        return topk_from_source(queries_raw, src, store, k=k,
                                batch_size=batch_size, verifier=verifier,
                                merge=merge, total=total,
                                dist_fn=dist_fn, on_verified=on_verified,
                                trace=trace)

    # -- snapshot serialization ------------------------------------------
    def to_snapshot(self):
        meta, arrays = self.tree.to_snapshot()
        meta["kind"] = "series"
        return meta, arrays

    @classmethod
    def from_snapshot(cls, encoder, meta: dict, arrays: dict,
                      ) -> "SeriesIndex":
        self = cls.__new__(cls)
        self.encoder = encoder
        self.adapter = adapter_for(encoder)
        self.tree = SplitTree.from_snapshot(self.adapter, meta, arrays)
        return self
