from repro.sharding.specs import (  # noqa: F401
    ShardingRules, constrain, pspec_for, named_sharding,
)
