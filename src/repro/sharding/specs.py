"""Logical-axis -> mesh-axis sharding rules.

Every tensor in the system is annotated with *logical* dims ("d", "ff",
"qdim", "batch", ...).  ``ShardingRules`` maps logical dims to mesh axes and
enforces divisibility: a logical dim is only sharded when its size divides the
product of the mapped mesh axes (jit rejects uneven shardings).  This is what
lets one rule table drive ten architectures with awkward head counts.

Default production mapping (single pod, mesh ("data", "model")):
    batch  -> ("data",)           data parallel
    d      -> ("data",)           FSDP: parameters' d_model dim sharded over dp
    qdim/kvdim/ff/ffe/vocab/d_inner/rflat -> ("model",)   tensor parallel
    experts -> ("model",)         expert parallel
    seq    -> ()                  (set to ("data",) for batch-1 long decode)

Multi-pod adds "pod" in front of batch (pure DP across pods) and optionally
into the FSDP axes (ZeRO across pods) — see ``for_mesh``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axes_size(mesh: Mesh, axes: tuple) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    # logical dim -> tuple of mesh axes (in major-to-minor order)
    table: dict = field(default_factory=dict)
    # >1 => group-local MoE dispatch with this many groups (aligned with
    # the data axes; see models/moe._moe_mlp_grouped)
    moe_groups: int = 0

    @staticmethod
    def for_mesh(mesh: Mesh, *, seq_sharded: bool = False,
                 zero_over_pod: bool = True,
                 fsdp: bool = True) -> "ShardingRules":
        axes = set(mesh.axis_names)
        has_pod = "pod" in axes
        batch = (("pod", "data") if has_pod else ("data",))
        dp = ("data",)
        if has_pod and zero_over_pod:
            dp = ("pod", "data")
        tp = ("model",)
        table = {
            "batch": batch,
            "seq": dp if seq_sharded else (),
            "d": dp if fsdp else (),          # FSDP on parameter d_model dim
            "vocab": tp,
            "qdim": tp,
            "kvdim": tp,
            "ff": tp,
            "ffe": (),                        # per-expert ff dim (E already EP)
            "experts": tp,
            "d_inner": tp,                    # mamba channels
            "rflat": tp,                      # rwkv flattened head dim (H*hd)
            "heads": (),                      # raw head counts rarely divisible
            "kvheads": tp,                    # kv cache heads (when divisible)
            "rheads": tp,                     # rwkv state heads
            "hd": tp,                         # fallback: head_dim (used-axis
                                              # tracking keeps one of the two)
            "cache_seq": dp if seq_sharded else (),
            "layers": (),
            "cap": (),
            "dt": (),
            "state": (),
            "conv": (),
            "lora": (),
            "frames": (),
            "prefix": (),
            "seq_act": (),
            "seq_tok": (),
            "d_act": (),
            "vec": (),
            "groups": dp,                     # MoE dispatch groups
        }
        return ShardingRules(mesh=mesh, table=table)

    def with_overrides(self, **kv) -> "ShardingRules":
        t = dict(self.table)
        extra = {}
        if "moe_groups" in kv:
            extra["moe_groups"] = kv.pop("moe_groups")
        t.update(kv)
        return replace(self, table=t, **extra)

    # ------------------------------------------------------------------
    def axes_for(self, dim_name: str, size: int):
        """Mesh axes for one logical dim, honoring divisibility."""
        axes = self.table.get(dim_name, ())
        if not axes:
            return None
        if size % _axes_size(self.mesh, tuple(axes)) != 0:
            return None                     # would be uneven -> replicate
        return tuple(axes) if len(axes) > 1 else axes[0]

    def pspec(self, dims: tuple, shape: tuple) -> P:
        assert len(dims) == len(shape), (dims, shape)
        used = set()
        out = []
        for dim_name, size in zip(dims, shape):
            ax = self.axes_for(dim_name, size)
            # one mesh axis may shard only one dim of a tensor
            flat = ax if isinstance(ax, tuple) else (ax,) if ax else ()
            if ax is None or any(a in used for a in flat):
                out.append(None)
            else:
                used.update(flat)
                out.append(ax)
        while out and out[-1] is None:
            out.pop()
        return P(*out)


def pspec_for(rules: Optional[ShardingRules], dims: tuple, shape: tuple) -> P:
    if rules is None:
        return P()
    return rules.pspec(dims, shape)


def named_sharding(rules: ShardingRules, dims: tuple, shape: tuple):
    return NamedSharding(rules.mesh, rules.pspec(dims, shape))


def constrain(x, rules: Optional[ShardingRules], dims: tuple):
    """with_sharding_constraint against the logical dims (no-op without rules)."""
    if rules is None or getattr(rules, "mesh", None) is None:
        return x
    spec = rules.pspec(dims, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
