"""Training launcher: ``python -m repro.launch.train --arch smollm-135m
--steps 300 --scale reduced``.

On this CPU container the default is a reduced config on a debug mesh;
pass ``--scale full`` on a real fleet (identical code path — the mesh and
configs are the only difference, which is the launcher's whole job).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.transformer import RunConfig
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import cosine_schedule
from repro.sharding.specs import ShardingRules
from repro.train.loop import FailureInjector, StragglerPolicy, train_loop
from repro.train.state import init_train_state
from repro.train.step import make_train_step
from repro.checkpoint.ckpt import Checkpointer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--scale", default="reduced",
                    choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps to fail at (FT demo)")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override reduced width (e.g. 256 for ~20M)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        n_heads=max(4, args.d_model // 64), head_dim=64,
                        n_kv_heads=2, d_ff=args.d_model * 3)
        if args.n_layers:
            patt_mult = max(1, args.n_layers // len(cfg.pattern))
            over["n_layers"] = patt_mult * len(cfg.pattern)
        cfg = reduced(cfg, **over)
        rules = None
        mesh = None
    else:
        mesh = make_production_mesh()
        rules = ShardingRules.for_mesh(mesh)

    rc = RunConfig(q_chunk=128, kv_chunk=128, mamba_chunk=64, rwkv_chunk=64,
                   loss_chunk=128, microbatch=args.microbatch)
    opt = AdamWConfig(lr=args.lr)
    sched = lambda step: cosine_schedule(step, warmup=max(10, args.steps // 20),
                                         total=args.steps)
    step_fn = jax.jit(make_train_step(
        cfg, rules, rc, opt, schedule=sched,
        compression=None if args.compression == "none" else args.compression))

    data = SyntheticLM(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    def batch_fn(step):
        b = data.batch(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    ckpt = Checkpointer(args.ckpt_dir, every=args.ckpt_every) \
        if args.ckpt_dir else None
    inj = None
    if args.inject_failures:
        inj = FailureInjector(
            fail_at=tuple(int(s) for s in args.inject_failures.split(",")))

    tot, act = cfg.param_counts()
    print(f"training {cfg.name}: {tot/1e6:.1f}M params "
          f"({act/1e6:.1f}M active), {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")
    state, hist = train_loop(
        init_state_fn=lambda: init_train_state(cfg, jax.random.PRNGKey(0)),
        train_step=step_fn, batch_fn=batch_fn, n_steps=args.steps,
        checkpointer=ckpt, failure_injector=inj,
        straggler=StragglerPolicy())
    print(f"final loss {hist['loss'][-1]:.4f} "
          f"(first {hist['loss'][0]:.4f}); restarts={hist['restarts']} "
          f"straggler_events={hist['straggler_events']}")
    return hist


if __name__ == "__main__":
    main()
