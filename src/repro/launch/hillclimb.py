import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Runs named variants of the three chosen cells, re-lowers, re-derives the
roofline terms, and appends (variant, hypothesis, terms) records to
results/hillclimb.json.  The markdown §Perf log is generated from that
file by benchmarks/roofline.py helpers.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell gemma3-decode
"""

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))

from repro.launch.dryrun import dryrun_cell  # noqa: E402

# variant -> (kwargs for dryrun_cell, hypothesis text)
CELLS = {
    # -------- worst roofline fraction: gemma3-12b decode_32k ----------
    "gemma3-decode": {
        "arch": "gemma3-12b", "shape": "decode_32k",
        "variants": [
            ("baseline", {}, "naive sharding: FSDP params + head-dim-sharded "
             "cache; HLO shows a full f32 cache all-gather (4.3 GB) because "
             "SPMD cannot reshard hd->grouped-heads (involuntary remat)"),
            ("seq_sharded_cache", {
                "rules_overrides": {"cache_seq": ("model",), "hd": (),
                                    "kvheads": ()}},
             "shard the KV cache SEQUENCE over the model axis "
             "(flash-decoding): QK^T becomes t-local, softmax needs only "
             "tiny cross-chip max/sum, AV partial-sums all-reduce is "
             "(B,K,G,Dh) — predict cache all-gather disappears, "
             "collective_s drops ~100x"),
            ("tp_only_params", {
                "rules_overrides": {"cache_seq": ("model",), "hd": (),
                                    "kvheads": (), "d": ()}},
             "serving never re-reads optimizer state: drop FSDP on params "
             "(replicate over data, keep TP) — predict the per-step weight "
             "all-gathers (252+177 MB f32) disappear"),
            ("bf16_weights", {
                "rules_overrides": {"cache_seq": ("model",), "hd": (),
                                    "kvheads": (), "d": ()},
                "serve_params_dtype": "bfloat16"},
             "serve from bf16 weights: any residual weight movement and "
             "all HBM weight streaming halves — predict memory_s ~2x down"),
        ],
    },
    # -------- most collective-bound: olmoe-1b-7b prefill_32k ----------
    "olmoe-prefill": {
        "arch": "olmoe-1b-7b", "shape": "prefill_32k",
        "variants": [
            ("baseline", {}, "64-expert EP dispatch + FSDP gathers at 32k "
             "tokens: collective_s 0.64s vs compute 0.14s"),
            ("tp_only_params", {"rules_overrides": {"d": ()}},
             "prefill re-reads weights once per step; FSDP all-gathers of "
             "f32 masters are pure overhead vs TP-resident bf16 — predict "
             "all-gather bytes drop by ~params_f32 volume"),
            ("bf16_weights", {"rules_overrides": {"d": ()},
                              "serve_params_dtype": "bfloat16"},
             "bf16 weight streams halve residual gather/HBM volume"),
            ("causal_skip", {"rules_overrides": {"d": ()},
                             "serve_params_dtype": "bfloat16",
                             "rc_overrides": {"causal_skip": True,
                                              "q_chunk": 2048}},
             "static causal block skipping halves attention-core FLOPs at "
             "32k (compute term ~2x down; collective unchanged)"),
            ("grouped_dispatch", {
                "rules_overrides": {"d": (), "moe_groups": 16},
                "serve_params_dtype": "bfloat16",
                "rc_overrides": {"causal_skip": True, "q_chunk": 2048}},
             "REFUTED-baseline follow-up: the 32GB was MoE dispatch, not "
             "weight gathers. Group-local dispatch (tokens grouped by data "
             "shard, cumsum within group, buffers (G@data,E@model)) lets "
             "every model rank build its expert slice locally — predict "
             "dispatch collectives drop to the (G,Tg,d) bf16 combine "
             "all-reduce, ~10-50x down"),
        ],
    },
    # -------- representative training cell: smollm-135m train_4k ------
    "smollm-train": {
        "arch": "smollm-135m", "shape": "train_4k",
        "variants": [
            ("baseline", {}, "full remat + full-S flash: compute term is "
             "4x(2 tokens P) + unskipped S^2 core; frac 0.37"),
            ("causal_skip", {"rc_overrides": {"causal_skip": True}},
             "causal block skipping: attention core ~halves; for a 135M "
             "model at 4k the core is a large share — predict compute_s "
             "down 20-30%"),
            ("dots_remat", {"rc_overrides": {"causal_skip": True,
                                             "remat_policy": "dots"}},
             "save matmul outputs in remat (dots_with_no_batch_dims): "
             "recompute factor 4x -> ~3.2x fwd — predict compute_s down "
             "another ~20% at the cost of saved-dot memory"),
            ("bigger_microbatch", {"rc_overrides": {"causal_skip": True,
                                                    "remat_policy": "dots",
                                                    "microbatch": 2}},
             "fewer accumulation steps amortize optimizer + collective "
             "launches; activation memory grows 2x — predict small "
             "compute win, memory_s up but far from the roofline term"),
        ],
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["all"] + list(CELLS))
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()

    cells = list(CELLS) if args.cell == "all" else [args.cell]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    records = []
    if os.path.exists(args.out):
        records = json.load(open(args.out))
    done = {(r["cell"], r["variant"]) for r in records}

    for cell in cells:
        spec = CELLS[cell]
        for name, kwargs, hypothesis in spec["variants"]:
            if (cell, name) in done:
                continue
            rec = dryrun_cell(spec["arch"], spec["shape"], multi_pod=False,
                              variant=name, **kwargs)
            rec["cell"] = cell
            rec["hypothesis"] = hypothesis
            records.append(rec)
            json.dump(records, open(args.out, "w"), indent=1)
            colls = rec.get("collectives", {})
            tot = sum(v for k, v in colls.items() if k != "count")
            print(f"[{cell}/{name}] status={rec['status']} "
                  f"coll={tot/1e6:.1f}MB compile={rec.get('compile_s')}s",
                  flush=True)


if __name__ == "__main__":
    main()
