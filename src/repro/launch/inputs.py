"""Abstract inputs (ShapeDtypeStruct) + shardings for every
(architecture x input-shape) cell — the dry-run's allocation-free stand-ins.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.transformer import init_cache, cache_logical_dims
from repro.sharding.specs import ShardingRules

SDS = jax.ShapeDtypeStruct


def batch_logical_dims(cfg: ModelConfig, with_labels: bool = True):
    dims = {"tokens": ("batch", "seq_tok")}
    if with_labels:
        dims["labels"] = ("batch", "seq_tok")
    if cfg.prefix_len:
        dims["prefix_embed"] = ("batch", "prefix", "vec")
    if cfg.is_enc_dec:
        dims["encoder_frames"] = ("batch", "frames", "vec")
    return dims


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, rules=None,
                      with_labels: bool = True):
    """(abstract batch, pspec tree)."""
    B, S = shape.global_batch, shape.seq_len
    s_text = S - cfg.prefix_len
    batch = {"tokens": SDS((B, s_text), jnp.int32)}
    if with_labels:
        batch["labels"] = SDS((B, s_text), jnp.int32)
    if cfg.prefix_len:
        batch["prefix_embed"] = SDS((B, cfg.prefix_len, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.is_enc_dec:
        batch["encoder_frames"] = SDS((B, cfg.encoder_seq, cfg.d_model),
                                      jnp.bfloat16)
    if rules is None:
        return batch, None
    dims = batch_logical_dims(cfg, with_labels)
    ps = {k: rules.pspec(dims[k], batch[k].shape) for k in batch}
    return batch, ps


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, rules=None):
    """(abstract (cache, token), pspec trees) for one decode step.

    The cache holds ``seq_len - 1`` tokens (pos = seq_len - 1); the step
    appends the one new token — "decode one token against a seq_len cache".
    """
    B, S = shape.global_batch, shape.seq_len
    cache = init_cache(cfg, B, S, abstract=True)
    cache = dict(cache, pos=SDS((), jnp.int32))
    token = SDS((B, 1), jnp.int32)
    if rules is None:
        return (cache, token), None
    dims = cache_logical_dims(cfg)
    cache_ps = jax.tree.map(
        lambda dm, leaf: rules.pspec(dm, leaf.shape), dims, cache,
        is_leaf=lambda x: isinstance(x, tuple) and (
            x == () or all(isinstance(e, str) for e in x)))
    token_ps = rules.pspec(("batch", "seq_tok"), (B, 1))
    return (cache, token), (cache_ps, token_ps)


def to_named(rules: ShardingRules, ps_tree):
    from jax.sharding import PartitionSpec
    return jax.tree.map(
        lambda ps: NamedSharding(rules.mesh, ps), ps_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
