"""Matching-service launcher: build a sharded sSAX (or SAX/tSAX/stSAX)
representation of a dataset and serve batched exact / approximate top-k
matches through the unified k-NN engine.

    PYTHONPATH=src python -m repro.launch.match \
        --n 40000 --strength 0.7 --technique ssax --queries 8 --k 32 \
        --ingest 4 --snapshot-dir /tmp/match-snaps

Device count is taken from the environment (set XLA_FLAGS
--xla_force_host_platform_device_count=8 for a local fleet simulation);
the same code drives the production ("pod","data") mesh axes.  The
sharded sweep produces lower bounds / candidate frontiers; raw
verification goes through ``core.engine.MatchEngine`` (Pallas euclid
kernel on TPU, one batched store fetch per round).  The engine is backed
by a ``repro.store.SymbolicStore``: ``--ingest N`` appends N chunks while
serving queries between them (only new rows are encoded), and
``--snapshot-dir`` persists the store + representation after the run.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--T", type=int, default=960)
    ap.add_argument("--L", type=int, default=10)
    ap.add_argument("--strength", type=float, default=0.7)
    ap.add_argument("--technique", default="ssax",
                    choices=["sax", "ssax", "tsax", "stsax"])
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--batch", type=int, default=256,
                    help="verification batch per query per round")
    ap.add_argument("--store", default="ssd", choices=["hdd", "ssd", "hbm"])
    ap.add_argument("--ingest", type=int, default=0,
                    help="chunks to append while serving (ingest demo)")
    ap.add_argument("--ingest-rows", type=int, default=1024,
                    help="rows per ingest chunk")
    ap.add_argument("--snapshot-dir", default="",
                    help="persist the store (raw + rep) after the run")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.core import SAX, SSAX, STSAX, TSAX
    from repro.core.distributed import make_engine_service
    from repro.core.matching import pairwise_euclidean
    from repro.data.synthetic import season_dataset
    from repro.launch.mesh import make_mesh_compat

    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    n = (args.n // n_dev) * n_dev
    n_ingest = args.ingest * args.ingest_rows
    X = season_dataset(n + args.queries + n_ingest, args.T, args.L,
                       args.strength, per_series_strength=True, seed=1)
    Q, D = X[:args.queries], X[args.queries:args.queries + n]
    ingest_pool = X[args.queries + n:]

    tech = {
        "sax": lambda: SAX(T=args.T, W=48, A=64),
        "ssax": lambda: SSAX(T=args.T, W=48, L=args.L, A_seas=16, A_res=32,
                             r2_season=args.strength),
        "tsax": lambda: TSAX(T=args.T, W=48, A_tr=64, A_res=32,
                             r2_trend=args.strength),
        "stsax": lambda: STSAX(T=args.T, W=48, L=args.L, A_tr=16,
                               A_seas=16, A_res=32,
                               r2_trend=0.2, r2_season=args.strength),
    }[args.technique]()

    print(f"[match] {args.technique} over {n} x {args.T} "
          f"on {n_dev} devices")
    t0 = time.perf_counter()
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=args.batch, media=args.store)
    store = engine.store                 # SymbolicStore: raw + live rep
    jax.block_until_ready(engine.rep)
    print(f"[match] encode: {time.perf_counter() - t0:.2f}s")

    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    true_nn = np.argsort(ed, axis=1, kind="stable")

    # exact top-k through the pruned batched scan
    for k in (1, args.k):
        store.reset()
        t0 = time.perf_counter()
        res = engine.topk(Q, k=k)
        dt = time.perf_counter() - t0
        hits = sum(int(np.array_equal(res.indices[qi],
                                      true_nn[qi, :k]))
                   for qi in range(args.queries))
        acc = res.raw_accesses.mean()
        print(f"[match] exact k={k}: {hits}/{args.queries} query frontiers "
              f"== brute force; raw rows/query {acc:.0f} "
              f"({acc / n:.2%} of dataset), {res.store_fetches} batched "
              f"fetches; modeled {args.store} I/O {res.io_seconds:.3f}s; "
              f"wall {dt:.2f}s")

    # approximate top-k from the sharded candidate frontier
    store.reset()
    t0 = time.perf_counter()
    res = engine.topk(Q, k=args.k, exact=False)
    dt = time.perf_counter() - t0
    hit1 = sum(int(res.indices[qi, 0] == true_nn[qi, 0])
               for qi in range(args.queries))
    print(f"[match] approx k={args.k}: 1-NN hit {hit1}/{args.queries}; "
          f"raw rows/query {res.raw_accesses.mean():.0f}; modeled "
          f"{args.store} I/O {res.io_seconds:.3f}s; wall {dt:.2f}s")

    # ingest-while-serving: append chunks, answer queries between them —
    # only the new chunk is encoded each round
    for c in range(args.ingest):
        chunk = ingest_pool[c * args.ingest_rows:(c + 1) * args.ingest_rows]
        t0 = time.perf_counter()
        engine.ingest(chunk)
        t_ing = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = engine.topk(Q, k=args.k, exact=False)
        t_q = time.perf_counter() - t0
        print(f"[match] ingest {c + 1}/{args.ingest}: +{chunk.shape[0]} "
              f"rows in {t_ing * 1e3:.0f}ms "
              f"({chunk.shape[0] / max(t_ing, 1e-9):.0f} rows/s), corpus "
              f"{store.n}; query k={args.k} under ingest {t_q * 1e3:.0f}ms")

    if args.snapshot_dir:
        t0 = time.perf_counter()
        path = store.save(args.snapshot_dir)
        print(f"[match] snapshot: {store.n} rows + rep -> {path} "
              f"({time.perf_counter() - t0:.2f}s)")


if __name__ == "__main__":
    main()
