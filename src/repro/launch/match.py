"""Matching-service launcher: build a sharded sSAX (or SAX/tSAX/stSAX)
representation of a dataset and serve batched exact / approximate top-k
matches through the unified k-NN engine.

    PYTHONPATH=src python -m repro.launch.match \
        --n 40000 --strength 0.7 --technique ssax --queries 8 --k 32 \
        --ingest 4 --snapshot-dir /tmp/match-snaps --index

``--index`` builds the split-tree index (``repro.index``) over the
store and serves exact top-k from its sublinear candidate generation
(bit-identical to the linear sweep, fewer candidates examined); the
index is maintained incrementally through ``--ingest`` appends and
persisted by ``--snapshot-dir``.  ``--leaf-fill`` tunes the leaf split
threshold.  Both flags apply to the ``--subseq`` windowed path too.

``--subseq`` switches to subsequence matching: the corpus rows become
long series, every z-normalized window of length ``--window`` at
``--stride`` is symbolically indexed (``repro.subseq.WindowView``), and
queries are snippets localized anywhere in the corpus through the pruned
windowed scan (``repro.subseq.SubseqEngine``), compared against the
MASS-style brute-force kernel:

    PYTHONPATH=src python -m repro.launch.match \
        --subseq --n 64 --T 3600 --window 240 --stride 4 --k 8

Device count is taken from the environment (set XLA_FLAGS
--xla_force_host_platform_device_count=8 for a local fleet simulation);
the same code drives the production ("pod","data") mesh axes.  The
sharded sweep produces lower bounds / candidate frontiers; raw
verification goes through ``core.engine.MatchEngine`` (Pallas euclid
kernel on TPU, one batched store fetch per round) — or, with
``--verify device``, stays device-resident end to end: the raw rows are
sharded across the mesh next to the representation and candidates are
verified per shard through the euclid kernel, moving zero raw rows to
the host (``--verify host`` is the bit-identical host fallback; both
apply to ``--subseq`` too).  The engine is backed
by a ``repro.store.SymbolicStore``: ``--ingest N`` appends N chunks while
serving queries between them (only new rows are encoded), and
``--snapshot-dir`` persists the store + representation after the run.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _explain(trace, *, device: bool):
    """Render the per-query plan and hard-fail on a broken trace.

    ``device=True`` additionally enforces the device-path invariants as
    a gate: ``host_order_bytes == 0`` (ordering stayed device-resident)
    and ``rows_to_host == 0`` (no raw row crossed to the host during
    verification).  CI runs this through ``--explain --dryrun``."""
    from repro.obs import check_trace, render_trace
    print(render_trace(trace))
    problems = check_trace(trace, device=device)
    if problems:
        raise SystemExit("[explain] trace check FAILED: "
                         + "; ".join(problems))


def _print_metrics(registry):
    """One-screen registry summary (counters + latency quantiles)."""
    snap = registry.snapshot()
    if snap["counters"]:
        kv = ", ".join(f"{k}={v:g}" for k, v in
                       sorted(snap["counters"].items()))
        print(f"[metrics] {kv}")
    for name, h in sorted(snap["histograms"].items()):
        hist = registry.histogram(name)
        if hist.count:
            print(f"[metrics] {name}: n={hist.count} "
                  f"p50<={hist.quantile(0.5):.3g}s "
                  f"p99<={hist.quantile(0.99):.3g}s")


def run_subseq(args):
    """Subsequence mode: index every window of an (n, T) long-series
    corpus, localize snippet queries exactly, compare against the
    brute-force windowed kernel scan."""
    import numpy as np

    from repro.core import make_technique
    from repro.data.synthetic import season_dataset
    from repro.obs import REGISTRY
    from repro.subseq import SubseqEngine, WindowView

    m, s = args.window, args.stride
    if m % args.L:
        raise SystemExit(f"--window {m} must be a multiple of --L {args.L}")
    if m > args.T:
        raise SystemExit(f"--window {m} longer than --T {args.T}")
    tech = make_technique(args.technique, T=m, W=m // args.L, L=args.L,
                          r2_season=args.strength)

    mesh = None
    if args.verify == "device":
        import jax
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((len(jax.devices()),), ("data",))
        print(f"[subseq] device-resident verification over "
              f"{len(jax.devices())} devices")

    rng = np.random.default_rng(7)
    D = season_dataset(args.n, args.T, args.L, args.strength,
                       per_series_strength=True, seed=7)
    q_rows = rng.integers(0, args.n, size=args.queries)
    offs = rng.integers(0, args.T - m + 1, size=args.queries)
    Q = np.stack([D[r, o:o + m] for r, o in zip(q_rows, offs)])
    Q = Q + 0.05 * rng.normal(size=Q.shape).astype(np.float32)

    t0 = time.perf_counter()
    view = WindowView(tech, D, stride=s, media=args.store)
    print(f"[subseq] {args.technique} over {args.n} x {args.T} "
          f"-> {view.n} windows (m={m}, stride={s}); "
          f"encode {time.perf_counter() - t0:.2f}s")
    engine = SubseqEngine(view, batch_size=args.batch, verify=args.verify,
                          mesh=mesh, metrics=REGISTRY)

    if args.index:
        t0 = time.perf_counter()
        view.build_index(leaf_fill=args.leaf_fill)
        print(f"[subseq] window index: {view.index.n_nodes} nodes over "
              f"{view.index.n} windows (leaf_fill {args.leaf_fill}) in "
              f"{time.perf_counter() - t0:.2f}s")

    view.reset()
    t0 = time.perf_counter()
    res = engine.topk(Q, k=args.k, exclusion=args.exclusion,
                      explain=args.explain)
    dt = time.perf_counter() - t0
    if args.explain:
        _explain(res.trace, device=args.verify == "device")
    t0 = time.perf_counter()
    scan = engine.scan_topk(Q, k=args.k, use_kernel=False)
    dt_scan = time.perf_counter() - t0
    hits = sum(int(res.window_ids[qi, 0] == scan.window_ids[qi, 0])
               for qi in range(args.queries))
    loc = sum(int(res.rows[qi, 0] == q_rows[qi]
                  and abs(res.starts[qi, 0] - offs[qi]) < m)
              for qi in range(args.queries))
    print(f"[subseq] exact k={args.k}"
          + (f" excl={args.exclusion}" if args.exclusion else "")
          + f": top-1 == scan {hits}/{args.queries}, snippet localized "
          f"{loc}/{args.queries}; windows/query "
          f"{res.raw_accesses.mean():.0f} "
          f"({1 - res.pruned_fraction.mean():.2%} of {view.n}); "
          f"rows read {res.store_accesses}/{view.n_rows}; modeled "
          f"{args.store} I/O {res.io_seconds * 1e3:.2f}ms vs scan "
          f"{scan.io_seconds * 1e3:.2f}ms "
          f"({scan.io_seconds / max(res.io_seconds, 1e-12):.1f}x); "
          f"wall {dt:.2f}s (scan {dt_scan:.2f}s)")

    if args.index:
        # cold-cache boundary: the indexed run above left its I/O counts
        # and a warm row buffer behind, which used to bleed into (and
        # under-report) the linear comparison below
        view.reset()
        lin = engine.topk(Q, k=args.k, exclusion=args.exclusion,
                          use_index=False, explain=args.explain)
        if args.explain:
            _explain(lin.trace, device=args.verify == "device")
        agree = int(np.array_equal(res.window_ids, lin.window_ids))
        print(f"[subseq] index vs linear sweep: bitwise identical "
              f"{'yes' if agree else 'NO'}; windows examined/query "
              f"{res.raw_accesses.mean():.0f} (indexed) vs "
              f"{lin.raw_accesses.mean():.0f} (linear) of {view.n}")

    # streaming: new long series are searchable immediately
    extra = season_dataset(2, args.T, args.L, args.strength, seed=8)
    t0 = time.perf_counter()
    view.append(extra)
    print(f"[subseq] append 2 rows (+{2 * view.windows_per_row} windows) "
          f"in {(time.perf_counter() - t0) * 1e3:.0f}ms; corpus "
          f"{view.n_rows} rows / {view.n} windows")
    o2 = min(100, args.T - m)
    res2 = engine.topk(extra[:1, o2:o2 + m], k=1)
    print(f"[subseq] query of appended row -> row {res2.rows[0, 0]} "
          f"start {res2.starts[0, 0]} d={res2.distances[0, 0]:.4f}")
    if args.explain:
        _print_metrics(REGISTRY)


def run_selfjoin(args):
    """Self-join mode: compute the corpus matrix profile exactly
    (``repro.profile.SelfJoinEngine``), report top-k motifs and
    discords, and check them bit-identical against the brute-force
    profile oracle.  A motif pair and a discord are planted into the
    synthetic corpus so the answer is visibly right."""
    import jax
    import numpy as np

    from repro.core import make_technique
    from repro.data.synthetic import season_dataset
    from repro.obs import REGISTRY
    from repro.profile import SelfJoinEngine, topk_discords, topk_motifs
    from repro.subseq import WindowView

    m, s = args.window, args.stride
    if m % args.L:
        raise SystemExit(f"--window {m} must be a multiple of --L {args.L}")
    if m > args.T:
        raise SystemExit(f"--window {m} longer than --T {args.T}")
    tech = make_technique(args.technique, T=m, W=m // args.L, L=args.L,
                          r2_season=args.strength)

    mesh = None
    if args.verify == "device":
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((len(jax.devices()),), ("data",))
        print(f"[selfjoin] device-resident verification over "
              f"{len(jax.devices())} devices")

    rng = np.random.default_rng(17)
    D = np.array(season_dataset(args.n, args.T, args.L, args.strength,
                                per_series_strength=True, seed=17))
    # plant a motif (one snippet duplicated across two rows) and a
    # discord (one burst unlike anything else) to make the self-join's
    # answer checkable by eye
    snippet = np.sin(np.linspace(0, 6 * np.pi, m)).astype(np.float32)
    o = (args.T - m) // 2
    D[0, o:o + m] = snippet + 0.01 * rng.normal(size=m)
    D[1, o:o + m] = snippet + 0.01 * rng.normal(size=m)
    D[2, o:o + m] += 6.0 * np.hanning(m).astype(np.float32)

    t0 = time.perf_counter()
    view = WindowView(tech, D, stride=s, media=args.store)
    print(f"[selfjoin] {args.technique} over {args.n} x {args.T} "
          f"-> {view.n} windows (m={m}, stride={s}); "
          f"encode {time.perf_counter() - t0:.2f}s")
    if args.index:
        view.build_index(leaf_fill=args.leaf_fill)
        print(f"[selfjoin] window index: {view.index.n_nodes} nodes")
    excl = args.exclusion if args.exclusion > 0 else None
    engine = SelfJoinEngine(view, batch_size=args.batch,
                            verify=args.verify, mesh=mesh,
                            exclusion=excl, metrics=REGISTRY)

    view.reset()
    t0 = time.perf_counter()
    prof = engine.profile(explain=args.explain)
    dt = time.perf_counter() - t0
    if args.explain:
        _explain(prof.trace, device=args.verify == "device")
    motifs = topk_motifs(prof, view.locate, args.k)
    discords = topk_discords(prof, view.locate, args.k)

    t0 = time.perf_counter()
    oracle = engine.scan_profile()
    dt_scan = time.perf_counter() - t0
    same = (np.array_equal(prof.distances, oracle.distances)
            and np.array_equal(prof.neighbors, oracle.neighbors))
    print(f"[selfjoin] profile over {prof.n} windows "
          f"(exclusion {prof.exclusion} samples, source {prof.source}): "
          f"bitwise == oracle {'yes' if same else 'NO'}; "
          f"windows verified/query {prof.raw_accesses.mean():.0f} "
          f"({1 - prof.pruned_fraction.mean():.2%} of {prof.n}); modeled "
          f"{args.store} I/O {prof.io_seconds * 1e3:.2f}ms vs scan "
          f"{oracle.io_seconds * 1e3:.2f}ms; wall {dt:.2f}s "
          f"(scan {dt_scan:.2f}s)")
    if not same:
        raise SystemExit("[selfjoin] profile diverged from the "
                         "brute-force oracle")
    rows, starts = view.locate(np.asarray([p[0] for p in motifs]))
    for i, (a, b, d) in enumerate(motifs):
        ra, sa = view.locate(np.asarray([a]))
        rb, sb = view.locate(np.asarray([b]))
        print(f"[selfjoin] motif {i + 1}: row {ra[0]}@{sa[0]} ~ "
              f"row {rb[0]}@{sb[0]} d={d:.4f}")
    for i, (w, d) in enumerate(discords):
        r, st = view.locate(np.asarray([w]))
        print(f"[selfjoin] discord {i + 1}: row {r[0]}@{st[0]} d={d:.4f}")
    if motifs:
        ra, _ = view.locate(np.asarray([motifs[0][0]]))
        rb, _ = view.locate(np.asarray([motifs[0][1]]))
        planted = {int(ra[0]), int(rb[0])} == {0, 1}
        print(f"[selfjoin] planted motif recovered: "
              f"{'yes' if planted else 'NO'}")
    if discords:
        r, _ = view.locate(np.asarray([discords[0][0]]))
        print(f"[selfjoin] planted discord recovered: "
              f"{'yes' if int(r[0]) == 2 else 'NO'}")
    if args.explain:
        _print_metrics(REGISTRY)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--T", type=int, default=960)
    ap.add_argument("--L", type=int, default=10)
    ap.add_argument("--strength", type=float, default=0.7)
    ap.add_argument("--technique", default="ssax",
                    choices=["sax", "ssax", "tsax", "stsax"])
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--batch", type=int, default=256,
                    help="verification batch per query per round")
    ap.add_argument("--store", default="ssd", choices=["hdd", "ssd", "hbm"])
    ap.add_argument("--verify", default="auto",
                    choices=["auto", "numpy", "kernel", "host", "device"],
                    help="raw verification path: 'device' shards the raw "
                    "rows across devices and verifies through the euclid "
                    "kernel without moving a row to the host; 'host' is "
                    "the bit-identical host fallback (store fetch + the "
                    "same kernel math, modeled-I/O oracle)")
    ap.add_argument("--ingest", type=int, default=0,
                    help="chunks to append while serving (ingest demo)")
    ap.add_argument("--ingest-rows", type=int, default=1024,
                    help="rows per ingest chunk")
    ap.add_argument("--snapshot-dir", default="",
                    help="persist the store (raw + rep) after the run")
    ap.add_argument("--index", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="build the split-tree index and serve "
                    "index-accelerated exact queries (--no-index: linear "
                    "sweep only)")
    ap.add_argument("--leaf-fill", type=int, default=64,
                    help="index leaf fill factor (split threshold)")
    ap.add_argument("--subseq", action="store_true",
                    help="subsequence matching over long series")
    ap.add_argument("--selfjoin", action="store_true",
                    help="matrix-profile self-join: exact per-window "
                    "nearest non-trivial neighbors, top-k motifs and "
                    "discords, checked bitwise against the brute-force "
                    "profile oracle")
    ap.add_argument("--window", type=int, default=240,
                    help="subsequence window length m (encoder T)")
    ap.add_argument("--stride", type=int, default=4,
                    help="window hop in samples")
    ap.add_argument("--exclusion", type=int, default=0,
                    help="non-overlap suppression distance (0: off)")
    ap.add_argument("--explain", action="store_true",
                    help="print a per-query plan (spans, candidates, "
                    "pruning, I/O, rounds) for every served path and "
                    "hard-fail if required spans are missing or a "
                    "device-path transfer invariant is violated")
    ap.add_argument("--dryrun", action="store_true",
                    help="shrink every dimension to a seconds-scale "
                    "smoke (the CI explain gate)")
    args = ap.parse_args()

    if args.dryrun:
        windowed = args.subseq or args.selfjoin
        args.n = min(args.n, 12 if windowed else 256)
        args.T = min(args.T, 480)
        args.queries = min(args.queries, 4)
        args.k = min(args.k, 8)
        args.batch = min(args.batch, 64)
        args.ingest = min(args.ingest, 1)
        if windowed:
            args.window = min(args.window, 240)
            args.stride = max(args.stride, 8)

    if args.selfjoin:
        args.k = min(args.k, 4)       # motif/discord count, not top-k
        return run_selfjoin(args)
    if args.subseq:
        return run_subseq(args)

    import jax
    import jax.numpy as jnp

    from repro.core.distributed import make_engine_service
    from repro.core.matching import pairwise_euclidean
    from repro.data.synthetic import season_dataset
    from repro.launch.mesh import make_mesh_compat
    from repro.obs import REGISTRY

    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    n = (args.n // n_dev) * n_dev
    n_ingest = args.ingest * args.ingest_rows
    X = season_dataset(n + args.queries + n_ingest, args.T, args.L,
                       args.strength, per_series_strength=True, seed=1)
    Q, D = X[:args.queries], X[args.queries:args.queries + n]
    ingest_pool = X[args.queries + n:]

    from repro.core import make_technique
    tech = make_technique(args.technique, T=args.T, W=48, L=args.L,
                          r2_season=args.strength)

    print(f"[match] {args.technique} over {n} x {args.T} "
          f"on {n_dev} devices (verify={args.verify})")
    t0 = time.perf_counter()
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=args.batch, media=args.store,
                                 verify=args.verify, metrics=REGISTRY)
    store = engine.store                 # SymbolicStore: raw + live rep
    jax.block_until_ready(engine.rep)
    print(f"[match] encode: {time.perf_counter() - t0:.2f}s")

    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    true_nn = np.argsort(ed, axis=1, kind="stable")

    # exact top-k through the pruned batched scan
    for k in (1, args.k):
        store.reset()
        t0 = time.perf_counter()
        res = engine.topk(Q, k=k, explain=args.explain)
        dt = time.perf_counter() - t0
        if args.explain:
            _explain(res.trace, device=args.verify == "device")
        hits = sum(int(np.array_equal(res.indices[qi],
                                      true_nn[qi, :k]))
                   for qi in range(args.queries))
        acc = res.raw_accesses.mean()
        print(f"[match] exact k={k}: {hits}/{args.queries} query frontiers "
              f"== brute force; raw rows/query {acc:.0f} "
              f"({acc / n:.2%} of dataset), {res.store_fetches} batched "
              f"fetches; modeled {args.store} I/O {res.io_seconds:.3f}s; "
              f"wall {dt:.2f}s")

    # index-accelerated exact top-k: the split tree generates a compact
    # candidate set instead of the linear sweep — bit-identical results
    if args.index:
        t0 = time.perf_counter()
        store.build_index(leaf_fill=args.leaf_fill)
        t_build = time.perf_counter() - t0
        store.reset()
        res_lin = engine.topk(Q, k=args.k)
        lin_acc = res_lin.raw_accesses.mean()
        store.reset()
        t0 = time.perf_counter()
        res_idx = engine.topk(Q, k=args.k, source="index",
                              explain=args.explain)
        dt = time.perf_counter() - t0
        if args.explain:
            _explain(res_idx.trace, device=args.verify == "device")
        agree = np.array_equal(res_idx.indices, res_lin.indices)
        print(f"[match] index: {store.index.n_nodes} nodes over "
              f"{store.index.n} rows (leaf_fill {args.leaf_fill}) in "
              f"{t_build:.2f}s; indexed k={args.k} bitwise==linear "
              f"{'yes' if agree else 'NO'}; candidates/query "
              f"{res_idx.raw_accesses.mean():.0f} (indexed) vs "
              f"{lin_acc:.0f} (linear) of {n}; wall {dt:.2f}s")

    # approximate top-k from the sharded candidate frontier
    store.reset()
    t0 = time.perf_counter()
    res = engine.topk(Q, k=args.k, exact=False, explain=args.explain)
    dt = time.perf_counter() - t0
    if args.explain:
        _explain(res.trace, device=args.verify == "device")
    hit1 = sum(int(res.indices[qi, 0] == true_nn[qi, 0])
               for qi in range(args.queries))
    print(f"[match] approx k={args.k}: 1-NN hit {hit1}/{args.queries}; "
          f"raw rows/query {res.raw_accesses.mean():.0f}; modeled "
          f"{args.store} I/O {res.io_seconds:.3f}s; wall {dt:.2f}s")

    # ingest-while-serving: append chunks, answer queries between them —
    # only the new chunk is encoded each round
    for c in range(args.ingest):
        chunk = ingest_pool[c * args.ingest_rows:(c + 1) * args.ingest_rows]
        t0 = time.perf_counter()
        engine.ingest(chunk)
        t_ing = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = engine.topk(Q, k=args.k, exact=False)
        t_q = time.perf_counter() - t0
        print(f"[match] ingest {c + 1}/{args.ingest}: +{chunk.shape[0]} "
              f"rows in {t_ing * 1e3:.0f}ms "
              f"({chunk.shape[0] / max(t_ing, 1e-9):.0f} rows/s), corpus "
              f"{store.n}; query k={args.k} under ingest {t_q * 1e3:.0f}ms")

    # the index was maintained incrementally through every ingest —
    # indexed queries stay exact with no rebuild
    if args.index and args.ingest:
        assert store.index is not None and store.index.n == store.n
        res_idx = engine.topk(Q, k=args.k, source="index")
        res_lin = engine.topk(Q, k=args.k)
        agree = np.array_equal(res_idx.indices, res_lin.indices)
        print(f"[match] index after {args.ingest} ingests: covers "
              f"{store.index.n} rows without rebuild; bitwise==linear "
              f"{'yes' if agree else 'NO'}")

    if args.snapshot_dir:
        t0 = time.perf_counter()
        path = store.save(args.snapshot_dir)
        print(f"[match] snapshot: {store.n} rows + rep -> {path} "
              f"({time.perf_counter() - t0:.2f}s)")

    if args.explain:
        _print_metrics(REGISTRY)


if __name__ == "__main__":
    main()
