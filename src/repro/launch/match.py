"""Matching-service launcher: build a sharded sSAX (or SAX/tSAX/stSAX)
representation of a dataset and serve exact/approximate matches.

    PYTHONPATH=src python -m repro.launch.match \
        --n 40000 --strength 0.7 --technique ssax --queries 8

Device count is taken from the environment (set XLA_FLAGS
--xla_force_host_platform_device_count=8 for a local fleet simulation);
the same code drives the production ("pod","data") mesh axes.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--T", type=int, default=960)
    ap.add_argument("--L", type=int, default=10)
    ap.add_argument("--strength", type=float, default=0.7)
    ap.add_argument("--technique", default="ssax",
                    choices=["sax", "ssax", "tsax", "stsax"])
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--store", default="ssd", choices=["hdd", "ssd", "hbm"])
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from jax.sharding import AxisType

    from repro.core import SAX, SSAX, STSAX, TSAX
    from repro.core.distributed import encode_sharded, repr_topk_sharded
    from repro.core.matching import RawStore, pairwise_euclidean
    from repro.data.synthetic import season_dataset

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",),
                         axis_types=(AxisType.Auto,))
    n = (args.n // n_dev) * n_dev
    X = season_dataset(n + args.queries, args.T, args.L, args.strength,
                       per_series_strength=True, seed=1)
    Q, D = X[:args.queries], X[args.queries:]

    tech = {
        "sax": lambda: SAX(T=args.T, W=48, A=64),
        "ssax": lambda: SSAX(T=args.T, W=48, L=args.L, A_seas=16, A_res=32,
                             r2_season=args.strength),
        "tsax": lambda: TSAX(T=args.T, W=48, A_tr=64, A_res=32,
                             r2_trend=args.strength),
        "stsax": lambda: STSAX(T=args.T, W=48, L=args.L, A_tr=16,
                               A_seas=16, A_res=32,
                               r2_trend=0.2, r2_season=args.strength),
    }[args.technique]()

    print(f"[match] {args.technique} over {n} x {args.T} "
          f"on {n_dev} devices")
    t0 = time.perf_counter()
    rep = encode_sharded(tech, jnp.asarray(D), mesh)
    jax.block_until_ready(rep)
    print(f"[match] encode: {time.perf_counter() - t0:.2f}s")

    rep_q = tech.encode(jnp.asarray(Q))
    t0 = time.perf_counter()
    dists, idx = repr_topk_sharded(tech, rep_q, rep, mesh, k=args.k)
    jax.block_until_ready(dists)
    print(f"[match] sweep+merge: {time.perf_counter() - t0:.2f}s "
          f"({args.queries} queries)")

    store = {"hdd": RawStore.hdd, "ssd": RawStore.ssd,
             "hbm": RawStore.hbm}[args.store](D)
    ed = np.asarray(pairwise_euclidean(jnp.asarray(Q), jnp.asarray(D)))
    hits = 0
    for qi in range(args.queries):
        cand = np.asarray(idx[qi])
        rows = store.fetch(cand)
        d = np.sqrt(np.sum((rows - Q[qi][None]) ** 2, -1))
        hits += int(cand[int(np.argmin(d))] == int(np.argmin(ed[qi])))
    io = store.modeled_io_seconds()
    print(f"[match] exact hits: {hits}/{args.queries}; raw reads "
          f"{store.accesses} ({store.accesses / n / args.queries:.2%} of "
          f"dataset/query); modeled {args.store} I/O {io:.3f}s")


if __name__ == "__main__":
    main()
