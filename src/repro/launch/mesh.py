"""Production meshes.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.  Single pod: 16x16 = 256 chips
("data", "model").  Multi-pod: 2x16x16 = 512 chips ("pod", "data",
"model") — the leading axis is the cross-pod (DCN) data-parallel axis.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_devices: int | None = None, *, model: int = 2):
    """Small mesh over however many (fake) devices are available."""
    n = n_devices or len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
