"""Production meshes.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.  Single pod: 16x16 = 256 chips
("data", "model").  Multi-pod: 2x16x16 = 512 chips ("pod", "data",
"model") — the leading axis is the cross-pod (DCN) data-parallel axis.

``jax.sharding.AxisType`` landed after jax 0.4; on older runtimes every
mesh axis is Auto-typed already, so ``make_mesh_compat`` simply omits the
argument there instead of crashing at import.
"""

from __future__ import annotations

import jax

try:                                  # jax >= 0.5
    from jax.sharding import AxisType

    def _axis_types(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:                   # jax 0.4: Auto is the only behavior
    def _axis_types(n: int) -> dict:
        return {}


def make_mesh_compat(shape, axes):
    """jax.make_mesh with Auto axis types on any supported jax version."""
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, model: int = 2):
    """Small mesh over however many (fake) devices are available."""
    n = n_devices or len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh_compat((n // model, model), ("data", "model"))
