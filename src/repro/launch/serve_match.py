"""Always-on matching service launcher.

    PYTHONPATH=src python -m repro.launch.serve_match \
        --n 40000 --technique ssax --clients 32 --k 8 --window-ms 2

Builds the sharded device-resident matching engine
(``core.distributed.make_engine_service``) with its split-tree index,
wraps it in a :class:`repro.service.MatchSession` — the coalescing
queue front-end plus the telemetry-driven query planner — and drives
it with ``--clients`` concurrent threads submitting single-query
requests.  The run demonstrates the service contract end to end:

* coalescing: waiting requests batch into one (Q, T) engine dispatch;
  the run reports requests-per-dispatch and the latency/QPS effect.
* exactness: planner-routed exact answers are checked bit-identical
  to a direct ``engine.topk`` oracle for every request.
* deadlines: a second wave runs under a tight per-request budget —
  deadline-threatened requests downgrade to the anytime tier and come
  back with an error bar instead of being shed.
* ``--explain`` renders the per-dispatch plan trace
  (``repro.obs.render_trace``) for the first request of each tier and
  validates it (device invariants included under ``--verify device``).
* ``--replicas N`` serves through N engine replicas over the ONE
  shared store (per-replica dispatch workers, planner-EWMA placement);
  ``--ingest-while-serving`` runs a writer thread appending rows
  throughout wave 1 — every request is pinned to its admission-time
  corpus epoch and the oracle check compares against a store truncated
  there, so exactness holds mid-ingest.

``--dryrun`` shrinks everything to a seconds-scale smoke (the CI
path).
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) \
        else float("nan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--T", type=int, default=960)
    ap.add_argument("--L", type=int, default=10)
    ap.add_argument("--strength", type=float, default=0.7)
    ap.add_argument("--technique", default="ssax",
                    choices=["sax", "ssax", "tsax", "stsax"])
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--clients", type=int, default=32,
                    help="concurrent client threads")
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per client per wave")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="coalescing window")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--deadline-ms", type=float, default=5.0,
                    help="per-request budget for the deadline wave")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--store", default="ssd",
                    choices=["hdd", "ssd", "hbm"])
    ap.add_argument("--verify", default="auto",
                    choices=["auto", "numpy", "kernel", "host", "device"])
    ap.add_argument("--leaf-fill", type=int, default=64)
    ap.add_argument("--explain", action="store_true",
                    help="render + validate one dispatch trace per tier")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas over the shared store")
    ap.add_argument("--ingest-while-serving", action="store_true",
                    help="append rows concurrently with wave 1; "
                         "answers stay exact at their pinned epochs")
    ap.add_argument("--dryrun", action="store_true",
                    help="seconds-scale smoke (the CI path)")
    args = ap.parse_args()

    if args.dryrun:
        args.n = min(args.n, 256)
        args.T = min(args.T, 480)
        args.clients = min(args.clients, 8)
        args.requests = min(args.requests, 2)
        args.k = min(args.k, 4)
        args.batch = min(args.batch, 64)
        args.leaf_fill = min(args.leaf_fill, 16)

    import jax
    import jax.numpy as jnp

    from repro.core import make_technique
    from repro.core.distributed import make_engine_service
    from repro.data.synthetic import season_dataset
    from repro.launch.mesh import make_mesh_compat
    from repro.obs import REGISTRY
    from repro.service import MatchSession

    n_dev = len(jax.devices())
    mesh = make_mesh_compat((n_dev,), ("data",))
    n = max((args.n // n_dev) * n_dev, n_dev)
    n_q = args.clients * args.requests
    n_ingest = (max(n // 8, n_dev) // n_dev) * n_dev \
        if args.ingest_while_serving else 0
    X = season_dataset(n + n_q + n_ingest, args.T, args.L,
                       args.strength, per_series_strength=True, seed=11)
    Q, D = X[:n_q], X[n_q:n_q + n]
    D_ingest = X[n_q + n:]
    tech = make_technique(args.technique, T=args.T, W=48, L=args.L,
                          r2_season=args.strength)

    print(f"[serve] {args.technique} over {n} x {args.T} on {n_dev} "
          f"devices (verify={args.verify})")
    t0 = time.perf_counter()
    engine = make_engine_service(tech, jnp.asarray(D), mesh,
                                 batch_size=args.batch, media=args.store,
                                 verify=args.verify, metrics=REGISTRY)
    engine.store.build_index(leaf_fill=args.leaf_fill)
    jax.block_until_ready(engine.rep)
    # replicas share the ONE store (dataset=None adopts it); each keeps
    # its own device mirrors, synced independently by store version
    replicas = [make_engine_service(tech, None, mesh,
                                    store=engine.store,
                                    batch_size=args.batch,
                                    media=args.store,
                                    verify=args.verify)
                for _ in range(max(args.replicas, 1) - 1)]
    print(f"[serve] engine + index ready in "
          f"{time.perf_counter() - t0:.2f}s"
          + (f" ({args.replicas} replicas)" if replicas else ""))

    session = MatchSession(engine, replicas=replicas, metrics=REGISTRY,
                           window_s=args.window_ms * 1e-3,
                           max_batch=args.max_batch,
                           max_queue=max(4 * n_q, 256)).start()
    cal = session.calibrate(Q[:1], k=args.k)
    print("[serve] planner calibration: "
          + ", ".join(f"{t} {e['wall_s'] * 1e3:.1f}ms" for t, e in
                      cal.items()))

    # -- wave 1: concurrent exact serving + bit-identity oracle ----------
    # (with --ingest-while-serving a writer appends rows throughout;
    # requests stay exact at their admission-pinned corpus epochs)
    results = [None] * n_q
    writer_stop = threading.Event()

    def client(cid):
        for j in range(args.requests):
            i = cid * args.requests + j
            req = session.submit(Q[i], k=args.k,
                                 explain=args.explain and i == 0)
            req.wait(120)
            results[i] = req

    def writer():
        chunk = max(n_dev, len(D_ingest) // 16)
        for lo in range(0, len(D_ingest), chunk):
            if writer_stop.is_set():
                break
            engine.ingest(D_ingest[lo:lo + chunk])
            time.sleep(0.001)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,))
               for c in range(args.clients)]
    wt = None
    if args.ingest_while_serving:
        wt = threading.Thread(target=writer)
        wt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if wt is not None:
        writer_stop.set()
        wt.join()
    wall = time.perf_counter() - t0

    ok = [r for r in results if r is not None and r.ok]
    lat = [r.latency_s for r in ok]
    snap = REGISTRY.snapshot()["counters"]
    batches = snap.get("serve.batches", 0)
    batched = snap.get("serve.batched_requests", 0)
    tiers = {}
    for r in ok:
        tiers[r.tier_served] = tiers.get(r.tier_served, 0) + 1
    print(f"[serve] wave 1: {len(ok)}/{n_q} served in {wall:.2f}s "
          f"({len(ok) / max(wall, 1e-9):.0f} QPS); p50 "
          f"{_percentile(lat, 50) * 1e3:.1f}ms p99 "
          f"{_percentile(lat, 99) * 1e3:.1f}ms; "
          f"{batched / max(batches, 1):.1f} requests/dispatch; "
          f"tiers {tiers}")
    if args.ingest_while_serving:
        epochs = sorted({r.epoch.n_rows for r in ok
                         if r.epoch is not None})
        print(f"[serve] ingested to {engine.store.n} rows during "
              f"wave 1; answers pinned across {len(epochs)} epochs "
              f"({epochs[0] if epochs else 0}.."
              f"{epochs[-1] if epochs else 0} rows)")
    if args.replicas > 1:
        by_rep = {}
        for r in ok:
            by_rep[r.replica] = by_rep.get(r.replica, 0) + 1
        print(f"[serve] replica placement: {by_rep}")

    mism = 0
    for r in ok:
        if r.tier_served == "approx":
            continue
        # the oracle answers at the request's PINNED epoch — under
        # --ingest-while-serving the live corpus has moved on, and
        # bit-identity is defined against the admission frontier
        oracle = engine.topk(
            r.query[None], k=r.k,
            source="index" if r.tier_served == "index" else None,
            epoch=r.epoch)
        if not (np.array_equal(r.indices, oracle.indices[0])
                and np.array_equal(r.distances, oracle.distances[0])):
            mism += 1
    exact_n = sum(1 for r in ok if r.tier_served != "approx")
    print(f"[serve] exact-tier bit-identity vs direct topk: "
          f"{exact_n - mism}/{exact_n}")
    if mism:
        raise SystemExit("[serve] exact-tier answers diverged from the "
                         "direct engine oracle")

    if args.explain and results[0] is not None \
            and results[0].trace is not None:
        from repro.launch.match import _explain
        _explain(results[0].trace, device=args.verify == "device")

    # -- wave 2: tight deadlines -> anytime downgrade + error bars -------
    reqs = session.serve(Q[:args.clients], k=args.k,
                         deadline_s=args.deadline_ms * 1e-3,
                         timeout=120.0)
    served = [r for r in reqs if r.ok]
    down = [r for r in served if r.plan is not None and r.plan.downgraded]
    bars = [r.error_bar for r in served if r.error_bar is not None]
    shed = [r for r in reqs if not r.ok]
    print(f"[serve] wave 2 (deadline {args.deadline_ms:.1f}ms): "
          f"{len(served)}/{len(reqs)} served, {len(down)} downgraded to "
          f"approx, {len(shed)} shed; error bar mean "
          f"{np.mean(bars) if bars else 0.0:.4f} "
          f"({sum(1 for b in bars if b == 0)}/{len(bars)} provably exact)")

    session.close()
    from repro.launch.match import _print_metrics
    _print_metrics(REGISTRY)
    print("[serve] planner estimates: "
          + ", ".join(f"{t} {e['wall_s'] * 1e3:.1f}ms (n={e['n_obs']})"
                      for t, e in session.planner.snapshot().items()))


if __name__ == "__main__":
    main()
