"""Serving launcher: ``python -m repro.launch.serve --arch qwen3-0.6b
--requests 16``.

Reduced-scale on this container; the identical engine + decode_step is
what the dry-run lowers for the production mesh serve cells (and the
§Perf OPTIMIZED_SERVE sharding is the deployable configuration).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models.transformer import RunConfig
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--metrics-out", default="",
                    help="write the repro.obs registry snapshot (request/"
                    "token counters + latency histogram) as JSON")
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(
        reduced(get_config(args.arch), d_model=args.d_model,
                n_heads=4, head_dim=args.d_model // 4,
                d_ff=3 * args.d_model),
        compute_dtype="float32")
    rc = RunConfig(q_chunk=32, kv_chunk=32, loss_chunk=32)
    model = build_model(cfg, rc=rc)
    params = model.init(jax.random.PRNGKey(0))
    tot, _ = cfg.param_counts()
    print(f"[serve] {cfg.name}: {tot/1e6:.1f}M params, "
          f"{args.slots} slots, max_len {args.max_len}")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size,
                        int(rng.integers(4, 24))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    from repro.obs import REGISTRY
    eng = ServeEngine(model, params, n_slots=args.slots,
                      max_len=args.max_len, metrics=REGISTRY)
    t0 = time.perf_counter()
    done = eng.run(list(reqs))
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} tokens, {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s CPU)")

    snap = REGISTRY.snapshot()
    kv = ", ".join(f"{k}={v:g}" for k, v in
                   sorted(snap["counters"].items()))
    print(f"[serve] metrics: {kv}")
    lat = REGISTRY.histogram("serve.request_latency_s")
    if lat.count:
        print(f"[serve] request latency: n={lat.count} "
              f"p50<={lat.quantile(0.5):.3g}s "
              f"p99<={lat.quantile(0.99):.3g}s")
    if args.metrics_out:
        import json
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[serve] metrics snapshot -> {args.metrics_out}")


if __name__ == "__main__":
    main()
