import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, record memory / cost / collective terms.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the 512 placeholder host devices exist only inside this
entry point (tests and benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --multi-pod both --out results/dryrun.json
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, SHAPES, get_config, shape_for
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.inputs import (
    train_batch_specs, decode_specs, to_named)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import (
    RunConfig, abstract_params, param_pspecs, lm_loss, decode_step, prefill,
)
from repro.optim.adamw import AdamWConfig
from repro.sharding.specs import ShardingRules
from repro.train.state import abstract_train_state, train_state_pspecs
from repro.train.step import make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Total bytes of all array shapes inside an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte totals parsed from post-SPMD HLO.

    Volume per op = max(result bytes, operand bytes) — covers both
    all-gather (result larger) and reduce-scatter (operand larger).
    ``*-start`` ops are counted; their ``*-done`` twins are skipped.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        result_type, opname = m.groups()
        base = opname.removesuffix("-start")
        if base not in _COLLECTIVES or opname.endswith("-done"):
            continue
        args = line[m.end() - 1:]
        vol = max(_type_bytes(result_type), _type_bytes(args))
        out[base] += vol
        out["count"] += 1
    return out


def _mem_dict(ma) -> dict:
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
    }


def choose_microbatch(global_batch: int, dp_total: int, target_mb: int) -> int:
    """Largest accumulation factor <= target that keeps every microbatch
    divisible by the data-parallel degree."""
    for m in sorted({target_mb, 16, 8, 4, 2, 1}, reverse=True):
        if m <= target_mb and global_batch % m == 0 \
                and (global_batch // m) % dp_total == 0:
            return m
    return 1


def run_config_for(cfg: ModelConfig, shape: ShapeSpec, dp_total: int,
                   overrides=None) -> RunConfig:
    """Per-cell execution knobs (microbatching keyed to model size)."""
    big = cfg.d_model >= 5000 or cfg.param_counts()[0] > 2e10
    target = 16 if big else (8 if cfg.d_model >= 2048 else 4)
    mb = choose_microbatch(shape.global_batch, dp_total, target) \
        if shape.mode == "train" else 0
    kw = dict(microbatch=mb, remat=True)
    if overrides:
        kw.update(overrides)
    return RunConfig(**kw)


def dryrun_cell(arch: str, shape_name: str, *, multi_pod,
                rc_overrides=None,
                rules_overrides=None,
                opt_cfg=None,
                serve_params_dtype=None,
                train_lowmem: bool = False,
                variant: str = "baseline") -> dict:
    """Lower + compile one cell; returns the roofline record."""
    cfg = get_config(arch)
    shape = shape_for(cfg, shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip(full-attn)",
                "note": "long_500k skipped: pure full-attention arch "
                        "(DESIGN.md §Arch-applicability)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    dp_total = mesh.shape["data"] * mesh.shape.get("pod", 1)
    # batch-1 long decode: shard the KV cache sequence instead of batch
    seq_sharded = (shape.mode == "decode"
                   and shape.global_batch % dp_total != 0)
    rules = ShardingRules.for_mesh(mesh, seq_sharded=seq_sharded)
    if rules_overrides:
        rules = rules.with_overrides(**rules_overrides)
    rc = run_config_for(cfg, shape, dp_total, rc_overrides)

    t0 = time.time()
    if shape.mode == "train":
        if train_lowmem:       # bf16 adam moments + bf16 master weights
            state_sds = abstract_train_state(
                cfg, opt_dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
        else:
            state_sds = abstract_train_state(cfg)
        state_ps = train_state_pspecs(cfg, rules)
        batch_sds, batch_ps = train_batch_specs(cfg, shape, rules)
        step = make_train_step(cfg, rules, rc, opt_cfg or AdamWConfig())
        jitted = jax.jit(
            step,
            in_shardings=(to_named(rules, state_ps),
                          to_named(rules, batch_ps)),
            donate_argnums=(0,))
        lowered = jitted.lower(state_sds, batch_sds)
    elif shape.mode == "prefill":
        params_sds = abstract_params(cfg, serve_params_dtype)
        params_ps = param_pspecs(cfg, rules)
        batch_sds, batch_ps = train_batch_specs(cfg, shape, rules,
                                                with_labels=False)

        def prefill_step(params, batch):
            return prefill(params, cfg, rules, batch["tokens"], rc=rc,
                           prefix_embed=batch.get("prefix_embed"),
                           encoder_frames=batch.get("encoder_frames"))

        jitted = jax.jit(
            prefill_step,
            in_shardings=(to_named(rules, params_ps),
                          to_named(rules, batch_ps)))
        lowered = jitted.lower(params_sds, batch_sds)
    else:                                   # decode
        params_sds = abstract_params(cfg, serve_params_dtype)
        params_ps = param_pspecs(cfg, rules)
        (cache_sds, token_sds), (cache_ps, token_ps) = \
            decode_specs(cfg, shape, rules)

        def serve_step(params, cache, token):
            return decode_step(params, cfg, rules, cache, token, rc=rc)

        jitted = jax.jit(
            serve_step,
            in_shardings=(to_named(rules, params_ps),
                          to_named(rules, cache_ps),
                          to_named(rules, token_ps)),
            donate_argnums=(1,))
        lowered = jitted.lower(params_sds, cache_sds, token_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax 0.4 returns [dict]
        cost = cost[0] if cost else {}
    mem = _mem_dict(compiled.memory_analysis())
    colls = collective_bytes(compiled.as_text())
    tot, act = cfg.param_counts()
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "variant": variant,
        "rc": {"microbatch": rc.microbatch, "causal_skip": rc.causal_skip,
               "remat_policy": rc.remat_policy},
        "serve_dtype": serve_params_dtype or "float32",
        "status": "ok", "n_chips": n_chips,
        "mode": shape.mode, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "microbatch": rc.microbatch,
        "params_total": tot, "params_active": act,
        "seq_sharded": seq_sharded,
        # cost_analysis is PER-DEVICE, post-SPMD; scans count ONE trip
        # (see EXPERIMENTS.md §Roofline methodology + analytical correction)
        "hlo_flops_per_dev": float(cost.get("flops", -1.0)),
        "hlo_bytes_accessed_per_dev": float(cost.get("bytes accessed", -1.0)),
        "memory": mem,
        "collectives": colls,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return rec


#: the §Perf-winning serving configuration (EXPERIMENTS.md): TP-only
#: params (no FSDP at inference), sequence-sharded decode caches, bf16
#: weight streams, causal block skipping, group-local MoE dispatch.
OPTIMIZED_SERVE = dict(
    rules_overrides={"d": (), "cache_seq": ("model",), "hd": (),
                     "kvheads": (), "moe_groups": 16},
    serve_params_dtype="bfloat16",
    rc_overrides={"causal_skip": True, "q_chunk": 2048},
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--serve-optimized", action="store_true",
                    help="apply the §Perf serving configuration to "
                         "prefill/decode cells (baseline runs without)")
    args = ap.parse_args()

    archs = list(ARCHITECTURES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    records = []
    if args.append and os.path.exists(args.out):
        records = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in records}

    for arch in archs:
        for shape in shapes:
            for mp in pods:
                if (arch, shape, mp) in done:
                    continue
                tag = f"{arch} x {shape} x {'2pod' if mp else '1pod'}"
                try:
                    kw = {}
                    if args.serve_optimized and \
                            SHAPES[shape].mode != "train":
                        kw = dict(OPTIMIZED_SERVE,
                                  variant="serve_optimized")
                    rec = dryrun_cell(arch, shape, multi_pod=mp, **kw)
                    if rec["status"] == "ok":
                        print(f"[ok] {tag}: flops/dev={rec['hlo_flops_per_dev']:.3e} "
                              f"coll={sum(rec['collectives'][k] for k in _COLLECTIVES)/1e6:.1f}MB "
                              f"compile={rec['compile_s']}s", flush=True)
                    else:
                        print(f"[skip] {tag}: {rec['status']}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[ERR] {tag}: {type(e).__name__}: {e}", flush=True)
                records.append(rec)
                json.dump(records, open(args.out, "w"), indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"].startswith("skip"))
    er = sum(1 for r in records if r["status"] == "error")
    print(f"dry-run complete: {ok} ok, {sk} documented skips, {er} errors")


if __name__ == "__main__":
    main()
