"""Train state pytree + abstract/sharded constructors for the dry-run."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import abstract_params, param_pspecs, init_params
from repro.optim.adamw import adamw_init


def init_train_state(cfg, key, *, opt_dtype=jnp.float32):
    params = init_params(cfg, key)
    opt = adamw_init(params, dtype=opt_dtype)
    return {"params": params, "opt": opt,
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg, *, opt_dtype=jnp.float32, param_dtype=None):
    """``opt_dtype``/``param_dtype`` support the low-memory training
    configuration (bf16 adam moments + bf16 master weights) that lets the
    398B jamba train state fit a single 256-chip pod — see
    EXPERIMENTS.md §Dry-run."""
    p = abstract_params(cfg, param_dtype)
    od = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(opt_dtype))
    return {"params": p,
            "opt": {"m": jax.tree.map(od, p), "v": jax.tree.map(od, p)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_pspecs(cfg, rules):
    from jax.sharding import PartitionSpec as P
    ps = param_pspecs(cfg, rules)
    return {"params": ps, "opt": {"m": ps, "v": ps}, "step": P()}


TrainState = dict     # structural alias: {"params", "opt", "step"}
