"""Train-step factory: loss -> grads (with microbatch accumulation) ->
AdamW update.

Gradient accumulation is a ``lax.scan`` over microbatches with a donated
f32 gradient carry; inside the scan each microbatch's backward runs under
the model's remat policy.  This shape (scan + reduce-scatterable carry) is
what lets the XLA latency-hiding scheduler overlap gradient collectives
with the next microbatch's compute on real hardware.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.transformer import lm_loss, RunConfig
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import compress_grads
from repro.sharding.specs import constrain

F32 = jnp.float32


def make_train_step(cfg, rules, rc: RunConfig, opt_cfg: AdamWConfig, *,
                    schedule=None, compression: Optional[str] = None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        return lm_loss(params, cfg, rules, mb, rc)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def grads_of(params, batch):
        m = rc.microbatch
        if not m or m <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            assert b % m == 0, (b, m)
            x = x.reshape(m, b // m, *x.shape[1:])
            # keep the *microbatch* batch dim data-sharded after the reshape
            return constrain(x, rules,
                             ("vec", "batch") + ("vec",) * (x.ndim - 2))

        mbs = jax.tree.map(split, batch)

        def acc_step(carry, mb):
            loss_acc, grads_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(F32) / m, grads_acc, grads)
            return (loss_acc + loss / m, grads_acc), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        (loss, grads), metrics = lax.scan(
            acc_step, (jnp.zeros((), F32), zeros), mbs)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        if compression:
            grads, cmetrics = compress_grads(grads, method=compression)
            metrics = {**metrics, **cmetrics}
        lr_scale = schedule(state["step"]) if schedule is not None else 1.0
        params, opt, om = adamw_update(
            opt_cfg, state["params"], grads, state["opt"], state["step"],
            lr_scale=lr_scale)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = {"loss": loss, **metrics, **om}
        return new_state, metrics

    return train_step
