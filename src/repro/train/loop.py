"""Fault-tolerant training loop.

Production behaviours implemented (and covered by tests):

* checkpoint/restart — cadence saves via ``Checkpointer``; any step failure
  triggers restore-from-LATEST and replay (idempotent because the data
  pipeline is step-indexed, not stateful);
* failure injection — ``FailureInjector`` raises simulated device losses
  so the restart path is exercised deterministically in CI;
* straggler mitigation — a step deadline (measured against a rolling
  median) marks slow steps; after ``patience`` consecutive stragglers the
  loop re-checkpoints and (on real fleets) would request re-scheduling —
  here it records the event and continues, which keeps the policy
  testable;
* crash-only design — the loop never needs clean shutdown; LATEST is
  always consistent (ckpt.py's atomic rename).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer


class SimulatedDeviceLoss(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministically fail specific steps (once each)."""

    fail_at: tuple = ()
    _fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise SimulatedDeviceLoss(f"injected failure at step {step}")


@dataclass
class StragglerPolicy:
    """Deadline-based straggler detection on step wall time."""

    slack: float = 3.0            # step is a straggler at slack x median
    patience: int = 3
    window: int = 32
    _times: list = field(default_factory=list)
    _consecutive: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True when mitigation should fire."""
        self._times.append(dt)
        self._times = self._times[-self.window:]
        if len(self._times) < 8:
            return False
        med = float(np.median(self._times[:-1]))
        if dt > self.slack * med:
            self._consecutive += 1
            self.events.append({"step": step, "dt": dt, "median": med})
        else:
            self._consecutive = 0
        if self._consecutive >= self.patience:
            self._consecutive = 0
            return True
        return False


def train_loop(*, init_state_fn: Callable, train_step: Callable,
               batch_fn: Callable, n_steps: int,
               checkpointer: Optional[Checkpointer] = None,
               failure_injector: Optional[FailureInjector] = None,
               straggler: Optional[StragglerPolicy] = None,
               state_shardings=None,
               max_restarts: int = 8,
               log_every: int = 10,
               metrics_cb: Optional[Callable] = None):
    """Run ``n_steps``, surviving injected failures.  Returns (state,
    history dict)."""
    restarts = 0
    history = {"loss": [], "restarts": 0, "straggler_events": 0,
               "checkpoints": 0}

    def boot():
        if checkpointer is not None:
            state, step = checkpointer.restore_or_init(
                init_state_fn, shardings=state_shardings)
            return state, int(step)
        return init_state_fn(), 0

    state, start = boot()
    step = start
    while step < n_steps:
        try:
            batch = batch_fn(step)
            t0 = time.perf_counter()
            if failure_injector is not None:
                failure_injector.check(step)
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            loss = float(metrics["loss"])
            history["loss"].append(loss)
            if metrics_cb:
                metrics_cb(step, metrics, dt)
            if log_every and step % log_every == 0:
                print(f"step {step:6d} loss {loss:.4f} {dt*1e3:.1f} ms",
                      flush=True)
            if straggler is not None and straggler.observe(step, dt):
                history["straggler_events"] += 1
                if checkpointer is not None:
                    checkpointer.maybe_save(step + 1, state, force=True)
                    history["checkpoints"] += 1
            step += 1
            if checkpointer is not None:
                if checkpointer.maybe_save(step, state):
                    history["checkpoints"] += 1
        except SimulatedDeviceLoss as e:
            restarts += 1
            history["restarts"] = restarts
            if restarts > max_restarts:
                raise RuntimeError("restart budget exhausted") from e
            print(f"[ft] {e} -> restoring from last checkpoint", flush=True)
            state, step = boot()
    if checkpointer is not None:
        checkpointer.maybe_save(step, state, force=True)
        history["checkpoints"] += 1
    return state, history
