"""MASS-style FFT sliding dot product + windowed distance expansion.

``kernels/windowed_euclid.py`` computes the sliding dot product of a
z-normalized query against every corpus window in ``m`` accumulation
steps inside the Pallas kernel — O(T * m) per row.  This module is the
other half of MASS (Mueen et al.): the same dot products through one
rfft/irfft convolution — O(T log T) per row, independent of ``m`` —
which is what makes matrix-profile self-joins tractable at m >= 1k.
The FFT runs in plain ``jnp.fft`` OUTSIDE Pallas (Pallas provides no
FFT primitive; XLA's native FFT is already fused and batched), and the
dot products feed the SAME rolling-statistics distance expansion as the
kernel (one cumulative sum -> window sum / sum-of-squares, the
``EPS``-clamped sigma of ``repro.core.normalize.znormalize``, the
zero-variance guard, the final clamp at 0) — only the dot-product
computation differs between the two paths.

Tolerance contract (documented, property-tested)
------------------------------------------------
The FFT path is NOT bitwise-identical to the m-step accumulation: an
f32 length-``nfft`` transform reorders the reduction and carries
rounding of order ``eps * log(nfft)`` relative to the operand scale.
Against the oracles (``kernels.ref.windowed_euclid_ref`` and the
accumulation kernel), squared distances agree within

    allclose(rtol=FFT_RTOL, atol=FFT_ATOL_PER_M * m)

(:func:`fft_tolerance`) — absolute tolerance scales with ``m`` because
z-normalized squared distances live in [0, ~4m].  Exact top-k
verification therefore NEVER consumes FFT distances: the engines'
verify paths stay on the bitwise f32 kernel/host reduction
(``core.engine``), and the FFT path serves the profile sweep and the
crossover benchmark (``benchmarks/bench_selfjoin.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.windowed_euclid import EPS, n_windows

#: Documented agreement of the FFT distance path vs the m-step
#: accumulation oracles (see module docstring).
FFT_RTOL = 1e-3
FFT_ATOL_PER_M = 1e-4


def fft_tolerance(m: int) -> dict:
    """``np.allclose`` kwargs of the documented FFT-vs-accumulation
    contract for window length ``m``."""
    return dict(rtol=FFT_RTOL, atol=FFT_ATOL_PER_M * float(m))


def _next_pow2(n: int) -> int:
    return 1 << (int(n) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("stride",))
def sliding_dot_fft(x, q, stride: int = 1):
    """(N, T) rows x (Q, m) queries -> (Q, N, S) sliding dot products
    ``dot[qi, n, s] = sum_i x[n, s*stride + i] * q[qi, i]`` via one
    rfft/irfft linear correlation per (query, row) pair."""
    x = jnp.asarray(x, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    N, T = x.shape
    Q, m = q.shape
    S = n_windows(T, m, stride)
    # linear (non-circular) correlation needs T + m - 1 samples; the
    # next power of two keeps the transform on XLA's fast path
    nfft = _next_pow2(T + m - 1)
    fx = jnp.fft.rfft(x, n=nfft, axis=-1)              # (N, F)
    fq = jnp.fft.rfft(q[:, ::-1], n=nfft, axis=-1)     # (Q, F)
    conv = jnp.fft.irfft(fq[:, None, :] * fx[None, :, :], n=nfft,
                         axis=-1)                      # (Q, N, nfft)
    # full convolution with the reversed query: the correlation at
    # window start s sits at output position m - 1 + s
    starts = m - 1 + jnp.arange(S) * stride
    return conv[..., starts]


@functools.partial(jax.jit, static_argnames=("stride",))
def sliding_dot_accum(x, q, stride: int = 1):
    """The m-step accumulation twin of :func:`sliding_dot_fft` — the
    windowed kernel's inner loop as plain XLA (O(T * m) per row), the
    fair off-TPU baseline for the FFT crossover benchmark (the Pallas
    kernel itself runs in interpret mode off-TPU, which benchmarks the
    interpreter, not the algorithm)."""
    x = jnp.asarray(x, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    N, T = x.shape
    Q, m = q.shape
    S = n_windows(T, m, stride)
    span = (S - 1) * stride + 1
    pad = span - 1 + m - T
    if pad > 0:                          # never taken: span - 1 + m <= T
        x = jnp.pad(x, ((0, 0), (0, pad)))

    def body(i, acc):
        xi = jax.lax.dynamic_slice(x, (0, i), (N, span))
        return acc + q[:, i][:, None, None] * xi[:, ::stride][None]

    return jax.lax.fori_loop(
        0, m, body, jnp.zeros((Q, N, S), jnp.float32))


def _window_stats(x, m: int, stride: int, S: int):
    """Rolling per-window sum / sum-of-squares via one cumulative sum
    each — the same O(1)-per-window statistics the Pallas kernel
    computes from its slab."""
    N = x.shape[0]
    zero = jnp.zeros((N, 1), jnp.float32)
    cs1 = jnp.concatenate([zero, jnp.cumsum(x, axis=1)], axis=1)
    cs2 = jnp.concatenate([zero, jnp.cumsum(x * x, axis=1)], axis=1)
    lo = jnp.arange(S) * stride
    s1 = cs1[:, lo + m] - cs1[:, lo]                   # (N, S)
    s2 = cs2[:, lo + m] - cs2[:, lo]
    return s1, s2


def _expand_distance(dot, s1, s2, q, m: int):
    """The windowed kernel's exact distance expansion applied to
    externally computed sliding dot products: with window mean mu and
    EPS-clamped sigma,

        d2 = sum(q^2) + (s2 - m*mu^2)/sig^2 - 2*(dot - mu*sum(q))/sig

    zero-variance windows z-normalize to the zero vector so their
    distance is exactly ``sum(q^2)``; the result clamps at 0."""
    mu = s1 / m
    var = s2 / m - mu * mu
    sig = jnp.maximum(jnp.sqrt(jnp.maximum(var, 0.0)), EPS)
    q_sum = jnp.sum(q, axis=1)[:, None, None]          # (Q, 1, 1)
    q_ss = jnp.sum(q * q, axis=1)[:, None, None]
    norm2 = jnp.maximum(s2 - m * mu * mu, 0.0) / (sig * sig)
    d2 = q_ss + norm2[None] - 2.0 * (dot - mu[None] * q_sum) / sig[None]
    d2 = jnp.where(var[None] > 0.0, d2, q_ss)
    return jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("stride",))
def windowed_euclid_fft(x, q, stride: int = 1):
    """FFT twin of ``kernels.windowed_euclid_pallas``: (N, T) raw rows
    vs (Q, m) z-normalized queries -> (Q, N, S) squared z-normalized
    window distances, dot products via :func:`sliding_dot_fft`, the
    rest of the expansion identical to the kernel.  Agreement with the
    accumulation paths is governed by :func:`fft_tolerance`."""
    x = jnp.asarray(x, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    m = q.shape[-1]
    S = n_windows(x.shape[-1], m, stride)
    s1, s2 = _window_stats(x, m, stride, S)
    dot = sliding_dot_fft(x, q, stride=stride)
    return _expand_distance(dot, s1, s2, q, m)
