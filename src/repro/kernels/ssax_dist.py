"""Pallas kernel: batched sSAX cell^2 sweep (Eq. 20, max form).

Stages per candidate tile (BLK_N):
  1. gather the four query-conditioned terms via one-hot MXU contractions:
         c1/c2 (BLK_N, L) from season symbols and t1/t2 (L, A_seas),
         d1/d2 (BLK_N, W) from residual symbols and u1/u2 (W, A_res);
  2. VPU cross-term:  cell[n,l,w] = max(0, c1+d1, c2+d2),
     accumulate sum of squares over (l, w).

The (L, W) cross never leaves VMEM; HBM traffic per candidate is L + W
symbol bytes.  This replaces the paper's 4*W*L scalar lookups with
L+W gathers + an L*W fused VPU loop (same math — DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_N = 128


def _kernel(seas_ref, res_ref, t1_ref, t2_ref, u1_ref, u2_ref, out_ref, *,
            A_seas: int, A_res: int):
    seas = seas_ref[...]                      # (BLK_N, L)
    res = res_ref[...]                        # (BLK_N, W)
    t1, t2 = t1_ref[...], t2_ref[...]         # (L, A_seas)
    u1, u2 = u1_ref[...], u2_ref[...]         # (W, A_res)

    oh_s = (seas[:, :, None] ==
            jax.lax.broadcasted_iota(jnp.int32, (1, 1, A_seas), 2))
    c1 = jnp.sum(oh_s * t1[None], axis=2, dtype=jnp.float32)   # (BLK_N, L)
    c2 = jnp.sum(oh_s * t2[None], axis=2, dtype=jnp.float32)
    oh_r = (res[:, :, None] ==
            jax.lax.broadcasted_iota(jnp.int32, (1, 1, A_res), 2))
    d1 = jnp.sum(oh_r * u1[None], axis=2, dtype=jnp.float32)   # (BLK_N, W)
    d2 = jnp.sum(oh_r * u2[None], axis=2, dtype=jnp.float32)

    cell = jnp.maximum(0.0,
                       jnp.maximum(c1[:, :, None] + d1[:, None, :],
                                   c2[:, :, None] + d2[:, None, :]))
    out_ref[...] = jnp.sum(cell * cell, axis=(1, 2))


def ssax_dist_pallas(seas_syms, res_syms, t1, t2, u1, u2, *,
                     interpret: bool = False):
    """(N, L) x (N, W) symbol arrays + four query tables -> (N,) f32."""
    N, L = seas_syms.shape
    _, W = res_syms.shape
    A_seas = t1.shape[1]
    A_res = u1.shape[1]
    blk = min(BLK_N, N)
    assert N % blk == 0, (N, blk)
    grid = (N // blk,)
    return pl.pallas_call(
        functools.partial(_kernel, A_seas=A_seas, A_res=A_res),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, L), lambda i: (i, 0)),
            pl.BlockSpec((blk, W), lambda i: (i, 0)),
            pl.BlockSpec((L, A_seas), lambda i: (0, 0)),
            pl.BlockSpec((L, A_seas), lambda i: (0, 0)),
            pl.BlockSpec((W, A_res), lambda i: (0, 0)),
            pl.BlockSpec((W, A_res), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(seas_syms, res_syms, t1, t2, u1, u2)
