"""Pallas kernel: PAA segmentation front-end (Eq. 5).

(N, T) -> (N, W) segment means.  Memory-bound streaming reduction: grid
tiles candidates; within a tile the (BLK_N, T) slab is reshaped
(BLK_N, W, T/W) in VMEM and mean-reduced on the VPU.  For long series the
T axis is additionally tiled and partial sums accumulate in the output
block (revisited across the seg-tile grid axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_N = 128
BLK_W = 64          # segments per grid step (bounds VMEM at BLK_N*BLK_W*E)


def _kernel(x_ref, out_ref, *, seg_len: int):
    x = x_ref[...]                                  # (BLK_N, BLK_W*seg_len)
    n, tw = x.shape
    w = tw // seg_len
    out_ref[...] = jnp.mean(
        x.reshape(n, w, seg_len).astype(jnp.float32), axis=-1)


def paa_pallas(x, n_segments: int, *, interpret: bool = False):
    """x: (N, T) -> (N, W) f32 segment means."""
    N, T = x.shape
    W = n_segments
    assert T % W == 0, (T, W)
    E = T // W
    blk_n = min(BLK_N, N)
    blk_w = min(BLK_W, W)
    while W % blk_w:                    # largest divisor of W <= BLK_W
        blk_w -= 1
    assert N % blk_n == 0 and W % blk_w == 0
    grid = (N // blk_n, W // blk_w)
    return pl.pallas_call(
        functools.partial(_kernel, seg_len=E),
        grid=grid,
        in_specs=[pl.BlockSpec((blk_n, blk_w * E), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((blk_n, blk_w), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, W), jnp.float32),
        interpret=interpret,
    )(x)
