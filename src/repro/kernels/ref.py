"""Pure-jnp oracles for every kernel (the allclose ground truth)."""

from __future__ import annotations

import jax.numpy as jnp


def sax_dist_ref(symbols, query_table):
    """SAX MINDIST^2 sweep.

    symbols: (N, W) int32 dataset symbols; query_table: (W, A) f32 with
    query_table[w, a] = cell(q_w, a)^2 (query-conditioned squared cells).
    Returns (N,) f32 = sum_w query_table[w, symbols[:, w]].
    """
    N, W = symbols.shape
    w_idx = jnp.arange(W)[None, :]
    return jnp.sum(query_table[w_idx, symbols], axis=-1)


def ssax_dist_ref(seas_syms, res_syms, t1, t2, u1, u2):
    """sSAX cell^2 sweep (Eq. 20 collapsed to max form).

    seas_syms: (N, L) int32; res_syms: (N, W) int32.
    t1/t2: (L, A_seas) query-conditioned season terms
        t1[l, a] = lower(q_l) - upper(a),  t2[l, a] = lower(a) - upper(q_l)
    u1/u2: (W, A_res) residual terms, same construction.
    Returns (N,) f32 = sum_{l,w} max(0, c1_l + d1_w, c2_l + d2_w)^2.
    """
    l_idx = jnp.arange(t1.shape[0])[None, :]
    w_idx = jnp.arange(u1.shape[0])[None, :]
    c1 = t1[l_idx, seas_syms]          # (N, L)
    c2 = t2[l_idx, seas_syms]
    d1 = u1[w_idx, res_syms]           # (N, W)
    d2 = u2[w_idx, res_syms]
    cell = jnp.maximum(
        0.0, jnp.maximum(c1[:, :, None] + d1[:, None, :],
                         c2[:, :, None] + d2[:, None, :]))
    return jnp.sum(jnp.square(cell), axis=(1, 2))


def paa_ref(x, n_segments: int):
    """(N, T) -> (N, W) segment means."""
    N, T = x.shape
    W = n_segments
    return jnp.mean(x.reshape(N, W, T // W), axis=-1)


def euclid_ref(x, q):
    """(N, T) vs (T,) -> (N,) squared Euclidean distances."""
    d = x - q[None, :]
    return jnp.sum(jnp.square(d), axis=-1)


def sliding_dot_ref(x, q, stride: int = 1):
    """(N, T) rows vs (Q, m) queries -> (Q, N, S) sliding dot products
    ``sum_i x[n, s*stride + i] * q[qi, i]``, windows materialized
    explicitly — the ground truth for both the m-step accumulation and
    the FFT paths in ``kernels.fft_dot``."""
    m = q.shape[-1]
    T = x.shape[-1]
    S = (T - m) // stride + 1
    starts = jnp.arange(S) * stride
    idx = starts[:, None] + jnp.arange(m)[None, :]     # (S, m)
    w = x[:, idx]                                      # (N, S, m)
    return jnp.einsum("nsm,qm->qns", w, q)


def windowed_euclid_ref(x, q, stride: int = 1):
    """(N, T) raw rows vs (Q, m) z-normalized queries -> (Q, N, S)
    squared distances to every z-normalized length-m window at ``stride``
    (S = (T - m) // stride + 1), windows materialized explicitly."""
    from repro.core.normalize import znormalize
    m = q.shape[-1]
    T = x.shape[-1]
    S = (T - m) // stride + 1
    starts = jnp.arange(S) * stride
    idx = starts[:, None] + jnp.arange(m)[None, :]     # (S, m)
    w = znormalize(x[:, idx])                          # (N, S, m)
    d = w[None] - q[:, None, None, :]                  # (Q, N, S, m)
    return jnp.sum(jnp.square(d), axis=-1)
