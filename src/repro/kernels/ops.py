"""Jit'd public wrappers around the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they execute
in ``interpret=True`` mode, which runs the kernel body step-by-step for
correctness — the tests sweep shapes/dtypes against the ref.py oracles in
exactly that mode.  Padding to block multiples happens here so callers
never see block constraints.

Helpers also build the query-conditioned tables the kernels consume
(``make_sax_query_table`` / ``make_ssax_query_tables``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.breakpoints import lower_bounds, upper_bounds
from repro.core.sax import cell_table
from repro.kernels import ref
from repro.kernels.euclid import euclid_pallas
from repro.kernels.paa import paa_pallas
from repro.kernels.sax_dist import sax_dist_pallas
from repro.kernels.ssax_dist import ssax_dist_pallas
from repro.kernels.windowed_euclid import windowed_euclid_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(x, mult: int):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, n


# -- query-table builders ---------------------------------------------------

def make_sax_query_table(query_syms, breakpoints):
    """(W,) query symbols -> (W, A) table of squared cell distances."""
    tab = cell_table(breakpoints)                   # (A, A)
    return jnp.square(tab[query_syms])              # (W, A)


def make_ssax_query_tables(q_seas, q_res, b_seas, b_res):
    """Query-conditioned (t1, t2, u1, u2) term tables for the sSAX kernel."""
    lo_s, hi_s = lower_bounds(b_seas), upper_bounds(b_seas)
    lo_r, hi_r = lower_bounds(b_res), upper_bounds(b_res)
    t1 = lo_s[q_seas][:, None] - hi_s[None, :]      # (L, A_seas)
    t2 = lo_s[None, :] - hi_s[q_seas][:, None]
    u1 = lo_r[q_res][:, None] - hi_r[None, :]       # (W, A_res)
    u2 = lo_r[None, :] - hi_r[q_res][:, None]
    # -inf - -inf would poison the kernel max; clamp to a huge negative
    big = jnp.float32(-3.4e38 / 4)
    fix = lambda t: jnp.nan_to_num(t, nan=0.0, neginf=big, posinf=-big)
    return tuple(fix(t.astype(jnp.float32)) for t in (t1, t2, u1, u2))


# -- dispatchers --------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("use_kernel",))
def sax_dist(symbols, query_table, use_kernel: bool = True):
    """Squared SAX MINDIST sweep: (N, W) x (W, A) -> (N,)."""
    if not use_kernel:
        return ref.sax_dist_ref(symbols, query_table)
    x, n = _pad_rows(symbols.astype(jnp.int32), 256)
    out = sax_dist_pallas(x, query_table.astype(jnp.float32),
                          interpret=not _on_tpu())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def ssax_dist(seas_syms, res_syms, t1, t2, u1, u2, use_kernel: bool = True):
    """Squared sSAX sweep: (N, L)/(N, W) + 4 tables -> (N,)."""
    if not use_kernel:
        return ref.ssax_dist_ref(seas_syms, res_syms, t1, t2, u1, u2)
    s, n = _pad_rows(seas_syms.astype(jnp.int32), 128)
    r, _ = _pad_rows(res_syms.astype(jnp.int32), 128)
    out = ssax_dist_pallas(s, r, *(t.astype(jnp.float32)
                                   for t in (t1, t2, u1, u2)),
                           interpret=not _on_tpu())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("n_segments", "use_kernel"))
def paa_segments(x, n_segments: int, use_kernel: bool = True):
    """(N, T) -> (N, W) segment means."""
    if not use_kernel:
        return ref.paa_ref(x, n_segments)
    xp, n = _pad_rows(x, 128)
    return paa_pallas(xp, n_segments, interpret=not _on_tpu())[:n]


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def euclid_batch(x, q, use_kernel: bool = True):
    """(N, T) vs (T,) or (Q, T) -> (N,) or (Q, N) squared distances.

    Ragged Q / N / T pad inside ``euclid_pallas`` itself."""
    if not use_kernel:
        if q.ndim == 1:
            return ref.euclid_ref(x, q)
        return jnp.stack([ref.euclid_ref(x, qi) for qi in q])
    return euclid_pallas(x, q, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("stride", "use_kernel", "method"))
def windowed_euclid(x, q, stride: int = 1, use_kernel: bool = True,
                    method: str = "accum"):
    """(N, T) raw rows vs (m,) or (Q, m) z-normalized queries ->
    (N, S) or (Q, N, S) squared z-normalized window distances (the
    MASS-style distance profile).  Ragged N / S pad inside
    ``windowed_euclid_pallas`` itself.

    ``method`` picks the sliding-dot-product formulation:
    ``"accum"`` (default) is the m-step accumulation — the Pallas
    kernel (or its ref oracle with ``use_kernel=False``), bitwise f32
    and the only path exact top-k verification consumes; ``"fft"`` is
    the MASS rfft/irfft path (``kernels.fft_dot``, jnp outside Pallas,
    O(T log T) per row) whose agreement with the accumulation paths is
    governed by the documented ``fft_dot.fft_tolerance(m)`` contract —
    use it for profile sweeps at large m, never for bitwise contracts.
    """
    if method == "fft":
        from repro.kernels.fft_dot import windowed_euclid_fft
        if q.ndim == 1:
            return windowed_euclid_fft(x, q[None], stride=stride)[0]
        return windowed_euclid_fft(x, q, stride=stride)
    if method != "accum":
        raise ValueError(f"unknown windowed_euclid method: {method!r}")
    if not use_kernel:
        if q.ndim == 1:
            return ref.windowed_euclid_ref(x, q[None], stride)[0]
        return ref.windowed_euclid_ref(x, q, stride)
    return windowed_euclid_pallas(x, q, stride=stride,
                                  interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("stride", "method"))
def sliding_dot(x, q, stride: int = 1, method: str = "fft"):
    """(N, T) rows vs (m,) or (Q, m) queries -> (N, S) or (Q, N, S)
    sliding dot products.  ``method="fft"`` (default) is the MASS
    rfft/irfft correlation; ``"accum"`` the m-step accumulation twin —
    both from ``kernels.fft_dot``, checked against
    ``ref.sliding_dot_ref``."""
    from repro.kernels.fft_dot import sliding_dot_accum, sliding_dot_fft
    if method == "fft":
        fn = sliding_dot_fft
    elif method == "accum":
        fn = sliding_dot_accum
    else:
        raise ValueError(f"unknown sliding_dot method: {method!r}")
    if q.ndim == 1:
        return fn(x, q[None], stride=stride)[0]
    return fn(x, q, stride=stride)
