"""Pallas TPU kernels for the matching engine's compute hot spots.

The paper's hot loop is the representation-distance sweep over the
candidate shard (its C implementation does W scalar LUT lookups per
candidate).  TPU adaptation (DESIGN.md §3): the per-query lookup tables
live in VMEM and the gather becomes a one-hot contraction on the MXU, so
the sweep is bounded by candidate-symbol HBM bandwidth (W bytes/candidate)
instead of scalar lookup latency.

Kernels (each <name>.py + oracle in ref.py, jit'd dispatch in ops.py):
  * sax_dist   — batched SAX MINDIST^2 sweep
  * ssax_dist  — batched sSAX 4-symbol cell distance sweep (Eq. 20)
  * paa        — segment-mean front-end (PAA, Eq. 5)
  * euclid     — batched Euclidean verification of surviving candidates
"""

from repro.kernels.ops import (  # noqa: F401
    sax_dist, ssax_dist, paa_segments, euclid_batch)
