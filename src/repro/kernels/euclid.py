"""Pallas kernel: batched Euclidean verification.

d2[qi, n] = sum_t (x[n, t] - q[qi, t])^2 for the candidate batch that
survived pruning, for one query or a whole query batch.  Grid tiles
(query-tiles x candidates x time); partial sums accumulate into the
output block across the time-tile axis (the output BlockSpec revisits the
same block for every j, so out_ref acts as the accumulator).  The query
axis is tiled in blocks of ``BLK_Q`` so large query batches fill the grid
instead of launching one program per query.

Ragged shapes are handled internally: Q, N and T are zero-padded up to
block multiples before the kernel launches and the padded rows are sliced
out of the result, so verification batches of any size coming out of
pruning are legal inputs.  Zero-padding the time axis pads both ``x`` and
``q``, contributing exactly 0 to every distance; zero-padded queries
produce rows that are sliced away.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_Q = 8
BLK_N = 128
BLK_T = 2048


def _kernel(x_ref, q_ref, out_ref):
    j = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)        # (BLK_N, BLK_T)
    q = q_ref[...].astype(jnp.float32)        # (BLK_Q, BLK_T)
    # one reduction per query row keeps the per-(query, candidate)
    # arithmetic identical to the single-query kernel (and to numpy):
    # each distance is still one elementwise subtract + sum over T
    rows = []
    for r in range(q.shape[0]):
        d = x - q[r][None, :]
        rows.append(jnp.sum(d * d, axis=-1))
    part = jnp.stack(rows, axis=0)            # (BLK_Q, BLK_N)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += part


def euclid_pallas(x, q, *, interpret: bool = False):
    """x: (N, T); q: (T,) or (Q, T) -> (N,) or (Q, N) f32 squared distances.

    Accepts ragged Q / N / T (padded internally to block multiples; padded
    rows are masked out of the result).
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None, :]
    N, T = x.shape
    Q = q.shape[0]
    blk_q = min(BLK_Q, Q)
    blk_n = min(BLK_N, N)
    blk_t = min(BLK_T, T)
    pad_q = (-Q) % blk_q
    pad_n = (-N) % blk_n
    pad_t = (-T) % blk_t
    if pad_n or pad_t:
        x = jnp.pad(x, ((0, pad_n), (0, pad_t)))
    if pad_q or pad_t:
        q = jnp.pad(q, ((0, pad_q), (0, pad_t)))
    qp, np_, tp = Q + pad_q, N + pad_n, T + pad_t
    grid = (qp // blk_q, np_ // blk_n, tp // blk_t)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, blk_t), lambda qi, i, j: (i, j)),
            pl.BlockSpec((blk_q, blk_t), lambda qi, i, j: (qi, j)),
        ],
        out_specs=pl.BlockSpec((blk_q, blk_n), lambda qi, i, j: (qi, i)),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.float32),
        interpret=interpret,
    )(x, q)
    out = out[:Q, :N]
    return out[0] if squeeze else out
