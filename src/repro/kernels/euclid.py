"""Pallas kernel: batched Euclidean verification.

d2[n] = sum_t (x[n, t] - q[t])^2 for the candidate batch that survived
pruning.  Grid tiles (candidates x time); partial sums accumulate into the
output block across the time-tile axis (the output BlockSpec revisits the
same block for every j, so out_ref acts as the accumulator).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_N = 128
BLK_T = 2048


def _kernel(x_ref, q_ref, out_ref):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)        # (BLK_N, BLK_T)
    q = q_ref[...].astype(jnp.float32)        # (1, BLK_T)
    d = x - q
    part = jnp.sum(d * d, axis=-1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += part


def euclid_pallas(x, q, *, interpret: bool = False):
    """x: (N, T); q: (T,) -> (N,) f32 squared distances."""
    N, T = x.shape
    blk_n = min(BLK_N, N)
    blk_t = min(BLK_T, T)
    assert N % blk_n == 0 and T % blk_t == 0, (N, T)
    grid = (N // blk_n, T // blk_t)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, blk_t), lambda i, j: (i, j)),
            pl.BlockSpec((1, blk_t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((blk_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(x, q.reshape(1, T))
