"""Pallas kernel: batched SAX MINDIST^2 sweep.

Per candidate the math is  d2[n] = sum_w LUT2[q_w, x[n, w]]  — a W-way
gather per candidate in the paper's C code.  TPU formulation: the
query-conditioned squared table M = LUT2[q] (W, A) sits in VMEM, the
candidate symbols are one-hot expanded in-register and contracted on the
MXU:

    d2[n] = sum_{w,a} onehot(x[n, w])[a] * M[w, a]

i.e. a (N_blk, W*A) x (W*A,) dot — HBM traffic is the int8/int32 symbol
tile only (W bytes/candidate at int8), which is the whole point of the
symbolic representation on TPU (DESIGN.md §3).

Block layout: grid over candidate tiles; symbols tile (BLK_N, W) and the
full (W, A) table per step.  VMEM budget: BLK_N*W*4 + W*A*4; for the
paper-max A=1024, W<=96 the table is <= 384 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_N = 256


def _kernel(sym_ref, table_ref, out_ref, *, A: int):
    syms = sym_ref[...]                       # (BLK_N, W) int32
    table = table_ref[...]                    # (W, A) f32
    # one-hot contraction on the MXU: (BLK_N, W, A) x (W, A) -> (BLK_N,)
    onehot = (syms[:, :, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, A), 2))
    acc = jnp.sum(onehot * table[None, :, :], axis=(1, 2),
                  dtype=jnp.float32)
    out_ref[...] = acc


def sax_dist_pallas(symbols, query_table, *, interpret: bool = False):
    """symbols: (N, W) int32; query_table: (W, A) f32 -> (N,) f32."""
    N, W = symbols.shape
    Wt, A = query_table.shape
    assert Wt == W
    blk = min(BLK_N, N)
    assert N % blk == 0, (N, blk)
    grid = (N // blk,)
    return pl.pallas_call(
        functools.partial(_kernel, A=A),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, W), lambda i: (i, 0)),
            pl.BlockSpec((W, A), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(symbols, query_table)
