"""Pallas kernel: MASS-style z-normalized windowed squared distances.

For a (Q, m) batch of z-normalized queries and an (N, T) raw corpus,
computes

    d2[qi, n, s] = || znorm(x[n, s*stride : s*stride + m]) - q[qi] ||^2

for every window start ``s`` — the distance profile that subsequence
matching brute-forces — WITHOUT materializing the N * S windows.  Like
MASS (Mueen et al.), each window's mean / std come from rolling
sum / sum-of-squares statistics; unlike MASS we compute the sliding dot
product directly (an m-step accumulation over the window tile, vectorized
across ``BLK_N`` rows x ``BLK_S`` window starts on the VPU) instead of an
FFT, which Pallas does not provide.  Per program instance:

* the rolling statistics are O(1) per window: one cumulative sum over the
  slab and two strided slices give every window's sum and sum-of-squares;
* with window mean mu and std sigma (clamped at ``EPS`` exactly like
  :func:`repro.core.normalize.znormalize`), the distance expands to

      d2 = sum(q^2) + (S2 - m*mu^2)/sigma_c^2 - 2*(dot - mu*sum(q))/sigma_c

  so only the three slab reductions are needed.

Grid tiles (queries x row-blocks x window-tiles) like
``kernels/euclid.py``; ragged N / S pad internally to block multiples and
the padded rows / window starts are sliced out of the result.  The time
axis is zero-padded so the last window tile's slab slice stays in bounds
(padded windows are computed on zeros and discarded).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_N = 8          # corpus rows per program (each holds its full row)
BLK_S = 512        # window starts per program

EPS = 1e-12        # must match repro.core.normalize.znormalize


def n_windows(T: int, m: int, stride: int) -> int:
    """Number of length-m windows of a length-T series at ``stride``."""
    if m > T:
        raise ValueError(f"window m={m} longer than series T={T}")
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    return (T - m) // stride + 1


def _kernel(x_ref, q_ref, out_ref, *, m: int, stride: int, blk_s: int):
    j = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)            # (BLK_N, T_pad)
    q = q_ref[...].astype(jnp.float32)            # (1, m)
    blk_n = x.shape[0]
    span = (blk_s - 1) * stride + 1               # strided starts footprint
    slab_len = span - 1 + m
    t0 = j * blk_s * stride
    slab = jax.lax.dynamic_slice(x, (0, t0), (blk_n, slab_len))

    # rolling window sums / sums of squares via one cumulative sum each:
    # window s covers slab[:, s*stride : s*stride + m]
    zero = jnp.zeros((blk_n, 1), jnp.float32)
    cs1 = jnp.concatenate([zero, jnp.cumsum(slab, axis=1)], axis=1)
    cs2 = jnp.concatenate([zero, jnp.cumsum(slab * slab, axis=1)], axis=1)
    lo1 = jax.lax.slice(cs1, (0, 0), (blk_n, span), (1, stride))
    hi1 = jax.lax.slice(cs1, (0, m), (blk_n, m + span), (1, stride))
    lo2 = jax.lax.slice(cs2, (0, 0), (blk_n, span), (1, stride))
    hi2 = jax.lax.slice(cs2, (0, m), (blk_n, m + span), (1, stride))
    s1 = hi1 - lo1                                # (BLK_N, BLK_S)
    s2 = hi2 - lo2

    # sliding dot product: m vectorized accumulations over the tile
    def body(i, acc):
        xi = jax.lax.dynamic_slice(slab, (0, i), (blk_n, span))
        qi = jax.lax.dynamic_slice(q, (0, i), (1, 1))
        return acc + qi * xi[:, ::stride]

    dot = jax.lax.fori_loop(0, m, body,
                            jnp.zeros((blk_n, blk_s), jnp.float32))

    mu = s1 / m
    var = s2 / m - mu * mu
    sig = jnp.maximum(jnp.sqrt(jnp.maximum(var, 0.0)), EPS)
    q_sum = jnp.sum(q)
    q_ss = jnp.sum(q * q)
    norm2 = jnp.maximum(s2 - m * mu * mu, 0.0) / (sig * sig)
    d2 = q_ss + norm2 - 2.0 * (dot - mu * q_sum) / sig
    # a zero-variance window z-normalizes to the zero vector (znormalize's
    # eps guard), so its distance is exactly sum(q^2); the expanded form
    # would divide rounding noise by eps instead
    d2 = jnp.where(var > 0.0, d2, q_ss)
    out_ref[...] = jnp.maximum(d2, 0.0)[None]     # (1, BLK_N, BLK_S)


def windowed_euclid_pallas(x, q, *, stride: int = 1,
                           interpret: bool = False):
    """x: (N, T) raw rows; q: (m,) or (Q, m) z-normalized queries ->
    (N, S) or (Q, N, S) f32 squared distances to every z-normalized
    window, S = (T - m) // stride + 1.

    Accepts ragged N / S (padded internally to block multiples; padded
    rows and window starts are sliced out of the result).
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None, :]
    N, T = x.shape
    Q, m = q.shape
    S = n_windows(T, m, stride)
    blk_n = min(BLK_N, N)
    blk_s = min(BLK_S, S)
    pad_n = (-N) % blk_n
    pad_s = (-S) % blk_s
    sp = S + pad_s
    # the last window tile's slab reads up to (sp - 1)*stride + m
    t_need = (sp - 1) * stride + m
    pad_t = max(t_need - T, 0)
    if pad_n or pad_t:
        x = jnp.pad(x, ((0, pad_n), (0, pad_t)))
    np_, tp = N + pad_n, T + pad_t
    grid = (Q, np_ // blk_n, sp // blk_s)
    out = pl.pallas_call(
        functools.partial(_kernel, m=m, stride=stride, blk_s=blk_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_n, tp), lambda qi, i, j: (i, 0)),
            pl.BlockSpec((1, m), lambda qi, i, j: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_n, blk_s),
                               lambda qi, i, j: (qi, i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, np_, sp), jnp.float32),
        interpret=interpret,
    )(x, q)
    out = out[:, :N, :S]
    return out[0] if squeeze else out
