"""Core transformer layers: norms, positions, attention (flash + decode),
SwiGLU — pure functions over param pytrees.

Attention supports GQA (grouped einsums, no kv replication), optional
qk-norm, sliding windows, prefix-LM masking, cross-attention and three
execution modes:

* ``flash_attention`` — chunked online-softmax attention used for train and
  prefill; memory is bounded by (q_chunk x kv_chunk) score blocks so 32k
  prefill never materializes an S^2 score tensor.
* ``decode_attention`` — single-query attention against a KV cache (dense
  over the cache; per-step cost is O(S·d)).
* ring-buffer caches for sliding-window layers: the cache holds only
  ``window`` slots, which is what makes gemma3-style local layers O(1)
  memory at 500k context.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import constrain

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(F32))).astype(dt)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope_tables(positions, head_dim: int, base: float):
    """cos/sin tables for rotary embedding. positions: (...,) int."""
    half = head_dim // 2
    freqs = jnp.exp(
        -math.log(base) * jnp.arange(half, dtype=F32) / half)
    angles = positions.astype(F32)[..., None] * freqs   # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, ..., Dh); cos/sin: (S, Dh/2) from ``rope_tables``."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    shape = (1, cos.shape[0]) + (1,) * (x.ndim - 3) + (half,)
    cos = cos.reshape(shape)
    sin = sin.reshape(shape)
    x1f, x2f = x1.astype(F32), x2.astype(F32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MaskSpec:
    causal: bool = True
    window: Optional[int] = None
    prefix_len: int = 0               # bidirectional over [0, prefix_len)

    def allowed(self, q_pos, k_pos):
        """Boolean mask (broadcast over q_pos x k_pos grids)."""
        q = q_pos[..., :, None]
        k = k_pos[..., None, :]
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
        if self.causal:
            ok = k <= q
            if self.prefix_len:
                ok = ok | (k < self.prefix_len)
        if self.window is not None:
            ok = ok & (q - k < self.window)
        return ok


# ---------------------------------------------------------------------------
# Flash attention (train / prefill)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, mask: MaskSpec, *, q_positions=None,
                    kv_positions=None, q_chunk: int = 512,
                    kv_chunk: int = 1024, causal_skip: bool = False):
    """Chunked online-softmax attention.

    q: (B, S, Hkv, G, Dh); k, v: (B, T, Hkv, Dh).  Returns (B, S, Hkv, G, Dh).

    ``causal_skip`` unrolls the q-chunk loop in Python and statically
    bounds each chunk's kv range to the causally-visible (and, for
    windowed layers, window-reachable) blocks — ~2x fewer attention-core
    FLOPs on causal stacks, at the cost of nq distinct inner loops in the
    HLO (perf-iteration lever, EXPERIMENTS.md §Perf).
    """
    B, S, K, G, Dh = q.shape
    T = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, q_chunk, T, kv_chunk)
    nq, nk = S // q_chunk, T // kv_chunk
    if q_positions is None:
        q_positions = jnp.arange(S)
    if kv_positions is None:
        kv_positions = jnp.arange(T)
    scale = 1.0 / math.sqrt(Dh)

    qc = q.reshape(B, nq, q_chunk, K, G, Dh)
    qpos = q_positions.reshape(nq, q_chunk)
    kc = k.reshape(B, nk, kv_chunk, K, Dh)
    vc = v.reshape(B, nk, kv_chunk, K, Dh)
    kpos = kv_positions.reshape(nk, kv_chunk)

    def process_chunk(qi, qp, kcs, vcs, kps):
        """Online-softmax over the given kv blocks (nk', B, kc, K, Dh)."""
        def kv_step(carry, kv):
            m, l, acc = carry
            ki, vi, kp = kv               # (B, kc, K, Dh), ..., (kc,)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, ki,
                           preferred_element_type=F32) * scale
            ok = mask.allowed(qp, kp)     # (qc, kc)
            s = jnp.where(ok[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(ok[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(qi.dtype), vi,
                            preferred_element_type=F32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), -jnp.inf, F32)
        l0 = jnp.zeros((B, K, G, q_chunk), F32)
        a0 = jnp.zeros((B, K, G, q_chunk, Dh), F32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kcs, vcs, kps))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)      # (B, qc, K, G, Dh)

    kT = kc.transpose(1, 0, 2, 3, 4)
    vT = vc.transpose(1, 0, 2, 3, 4)

    if causal_skip and mask.causal:
        outs = []
        for i in range(nq):
            # visible kv block range for q positions [i*qc, (i+1)*qc)
            hi = -(-((i + 1) * q_chunk) // kv_chunk)          # ceil
            lo = 0
            if mask.window is not None and not mask.prefix_len:
                lo = max(0, (i * q_chunk - mask.window + 1) // kv_chunk)
            outs.append(process_chunk(qc[:, i], qpos[i],
                                      kT[lo:hi], vT[lo:hi], kpos[lo:hi]))
        out = jnp.stack(outs, axis=0)
    else:
        out = lax.map(lambda a: process_chunk(a[0], a[1], kT, vT, kpos),
                      (qc.transpose(1, 0, 2, 3, 4, 5), qpos))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, Dh)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_mask):
    """Single-position attention against a cache.

    q: (B, 1, K, G, Dh); caches: (B, T, K, Dh); kv_mask: (B, T) bool.
    """
    B, _, K, G, Dh = q.shape
    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k_cache,
                   preferred_element_type=F32) * scale
    s = jnp.where(kv_mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(q.dtype), v_cache,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + core) and its cache
# ---------------------------------------------------------------------------

def attention_layer(p, x, cfg, spec, rules, *, positions, kv_x=None,
                    cache=None, pos=None, q_chunk=512, kv_chunk=1024,
                    collect_kv=False, causal=True, is_cross=False,
                    pad_to=0, causal_skip=False):
    """Full attention layer.  Returns (out, cache_out).

    Modes (x: (B, S, d)):
      * train / encoder : cache=None, collect_kv=False -> (out, None)
      * prefill         : cache=None, collect_kv=True  -> (out, {"k","v"})
        (ring-layout tail for windowed layers, ready for decode)
      * decode (S == 1) : cache={"k","v"}, pos = scalar absolute position.
        Self-attention appends at pos; with ``is_cross`` the cache holds
        precomputed encoder k/v and is read untouched.
    kv_x: encoder states for cross-attention (train/prefill).
    """
    B, S, d = x.shape
    K, G, Dh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(B, S, K, G, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)

    if kv_x is not None:                       # cross-attn with encoder states
        k = (kv_x @ p["wk"].astype(dt)).reshape(B, -1, K, Dh)
        v = (kv_x @ p["wv"].astype(dt)).reshape(B, -1, K, Dh)
        if cfg.qk_norm:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if S == 1 and cache is not None:       # (unused path; decode uses cache)
            kv_mask = jnp.ones((B, k.shape[1]), bool)
            out = decode_attention(q, k, v, kv_mask)
        else:
            out = flash_attention(q, k, v, MaskSpec(causal=False),
                                  q_chunk=q_chunk,
                                  kv_chunk=pick_divisor(k.shape[1], kv_chunk))
        cache_out = {"k": k, "v": v} if collect_kv else None
    elif is_cross:                             # cross-attn decode from cache
        assert cache is not None
        kv_mask = jnp.ones((B, cache["k"].shape[1]), bool)
        out = decode_attention(q, cache["k"].astype(dt),
                               cache["v"].astype(dt), kv_mask)
        cache_out = cache
    else:                                      # self-attention
        k = (x @ p["wk"].astype(dt)).reshape(B, S, K, Dh)
        v = (x @ p["wv"].astype(dt)).reshape(B, S, K, Dh)
        if cfg.qk_norm:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if cfg.use_rope:
            cos, sin = rope_tables(positions, Dh, cfg.rope_base)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        if cache is not None:                  # decode
            cache_out, k_all, v_all, kv_mask = _cache_update(
                cache, k, v, spec.window, pos)
            out = decode_attention(q, k_all, v_all, kv_mask)
        else:
            mask = MaskSpec(
                causal=causal, window=spec.window,
                prefix_len=cfg.prefix_len if cfg.prefix_lm else 0)
            out = flash_attention(q, k, v, mask, q_chunk=q_chunk,
                                  kv_chunk=pick_divisor(S, kv_chunk),
                                  causal_skip=causal_skip)
            cache_out = None
            if collect_kv:
                cache_out = prefill_attn_cache(spec, k, v, S, pad_to=pad_to)

    out = out.reshape(B, S, K * G * Dh)
    out = constrain(out, rules, ("batch", "seq_act", "qdim"))
    out = out @ p["wo"].astype(dt)
    return out, cache_out


def pick_divisor(n: int, target: int) -> int:
    c = min(n, target)
    while n % c:
        c -= 1
    return c


def init_attn_cache(cfg, spec, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache arrays for one self-attention layer (ring buffer if windowed)."""
    slots = max_len if spec.window is None else min(spec.window, max_len)
    K, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, K, Dh), dtype),
        "v": jnp.zeros((batch, slots, K, Dh), dtype),
    }


def _cache_update(cache, k_new, v_new, window, pos):
    """Insert one step at absolute position ``pos`` into a (ring) cache."""
    slots = cache["k"].shape[1]
    slot = pos % slots if window is not None else pos
    k = lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                 (0, slot, 0, 0))
    v = lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                 (0, slot, 0, 0))
    idx = jnp.arange(slots)
    if window is None:
        valid = idx <= pos
    else:
        valid = (idx <= pos) | (pos >= slots)    # ring full => all valid
    B = k.shape[0]
    kv_mask = jnp.broadcast_to(valid[None, :], (B, slots))
    return {"k": k, "v": v}, k, v, kv_mask


def prefill_attn_cache(spec, k, v, seq_len: int, dtype=None,
                       pad_to: int = 0):
    """Build a decode-ready cache from prefill k/v: (B, S, K, Dh).

    For windowed layers only the last ``window`` positions are kept, rolled
    so that position p sits at slot p % window (ring-consistent with
    ``_cache_update``).  ``pad_to`` reserves decode headroom: global caches
    are zero-padded to ``pad_to`` slots, windowed caches to the window (a
    ring never needs more).  dtype defaults to the compute dtype of k/v.
    """
    dtype = dtype or k.dtype
    if spec.window is not None and seq_len > spec.window:
        w = spec.window
        start = seq_len - w
        tail_k = lax.dynamic_slice_in_dim(k, start, w, axis=1)
        tail_v = lax.dynamic_slice_in_dim(v, start, w, axis=1)
        roll = start % w
        tail_k = jnp.roll(tail_k, roll, axis=1)
        tail_v = jnp.roll(tail_v, roll, axis=1)
        return {"k": tail_k.astype(dtype), "v": tail_v.astype(dtype)}
    slots = seq_len
    if spec.window is not None:
        slots = min(spec.window, max(pad_to, seq_len))
    elif pad_to:
        slots = max(pad_to, seq_len)
    if slots > seq_len:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, slots - seq_len)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    return {"k": k.astype(dtype), "v": v.astype(dtype)}


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_mlp(p, x, rules):
    dt = x.dtype
    g = x @ p["w_gate"].astype(dt)
    u = x @ p["w_up"].astype(dt)
    h = jax.nn.silu(g.astype(F32)).astype(dt) * u
    h = constrain(h, rules, ("batch", "seq_act", "ff"))
    return h @ p["w_down"].astype(dt)
