"""Model assembly: schema-driven parameters, scan-stacked blocks, and the
three execution paths (train forward, prefill, decode).

Parameters are described by a *schema* tree of ``PSpec(shape, dims, init)``
leaves — the single source of truth used for (a) random init, (b)
ShapeDtypeStruct trees for the allocation-free dry-run, and (c)
PartitionSpec trees via the logical-dim sharding rules.

Layers are stacked with ``lax.scan`` over the repeating block pattern
(DESIGN.md §4): every leaf of a pattern-position subtree carries a leading
``pattern_repeats`` axis.  Heterogeneous patterns (jamba 1:7, gemma3 5:1)
scan over the super-block.  ``jax.checkpoint`` around the scanned body gives
layer-boundary-only activation residency.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ATTN, MAMBA, RWKV, LayerSpec, ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models import rwkv as R
from repro.sharding.specs import constrain

F32 = jnp.float32


def pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target."""
    c = min(n, target)
    while n % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# Parameter schema
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PSpec:
    shape: tuple
    dims: tuple
    init: str = "linear"        # linear | embed | zeros | ones | mamba_A | mamba_dt

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


def _attn_schema(cfg: ModelConfig) -> dict:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    s = {
        "wq": PSpec((d, qd), ("d", "qdim")),
        "wk": PSpec((d, kvd), ("d", "kvdim")),
        "wv": PSpec((d, kvd), ("d", "kvdim")),
        "wo": PSpec((qd, d), ("qdim", "d")),
    }
    if cfg.qk_norm:
        s["q_norm"] = PSpec((hd,), ("vec",), "zeros")
        s["k_norm"] = PSpec((hd,), ("vec",), "zeros")
    return s


def _dense_mlp_schema(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": PSpec((d, f), ("d", "ff")),
        "w_up": PSpec((d, f), ("d", "ff")),
        "w_down": PSpec((f, d), ("ff", "d")),
    }


def _moe_schema(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff_e, cfg.n_experts
    s = {
        "router": PSpec((d, E), ("d", "vec")),
        "w_gate": PSpec((E, d, f), ("experts", "d", "ffe")),
        "w_up": PSpec((E, d, f), ("experts", "d", "ffe")),
        "w_down": PSpec((E, f, d), ("experts", "ffe", "d")),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff
        s["shared_w_gate"] = PSpec((d, fs), ("d", "ff"))
        s["shared_w_up"] = PSpec((d, fs), ("d", "ff"))
        s["shared_w_down"] = PSpec((fs, d), ("ff", "d"))
    return s


def _mamba_schema(cfg: ModelConfig) -> dict:
    d, D, N, Rk, KC = (cfg.d_model, cfg.d_inner, cfg.mamba_d_state,
                       cfg.dt_rank, cfg.mamba_d_conv)
    return {
        "in_proj": PSpec((d, 2 * D), ("d", "d_inner")),
        "conv_w": PSpec((D, KC), ("d_inner", "vec")),
        "conv_b": PSpec((D,), ("d_inner",), "zeros"),
        "x_proj": PSpec((D, Rk + 2 * N), ("d_inner", "vec")),
        "dt_proj": PSpec((Rk, D), ("vec", "d_inner")),
        "dt_bias": PSpec((D,), ("d_inner",), "mamba_dt"),
        "A_log": PSpec((D, N), ("d_inner", "vec"), "mamba_A"),
        "D_skip": PSpec((D,), ("d_inner",), "ones"),
        "out_proj": PSpec((D, d), ("d_inner", "d")),
    }


def _rwkv_tm_schema(cfg: ModelConfig) -> dict:
    d, r = cfg.d_model, cfg.rwkv_lora_dim
    return {
        "mu_x": PSpec((d,), ("vec",), "zeros"),
        "mu_rkvwg": PSpec((5, d), ("vec", "d"), "zeros"),
        "lora_mix_A": PSpec((d, 5 * r), ("d", "vec")),
        "lora_mix_B": PSpec((5, r, d), ("vec", "lora", "d")),
        "Wr": PSpec((d, d), ("d", "rflat")),
        "Wk": PSpec((d, d), ("d", "rflat")),
        "Wv": PSpec((d, d), ("d", "rflat")),
        "Wg": PSpec((d, d), ("d", "rflat")),
        "Wo": PSpec((d, d), ("rflat", "d")),
        "w_base": PSpec((d,), ("vec",), "zeros"),
        "lora_w_A": PSpec((d, r), ("d", "lora")),
        "lora_w_B": PSpec((r, d), ("lora", "d")),
        "u_bonus": PSpec((d,), ("vec",), "zeros"),
        "ln_w": PSpec((d,), ("vec",), "ones"),
        "ln_b": PSpec((d,), ("vec",), "zeros"),
    }


def _rwkv_cm_schema(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": PSpec((d,), ("vec",), "zeros"),
        "mu_r": PSpec((d,), ("vec",), "zeros"),
        "Wk": PSpec((d, f), ("d", "ff")),
        "Wv": PSpec((f, d), ("ff", "d")),
        "Wr": PSpec((d, d), ("d", "rflat")),
    }


def _block_schema(cfg: ModelConfig, spec: LayerSpec) -> dict:
    d = cfg.d_model
    s = {"norm_mix": PSpec((d,), ("vec",), "zeros"),
         "norm_mlp": PSpec((d,), ("vec",), "zeros")}
    if spec.kind == ATTN:
        s["mix"] = _attn_schema(cfg)
    elif spec.kind == MAMBA:
        s["mix"] = _mamba_schema(cfg)
    else:
        s["mix"] = _rwkv_tm_schema(cfg)
    if spec.cross_attn:
        s["norm_cross"] = PSpec((d,), ("vec",), "zeros")
        s["cross"] = _attn_schema(cfg)
    if spec.kind == RWKV:
        s["mlp"] = _rwkv_cm_schema(cfg)
    elif spec.moe:
        s["mlp"] = _moe_schema(cfg)
    else:
        s["mlp"] = _dense_mlp_schema(cfg)
    return s


def _stack(schema, n: int):
    return jax.tree.map(
        lambda p: PSpec((n,) + p.shape, ("layers",) + p.dims, p.init),
        schema, is_leaf=lambda x: isinstance(x, PSpec))


def param_schema(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.padded_vocab
    Rn = cfg.pattern_repeats
    schema = {
        "embed": PSpec((V, d), ("vocab", "d"), "embed"),
        "blocks": [_stack(_block_schema(cfg, s), Rn) for s in cfg.pattern],
        "final_norm": PSpec((d,), ("vec",), "zeros"),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = PSpec((d, V), ("d", "vocab"))
    if cfg.is_enc_dec:
        enc_block = {
            "norm_mix": PSpec((d,), ("vec",), "zeros"),
            "norm_mlp": PSpec((d,), ("vec",), "zeros"),
            "mix": _attn_schema(cfg),
            "mlp": _dense_mlp_schema(cfg),
        }
        schema["encoder"] = {
            "blocks": [_stack(enc_block, cfg.n_encoder_layers)],
            "final_norm": PSpec((d,), ("vec",), "zeros"),
        }
    return schema


# -- schema consumers -------------------------------------------------------

def _leaves_with_path(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, PSpec))


def init_params(cfg: ModelConfig, key) -> dict:
    schema = param_schema(cfg)
    dtype = jnp.dtype(cfg.param_dtype)

    def make(path, spec: PSpec):
        k = jax.random.fold_in(key, hash(jax.tree_util.keystr(path)) % (2**31))
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        if spec.init == "linear":
            return (jax.random.normal(k, spec.shape, dtype)
                    / math.sqrt(max(1, fan_in)))
        if spec.init == "embed":
            return jax.random.normal(k, spec.shape, dtype) * 0.02
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "mamba_A":
            a = jnp.broadcast_to(
                jnp.arange(1, spec.shape[-1] + 1, dtype=F32), spec.shape)
            return jnp.log(a).astype(dtype)
        if spec.init == "mamba_dt":
            u = jax.random.uniform(k, spec.shape, F32,
                                   minval=math.log(1e-3), maxval=math.log(1e-1))
            dt = jnp.exp(u)
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inv softplus
        raise ValueError(spec.init)

    paths, treedef = _leaves_with_path(schema)
    leaves = [make(p, s) for p, s in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    dt = jnp.dtype(dtype or cfg.param_dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt),
        param_schema(cfg), is_leaf=lambda x: isinstance(x, PSpec))


def param_pspecs(cfg: ModelConfig, rules):
    """PartitionSpec tree mirroring the params."""
    return jax.tree.map(
        lambda s: rules.pspec(s.dims, s.shape),
        param_schema(cfg), is_leaf=lambda x: isinstance(x, PSpec))


def param_logical_dims(cfg: ModelConfig):
    return jax.tree.map(lambda s: s.dims, param_schema(cfg),
                        is_leaf=lambda x: isinstance(x, PSpec))


# ---------------------------------------------------------------------------
# Execution knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    q_chunk: int = 512
    kv_chunk: int = 1024
    mamba_chunk: int = 256
    rwkv_chunk: int = 256
    loss_chunk: int = 256
    remat: bool = True
    microbatch: int = 0          # 0 = no gradient accumulation
    prefill_pad: int = 0         # pad prefill KV caches to this many slots
                                 # (0 = exactly the prompt; decode then has
                                 # no headroom — fine for the dry-run cell)
    causal_skip: bool = False    # static causal block skipping in flash
    remat_policy: str = "full"   # full | dots (save matmul outputs)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _apply_mix(p, x, cfg, spec, rules, rc: RunConfig, *, positions,
               cache=None, pos=None, collect=False):
    """Mixer sublayer dispatch. Returns (out, cache_out)."""
    if spec.kind == ATTN:
        return L.attention_layer(
            p, x, cfg, spec, rules, positions=positions, cache=cache, pos=pos,
            q_chunk=pick_chunk(x.shape[1], rc.q_chunk),
            kv_chunk=rc.kv_chunk, collect_kv=collect,
            pad_to=rc.prefill_pad, causal_skip=rc.causal_skip)
    if spec.kind == MAMBA:
        return M.mamba_mixer(p, x, cfg, rules, state=cache,
                             chunk=pick_chunk(x.shape[1], rc.mamba_chunk),
                             collect_state=collect)
    return R.rwkv_time_mix(p, x, cfg, rules, state=cache,
                           chunk=pick_chunk(x.shape[1], rc.rwkv_chunk),
                           collect_state=collect)


def apply_block(bp, x, cfg, spec: LayerSpec, rules, rc: RunConfig, *,
                positions, encoder_out=None, cache=None, pos=None,
                aux=None, collect=False):
    """One block: mixer + (cross) + mlp with pre-norms and residuals.

    Returns (x, cache_out) — cache_out has the layer-cache structure when
    ``collect`` or ``cache`` is given, else None.
    """
    eps = cfg.norm_eps
    h = L.rms_norm(x, bp["norm_mix"], eps)
    mix_cache = None if cache is None else cache.get("mix")
    mix, mix_cache_out = _apply_mix(
        bp["mix"], h, cfg, spec, rules, rc, positions=positions,
        cache=mix_cache, pos=pos, collect=collect)
    x = x + mix

    cross_cache_out = None
    if spec.cross_attn:
        h = L.rms_norm(x, bp["norm_cross"], eps)
        cross_cache = None if cache is None else cache.get("cross")
        cr, cross_cache_out = L.attention_layer(
            bp["cross"], h, cfg, spec, rules, positions=positions,
            kv_x=encoder_out, cache=cross_cache, pos=pos,
            is_cross=(cache is not None and encoder_out is None),
            q_chunk=pick_chunk(x.shape[1], rc.q_chunk),
            kv_chunk=rc.kv_chunk, collect_kv=collect)
        x = x + cr

    h = L.rms_norm(x, bp["norm_mlp"], eps)
    mlp_cache_out = None
    if spec.kind == RWKV:
        cm_cache = None if cache is None else cache.get("mlp")
        mlp, mlp_cache_out = R.rwkv_channel_mix(
            bp["mlp"], h, cfg, rules, state=cm_cache, collect_state=collect)
    elif spec.moe:
        mlp = MoE.moe_mlp(bp["mlp"], h, cfg, rules, aux=aux)
    else:
        mlp = L.swiglu_mlp(bp["mlp"], h, rules)
    x = x + mlp
    x = constrain(x, rules, ("batch", "seq_act", "vec"))

    cache_out = None
    if (cache is not None) or collect:
        cache_out = {"mix": mix_cache_out}
        if spec.cross_attn:
            cache_out["cross"] = cross_cache_out
        if spec.kind == RWKV:
            cache_out["mlp"] = mlp_cache_out
    return x, cache_out


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, rules, frames, rc: RunConfig):
    """frames: (B, F, d) precomputed frontend embeddings (stub)."""
    B, Fr, d = frames.shape
    x = frames + L.sinusoidal_embedding(jnp.arange(Fr), d)[None].astype(
        frames.dtype)
    positions = jnp.arange(Fr)
    enc_spec = LayerSpec(kind=ATTN)

    def body(x, bp):
        h = L.rms_norm(x, bp["norm_mix"], cfg.norm_eps)
        mix, _ = L.attention_layer(
            bp["mix"], h, cfg, enc_spec, rules, positions=positions,
            causal=False, q_chunk=pick_chunk(Fr, rc.q_chunk),
            kv_chunk=pick_chunk(Fr, rc.kv_chunk))
        x = x + mix
        h = L.rms_norm(x, bp["norm_mlp"], cfg.norm_eps)
        x = x + L.swiglu_mlp(bp["mlp"], h, rules)
        return x, None

    fn = jax.checkpoint(body) if rc.remat else body
    x, _ = lax.scan(fn, x, params["blocks"][0])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens, dtype):
    emb = params["embed"].astype(dtype)
    return jnp.take(emb, tokens, axis=0)


def forward(params, cfg: ModelConfig, rules, tokens, *, rc: RunConfig,
            prefix_embed=None, encoder_frames=None, collect_cache=False):
    """tokens: (B, S_text).  Returns (hidden (B,S,d), aux, caches|None).

    S = prefix_len + S_text for VLM configs (prefix embeddings prepended).
    """
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params, cfg, tokens, dt)
    if cfg.prefix_len:
        assert prefix_embed is not None
        x = jnp.concatenate([prefix_embed.astype(dt), x], axis=1)
    B, S, d = x.shape
    positions = jnp.arange(S)
    if not cfg.use_rope and not cfg.is_enc_dec:
        x = x + L.sinusoidal_embedding(positions, d)[None].astype(dt)

    encoder_out = None
    if cfg.is_enc_dec:
        assert encoder_frames is not None
        encoder_out = encode(params["encoder"], cfg, rules,
                             encoder_frames.astype(dt), rc)
        x = x + L.sinusoidal_embedding(positions, d)[None].astype(dt)

    x = constrain(x, rules, ("batch", "seq_act", "vec"))
    aux0 = {"load_balance": jnp.zeros((), F32),
            "router_z": jnp.zeros((), F32),
            "dropped_frac": jnp.zeros((), F32)}

    # one scan over pattern repeats; the body applies the whole super-block
    # in pattern order (gemma3: 5 local + 1 global; jamba: 1 attn + 7 mamba)
    def superblock(carry, bps):
        x, aux = carry
        aux = dict(aux)
        cache_outs = []
        for i, spec in enumerate(cfg.pattern):
            x, cache_out = apply_block(
                bps[i], x, cfg, spec, rules, rc, positions=positions,
                encoder_out=encoder_out, aux=aux, collect=collect_cache)
            cache_outs.append(cache_out)
        return (x, aux), (tuple(cache_outs) if collect_cache else None)

    if rc.remat:
        policy = None
        if rc.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        fn = jax.checkpoint(superblock, policy=policy)
    else:
        fn = superblock
    (x, aux0), caches = lax.scan(fn, (x, aux0), tuple(params["blocks"]))

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if not collect_cache:
        return x, aux0, None
    cache = {"blocks": list(caches), "pos": jnp.asarray(S, jnp.int32)}
    if cfg.is_enc_dec:
        cache["encoder_out"] = encoder_out
    return x, aux0, cache


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy, vocab-sharded logits)
# ---------------------------------------------------------------------------

def unembed(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T      # (d, V)
    return params["lm_head"]


def lm_loss(params, cfg: ModelConfig, rules, batch, rc: RunConfig):
    """batch: dict(tokens, labels[, prefix_embed, encoder_frames]).

    labels < 0 are masked.  Returns (loss, metrics).
    """
    x, aux, _ = forward(
        params, cfg, rules, batch["tokens"], rc=rc,
        prefix_embed=batch.get("prefix_embed"),
        encoder_frames=batch.get("encoder_frames"))
    B, S, d = x.shape
    labels = batch["labels"]
    if cfg.prefix_len:      # prefix positions carry no LM loss
        pad = jnp.full((B, cfg.prefix_len), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    head = unembed(params, cfg).astype(x.dtype)

    cs = pick_chunk(S, rc.loss_chunk)
    nch = S // cs
    xc = x.reshape(B, nch, cs, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, nch, cs).transpose(1, 0, 2)

    def ce_chunk(carry, inp):
        tot, cnt = carry
        xi, yi = inp                                  # (B, cs, d), (B, cs)
        logits = (xi @ head).astype(F32)              # (B, cs, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(yi, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        mask = (yi >= 0).astype(F32)
        tot = tot + jnp.sum((lse - gold) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = lax.scan(jax.checkpoint(ce_chunk),
                             (jnp.zeros((), F32), jnp.zeros((), F32)),
                             (xc, yc))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce
    if cfg.n_experts:
        loss = loss + cfg.router_aux_weight * aux["load_balance"] \
            + 1e-3 * aux["router_z"]
    metrics = {"ce": ce, "tokens": cnt, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    """Cache pytree for decoding; leaves stacked over pattern repeats.

    ``abstract=True`` returns ShapeDtypeStructs without any allocation
    (dry-run path — a 500k-context cache never touches host memory).
    """
    if abstract:
        return jax.eval_shape(
            lambda: init_cache(cfg, batch, max_len, dtype, abstract=False))
    Rn = cfg.pattern_repeats

    def one(spec: LayerSpec):
        c = {}
        if spec.kind == ATTN:
            c["mix"] = L.init_attn_cache(cfg, spec, batch, max_len, dtype)
        elif spec.kind == MAMBA:
            c["mix"] = M.init_mamba_state(cfg, batch, dtype)
        else:
            c["mix"] = R.init_rwkv_state(cfg, batch, dtype)
            c["mlp"] = {"shift_cm": jnp.zeros((batch, 1, cfg.d_model), dtype)}
        if spec.cross_attn:
            K, Dh = cfg.n_kv_heads, cfg.head_dim
            c["cross"] = {
                "k": jnp.zeros((batch, cfg.encoder_seq, K, Dh), dtype),
                "v": jnp.zeros((batch, cfg.encoder_seq, K, Dh), dtype)}
        return c

    blocks = [jax.tree.map(lambda a: jnp.broadcast_to(a, (Rn,) + a.shape),
                           one(s)) for s in cfg.pattern]
    cache = {"blocks": blocks, "pos": jnp.zeros((), jnp.int32)}
    if cfg.is_enc_dec:
        cache["encoder_out"] = jnp.zeros(
            (batch, cfg.encoder_seq, cfg.d_model), dtype)
    return cache


def cache_logical_dims(cfg: ModelConfig):
    """Logical-dim tree mirroring ``init_cache`` (drives cache sharding)."""
    def one(spec: LayerSpec):
        c = {}
        if spec.kind == ATTN:
            c["mix"] = {"k": ("batch", "cache_seq", "kvheads", "hd"),
                        "v": ("batch", "cache_seq", "kvheads", "hd")}
        elif spec.kind == MAMBA:
            c["mix"] = {"conv": ("batch", "vec", "d_inner"),
                        "ssm": ("batch", "d_inner", "vec")}
        else:
            c["mix"] = {"shift_tm": ("batch", "vec", "vec"),
                        "wkv": ("batch", "rheads", "vec", "vec")}
            c["mlp"] = {"shift_cm": ("batch", "vec", "vec")}
        if spec.cross_attn:
            c["cross"] = {"k": ("batch", "frames", "kvheads", "hd"),
                          "v": ("batch", "frames", "kvheads", "hd")}
        return c

    blocks = [jax.tree.map(lambda dims: ("layers",) + dims, one(s),
                           is_leaf=lambda x: isinstance(x, tuple))
              for s in cfg.pattern]
    dims = {"blocks": blocks, "pos": ()}
    if cfg.is_enc_dec:
        dims["encoder_out"] = ("batch", "frames", "vec")
    return dims


def cache_pspecs(cfg: ModelConfig, rules, cache):
    """PartitionSpec tree for a concrete cache pytree."""
    dims = cache_logical_dims(cfg)
    return jax.tree.map(
        lambda dm, leaf: rules.pspec(dm, leaf.shape),
        dims, cache, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, str) for e in x) or x == ())


def decode_step(params, cfg: ModelConfig, rules, cache, token, *,
                rc: RunConfig):
    """One decode step.  token: (B, 1) int32.  Returns (logits, new_cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params, cfg, token, dt)           # (B, 1, d)
    pos = cache["pos"]
    positions = pos[None]
    if not cfg.use_rope:
        x = x + L.sinusoidal_embedding(positions, cfg.d_model)[None].astype(dt)
    x = constrain(x, rules, ("batch", "seq_act", "vec"))

    def superblock(x, xs):
        bps, bcs = xs
        new_cs = []
        for i, spec in enumerate(cfg.pattern):
            x, cache_out = apply_block(
                bps[i], x, cfg, spec, rules, rc, positions=positions,
                cache=bcs[i], pos=pos)
            new_cs.append(cache_out)
        return x, tuple(new_cs)

    x, new_blocks = lax.scan(
        superblock, x, (tuple(params["blocks"]), tuple(cache["blocks"])))
    new_blocks = list(new_blocks)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ unembed(params, cfg).astype(dt)).astype(F32)
    new_cache = dict(cache, blocks=new_blocks, pos=pos + 1)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, rules, tokens, *, rc: RunConfig,
            prefix_embed=None, encoder_frames=None):
    """Run the full prompt, return (last-position logits, cache)."""
    x, _, cache = forward(
        params, cfg, rules, tokens, rc=rc, prefix_embed=prefix_embed,
        encoder_frames=encoder_frames, collect_cache=True)
    logits = (x[:, -1:] @ unembed(params, cfg).astype(x.dtype)).astype(F32)
    return logits[:, 0], cache
