"""RWKV-6 (Finch) block: token-shift mixing, data-dependent decay time mix,
squared-ReLU channel mix — pure JAX.

The WKV recurrence per head (hd = head dim):

    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t          S: (hd, hd)
    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)

with w_t in (0,1) the *data-dependent* per-channel decay (the paper's Finch
contribution) and u the learned "bonus" for the current token.  Like the
mamba block, train/prefill uses an outer chunk scan (remat at chunk
boundaries) with a sequential inner scan; decode is a single step on the
carried (shift, wkv-state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import constrain

F32 = jnp.float32


def _token_shift(x, last):
    """Shift sequence right by one; ``last`` (B, 1, d) fills position 0."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _lora(x, A, B_, dt):
    return jnp.tanh(x @ A.astype(dt)) @ B_.astype(dt)


def _wkv_chunk_scan(s0, r, k, v, w, u):
    """Sequential WKV scan over one chunk.

    s0: (B, H, K, V); r,k,v: (B, c, H, hd); w: (B, c, H, hd) decay in (0,1).
    Returns y: (B, c, H, hd), s_last.
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                       # (B, H, hd)
        kv = kt[..., :, None] * vt[..., None, :]   # (B, H, K, V)
        bonus = (u[None] * kt)[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + bonus)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    s_last, y = lax.scan(step, s0, xs)
    return y.transpose(1, 0, 2, 3), s_last


def rwkv_time_mix(p, x, cfg, rules, *, state=None, chunk: int = 256,
                  collect_state: bool = False):
    B, S, d = x.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    dt = x.dtype

    last = state["shift_tm"].astype(dt) if state is not None else \
        jnp.zeros((B, 1, d), dt)
    xs = _token_shift(x, last)
    diff = xs - x

    # data-dependent lerp coefficients (one shared + five per-stream loras)
    xxx = x + diff * p["mu_x"].astype(dt)
    mix = jnp.tanh(xxx @ p["lora_mix_A"].astype(dt))       # (B, S, 5*r)
    mix = mix.reshape(B, S, 5, -1)
    streams = jnp.einsum("bsfr,frd->bsfd", mix, p["lora_mix_B"].astype(dt))
    mus = p["mu_rkvwg"].astype(dt)                          # (5, d)
    xr, xk, xv, xw, xg = [
        x + diff * (mus[i] + streams[:, :, i]) for i in range(5)]

    r = (xr @ p["Wr"].astype(dt)).reshape(B, S, H, hd)
    k = (xk @ p["Wk"].astype(dt)).reshape(B, S, H, hd)
    v = (xv @ p["Wv"].astype(dt)).reshape(B, S, H, hd)
    g = jax.nn.silu((xg @ p["Wg"].astype(dt)).astype(F32)).astype(dt)

    w_raw = p["w_base"].astype(F32) + \
        _lora(xw, p["lora_w_A"], p["lora_w_B"], dt).astype(F32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(B, S, H, hd)       # decay in (0,1)
    u = p["u_bonus"].astype(F32).reshape(H, hd)

    rf, kf, vf = (t.astype(F32) for t in (r, k, v))
    if state is not None:                                   # decode
        y, s_new = _wkv_chunk_scan(state["wkv"], rf, kf, vf, w, u)
        new_state = {"shift_tm": x[:, -1:], "wkv": s_new}
    else:
        c = min(chunk, S)
        assert S % c == 0
        nch = S // c
        resh = lambda t: t.reshape(B, nch, c, H, hd).transpose(1, 0, 2, 3, 4)

        def chunk_step(s, inp):
            rc, kc, vc, wc = inp
            y, s_new = _wkv_chunk_scan(s, rc, kc, vc, wc, u)
            return s_new, y

        s0 = jnp.zeros((B, H, hd, hd), F32)
        s_last, y = lax.scan(jax.checkpoint(chunk_step), s0,
                             (resh(rf), resh(kf), resh(vf), resh(w)))
        y = y.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
        new_state = None
        if collect_state:
            new_state = {"shift_tm": x[:, -1:], "wkv": s_last}

    # per-head group norm, then gate
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * lax.rsqrt(var + 64e-5)
    y = y * p["ln_w"].astype(F32).reshape(H, hd) + \
        p["ln_b"].astype(F32).reshape(H, hd)
    y = y.reshape(B, S, d).astype(dt) * g
    y = constrain(y, rules, ("batch", "seq_act", "rflat"))
    out = y @ p["Wo"].astype(dt)
    return out, new_state


def rwkv_channel_mix(p, x, cfg, rules, *, state=None,
                     collect_state: bool = False):
    B, S, d = x.shape
    dt = x.dtype
    last = state["shift_cm"].astype(dt) if state is not None else \
        jnp.zeros((B, 1, d), dt)
    xs = _token_shift(x, last)
    diff = xs - x
    xk = x + diff * p["mu_k"].astype(dt)
    xr = x + diff * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu((xk @ p["Wk"].astype(dt)).astype(F32)))
    k = constrain(k.astype(dt), rules, ("batch", "seq_act", "ff"))
    kv = k @ p["Wv"].astype(dt)
    out = jax.nn.sigmoid((xr @ p["Wr"].astype(dt)).astype(F32)).astype(dt) * kv
    new_state = {"shift_cm": x[:, -1:]} \
        if (state is not None or collect_state) else None
    return out, new_state


def init_rwkv_state(cfg, batch: int, dtype=jnp.bfloat16):
    """Time-mix state only; the channel-mix shift lives in the block's
    "mlp" cache slot (structure must match the decode-step output)."""
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    return {
        "shift_tm": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), F32),
    }
