"""Mamba (selective SSM) block — Jamba-style, pure JAX.

Training/prefill runs a chunked associative scan: the sequence is cut into
``chunk``-sized pieces; an outer ``lax.scan`` carries the (B, d_inner, N)
state across chunks (saving only chunk-boundary states for the backward
pass via remat), and within a chunk the recurrence

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t

is evaluated with ``lax.associative_scan`` (parallel on TPU).  The
(chunk, d_inner, N) discretized tensors exist only transiently per chunk —
this is the TPU-shaped replacement for the fused CUDA kernel: VMEM-sized
working sets via chunking instead of warp-level fusion.

Decode is a single recurrence step on carried (conv window, ssm state).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import constrain

F32 = jnp.float32


def _ssm_scan_chunk(h0, dA, dBx):
    """Associative scan within one chunk.

    h0: (B, D, N); dA, dBx: (B, c, D, N).  Returns (h_all (B,c,D,N), h_last).
    """
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a2 * a1, a2 * b1 + b2

    aA, aB = lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = aA * h0[:, None] + aB
    return h_all, h_all[:, -1]


def mamba_mixer(p, x, cfg, rules, *, state=None, chunk: int = 256,
                collect_state: bool = False):
    """x: (B, S, d) -> (B, S, d).

    state: None for train/prefill-from-scratch, else dict(conv, ssm) for
    decode (S == 1).  Returns (y, new_state); new_state is None in train
    unless ``collect_state`` (prefill) is set.
    """
    B, S, d = x.shape
    D, N, R = cfg.d_inner, cfg.mamba_d_state, cfg.dt_rank
    KC = cfg.mamba_d_conv
    dt_ = x.dtype

    xz = x @ p["in_proj"].astype(dt_)                 # (B, S, 2D)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, rules, ("batch", "seq_act", "d_inner"))

    # -- causal depthwise conv ----------------------------------------
    w = p["conv_w"].astype(dt_)                       # (D, KC)
    if state is None:
        pad = jnp.zeros((B, KC - 1, D), dt_)
        xp = jnp.concatenate([pad, x_in], axis=1)     # (B, S+KC-1, D)
        new_conv = None
    else:
        xp = jnp.concatenate([state["conv"].astype(dt_), x_in], axis=1)
        new_conv = xp[:, 1:]                          # keep last KC-1
    x_c = sum(xp[:, i:i + S] * w[None, None, :, i] for i in range(KC))
    x_c = x_c + p["conv_b"].astype(dt_)
    x_c = jax.nn.silu(x_c.astype(F32)).astype(dt_)

    # -- input-dependent dt, B, C --------------------------------------
    dbc = x_c @ p["x_proj"].astype(dt_)               # (B, S, R + 2N)
    dt_r = dbc[..., :R]
    Bm = dbc[..., R:R + N].astype(F32)                # (B, S, N)
    Cm = dbc[..., R + N:].astype(F32)
    dt_full = dt_r @ p["dt_proj"].astype(dt_) + p["dt_bias"].astype(dt_)
    delta = jax.nn.softplus(dt_full.astype(F32))      # (B, S, D)
    A = -jnp.exp(p["A_log"].astype(F32))              # (D, N)

    dA = jnp.exp(delta[..., None] * A[None, None])            # (B, S, D, N)
    dBx = (delta * x_c.astype(F32))[..., None] * Bm[:, :, None, :]

    if state is not None:                              # decode: one step
        h = dA[:, 0] * state["ssm"] + dBx[:, 0]        # (B, D, N)
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]    # (B, 1, D)
        new_state = {"conv": new_conv, "ssm": h}
    else:
        c = min(chunk, S)
        assert S % c == 0
        nch = S // c
        dA_c = dA.reshape(B, nch, c, D, N).transpose(1, 0, 2, 3, 4)
        dBx_c = dBx.reshape(B, nch, c, D, N).transpose(1, 0, 2, 3, 4)
        Cm_c = Cm.reshape(B, nch, c, N).transpose(1, 0, 2, 3)

        def chunk_step(h, inputs):
            da, dbx, cm = inputs
            h_all, h_last = _ssm_scan_chunk(h, da, dbx)
            y = jnp.einsum("bcdn,bcn->bcd", h_all, cm)
            return h_last, y

        h0 = jnp.zeros((B, D, N), F32)
        h_last, y = lax.scan(jax.checkpoint(chunk_step), h0,
                             (dA_c, dBx_c, Cm_c))
        y = y.transpose(1, 0, 2, 3).reshape(B, S, D)
        new_state = None
        if collect_state:                      # prefill: decode-ready state
            conv_tail = xp[:, S:] if KC > 1 else \
                jnp.zeros((B, 0, D), dt_)
            new_state = {"conv": conv_tail, "ssm": h_last}

    y = y + x_c.astype(F32) * p["D_skip"].astype(F32)[None, None]
    y = (y.astype(dt_)) * jax.nn.silu(z.astype(F32)).astype(dt_)
    y = constrain(y, rules, ("batch", "seq_act", "d_inner"))
    return y @ p["out_proj"].astype(dt_), new_state


def init_mamba_state(cfg, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.mamba_d_state), F32),
    }
