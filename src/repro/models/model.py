"""Public model API: ``build_model(cfg)`` returns a ``Model`` bundle with
init / loss / prefill / decode entry points plus the abstract-parameter and
PartitionSpec trees that power the allocation-free dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer as T
from repro.models.transformer import (
    RunConfig, init_params, abstract_params, param_pspecs,
    param_logical_dims,
)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    rules: Any = None                  # ShardingRules or None
    rc: RunConfig = field(default_factory=RunConfig)

    # -- parameters ----------------------------------------------------
    def init(self, key):
        return init_params(self.cfg, key)

    def abstract_params(self, dtype=None):
        return abstract_params(self.cfg, dtype)

    def param_pspecs(self):
        assert self.rules is not None
        return param_pspecs(self.cfg, self.rules)

    def param_count(self) -> tuple[int, int]:
        return self.cfg.param_counts()

    # -- train ----------------------------------------------------------
    def loss(self, params, batch):
        """batch: dict(tokens, labels[, prefix_embed, encoder_frames])."""
        return T.lm_loss(params, self.cfg, self.rules, batch, self.rc)

    def hidden_states(self, params, batch):
        x, aux, _ = T.forward(
            params, self.cfg, self.rules, batch["tokens"], rc=self.rc,
            prefix_embed=batch.get("prefix_embed"),
            encoder_frames=batch.get("encoder_frames"))
        return x, aux

    def logits(self, params, batch):
        """Full logits — small configs only (materializes (B, S, V))."""
        x, aux = self.hidden_states(params, batch)
        head = T.unembed(params, self.cfg).astype(x.dtype)
        return (x @ head).astype(jnp.float32), aux

    # -- serve ----------------------------------------------------------
    def prefill(self, params, batch):
        return T.prefill(
            params, self.cfg, self.rules, batch["tokens"], rc=self.rc,
            prefix_embed=batch.get("prefix_embed"),
            encoder_frames=batch.get("encoder_frames"))

    def decode_step(self, params, cache, token):
        return T.decode_step(params, self.cfg, self.rules, cache, token,
                             rc=self.rc)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   abstract: bool = False):
        return T.init_cache(self.cfg, batch, max_len, dtype,
                            abstract=abstract)


def build_model(cfg: ModelConfig, rules=None,
                rc: Optional[RunConfig] = None) -> Model:
    return Model(cfg=cfg, rules=rules, rc=rc or RunConfig())
