"""Mixture-of-Experts MLP with top-k routing and capacity-bounded
scatter/gather dispatch (no (T,E,C) one-hot einsum — dispatch moves
T·k·d bytes instead of burning T·E·C·d FLOPs, so HLO compute stays
proportional to *active* parameters).

Experts are expert-parallel over the "model" mesh axis (dims: ("experts",
"d", "ffe")); tokens are data-parallel.  GSPMD inserts the token
all-to-all/all-gather at the dispatch boundary.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.specs import constrain

F32 = jnp.float32


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    c = math.ceil(top_k * n_tokens / n_experts * capacity_factor)
    return max(8, ((c + 7) // 8) * 8)       # pad for lane alignment


def moe_mlp(p, x, cfg, rules, *, aux: Optional[dict] = None):
    """x: (B, S, d) -> (B, S, d).  Router stats go into ``aux`` if given.

    When the sharding rules carry ``moe_groups`` > 1, dispatch is
    GROUP-LOCAL: tokens are split into G groups aligned with the
    data-parallel sharding, positions-in-expert are cumsum'd *within* a
    group (no cross-shard cumsum), and the (G, E, C_g, d) buffers are
    sharded (G->data, E->model).  Because activations are replicated over
    the model axis, every model rank can build its own expert slice with
    no dispatch collective at all; only the final combine all-reduces a
    bf16 (G, T_g, d) over the model axis (EXPERIMENTS.md §Perf,
    olmoe-prefill iterations).
    """
    G = getattr(rules, "moe_groups", 0) or 1
    if G > 1 and (x.shape[0] * x.shape[1]) % G == 0:
        return _moe_mlp_grouped(p, x, cfg, rules, G, aux=aux)
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.moe_top_k
    dt = x.dtype
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(dt)).astype(F32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- capacity-bounded positions ---------------------------------
    C = capacity(T, E, K, cfg.capacity_factor)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (T, K, E)
    # priority: kth choices ranked after (k-1)th across all tokens
    flat = onehot.transpose(1, 0, 2).reshape(K * T, E)       # (K*T, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat          # (K*T, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(K, T).T   # (T, K)
    fits = pos < C
    gate_vals = jnp.where(fits, gate_vals, 0.0)

    # ---- scatter tokens into (E, C, d) buffers ----------------------
    tok_idx = jnp.tile(jnp.arange(T)[:, None], (1, K)).reshape(-1)
    e_idx = expert_idx.reshape(-1)
    c_idx = pos.reshape(-1)
    keep = fits.reshape(-1)
    e_idx = jnp.where(keep, e_idx, E)       # out-of-range rows are dropped
    buf = jnp.zeros((E + 1, C, d), dt)
    buf = buf.at[e_idx, jnp.where(keep, c_idx, 0)].add(
        xt[tok_idx] * keep[:, None].astype(dt), mode="drop")
    xe = buf[:E]                             # (E, C, d)
    xe = constrain(xe, rules, ("experts", "cap", "d_act"))

    # ---- expert SwiGLU ----------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    h = jax.nn.silu(g.astype(F32)).astype(dt) * u
    h = constrain(h, rules, ("experts", "cap", "ffe"))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))  # (E, C, d)

    # ---- gather back + combine --------------------------------------
    gathered = ye[jnp.where(keep, e_idx, 0), c_idx]           # (T*K, d)
    gathered = gathered * (gate_vals.reshape(-1) * keep)[:, None].astype(dt)
    y = jnp.zeros((T, d), dt).at[tok_idx].add(gathered)

    if cfg.n_shared_experts:
        gs = xt @ p["shared_w_gate"].astype(dt)
        us = xt @ p["shared_w_up"].astype(dt)
        hs = jax.nn.silu(gs.astype(F32)).astype(dt) * us
        y = y + hs @ p["shared_w_down"].astype(dt)

    if aux is not None:
        # Switch-style load-balance loss + router z-loss
        me = jnp.mean(probs, axis=0)                          # (E,)
        ce = jnp.mean(
            jnp.sum(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)) / T
        frac = jnp.bincount(
            expert_idx.reshape(-1), length=E).astype(F32) / (T * K)
        aux["load_balance"] = aux.get("load_balance", 0.0) + \
            E * jnp.sum(frac * me)
        aux["router_z"] = aux.get("router_z", 0.0) + \
            jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux["dropped_frac"] = aux.get("dropped_frac", 0.0) + \
            jnp.mean(1.0 - fits.astype(F32))
        del ce
    return y.reshape(B, S, d)


def _moe_mlp_grouped(p, x, cfg, rules, G: int, *, aux=None):
    """Group-local capacity dispatch (see moe_mlp docstring)."""
    B, S, d = x.shape
    T = B * S
    Tg = T // G
    E, K = cfg.n_experts, cfg.moe_top_k
    dt = x.dtype
    xg = x.reshape(G, Tg, d)
    xg = constrain(xg, rules, ("groups", "vec", "vec"))

    logits = (xg @ p["router"].astype(dt)).astype(F32)       # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, K)              # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = capacity(Tg, E, K, cfg.capacity_factor)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (G, Tg, K, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * Tg, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat               # group-local
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(G, K, Tg) \
        .transpose(0, 2, 1)                                  # (G, Tg, K)
    fits = pos < C
    gate_vals = jnp.where(fits, gate_vals, 0.0)

    tok_idx = jnp.tile(jnp.arange(Tg)[:, None], (1, K)).reshape(-1)
    e_idx = jnp.where(fits, expert_idx, E).reshape(G, -1)    # (G, Tg*K)
    c_idx = jnp.where(fits, pos, 0).reshape(G, -1)
    keep = fits.reshape(G, -1)

    def scatter_group(xq, ei, ci, kp):
        buf = jnp.zeros((E + 1, C, d), dt)
        vals = xq[tok_idx] * kp[:, None].astype(dt)
        return buf.at[ei, ci].add(vals, mode="drop")[:E]

    xe = jax.vmap(scatter_group)(xg, e_idx, c_idx, keep)     # (G, E, C, d)
    xe = constrain(xe, rules, ("groups", "experts", "cap", "d_act"))

    g_ = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
    u_ = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    h = jax.nn.silu(g_.astype(F32)).astype(dt) * u_
    h = constrain(h, rules, ("groups", "experts", "cap", "ffe"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    # NOTE (§Perf olmoe iteration v4, refuted): replicating ye over the
    # model axis here swaps the combine all-reduce for an all-gather but
    # XLA promotes the gather to f32 — net +17% collective bytes. Keep
    # the expert-sharded layout.
    ye = constrain(ye, rules, ("groups", "experts", "cap", "d_act"))

    gv = (gate_vals.reshape(G, -1) * keep).astype(dt)        # (G, Tg*K)

    def gather_group(ye_g, ei, ci, gv_g):
        # combine as K direct indexed adds (k-th choice of token t is row
        # t*? no — ei is (Tg*K,) laid out (Tg, K)); summing BEFORE the
        # model-axis reduction lets XLA reassociate the K all-reduces into
        # one (Tg, d) all-reduce instead of a (Tg*K, d) gather reduction
        e2 = jnp.where(ei < E, ei, 0).reshape(Tg, K)
        c2 = ci.reshape(Tg, K)
        g2 = gv_g.reshape(Tg, K)
        y = jnp.zeros((Tg, d), dt)
        for k in range(K):
            y = y + ye_g[e2[:, k], c2[:, k]] * g2[:, k][:, None]
        return y

    y = jax.vmap(gather_group)(ye, e_idx, c_idx, gv)         # (G, Tg, d)
    y = constrain(y, rules, ("groups", "vec", "vec"))
    y = y.reshape(B, S, d)
    y = constrain(y, rules, ("batch", "seq_act", "vec"))
    y = y.reshape(T, d)

    if cfg.n_shared_experts:
        xt = x.reshape(T, d)
        gs = xt @ p["shared_w_gate"].astype(dt)
        us = xt @ p["shared_w_up"].astype(dt)
        hs = jax.nn.silu(gs.astype(F32)).astype(dt) * us
        y = y + hs @ p["shared_w_down"].astype(dt)

    if aux is not None:
        me = jnp.mean(probs, axis=(0, 1))
        frac = jnp.bincount(expert_idx.reshape(-1),
                            length=E).astype(F32) / (T * K)
        aux["load_balance"] = aux.get("load_balance", 0.0) + \
            E * jnp.sum(frac * me)
        aux["router_z"] = aux.get("router_z", 0.0) + \
            jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux["dropped_frac"] = aux.get("dropped_frac", 0.0) + \
            jnp.mean(1.0 - fits.astype(F32))
    return y.reshape(B, S, d)
