from repro.models.model import (  # noqa: F401
    build_model, Model, init_params, abstract_params, param_pspecs,
)
