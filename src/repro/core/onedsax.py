"""1d-SAX (Malinowski et al., IDA 2013) — the only SAX extension with the
same representation size, used as the trend-aware baseline on Economy.

Each segment is summarized by (mean at segment midpoint, slope) from a
per-segment linear regression; both are quantized — the mean against
N(0,1) quantiles (alphabet A_a), the slope against N(0, sigma_s^2)
quantiles with sigma_s^2 = 0.03 / seg_len (the paper's recommended
heuristic).  The distance reconstructs the per-segment line from symbol
centroids and sums squared differences — faithful to the original; as the
survey table notes, it is *not* proven lower-bounding (we measure this
empirically in the TLB benchmark).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
from jax.scipy.special import ndtri

from repro.core.breakpoints import discretize, gaussian_breakpoints


def _centroids(alphabet: int, sd: float):
    """Gaussian cell centroids (median of each equiprobable cell)."""
    qs = (jnp.arange(alphabet, dtype=jnp.float32) + 0.5) / alphabet
    return sd * ndtri(qs)


def segment_regression(x, W: int):
    """Per-segment (midpoint value, slope).  x: (..., T) -> two (..., W)."""
    T = x.shape[-1]
    assert T % W == 0
    n = T // W
    xs = x.reshape(*x.shape[:-1], W, n)
    s = jnp.arange(n, dtype=x.dtype)
    s_bar = (n - 1) / 2.0
    den = jnp.sum(jnp.square(s - s_bar))
    slope = jnp.sum(xs * (s - s_bar), axis=-1) / jnp.maximum(den, 1e-12)
    mid = jnp.mean(xs, axis=-1)           # value of the fit at the midpoint
    return mid, slope


@dataclass(frozen=True)
class OneDSAX:
    T: int
    W: int
    A_a: int          # mean alphabet
    A_s: int          # slope alphabet

    @property
    def seg_len(self) -> int:
        return self.T // self.W

    @property
    def sd_slope(self) -> float:
        return math.sqrt(0.03 / self.seg_len)

    @property
    def bits(self) -> float:
        return self.W * (math.log2(self.A_a) + math.log2(self.A_s))

    def encode(self, x):
        mid, slope = segment_regression(x, self.W)
        sa = discretize(mid, gaussian_breakpoints(self.A_a, 1.0))
        ss = discretize(slope, gaussian_breakpoints(self.A_s, self.sd_slope))
        return sa, ss

    def reconstruct(self, rep):
        """Symbol centroids -> per-timestep reconstruction (..., T)."""
        sa, ss = rep
        mid = _centroids(self.A_a, 1.0)[sa]            # (..., W)
        slope = _centroids(self.A_s, self.sd_slope)[ss]
        n = self.seg_len
        s = jnp.arange(n, dtype=jnp.float32) - (n - 1) / 2.0
        vals = mid[..., None] + slope[..., None] * s   # (..., W, n)
        return vals.reshape(*sa.shape[:-1], self.T)

    def distance(self, ra, rb):
        va = self.reconstruct(ra)
        vb = self.reconstruct(rb)
        return jnp.sqrt(jnp.sum(jnp.square(va - vb), axis=-1))

    def pairwise_distance(self, rq, rx):
        vq = self.reconstruct(rq)                       # (Q, T)
        vx = self.reconstruct(rx)                       # (N, T)
        d2 = jnp.sum(vq * vq, -1)[:, None] + jnp.sum(vx * vx, -1)[None, :] \
            - 2.0 * vq @ vx.T
        return jnp.sqrt(jnp.maximum(d2, 0.0))
