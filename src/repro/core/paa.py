"""Piecewise Aggregate Approximation (Eq. 5) and its distance (Eq. 9)."""

from __future__ import annotations

import jax.numpy as jnp


def paa(x, n_segments: int):
    """x: (..., T) -> segment means (..., W).  W must divide T."""
    T = x.shape[-1]
    W = n_segments
    assert T % W == 0, (T, W)
    return jnp.mean(x.reshape(*x.shape[:-1], W, T // W), axis=-1)


def paa_distance(a, b, T: int):
    """d_PAA (Eq. 9): sqrt(T/W) * ||a - b||_2 along the last axis."""
    W = a.shape[-1]
    return jnp.sqrt(T / W) * jnp.sqrt(jnp.sum(jnp.square(a - b), axis=-1))
