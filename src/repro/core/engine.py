"""Unified batched k-NN matching engine.

``MatchEngine`` answers batched multi-query **top-k** matching — exact
(lower-bound pruned scan) and approximate (representation top-k then
verify) — over any encoder with ``encode`` + ``pairwise_distance``
(SAX, sSAX, tSAX, stSAX, 1d-SAX) and a ``RawStore`` for raw
verification.

API
---
::

    engine = MatchEngine(encoder, RawStore.ssd(D))
    res = engine.topk(queries, k=32)                  # exact k-NN
    res = engine.topk(queries, k=32, exact=False)     # approximate
    res = engine.verify_candidates(queries, cand_idx) # external candidates

``res`` is a :class:`TopKResult`: per-query ``indices``/``distances``
(Q, k), per-query ``raw_accesses`` / ``pruned_fraction``, and the
store-level deduplicated access count + modeled I/O seconds.
``verify_candidates`` is the hook for distributed serving:
``core.distributed.repr_topk_sharded`` produces the candidate frontier,
the engine verifies it against raw storage
(``core.distributed.make_engine_service`` wires the two together).

Batched-verification correctness argument
-----------------------------------------
The paper's sequential exact scan visits candidates in representation-
distance order and stops when best-so-far ED <= the next representation
distance; since every representation distance lower-bounds d_ED
(Appendix A.1–A.5), no pruned candidate can be the NN.  The engine
generalizes this to top-k and to fixed-size batches:

* Per query it maintains a best-k *frontier* (the k smallest verified
  true distances so far, with their indices).  The pruning threshold is
  the k-th best frontier distance — ``inf`` until k candidates are
  verified, so the first ceil(k / batch) batches are never pruned.
* Candidates are consumed in representation-distance order in batches
  of ``batch_size``.  Before verifying a batch, the engine checks
  ``kth_best < repr_dist(next unseen)``; because the candidate order is
  sorted, that single comparison lower-bounds *every* unseen candidate,
  so stopping there cannot drop a true top-k member (any unseen c has
  d_ED(q, c) >= d_repr(q, c) >= repr_dist(next) > kth_best).  The
  comparison is strict: a candidate whose bound exactly equals the k-th
  best could still TIE the k-th member's true distance and win on the
  (distance, dataset index) tie-break, so boundary-equal candidates are
  verified rather than pruned.
* Therefore the surviving frontier equals the sequential scan's result
  exactly; batching only over-fetches by at most one batch per query
  (the batch in flight when the threshold crossed).

Verification itself is batched on device: the surviving candidate rows
of *all* active queries are fetched from the store in one call (one
modeled seek per round instead of one per row) and distanced via the
Pallas kernel ``kernels.euclid.euclid_pallas`` — natively on TPU,
``interpret=True`` elsewhere.  The frontier merge uses
``jax.lax.top_k`` on device and a numpy lexicographic sort
(distance, index) on host; the host path is bit-identical to a numpy
brute-force scan because each row's distance is reduced over the same T
values in the same order regardless of batch shape.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.matching import RawStore


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass
class TopKResult:
    """Batched top-k matches.  Rows padded with index -1 / distance inf
    when fewer than k candidates exist."""

    indices: np.ndarray          # (Q, k) int64 dataset rows, best first
    distances: np.ndarray        # (Q, k) true d_ED (verifier dtype)
    raw_accesses: np.ndarray     # (Q,) candidates verified per query
    pruned_fraction: np.ndarray  # (Q,) 1 - raw_accesses / N
    store_accesses: int          # deduplicated physical row reads
    store_fetches: int           # batched fetch() calls (modeled seeks)
    io_seconds: float            # batch-accounted modeled I/O


# ---------------------------------------------------------------------------
# Verifiers: (union_rows (U, T), queries (Qa, T), gather (Qa, B)) -> (Qa, B)
# ---------------------------------------------------------------------------

def numpy_verifier(rows: np.ndarray, qs: np.ndarray,
                   gather: np.ndarray) -> np.ndarray:
    """Host verification, bit-identical to a numpy brute-force scan (each
    row's sum runs over the same contiguous T values)."""
    per_q = rows[gather]                             # (Qa, B, T)
    d2 = np.sum(np.square(per_q - qs[:, None, :]), axis=-1)
    return np.sqrt(d2)


def kernel_verifier(rows: np.ndarray, qs: np.ndarray,
                    gather: np.ndarray) -> np.ndarray:
    """Device verification through the Pallas euclid kernel (interpret
    mode off-TPU).  Each query is distanced against its own candidate
    rows only — one kernel launch per active query, all with the same
    (B, T) shape so repeated rounds hit the jit cache."""
    import jax.numpy as jnp
    from repro.kernels import ops

    per_q = rows[gather]                             # (Qa, B, T)
    out = np.empty(gather.shape, np.float32)
    for r in range(qs.shape[0]):
        d2 = np.asarray(ops.euclid_batch(
            jnp.asarray(per_q[r], jnp.float32),
            jnp.asarray(qs[r], jnp.float32)))
        out[r] = np.sqrt(np.maximum(d2, 0.0))
    return out


def make_verifier(mode: str) -> Callable:
    if mode == "numpy":
        return numpy_verifier
    if mode in ("kernel", "host"):
        # "host" is the host-side fallback of the device-resident
        # verification path: raw rows are fetched from the store (modeled
        # I/O oracle) but distanced through the SAME Pallas kernel math
        # the sharded device path uses, so the two are bit-identical
        return kernel_verifier
    if mode == "auto":
        import jax
        return kernel_verifier if jax.default_backend() == "tpu" \
            else numpy_verifier
    raise ValueError(f"unknown verify mode {mode!r}")


# ---------------------------------------------------------------------------
# Frontier merge: keep the k smallest of (frontier ++ batch) per query
# ---------------------------------------------------------------------------

def merge_topk_numpy(all_d: np.ndarray, all_i: np.ndarray, k: int):
    """(Qa, M) -> (Qa, k); ties broken by smaller dataset index, matching
    a stable argsort of the full distance array."""
    n_big = np.int64(np.iinfo(np.int64).max)
    tie = np.where(all_i < 0, n_big, all_i)
    out_d = np.empty((all_d.shape[0], k), all_d.dtype)
    out_i = np.empty((all_i.shape[0], k), np.int64)
    for r in range(all_d.shape[0]):
        sel = np.lexsort((tie[r], all_d[r]))[:k]
        out_d[r] = all_d[r][sel]
        out_i[r] = all_i[r][sel]
    return out_d, out_i


def merge_topk_device(all_d: np.ndarray, all_i: np.ndarray, k: int):
    """Device merge with the host tie-break contract: a lexicographic
    sort on the stable composite key (distance, dataset index), so ties
    at exactly-equal distances resolve to the smaller dataset index —
    same contract as ``merge_topk_numpy`` (padding index -1 sorts last).
    Runs at device precision (f32 when x64 is off): the returned
    distances are the ones the sort saw, keeping the frontier ascending
    and the k-th-best pruning threshold consistent — distances that are
    distinct in f64 but equal in f32 count as ties, the device merge's
    documented precision contract."""
    import jax.numpy as jnp
    d = jnp.asarray(all_d)
    i = jnp.asarray(all_i)
    tie = jnp.where(i < 0, jnp.iinfo(jnp.int32).max, i)
    sel = jnp.lexsort((tie, d), axis=-1)[:, :k]
    return (np.asarray(jnp.take_along_axis(d, sel, axis=1)),
            np.asarray(jnp.take_along_axis(i, sel, axis=1)).astype(np.int64))


# ---------------------------------------------------------------------------
# Core batched scan
# ---------------------------------------------------------------------------

def topk_verify(queries_raw, repr_dists, store: RawStore, *, k: int = 1,
                batch_size: int = 64, verifier: Callable = numpy_verifier,
                merge: Callable = merge_topk_numpy,
                init_d=None, init_i=None, col_ids=None,
                dist_fn: Optional[Callable] = None,
                on_verified: Optional[Callable] = None,
                stream=None, trace=None) -> TopKResult:
    """Exact top-k under d_ED for a query batch given lower-bounding
    representation distances (Q, N).  See the module docstring for the
    correctness argument.

    ``init_d`` / ``init_i``: optional (Q, <=k) already-verified frontier
    (sorted ascending, ties by index) to seed the best-k with — used by
    the index candidate source (``repro.index.candidates``) so tree seed
    candidates are not verified twice.  Seeded
    candidates must carry +inf in ``repr_dists`` (or be absent), otherwise
    they would enter the merge a second time.

    ``col_ids``: optional (N,) dataset row ids, one per ``repr_dists``
    column, STRICTLY INCREASING — lets a sparse caller pass only the
    surviving candidates instead of a full-corpus-width matrix (column j
    means row ``col_ids[j]``; ``pruned_fraction`` is then relative to the
    candidate set, not the corpus).

    ``dist_fn``: optional device-resident verification hook
    (``core.distributed``): ``dist_fn(q_idx, cand) -> (Qa, B) true
    distances`` for the active-query id batch, computed WITHOUT moving
    raw rows to the host — the store is never fetched (its accounting
    stays untouched: zero rows moved to host is the device path's
    truthful I/O).  ``-1`` candidate entries may return anything; they
    are masked to +inf here.

    ``on_verified``: optional ``on_verified(qi, ids, dists)`` callback
    fired once per verification round per active query with exactly the
    (dataset/window ids, true distances) that round verified — the hook
    exclusion widening uses to accumulate the every-id-verified-once
    frontier (``repro.subseq.SubseqEngine``).

    ``stream``: optional device-ordered candidate stream
    (``core.distributed.DeviceOrderedStream`` duck type: ``peek() ->
    (Q,) next unverified bound``, ``take(aq, batch) -> (len(aq), batch)
    GLOBAL ids, -1-padded, self-advancing``, ``width``) replacing
    ``repr_dists`` entirely — the (Q, N) bound matrix then never
    materializes on the host.  The stream already yields dataset ids,
    so it is mutually exclusive with ``col_ids``; the verification
    schedule is identical to the matrix path when the stream's order is
    (bound, id)-sorted, and the result is exact for ANY valid-bound
    order.

    ``trace``: optional ``repro.obs.Trace``.  Every recording site is
    guarded by ``trace is None`` and records copies after the round's
    computation — with no trace the loop executes the exact
    pre-observability instruction stream, and with one the results and
    store accounting stay bit-identical (property-tested in
    tests/test_obs_neutrality.py)."""
    import time as _time
    qs = np.asarray(queries_raw)        # native dtype: the host verifier
    if qs.ndim == 1:                    # stays bit-identical to brute force
        qs = qs[None]
    if stream is not None:
        assert repr_dists is None and col_ids is None, \
            "stream replaces the bound matrix and yields global ids"
        rd = None
        q_n, n = qs.shape[0], int(stream.width)
    else:
        rd = np.asarray(repr_dists)
        if rd.ndim == 1:
            rd = rd[None]
        q_n, n = rd.shape
        if col_ids is not None:
            col_ids = np.asarray(col_ids, np.int64)
            assert col_ids.shape == (n,), (col_ids.shape, n)

    init_w = 0
    if init_d is not None:
        init_d = np.asarray(init_d, np.float64)
        init_i = np.asarray(init_i, np.int64)
        if init_d.ndim == 1:
            init_d, init_i = init_d[None], init_i[None]
        init_w = init_d.shape[1]
    k = min(k, n + init_w)
    front_d = np.full((q_n, k), np.inf, np.float64)
    front_i = np.full((q_n, k), -1, np.int64)
    if init_w:
        m = min(k, init_w)
        front_d[:, :m] = init_d[:, :m]
        front_i[:, :m] = init_i[:, :m]
    if n == 0:                          # nothing to scan: seeded frontier
        return TopKResult(indices=front_i, distances=front_d,
                          raw_accesses=np.zeros(q_n, np.int64),
                          pruned_fraction=np.ones(q_n),
                          store_accesses=0, store_fetches=0, io_seconds=0.0)
    if stream is None:
        order = np.argsort(rd, axis=1, kind="stable")
        sorted_d = np.take_along_axis(rd, order, axis=1)
        # +inf bounds mark non-candidates (e.g. another query's rows in a
        # sparse sweep, or already-seeded members): they must never enter a
        # verification batch, even as over-fetch — a seeded member verified
        # again would enter the merge twice
        n_fin = np.isfinite(rd).sum(axis=1)
    pos = np.zeros(q_n, np.int64)
    acc = np.zeros(q_n, np.int64)
    start_acc, start_fetch = store.accesses, store.fetches
    if trace is not None:                # candidates handed to this scan
        if stream is None:
            gen = n_fin.astype(np.int64)
            # id layer behind the accumulated count: exclusion widening
            # re-hands surviving candidates every round, so the summed
            # "generated" over-counts — the noted ids dedup it into the
            # per-query "generated_unique" the engines finalize
            note = getattr(trace, "note_ids", None)
            if note is not None:
                for qi in range(q_n):
                    fin = np.nonzero(np.isfinite(rd[qi]))[0]
                    note("generated", qi,
                         col_ids[fin] if col_ids is not None else fin)
        else:
            nf = getattr(stream, "n_finite", None)
            gen = (np.asarray(nf, np.int64) if nf is not None
                   else np.full(q_n, n, np.int64))
            # a stream never re-hands an id, so its count is already a
            # dedup count — no host-side id materialization needed
            note = getattr(trace, "note_counts", None)
            if note is not None:
                note("generated", gen)
        trace.add("generated", gen)

    while True:
        # >= (not >): a candidate whose bound ties the k-th best verified
        # distance may tie it in true distance too and then win on the
        # smaller dataset index — it must be verified, not pruned.  The
        # finite guard keeps +inf-bound candidates (e.g. the masked rows
        # of a seeded index sweep) out of the scan entirely; a stream
        # peeks +inf past its finite frontier, so the guard doubles as
        # its exhaustion check.
        if stream is None:
            nxt = sorted_d[np.arange(q_n), np.minimum(pos, n - 1)]
            active = (pos < n) & np.isfinite(nxt) & (front_d[:, -1] >= nxt)
        else:
            nxt = stream.peek()
            active = np.isfinite(nxt) & (front_d[:, -1] >= nxt)
        if not active.any():
            break
        aq = np.nonzero(active)[0]
        t_round = _time.perf_counter() if trace is not None else 0.0
        if stream is None:
            cand = np.full((len(aq), batch_size), -1, np.int64)
            for r, qi in enumerate(aq):
                c = order[qi, pos[qi]:min(pos[qi] + batch_size, n_fin[qi])]
                cand[r, :len(c)] = c
            if col_ids is not None:      # column -> dataset row translation
                cand = np.where(cand >= 0, col_ids[cand], -1)
        else:                            # global ids straight off device
            cand = np.asarray(stream.take(aq, batch_size), np.int64)
        mask = cand >= 0
        if dist_fn is not None:          # device-resident: no host fetch
            d = np.asarray(dist_fn(aq, cand))
        else:
            ids = np.unique(cand[mask])          # sorted
            rows = store.fetch(ids)              # one physical fetch/round
            gather = np.searchsorted(ids, np.where(mask, cand, ids[0]))
            d = verifier(rows, qs[aq], gather)
        d = np.where(mask, d, np.inf)
        if on_verified is not None:
            for r, qi in enumerate(aq):
                on_verified(int(qi), cand[r][mask[r]],
                            np.asarray(d[r][mask[r]], np.float64))

        new_d, new_i = merge(np.concatenate([front_d[aq], d], axis=1),
                             np.concatenate([front_i[aq], cand], axis=1), k)
        front_d[aq] = new_d
        front_i[aq] = new_i
        n_real = mask.sum(axis=1)
        acc[aq] += n_real
        if stream is None:               # a stream advances its own cursor
            pos[aq] += n_real
        if trace is not None:            # round telemetry: the k-th-best
            trace.record_round(          # threshold AFTER this merge
                phase="scan", active=int(len(aq)),
                examined=int(n_real.sum()), kth=front_d[aq, -1].copy(),
                wall_s=_time.perf_counter() - t_round)

    total = store.accesses - start_acc
    n_fetch = store.fetches - start_fetch
    io_s = store.modeled_io_seconds(total, n_fetch)
    if trace is not None:
        trace.add("examined", acc)
        trace.add("verified", acc)
        trace.add("rows_fetched", int(total))
        trace.add("seeks", int(n_fetch))
        trace.add("modeled_io_s", float(io_s))
    return TopKResult(indices=front_i, distances=front_d,
                      raw_accesses=acc,
                      pruned_fraction=1.0 - acc / n,
                      store_accesses=total, store_fetches=n_fetch,
                      io_seconds=io_s)


def verify_candidates(queries_raw, cand_idx, store: RawStore, *,
                      k: Optional[int] = None,
                      verifier: Callable = numpy_verifier,
                      merge: Callable = merge_topk_numpy,
                      dist_fn: Optional[Callable] = None,
                      on_verified: Optional[Callable] = None,
                      trace=None, trace_phase: str = "seed") -> TopKResult:
    """Approximate top-k: verify an externally supplied candidate set
    (e.g. the sharded representation top-k) and rank by true d_ED.
    cand_idx: (Q, C) dataset rows; -1 entries are padding.  ``dist_fn``
    / ``on_verified``: same contracts as :func:`topk_verify` — with a
    ``dist_fn`` the store is never fetched (device-resident
    verification).  ``trace`` records this call as one verification
    round labelled ``trace_phase`` ("seed" for the tree seed walk,
    "approx" for the approximate path)."""
    import time as _time
    t0 = _time.perf_counter() if trace is not None else 0.0
    qs = np.asarray(queries_raw)
    if qs.ndim == 1:
        qs = qs[None]
    cand = np.asarray(cand_idx, np.int64)
    if cand.ndim == 1:
        cand = cand[None]
    q_n, c = cand.shape
    k = c if k is None else min(k, c)
    # candidate-id space size: windows for a WindowView (``n``), rows
    # for a raw/symbolic store
    n = getattr(store, "n", None)
    if n is None:
        n = store.data.shape[0]
    mask = cand >= 0
    ids = np.unique(cand[mask])
    if ids.size == 0:
        return TopKResult(indices=np.full((q_n, k), -1, np.int64),
                          distances=np.full((q_n, k), np.inf),
                          raw_accesses=np.zeros(q_n, np.int64),
                          pruned_fraction=np.ones(q_n),
                          store_accesses=0, store_fetches=0,
                          io_seconds=0.0)
    start_acc, start_fetch = store.accesses, store.fetches
    if dist_fn is not None:                      # device-resident path
        d = np.asarray(dist_fn(np.arange(q_n), cand))
    else:
        rows = store.fetch(ids)                  # one batched fetch
        gather = np.searchsorted(ids, np.where(mask, cand, ids[0]))
        d = verifier(rows, qs, gather)
    d = np.where(mask, d, np.inf)
    if on_verified is not None:
        for r in range(q_n):
            on_verified(r, cand[r][mask[r]],
                        np.asarray(d[r][mask[r]], np.float64))
    out_d, out_i = merge(d, cand, k)
    total = store.accesses - start_acc
    n_fetch = store.fetches - start_fetch
    acc = mask.sum(axis=1)
    io_s = store.modeled_io_seconds(total, n_fetch)
    if trace is not None:
        trace.add("generated", acc.astype(np.int64))
        note = getattr(trace, "note_ids", None)
        if note is not None:
            for r in range(q_n):
                note("generated", r, cand[r][mask[r]])
        trace.add("examined", acc.astype(np.int64))
        trace.add("verified", acc.astype(np.int64))
        trace.add("rows_fetched", int(total))
        trace.add("seeks", int(n_fetch))
        trace.add("modeled_io_s", float(io_s))
        trace.record_round(phase=trace_phase, active=q_n,
                           examined=int(acc.sum()),
                           kth=out_d[:, -1].copy(),
                           wall_s=_time.perf_counter() - t0)
    return TopKResult(indices=out_i, distances=out_d, raw_accesses=acc,
                      pruned_fraction=1.0 - acc / n,
                      store_accesses=total, store_fetches=n_fetch,
                      io_seconds=io_s)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class DeviceRepCache:
    """Device-resident copy of a live representation — anything with the
    ``rep_view()`` + ``version`` protocol (``SymbolicStore``,
    ``subseq.WindowView``) — refreshed only when the version changes, so
    appends are served without paying a host->device transfer per query."""

    def __init__(self, store):
        self._store = store
        self._val = None
        self._version = -1

    def get(self):
        if self._version != self._store.version:
            import jax.numpy as jnp
            view = self._store.rep_view()
            leaves = view if isinstance(view, tuple) else (view,)
            dev = tuple(jnp.asarray(l) for l in leaves)
            self._val = dev if isinstance(view, tuple) else dev[0]
            self._version = self._store.version
        return self._val


class MatchEngine:
    """Batched multi-query top-k matcher over one encoder + store.

    Parameters
    ----------
    encoder:    SAX / SSAX / TSAX / STSAX / OneDSAX instance.
    store:      preferably a ``repro.store.SymbolicStore`` — it already
                owns the live representation, so construction is free and
                rows appended to it are served by the very next query
                (streaming ingestion).  A bare ``RawStore`` over the
                (N, T) raw dataset is still accepted; the engine then
                pays a one-shot encode at construction (the legacy
                static-corpus behaviour).
    batch_size: verification batch per query per round.
    verify:     "auto" (kernel on TPU, numpy host elsewhere), "kernel"
                (always route through euclid_pallas; interpret off-TPU),
                "numpy" (bit-identical to a host brute-force scan),
                "host" (alias of "kernel": the host-side fallback of the
                device-resident path — store fetch + modeled I/O, same
                kernel distance math as "device"), or "device"
                (device-resident sharded verification: raw rows never
                move to the host; requires ``dist_factory``, wired by
                ``core.distributed.make_engine_service``; bit-identical
                to "host").
    rep:        precomputed dataset representation (skips encode), e.g.
                the sharded output of ``distributed.encode_sharded``.
    repr_fn:    override for representation distances
                (queries_raw -> (Q, N)); used by the sharded service.
    cand_fn:    override for approximate candidates
                (queries_raw, k -> (Q, k) indices).
    stream_factory: override producing a device-ordered candidate
                stream for exact top-k (queries_raw ->
                ``distributed.DeviceOrderedStream``); when set, the
                linear sweep and the index source feed ``topk_verify``
                through the stream — the (Q, N) bound matrix never
                materializes on the host.  Wired by
                ``core.distributed.make_engine_service``.

    Candidate sources: exact ``topk`` consumes candidates from a
    ``repro.index.candidates.CandidateSource``.  The default is the
    linear lower-bound sweep; pass ``source="index"`` (or any source
    object) to generate candidates sublinearly from the backing store's
    split-tree index (``store.build_index()``) — bit-identical results,
    same k-th-best early-stop verification.
    """

    def __init__(self, encoder, store, *, batch_size: int = 64,
                 verify: str = "auto", pairwise: Callable | None = None,
                 rep=None, repr_fn: Callable | None = None,
                 cand_fn: Callable | None = None,
                 device_merge: bool = False,
                 dist_factory: Callable | None = None,
                 stream_factory: Callable | None = None,
                 metrics=None):
        self.encoder = encoder
        self.store = store
        self.batch_size = batch_size
        self.verify_mode = verify
        # opt-in repro.obs.MetricsRegistry: per-query counters and
        # latency histograms; None (the default) records nothing
        self.metrics = metrics
        self.device_verify = verify == "device"
        if self.device_verify and dist_factory is None:
            raise ValueError(
                'verify="device" needs a dist_factory (device-resident '
                "sharded verification; build the engine through "
                "core.distributed.make_engine_service)")
        self._dist_factory = dist_factory
        # the device path's host twin is the kernel verifier: same f32
        # distance definition, so "device" and "host" are bit-identical
        self.verifier = (kernel_verifier if self.device_verify
                         else make_verifier(verify))
        self.merge = (merge_topk_device
                      if device_merge or self.device_verify
                      else merge_topk_numpy)
        self._pw = pairwise or encoder.pairwise_distance
        self._repr_fn = repr_fn
        self._cand_fn = cand_fn
        self._stream_factory = stream_factory
        self._sym = store if hasattr(store, "rep_view") else None
        if self._sym is not None and self._sym.encoder != encoder:
            raise ValueError("SymbolicStore was built for a different "
                             "encoder configuration than this engine's")
        self._rep_cache = (DeviceRepCache(self._sym)
                           if self._sym is not None else None)
        if rep is not None or repr_fn is not None:
            self._rep = rep
        elif self._sym is not None:
            self._rep = None             # live view, refreshed on append
        else:
            import jax.numpy as jnp
            self._rep = encoder.encode(jnp.asarray(store.data))

    @property
    def rep(self):
        """Dataset representation: when backed by a ``SymbolicStore``, a
        device-resident copy of the store's live representation
        (``DeviceRepCache``); else the construction-time (or explicitly
        passed) representation."""
        if self._rep is not None:
            return self._rep
        if self._rep_cache is None:
            return None
        return self._rep_cache.get()

    def append(self, rows) -> np.ndarray:
        """Ingest rows into the backing ``SymbolicStore`` (incremental
        encode); they are matchable on the next ``topk`` call."""
        if self._sym is None:
            raise TypeError("append() needs a SymbolicStore-backed engine; "
                            "this one wraps a static RawStore")
        return self._sym.append(rows)

    # -- representation sweep -------------------------------------------
    def encode_queries(self, queries_raw):
        import jax.numpy as jnp
        return self.encoder.encode(jnp.asarray(queries_raw, jnp.float32))

    def repr_distances(self, queries_raw) -> np.ndarray:
        """(Q, N) lower-bounding representation distances."""
        if self._repr_fn is not None:
            return np.asarray(self._repr_fn(queries_raw))
        return np.asarray(self._pw(self.encode_queries(queries_raw),
                                   self.rep))

    def candidates(self, queries_raw, k: int) -> np.ndarray:
        """(Q, k) approximate candidates by representation distance."""
        if self._cand_fn is not None:
            return np.asarray(self._cand_fn(queries_raw, k))
        rd = self.repr_distances(queries_raw)
        k = min(k, rd.shape[1])
        if k == 0:
            return np.empty((rd.shape[0], 0), np.int64)
        part = np.argpartition(rd, k - 1, axis=1)[:, :k]
        part_d = np.take_along_axis(rd, part, axis=1)
        return np.take_along_axis(part, np.argsort(part_d, axis=1,
                                                   kind="stable"), axis=1)

    def index_source(self, epoch=None):
        """The backing store's split-tree index as a candidate source
        (``store.build_index()`` first).  With a ``stream_factory``
        present the tree's union bounds are device-ordered too
        (``device_order=True``).  ``epoch`` restricts generation to the
        items indexed before that frontier."""
        idx = getattr(self.store, "index", None)
        if idx is None:
            raise ValueError("store has no index; call "
                             "store.build_index() first")
        return idx.source(device_order=self._stream_factory is not None,
                          epoch=epoch)

    # -- matching --------------------------------------------------------
    def topk(self, queries_raw, k: int = 1, *, exact: bool = True,
             batch_size: Optional[int] = None, expand: int = 4,
             source=None, trace=None, explain: bool = False,
             epoch=None) -> TopKResult:
        """Top-k matches for a (Q, T) query batch (or a single (T,) query).

        exact=True:  pruned scan, provably identical to brute force.
                     ``source`` picks the candidate generator: None for
                     the linear lower-bound sweep, "index" for the
                     store's split-tree index, or any
                     ``CandidateSource`` — all bit-identical.
        exact=False: verify the top ``k * expand`` representation
                     candidates only (the paper's approximate matching,
                     generalized to k-NN); ``source`` is ignored.

        epoch: pin the answer to a published corpus frontier
        (``repro.store.CorpusEpoch`` or a plain row count).  Only rows
        with id < ``epoch.n_rows`` are generated, verified or returned
        — exact results are bit-identical to a frozen copy of the store
        truncated to that epoch, regardless of concurrent ``append`` /
        ``ingest`` (the store is append-only, so the epoch prefix is
        immutable).  None (the default) serves the live frontier.
        Sources passed as OBJECTS must already carry their own epoch
        (``SeriesIndex.source(epoch=...)``); the string/None forms are
        epoch-wired here.

        trace / explain: ``trace`` records a per-query ``repro.obs``
        query trace into the given object; ``explain=True`` creates one
        and attaches it to the result as ``res.trace`` (render with
        ``repro.obs.render_trace``).  Tracing never changes results or
        store accounting (observability neutrality, property-tested).
        """
        import time as _time
        from repro.store.symbolic import epoch_rows
        qs = np.asarray(queries_raw)
        if qs.ndim == 1:
            qs = qs[None]
        if explain and trace is None:
            from repro.obs import Trace
            trace = Trace("match.topk")
        total = getattr(self.store, "n", None)
        if total is None:
            total = self.store.data.shape[0]
        n_e = epoch_rows(epoch)
        if n_e is not None:
            total = min(total, n_e)
        observing = trace is not None or self.metrics is not None
        t0 = _time.perf_counter() if observing else 0.0
        sweep = getattr(self, "sweep", None)
        if trace is not None:
            approx_src = bool(getattr(source, "is_approx", False))
            src_name = ("index" if source == "index" else
                        "linear" if source is None else
                        "index-approx" if approx_src else
                        type(source).__name__)
            trace.meta.update(engine="match", k=int(k),
                              exact=bool(exact) and not approx_src,
                              q_n=int(qs.shape[0]), total=int(total),
                              source=src_name, verify=self.verify_mode)
            if n_e is not None:
                trace.meta["epoch_rows"] = int(n_e)
        hob0 = sweep.host_order_bytes if sweep is not None else 0
        h2d0 = sweep.h2d_bytes if sweep is not None else 0
        dfn = self._make_dist_fn(qs)
        if exact:
            from repro.index.candidates import LinearSweep, topk_from_source
            if source is None:
                if n_e is None:
                    source = LinearSweep(self.repr_distances,
                                         stream_fn=self._stream_factory)
                else:
                    # epoch-clamped linear sweep: the stream masks rows
                    # past the frontier to +inf ON DEVICE (they never
                    # reach verification); the host matrix path trims
                    # columns to the epoch prefix — both are exactly
                    # the sweep a store truncated at the epoch would run
                    stream_fn = None
                    if self._stream_factory is not None:
                        def stream_fn(q, _n=n_e):
                            return self._stream_factory(
                                q, mask_fn=lambda ids: ids >= _n)
                    source = LinearSweep(
                        lambda q, _n=n_e: self.repr_distances(q)[:, :_n],
                        stream_fn=stream_fn)
            elif source == "index":
                source = self.index_source(epoch=n_e)
            res = topk_from_source(
                qs, source, self.store, k=k,
                batch_size=batch_size or self.batch_size,
                verifier=self.verifier, merge=self.merge, total=total,
                dist_fn=dfn, trace=trace)
        else:
            from repro.obs.trace import maybe_span
            with maybe_span(trace, "order"):
                cand = self.candidates(qs, k * max(expand, 1))
                if n_e is not None:
                    # epoch filter on the approximate frontier: rows
                    # past the pinned frontier are dropped (-1 padding,
                    # ignored by verification), never returned
                    cand = np.where(cand < n_e, cand, -1)
            with maybe_span(trace, "verify"):
                res = verify_candidates(
                    qs, cand, self.store, k=k, verifier=self.verifier,
                    merge=self.merge, dist_fn=dfn, trace=trace,
                    trace_phase="approx")
        if observing:
            self._observe(trace, res, sweep, total, qs.shape[0],
                          _time.perf_counter() - t0, hob0, h2d0)
        if trace is not None:
            res.trace = trace
        return res

    def topk_approx(self, queries_raw, k: int = 1, *,
                    collect: Optional[int] = None, trace=None,
                    explain: bool = False, epoch=None) -> TopKResult:
        """Anytime/approximate top-k with a per-query error bar.

        When the backing store carries a split-tree index, routes
        through ``TreeCandidates`` approximate mode: the exact seed walk
        runs in full, then the collect phase keeps only the ``collect``
        best-bound survivors (default ``max(4 * k, 32)``).  The result
        carries ``res.kth_lb`` (the k-th smallest of verified true
        distances and the DROPPED candidates' lower bounds — a certified
        lower bound on the true k-th-NN distance) and ``res.error_bar``
        (``d_k - kth_lb``, >= 0; zero proves the answer exact).  Without
        an index, falls back to the representation-top-k approximate
        path (``exact=False``), which has no dropped-bound certificate —
        ``kth_lb`` / ``error_bar`` are then absent."""
        idx = getattr(self.store, "index", None)
        if idx is None:
            return self.topk(queries_raw, k=k, exact=False, trace=trace,
                             explain=explain, epoch=epoch)
        src = idx.source(device_order=self._stream_factory is not None,
                         approx_collect=(collect if collect is not None
                                         else max(4 * k, 32)),
                         epoch=epoch)
        return self.topk(queries_raw, k=k, source=src, trace=trace,
                         explain=explain, epoch=epoch)

    def _observe(self, trace, res: TopKResult, sweep, total: int,
                 q_n: int, wall_s: float, hob0: int, h2d0: int) -> None:
        """Post-call recording: transfer deltas, pruning power, registry
        metrics.  Runs only when a trace or a registry is attached and
        only AFTER the result exists — it cannot perturb matching."""
        hob = (sweep.host_order_bytes - hob0) if sweep is not None else None
        h2d = (sweep.h2d_bytes - h2d0) if sweep is not None else None
        # the device path never fetches the store; any store accesses
        # during a device-verified call ARE rows moved to the host
        rth = int(res.store_accesses) if self.device_verify else None
        if trace is not None:
            trace.set("wall_s", wall_s)
            trace.set("pruning_power", res.pruned_fraction.copy())
            gu = trace.unique_counts("generated", q_n) \
                if hasattr(trace, "unique_counts") else None
            if gu is not None:
                trace.set("generated_unique", gu)
            if sweep is not None:
                trace.set("host_order_bytes", int(hob))
                trace.set("h2d_bytes", int(h2d))
            if rth is not None:
                trace.set("rows_to_host", rth)
        if self.metrics is not None:
            m = self.metrics
            m.counter("match.queries").inc(q_n)
            m.counter("match.candidates_verified").inc(
                int(res.raw_accesses.sum()))
            m.counter("match.rows_fetched").inc(int(res.store_accesses))
            m.counter("match.seeks").inc(int(res.store_fetches))
            m.counter("match.modeled_io_s").inc(float(res.io_seconds))
            m.gauge("match.pruning_power").set(
                float(res.pruned_fraction.mean()))
            m.histogram("match.topk_latency_s").observe(wall_s)
            if hob is not None:
                m.counter("match.host_order_bytes").inc(int(hob))
                m.counter("match.h2d_bytes").inc(int(h2d))
            if rth is not None:
                m.counter("match.rows_to_host").inc(rth)

    def _make_dist_fn(self, qs) -> Optional[Callable]:
        """Device-resident verification closure for this query batch
        (None outside verify="device")."""
        if not self.device_verify:
            return None
        return self._dist_factory(qs)

    def verify_candidates(self, queries_raw, cand_idx,
                          k: Optional[int] = None) -> TopKResult:
        """Rank an external candidate frontier by true d_ED (one batched
        raw fetch; device-resident under verify="device")."""
        qs = np.asarray(queries_raw)
        if qs.ndim == 1:
            qs = qs[None]
        return verify_candidates(qs, cand_idx, self.store, k=k,
                                 verifier=self.verifier, merge=self.merge,
                                 dist_fn=self._make_dist_fn(qs))
