"""Z-normalization — the paper's precondition (4): zero sample mean, unit
sample variance per series."""

from __future__ import annotations

import jax.numpy as jnp


def znormalize(x, axis: int = -1, eps: float = 1e-12):
    """Normalize each series to mean 0 / variance 1 along ``axis``."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    sd = jnp.std(x, axis=axis, keepdims=True)
    return (x - mu) / jnp.maximum(sd, eps)
