"""Time-series matching (paper §4.1) on top of any lower-bounding
representation distance.

Exact matching: the paper scans candidates in representation-distance order
and stops when best-so-far ED <= next representation distance.  That
per-candidate control flow is TPU-hostile, so the engine works in fixed-size
*verification batches* (DESIGN.md §3): sort once, verify a batch of raw
candidates, tighten best-so-far, and stop at the first batch whose leading
representation distance already exceeds best-so-far.  Because the
representation distance lower-bounds ED, no pruned candidate can win —
results are identical to the paper's scan, and the number of raw accesses
differs by at most one batch of padding.

A ``RawStore`` abstracts the cold storage the paper keeps on HDD/SSD; the
cost model converts raw accesses into modeled I/O time at configurable
rates so the Table-5 experiment can be reproduced without a 100 Gb disk.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np


def euclidean(a, b):
    """d_ED (Eq. 3) along the last axis."""
    return jnp.sqrt(jnp.sum(jnp.square(a - b), axis=-1))


def pairwise_euclidean(q, x):
    """(Q, T) x (N, T) -> (Q, N)."""
    d2 = (jnp.sum(q * q, -1)[:, None] + jnp.sum(x * x, -1)[None, :]
          - 2.0 * q @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


# ---------------------------------------------------------------------------
# Raw store (simulated cold storage)
# ---------------------------------------------------------------------------

# (seek_seconds, bytes_per_second) presets — the single source of truth,
# shared by the RawStore constructors below and repro.store.SymbolicStore
MEDIA = {
    "hdd": (5e-3, 150e6),
    "ssd": (6e-5, 500e6),
    "hbm": (1e-7, 819e9),
}


@dataclass
class RawStore:
    """Raw time-series access with an I/O cost model.

    rates are (seek_seconds, bytes_per_second); defaults model the paper's
    HDD.  ``hbm()`` models the TPU-resident configuration where the raw
    shard lives in device memory — the paper's disk-bound gap becomes a
    bandwidth gap (DESIGN.md §8.1).
    """

    data: np.ndarray                  # (N, T) float32
    seek_s: float = 5e-3
    read_bps: float = 150e6
    accesses: int = 0                 # rows read
    fetches: int = 0                  # fetch() calls (modeled seeks)

    @staticmethod
    def hdd(data):
        return RawStore(data, *MEDIA["hdd"])

    @staticmethod
    def ssd(data):
        return RawStore(data, *MEDIA["ssd"])

    @staticmethod
    def hbm(data):
        return RawStore(data, *MEDIA["hbm"])

    def fetch(self, idx) -> np.ndarray:
        idx = np.asarray(idx)
        if idx.dtype == bool:            # boolean masks keep working
            idx = np.nonzero(idx)[0]
        idx = idx.astype(np.int64)
        if idx.size == 0:
            # an all-pruned round touches no media: no seek, no rows
            # (np.asarray([]) would otherwise arrive float64 and crash
            # the gather)
            return np.empty((0,) + self.data.shape[1:], self.data.dtype)
        # a physical row is read once per fetch no matter how many times
        # it appears in idx (subsequence verification asks for overlapping
        # windows of the same underlying rows) — bill deduplicated
        self.accesses += int(np.unique(idx).size)
        self.fetches += 1
        return self.data[idx]

    def modeled_io_seconds(self, n_accesses: Optional[int] = None,
                           n_fetches: Optional[int] = None) -> float:
        """Batch-accounted I/O model: one seek per fetch() call plus a
        bandwidth term per row.  With an explicit ``n_accesses`` and no
        ``n_fetches`` every access pays its own seek (the paper's
        row-at-a-time baseline)."""
        if n_accesses is None:
            n, f = self.accesses, self.fetches
        else:
            n = int(n_accesses)
            f = n if n_fetches is None else int(n_fetches)
        bytes_per = self.data.shape[-1] * 4
        return f * self.seek_s + n * bytes_per / self.read_bps

    def reset_counters(self):
        """Zero the I/O accounting (``accesses`` / ``fetches``) without
        touching anything else — the phase boundary every benchmark /
        launcher measurement should call so counters never bleed from
        one measured run into the next (a reused store otherwise keeps
        accumulating and the later phase under- or over-reports)."""
        self.accesses = 0
        self.fetches = 0

    def reset(self):
        self.reset_counters()


# ---------------------------------------------------------------------------
# Exact matching with lower-bound pruning
# ---------------------------------------------------------------------------

@dataclass
class MatchResult:
    index: int
    distance: float
    raw_accesses: int
    pruned_fraction: float
    repr_distances: Optional[np.ndarray] = None


def exact_match(query_raw, repr_dists, store: RawStore, *,
                batch_size: int = 64) -> MatchResult:
    """Exact nearest neighbour under d_ED using lower-bounding repr dists.

    query_raw: (T,) raw query.  repr_dists: (N,) representation distances
    of the query to every stored series.  store: raw access for
    verification.  Thin single-query wrapper over the batched k-NN core
    (``core.engine.topk_verify``) with the host verifier, so results are
    bit-identical to the historical sequential loop.
    """
    from repro.core.engine import topk_verify
    res = topk_verify(np.asarray(query_raw)[None],
                      np.asarray(repr_dists)[None], store,
                      k=1, batch_size=batch_size)
    return MatchResult(index=int(res.indices[0, 0]),
                       distance=float(res.distances[0, 0]),
                       raw_accesses=int(res.raw_accesses[0]),
                       pruned_fraction=float(res.pruned_fraction[0]))


def approximate_match(query_raw, repr_dists, store: RawStore, *,
                      rtol: float = 1e-6) -> MatchResult:
    """Paper's approximate matching: min representation distance; ties
    broken by true ED among the tied set."""
    repr_dists = np.asarray(repr_dists)
    N = repr_dists.shape[0]
    dmin = repr_dists.min()
    ties = np.nonzero(repr_dists <= dmin + rtol * (1.0 + dmin))[0]
    start0 = store.accesses
    if len(ties) == 1:
        idx = int(ties[0])
        rows = store.fetch(np.asarray([idx]))
        d = float(np.sqrt(np.sum((rows[0] - np.asarray(query_raw)) ** 2)))
    else:
        rows = store.fetch(ties)
        ds = np.sqrt(np.sum((rows - np.asarray(query_raw)[None]) ** 2, -1))
        j = int(np.argmin(ds))
        idx, d = int(ties[j]), float(ds[j])
    return MatchResult(index=idx, distance=d,
                       raw_accesses=store.accesses - start0,
                       pruned_fraction=1.0 - (store.accesses - start0) / N)


def pruning_power(query_raw, repr_dists, raw_data, k: int = 1) -> float:
    """Fraction of observations never verified (paper, Chen et al. [3]):
    with the true k-NN distance d*_k, everything with repr dist > d*_k is
    pruned.  k=1 is the paper's definition; k>1 measures the k-NN
    generalization served by ``core.engine.MatchEngine``."""
    d_true = np.sqrt(np.sum((np.asarray(raw_data)
                             - np.asarray(query_raw)[None]) ** 2, -1))
    d_star = np.sort(d_true)[min(k, d_true.shape[0]) - 1]
    repr_dists = np.asarray(repr_dists)
    return float(np.mean(repr_dists > d_star))


def tightness_of_lower_bound(repr_d, true_d, eps: float = 1e-12):
    """TLB (Eq. 33) averaged over all pairs; inputs (..., ) matched."""
    r = np.asarray(repr_d, dtype=np.float64)
    t = np.asarray(true_d, dtype=np.float64)
    mask = t > eps
    return float(np.mean(np.where(mask, r / np.maximum(t, eps), 1.0)))
