"""SAX extensions from the paper's §2.4 survey (Table 1), implemented as
additional baselines: ESAX, SAX_SD, TD-SAX.

These are *survey* baselines — the paper's own evaluation compares against
SAX and 1d-SAX only; we include them for the Table-1 property benchmark
(representation size / #lookups / lower-bounding) and for extra TLB
ablations.  Distances follow the cited originals; each one states whether
it is lower-bounding.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp

from repro.core.breakpoints import discretize, gaussian_breakpoints
from repro.core.paa import paa
from repro.core.sax import cell_table


@dataclass(frozen=True)
class ESAX:
    """ESAX (Lkhagva et al. 2006): (min, mean, max) symbol per segment.

    Lower-bounding: the mean-symbol MINDIST term alone already
    lower-bounds d_ED; the min/max terms are used only as tie-sharpeners
    in the original (which proposes max over feature distances — NOT
    guaranteed LB).  We use the safe variant: distance = SAX MINDIST on
    the mean symbols (LB), and expose ``distance_maxfeat`` for the
    original behaviour.
    """

    T: int
    W: int
    A: int

    @property
    def bits(self) -> float:
        return 3 * self.W * math.log2(self.A)

    def encode(self, x):
        T, W = self.T, self.W
        xs = x.reshape(*x.shape[:-1], W, T // W)
        bp = gaussian_breakpoints(self.A, 1.0)
        return (discretize(jnp.min(xs, -1), bp),
                discretize(jnp.mean(xs, -1), bp),
                discretize(jnp.max(xs, -1), bp))

    def distance(self, ra, rb):
        tab = cell_table(gaussian_breakpoints(self.A, 1.0))
        c = tab[ra[1], rb[1]]
        return jnp.sqrt(self.T / self.W) * \
            jnp.sqrt(jnp.sum(jnp.square(c), axis=-1))

    def distance_maxfeat(self, ra, rb):
        tab = cell_table(gaussian_breakpoints(self.A, 1.0))
        cs = jnp.stack([tab[ra[i], rb[i]] for i in range(3)], axis=0)
        c = jnp.max(cs, axis=0)
        return jnp.sqrt(self.T / self.W) * \
            jnp.sqrt(jnp.sum(jnp.square(c), axis=-1))


@dataclass(frozen=True)
class SAXSD:
    """SAX_SD (Zan & Yamana 2016): mean symbol + raw stddev per segment.

    Distance adds the segment-stddev gap to MINDIST; LB per the original.
    Representation grows by 32 bits/segment (Table 1).
    """

    T: int
    W: int
    A: int

    @property
    def bits(self) -> float:
        return self.W * (math.log2(self.A) + 32)

    def encode(self, x):
        T, W = self.T, self.W
        xs = x.reshape(*x.shape[:-1], W, T // W)
        bp = gaussian_breakpoints(self.A, 1.0)
        return discretize(jnp.mean(xs, -1), bp), jnp.std(xs, -1)

    def distance(self, ra, rb):
        tab = cell_table(gaussian_breakpoints(self.A, 1.0))
        c = tab[ra[0], rb[0]]
        sd_gap = jnp.abs(ra[1] - rb[1])
        return jnp.sqrt(self.T / self.W) * \
            jnp.sqrt(jnp.sum(jnp.square(c) + jnp.square(sd_gap), axis=-1))


@dataclass(frozen=True)
class TDSAX:
    """TD-SAX (Sun et al. 2014): mean symbol + raw (start, end) trend values.

    Distance: MINDIST + weighted trend distance on the real-valued
    start/end deltas (not a LUT).  LB per the original's Theorem 1 with
    weight <= 1; we use the conservative w=0 trend weight in exact
    matching (pure MINDIST) and w=0.5 for accuracy experiments.
    """

    T: int
    W: int
    A: int
    trend_weight: float = 0.5

    @property
    def bits(self) -> float:
        return self.W * (math.log2(self.A) + 32) + 32

    def encode(self, x):
        T, W = self.T, self.W
        xs = x.reshape(*x.shape[:-1], W, T // W)
        bp = gaussian_breakpoints(self.A, 1.0)
        return (discretize(jnp.mean(xs, -1), bp),
                xs[..., 0], xs[..., -1])

    def distance(self, ra, rb):
        tab = cell_table(gaussian_breakpoints(self.A, 1.0))
        c = tab[ra[0], rb[0]]
        mind = (self.T / self.W) * jnp.sum(jnp.square(c), axis=-1)
        tr = jnp.sum(jnp.square(ra[1] - rb[1]) + jnp.square(ra[2] - rb[2]),
                     axis=-1)
        return jnp.sqrt(mind + self.trend_weight * tr)
