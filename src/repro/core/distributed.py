"""Distributed matching engine: the paper's pipeline mapped onto a JAX mesh.

The dataset of N series is sharded over the ("pod","data") axes; queries
are replicated.  One ``shard_map`` pass per stage:

  1. ``encode_sharded`` — representation construction (one pass/series,
     exactly the paper's "Representation Time = 1 pass" property, batched).
  2. ``repr_topk_sharded`` — symbolic distances on the local shard
     (Pallas ``sax_dist`` kernel where available, jnp otherwise), local
     top-k, then a global candidate merge via ``all_gather`` of k
     candidates per shard (collective volume independent of N — the
     property that scales to 1000+ nodes, DESIGN.md §3).
  3. Raw verification of the surviving candidates against the cold store
     via the batched k-NN engine (``core.engine.MatchEngine``):
     ``repr_topk_sharded`` produces the candidate frontier for
     approximate top-k, the sharded bound sweep the exact frontier —
     ``make_engine_service`` wires both into an engine whose raw
     verification is one batched fetch per round (host path) or never
     leaves the devices (``verify="device"``).

Shard layout contract (device mirrors)
--------------------------------------
Every device mirror (``RoundRobinMirror``) is laid out ROUND-ROBIN:
global row ``i`` lives on shard ``i % n_shards`` at local slot
``i // n_shards``, in a ``(n_shards, capacity, *rest)`` buffer whose
leading axis is sharded over the data axes.  A head-aligned append of
``d * n_shards`` rows therefore lands in slots
``[per_live, per_live + d)`` of EVERY shard — host->device traffic is
O(chunk) and the resident corpus is never re-laid-out, unlike a
contiguous-range layout where each append shifts every shard boundary
(O(corpus) collective re-layout).  Capacity doubles device-side
(``jnp.pad``, no host traffic), so amortized append cost stays O(chunk).
The largest shard-divisible prefix (the "head", always a multiple of
``n_shards``) lives in the mirrors; the < n_shards remainder (the
"tail") is swept host-side through the same kernel math and min-merged.

The ON-DISK layout is deliberately NOT the mirror layout: snapshots
(``store.snapshot``) keep contiguous per-host row ranges
(``_shard_ranges``) as their manifest unit — ``ShardedRepSweep.
shard_ranges()`` still reports those manifest ranges, while
``owned_rows()`` / ``mirror_layout`` describe the device placement.
Matching results are layout-independent (bit-identical either way)
because every per-(query, row) quantity is computed element-wise.

Device-resident candidate ORDER: the bound matrix never materializes on
the host for the exact path.  ``candidate_stream`` sorts the blocked
round-robin bound matrix (plus the tail) by ``(bound, id)`` once, on
device, and hands ``core.engine.topk_verify`` a
:class:`DeviceOrderedStream` — ``peek``/``take`` move only O(Q) /
O(Q·batch) scalars and ids per round, never the (Q, N) matrix
(``host_order_bytes`` stays 0; the legacy ``repr_distances`` matrix
path counts every byte it assembles there).

Device-resident verification (``verify="device"``): a verification
round hands the candidate id batch to every shard; each shard distances
its OWN candidates (ownership is ``id % n_shards``) through the
multi-query Pallas euclid kernel (``kernels.euclid``) and a device-side
min-merge combines shards.  The distance definition is the kernel's f32
reduction — identical math to the host ``verify="host"`` fallback
(store fetch + the same kernel), so the two paths are bit-identical;
the host ``verify="numpy"`` path stays the brute-force oracle with
modeled I/O.  Tail rows are distanced host-side through the same
kernel — they are already host-resident, so the device path still
moves zero raw rows device->host.

The helpers take any encoder with ``encode`` + ``pairwise_distance`` —
SAX, sSAX, tSAX and 1d-SAX all plug in.
"""

from __future__ import annotations

import threading
from functools import lru_cache, partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# The shard_map'd sweep callables are built once per (mesh, encoder /
# pairwise, pytree structure) and jitted: rebuilding the closure per
# call used to defeat jax's trace cache entirely, paying a full XLA
# recompile on EVERY sweep (tens of seconds for the richer encoders).
# The cached callables compile once per input shape and are shared by
# every engine over the same mesh.  The compiled body is unchanged, so
# results are unchanged.

@lru_cache(maxsize=64)
def _encode_fn(mesh: Mesh, encoder, out_def, out_ndims):
    axes = _data_axes(mesh)
    # representation leaves keep their leading N axis sharded; trailing
    # axes replicated
    spec_out = jax.tree.unflatten(
        out_def, [P(axes, *([None] * (nd - 1))) for nd in out_ndims])
    return jax.jit(shard_map(
        lambda x: encoder.encode(x), mesh=mesh, in_specs=(P(axes, None),),
        out_specs=spec_out, check_rep=False))


def encode_sharded(encoder, dataset, mesh: Mesh):
    """Encode a dataset sharded over the data axes.  dataset: (N, T)."""
    rep_struct = jax.eval_shape(encoder.encode,
                                jax.ShapeDtypeStruct(dataset.shape,
                                                     dataset.dtype))
    leaves, out_def = jax.tree.flatten(rep_struct)
    fn = _encode_fn(mesh, encoder, out_def,
                    tuple(len(l.shape) for l in leaves))
    return fn(dataset)


def rowwise_sharded(obj, method: str, rows, mesh: Mesh):
    """Run ``getattr(obj, method)`` — any pure row-wise device map with a
    (N, T) input — over ``rows`` sharded on the mesh data axes (pad to a
    shard multiple, trim) and return the same pytree of host arrays.

    The map runs EAGERLY on the sharded array (the row sharding
    propagates through every row-parallel op), deliberately NOT under
    ``jit(shard_map(...))``: eager dispatch executes the exact op-by-op
    kernels the host path runs, so the float output is bitwise identical
    to the unsharded call.  A jitted variant fuses differently and
    drifts by ulps — harmless for the QUANTIZED symbols
    :func:`encode_sharded` produces, fatal for the float features the
    split tree stores and compares (``index.features``)."""
    rows = np.asarray(rows, np.float32)
    if rows.ndim == 1:
        rows = rows[None]
    m = rows.shape[0]
    fn = getattr(obj, method)
    if m == 0:
        return jax.tree.map(np.asarray, fn(jnp.asarray(rows)))
    axes = _data_axes(mesh)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    pad = (-m) % n_shards
    if pad:
        rows = np.concatenate([rows, rows[-1:].repeat(pad, axis=0)])
    sharded = jax.device_put(jnp.asarray(rows, jnp.float32),
                             NamedSharding(mesh, P(axes, None)))
    return jax.tree.map(lambda l: np.asarray(l)[:m], fn(sharded))


def _rep_specs(rep_query, rep_data):
    """Hashable (treedefs, ndims) cache key for a (query, data) rep
    pair — enough to rebuild the P-specs (query replicated, data
    sharded on its leading axis)."""
    ql, q_def = jax.tree.flatten(rep_query)
    xl, x_def = jax.tree.flatten(rep_data)
    return (q_def, x_def, tuple(l.ndim for l in ql),
            tuple(l.ndim for l in xl))


@lru_cache(maxsize=64)
def _repr_dists_fn(mesh: Mesh, pw, q_def, x_def, q_ndims, x_ndims):
    axes = _data_axes(mesh)
    in_q = jax.tree.unflatten(q_def, [P(*([None] * nd)) for nd in q_ndims])
    in_x = jax.tree.unflatten(
        x_def, [P(axes, *([None] * (nd - 1))) for nd in x_ndims])
    return jax.jit(shard_map(
        lambda rq, rx: pw(rq, rx), mesh=mesh, in_specs=(in_q, in_x),
        out_specs=P(None, axes), check_rep=False))


def repr_distances_sharded(encoder, rep_query, rep_data, mesh: Mesh,
                           pairwise: Callable | None = None):
    """(Q, N) representation distances, N sharded.  Output replicated-Q,
    N-sharded."""
    pw = pairwise or encoder.pairwise_distance
    fn = _repr_dists_fn(mesh, pw, *_rep_specs(rep_query, rep_data))
    return fn(rep_query, rep_data)


@lru_cache(maxsize=64)
def _repr_topk_fn(mesh: Mesh, pw, k: int, q_def, x_def, q_ndims, x_ndims):
    axes = _data_axes(mesh)

    def local(rq, rx):
        d = pw(rq, rx)                                 # (Q, n_local)
        n_local = d.shape[1]
        kk = min(k, n_local)
        neg, idx = jax.lax.top_k(-d, kk)               # smallest distances
        gidx = idx + _shard_index(axes) * n_local      # global offset
        cand_d = jax.lax.all_gather(-neg, axes, axis=1, tiled=True)
        cand_i = jax.lax.all_gather(gidx, axes, axis=1, tiled=True)
        best_neg, best_pos = jax.lax.top_k(-cand_d, min(k, cand_d.shape[1]))
        best_i = jnp.take_along_axis(cand_i, best_pos, axis=1)
        return -best_neg, best_i

    in_q = jax.tree.unflatten(q_def, [P(*([None] * nd)) for nd in q_ndims])
    in_x = jax.tree.unflatten(
        x_def, [P(axes, *([None] * (nd - 1))) for nd in x_ndims])
    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=(in_q, in_x),
        out_specs=(P(None, None), P(None, None)), check_rep=False))


def repr_topk_sharded(encoder, rep_query, rep_data, mesh: Mesh, *,
                      k: int = 64, pairwise: Callable | None = None):
    """Global top-k candidate (distance, index) per query.

    Local shard computes distances + local top-k; k*shards candidates are
    all-gathered and reduced — collective volume O(Q*k*shards), never O(N).
    Returns (dists (Q, k), global indices (Q, k)).  Data is contiguously
    sharded on its leading axis (the :func:`encode_sharded` layout).
    """
    pw = pairwise or encoder.pairwise_distance
    fn = _repr_topk_fn(mesh, pw, int(k),
                       *_rep_specs(rep_query, rep_data))
    return fn(rep_query, rep_data)


# ---------------------------------------------------------------------------
# Round-robin device mirror
# ---------------------------------------------------------------------------

def _shard_index(axes):
    """Linear shard id of the executing program over the data axes."""
    sid = jax.lax.axis_index(axes[0])
    if len(axes) == 2:
        sid = sid * jax.lax.axis_size(axes[1]) + jax.lax.axis_index(axes[1])
    return sid


@lru_cache(maxsize=64)
def _rr_place_fn(mesh: Mesh, ndim: int):
    """Jitted in-place slot write: ``buf[:, start:start+d] = delta``,
    donating the old buffer — the per-append device work is O(chunk)
    window writes, never a corpus-wide concatenate."""
    axes = _data_axes(mesh)
    sh = NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))

    @partial(jax.jit, out_shardings=sh, donate_argnums=0)
    def place(buf, delta, start):
        zeros = (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, delta, (0, start) + zeros)

    return place


@lru_cache(maxsize=64)
def _rr_grow_fn(mesh: Mesh, ndim: int):
    """Jitted capacity growth (device-side zero-pad of the slot axis)."""
    axes = _data_axes(mesh)
    sh = NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))

    @partial(jax.jit, static_argnums=1, out_shardings=sh, donate_argnums=0)
    def grow(buf, new_cap):
        pad = [(0, 0)] * buf.ndim
        pad[1] = (0, new_cap - buf.shape[1])
        return jnp.pad(buf, pad)

    return grow


class RoundRobinMirror:
    """Append-local device mirror of host rows, sharded round-robin.

    Global row ``i`` lives on shard ``i % n_shards`` at local slot
    ``i // n_shards``; the device buffer is ``(n_shards, capacity,
    *rest)`` with the leading axis sharded over the mesh data axes.  An
    append of ``d * n_shards`` rows uploads exactly those rows
    (O(chunk) host->device, counted in ``h2d_bytes``) into slots
    ``[per_live, per_live + d)`` of every shard — the resident corpus
    is never re-uploaded or re-laid-out, unlike a contiguous-range
    layout where every append shifts every shard boundary.  Capacity
    doubles device-side when exhausted (``jnp.pad``, no host traffic),
    so amortized append cost stays O(chunk).  Slots ``>= per_live`` are
    dead padding; every consumer masks them via the ``per_live``
    scalar."""

    def __init__(self, mesh: Mesh, n_shards: int):
        self.mesh = mesh
        self.n_shards = int(n_shards)
        self.buf = None                  # (S, cap, *rest) device array
        self.per_live = 0                # live slots per shard
        self.h2d_bytes = 0               # host->device upload accounting

    @property
    def cap(self) -> int:
        return 0 if self.buf is None else self.buf.shape[1]

    @property
    def live(self) -> int:
        return self.per_live * self.n_shards

    def append(self, rows) -> None:
        """Upload ``rows`` (a head-aligned multiple of n_shards, in
        global row order) into the next free slot of every shard."""
        rows = np.asarray(rows)
        S = self.n_shards
        if rows.shape[0] % S:
            raise ValueError(f"append of {rows.shape[0]} rows is not a "
                             f"multiple of n_shards={S}")
        d = rows.shape[0] // S
        if d == 0:
            return
        rest = rows.shape[1:]
        # (d*S, ...) -> (S, d, ...): appended row j*S + s -> shard s,
        # slot per_live + j
        blk = np.ascontiguousarray(
            rows.reshape((d, S) + rest).swapaxes(0, 1))
        sh = NamedSharding(self.mesh, P(_data_axes(self.mesh),
                                        *([None] * len(rest))))
        dev = jax.device_put(blk, sh)
        self.h2d_bytes += blk.nbytes
        if self.buf is None:
            self.buf = dev
        else:
            if self.per_live + d > self.cap:
                new_cap = max(2 * self.cap, self.per_live + d)
                self.buf = _rr_grow_fn(self.mesh, self.buf.ndim)(
                    self.buf, new_cap)
            self.buf = _rr_place_fn(self.mesh, self.buf.ndim)(
                self.buf, dev, jnp.int32(self.per_live))
        self.per_live += d


# ---------------------------------------------------------------------------
# Round-robin sweeps (bounds, top-k, verification)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=64)
def _rr_bounds_fn(mesh: Mesh, pw, q_def, x_def, q_ndims, x_ndims):
    """(Q, S*cap) blocked bound matrix over round-robin mirrors: the
    block column ``s*cap + j`` holds global row ``j*S + s``; dead slots
    are +inf.  Output stays column-sharded on device — the host
    unpermute (``ShardedRepSweep.repr_distances``) is the legacy matrix
    path only."""
    axes = _data_axes(mesh)
    in_q = jax.tree.unflatten(q_def, [P(*([None] * nd)) for nd in q_ndims])
    in_x = jax.tree.unflatten(
        x_def, [P(axes, *([None] * (nd - 1))) for nd in x_ndims])

    def local(rq, rx, per):
        rx = jax.tree.map(lambda l: l[0], rx)          # strip shard axis
        d = pw(rq, rx)                                 # (Q, cap)
        dead = jnp.arange(d.shape[1])[None, :] >= per
        return jnp.where(dead, jnp.inf, d)

    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=(in_q, in_x, P()),
        out_specs=P(None, axes), check_rep=False))


@lru_cache(maxsize=64)
def _rr_topk_fn(mesh: Mesh, pw, k: int, n_shards: int,
                q_def, x_def, q_ndims, x_ndims):
    """Global top-k (distance, GLOBAL id) over round-robin mirrors.
    Local top-k ids ``slot*S + shard`` are all-gathered and merged with
    the same (distance, smallest-id) lexicographic tie-break the host
    ``merge_topk_numpy`` applies — a plain ``top_k`` over the gathered
    pool would break that on ties because round-robin global ids are
    not monotone in gather position."""
    axes = _data_axes(mesh)
    in_q = jax.tree.unflatten(q_def, [P(*([None] * nd)) for nd in q_ndims])
    in_x = jax.tree.unflatten(
        x_def, [P(axes, *([None] * (nd - 1))) for nd in x_ndims])

    def local(rq, rx, per):
        rx = jax.tree.map(lambda l: l[0], rx)
        d = pw(rq, rx)                                 # (Q, cap)
        cap = d.shape[1]
        d = jnp.where(jnp.arange(cap)[None, :] >= per, jnp.inf, d)
        kk = min(k, cap)
        neg, idx = jax.lax.top_k(-d, kk)
        cd = -neg
        gidx = idx * n_shards + _shard_index(axes)
        gidx = jnp.where(jnp.isfinite(cd), gidx, -1)
        cand_d = jax.lax.all_gather(cd, axes, axis=1, tiled=True)
        cand_i = jax.lax.all_gather(gidx, axes, axis=1, tiled=True)
        tie = jnp.where(cand_i < 0, jnp.iinfo(jnp.int32).max, cand_i)
        best = jnp.lexsort((tie, cand_d), axis=-1)[:, :min(k,
                                                           cand_d.shape[1])]
        return (jnp.take_along_axis(cand_d, best, axis=1),
                jnp.take_along_axis(cand_i, best, axis=1))

    return jax.jit(shard_map(
        local, mesh=mesh, in_specs=(in_q, in_x, P()),
        out_specs=(P(None, None), P(None, None)), check_rep=False))


def _kernel_cand_d2(rows, qs):
    """rows (Qa, B, T) x qs (Qa, T) -> (Qa, B) squared distances through
    the multi-query Pallas euclid kernel — one launch per query row, all
    with the same (B, T) shape so repeated rounds hit the jit cache.
    Per (query, candidate) the reduction order over T is the kernel's,
    independent of batch shape — the shared distance definition that
    makes the device and host-kernel paths bit-identical."""
    from repro.kernels import ops
    return jnp.stack([ops.euclid_batch(rows[r], qs[r])
                      for r in range(rows.shape[0])])


@lru_cache(maxsize=64)
def _rr_rows_verify_fn(mesh: Mesh, n_shards: int):
    """Jitted sharded row-verification over a round-robin raw mirror
    (ownership: ``id % n_shards``), cached per mesh (the jit cache then
    folds repeated (Qa, B, T) round shapes)."""
    axes = _data_axes(mesh)

    def local(x, q, c, per):
        x = x[0]                                      # (cap, T) local
        cap = x.shape[0]
        slot = c // n_shards
        valid = ((c >= 0) & (c % n_shards == _shard_index(axes))
                 & (slot < per))
        rows = x[jnp.clip(slot, 0, cap - 1)]          # (Qa, B, T)
        d2 = _kernel_cand_d2(rows, q)
        # each candidate is owned by exactly one shard: min-merge
        return jax.lax.pmin(jnp.where(valid, d2, jnp.inf), axes)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None, None), P(None, None), P(None, None), P()),
        out_specs=P(None, None), check_rep=False))


def cand_dists_rows_rr(raw_buf, q_dev, cand, mesh: Mesh, n_shards: int,
                       per_live: int) -> np.ndarray:
    """True d_ED of candidate ROW ids against a round-robin raw mirror.

    raw_buf: the mirror's (S, cap, T) device buffer.  q_dev: (Qa, T)
    replicated queries.  cand: (Qa, B) int ids, -1 padding.  Ids outside
    the mirrored head return +inf (the caller min-merges the host-side
    tail).  Raw rows never leave the devices."""
    d2 = _rr_rows_verify_fn(mesh, int(n_shards))(
        raw_buf, q_dev, jnp.asarray(cand), jnp.int32(per_live))
    return np.asarray(jnp.sqrt(jnp.maximum(d2, 0.0)))


@lru_cache(maxsize=64)
def _rr_windows_gather_fn(mesh: Mesh, n_shards: int, nw: int, stride: int,
                          m: int):
    """Jitted sharded window extraction over a round-robin SOURCE-row
    mirror: each shard slices windows of its own rows (pure gather —
    bit-exact), off-shard entries contribute zeros and a psum
    re-assembles the full batch (x + 0 is exact in f32)."""
    axes = _data_axes(mesh)

    def local(x, c, per):
        x = x[0]                                      # (cap, T_src)
        cap = x.shape[0]
        row = jnp.where(c >= 0, c // nw, -1)
        start = (c % nw) * stride          # in-bounds even for c == -1
        slot = row // n_shards
        valid = ((c >= 0) & (row % n_shards == _shard_index(axes))
                 & (slot < per))
        slab = x[jnp.clip(slot, 0, cap - 1)]          # (Qa, B, T_src)
        gat = start[..., None] + jnp.arange(m)[None, None, :]
        w = jnp.take_along_axis(slab, gat, axis=2)    # (Qa, B, m)
        return jax.lax.psum(jnp.where(valid[..., None], w, 0.0), axes)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None, None), P(None, None), P()),
        out_specs=P(None, None, None), check_rep=False))


def cand_dists_windows_rr(raw_buf, q_dev, cand, mesh: Mesh, *,
                          n_shards: int, per_live: int, nw: int,
                          stride: int, m: int,
                          head_rows: int) -> np.ndarray:
    """True z-normalized d_ED of candidate WINDOW ids against windows of
    round-robin-mirrored SOURCE rows (``repro.subseq.WindowView``
    geometry: ``wid = row * nw + j`` covers
    ``source[row, j*stride : j*stride+m]``).

    Each shard extracts its own rows' windows on device (sharded
    gather); the assembled device batch is then z-normalized and
    distanced through the SAME eagerly-dispatched ``znormalize`` +
    jitted euclid-kernel pipeline the host ``WindowView.fetch`` +
    kernel-verifier path runs — z-normalization must not be fused into
    a larger jit graph, or XLA re-associates its reductions and the
    device path drifts from the host path by an ulp.  Window ids whose
    source row falls outside the mirrored head return +inf (the caller
    min-merges the host-side tail); window values never reach the
    host."""
    from repro.core.normalize import znormalize
    fn = _rr_windows_gather_fn(mesh, int(n_shards), int(nw), int(stride),
                               int(m))
    w = fn(raw_buf, jnp.asarray(cand), jnp.int32(per_live))
    wz = znormalize(w)                   # eager: host-identical dispatch
    d2 = np.asarray(_kernel_cand_d2(wz, q_dev))  # one host transfer
    out = np.sqrt(np.maximum(d2, 0.0))
    row = np.where(cand >= 0, cand // nw, -1)
    valid = (cand >= 0) & (row < head_rows)
    return np.where(valid, out, np.float32(np.inf)).astype(np.float32)


def _host_cand_dists_rows(tail_rows, lo, qs, cand) -> np.ndarray:
    """Host twin of :func:`cand_dists_rows_rr` for the
    non-shard-divisible tail remainder — same kernel distance math; the
    tail rows are already host-resident, so nothing moves off device."""
    loc = cand - lo
    valid = (cand >= 0) & (loc >= 0) & (loc < tail_rows.shape[0])
    rows = tail_rows[np.clip(loc, 0, tail_rows.shape[0] - 1)]
    d2 = np.asarray(_kernel_cand_d2(jnp.asarray(rows, jnp.float32),
                                    jnp.asarray(qs, jnp.float32)))
    return np.where(valid, np.sqrt(np.maximum(d2, 0.0)),
                    np.float32(np.inf)).astype(np.float32)


def _host_cand_dists_windows(tail_rows, row_lo, qs, cand, *, nw: int,
                             stride: int, m: int) -> np.ndarray:
    """Host twin of :func:`cand_dists_windows_rr` for windows whose
    source row lives in the tail remainder."""
    from repro.subseq.windows import znorm_windows
    row = np.where(cand >= 0, cand // nw, -1)
    start = (cand % nw) * stride
    loc = row - row_lo
    valid = (cand >= 0) & (loc >= 0) & (loc < tail_rows.shape[0])
    slab = tail_rows[np.clip(loc, 0, tail_rows.shape[0] - 1)]
    gat = start[..., None] + np.arange(m)[None, None, :]
    wz = znorm_windows(np.take_along_axis(slab, gat, axis=2))
    d2 = np.asarray(_kernel_cand_d2(jnp.asarray(wz),
                                    jnp.asarray(qs, jnp.float32)))
    return np.where(valid, np.sqrt(np.maximum(d2, 0.0)),
                    np.float32(np.inf)).astype(np.float32)


# ---------------------------------------------------------------------------
# Device-ordered candidate stream
# ---------------------------------------------------------------------------

class DeviceOrderedStream:
    """Candidate frontier sorted by (bound, id) ONCE on device; the full
    (Q, N) bound matrix never reaches the host.

    ``core.engine.topk_verify`` drives it through two calls per round:
    ``peek()`` returns the next unverified bound per query ((Q,) f32 —
    the only per-round host transfer besides the ids themselves) and
    ``take(aq, batch)`` pops the next ``batch`` GLOBAL ids for the
    active queries, -1-padded past each query's finite frontier.  The
    (bound, id) sort equals the host matrix path's stable argsort
    (ties break toward the smaller id), so the verification schedule is
    identical — and the verified top-k is exact for ANY valid-bound
    order regardless."""

    def __init__(self, sorted_bounds, sorted_ids, n_fin, width: int):
        self._b = sorted_bounds          # (Q, C) device, ascending
        self._i = sorted_ids             # (Q, C) device int32 global ids
        self._n_fin = np.asarray(n_fin, np.int64)
        self._pos = np.zeros(self._n_fin.shape[0], np.int64)
        self._C = 0 if sorted_bounds is None else int(sorted_bounds.shape[1])
        self.width = int(width)

    @classmethod
    def empty(cls, q_n: int) -> "DeviceOrderedStream":
        return cls(None, None, np.zeros(q_n, np.int64), 0)

    @property
    def n_finite(self) -> np.ndarray:
        """(Q,) finite-bound candidate count per query — what the
        observability layer reports as 'candidates generated' when the
        (Q, N) matrix never reaches the host."""
        return self._n_fin.copy()

    def peek(self) -> np.ndarray:
        """(Q,) next unverified bound per query; +inf when exhausted."""
        if self._C == 0:
            return np.full(self._pos.shape[0], np.inf)
        idx = jnp.asarray(np.minimum(self._pos, self._C - 1)[:, None])
        nxt = np.asarray(jnp.take_along_axis(self._b, idx, axis=1),
                         np.float64)[:, 0]
        # a fully-finite row clipped at pos == C would leak a finite
        # bound: the exhaustion guard is load-bearing
        return np.where(self._pos < self._n_fin, nxt, np.inf)

    def take(self, aq, batch: int) -> np.ndarray:
        """Pop the next ``batch`` global ids for the active queries
        ``aq`` ((len(aq), batch) int64, -1-padded); advances the
        cursors by the number of real ids returned."""
        aq = np.asarray(aq, np.int64)
        if self._C == 0 or len(aq) == 0:
            return np.full((len(aq), batch), -1, np.int64)
        cols = (self._pos[aq][:, None]
                + np.arange(batch, dtype=np.int64)[None, :])
        valid = cols < self._n_fin[aq][:, None]
        gat = jnp.asarray(np.minimum(cols, self._C - 1))
        ids = np.asarray(jnp.take_along_axis(self._i[jnp.asarray(aq)],
                                             gat, axis=1), np.int64)
        self._pos[aq] += valid.sum(axis=1)
        return np.where(valid, ids, -1)


def _order_stream(bounds_dev, ids, width: int) -> DeviceOrderedStream:
    """One device lexsort of (bounds, broadcast ids) -> stream."""
    b = jnp.asarray(bounds_dev, jnp.float32)
    ib = jnp.broadcast_to(
        jnp.asarray(np.asarray(ids, np.int32))[None, :], b.shape)
    order = jnp.lexsort((ib, b), axis=-1)
    sb = jnp.take_along_axis(b, order, axis=1)
    si = jnp.take_along_axis(ib, order, axis=1)
    n_fin = np.asarray(jnp.sum(jnp.isfinite(b), axis=1))
    return DeviceOrderedStream(sb, si, n_fin, width)


def host_order_stream(bounds, ids) -> DeviceOrderedStream:
    """Order a host bound matrix on device (the ``TreeCandidates``
    device-ordering path: columns are the union candidate ids).  f64
    bounds are rounded DOWNWARD to f32 so every sorted bound is still a
    valid d_ED lower bound — the engine's exactness argument needs
    nothing more from the order."""
    b = np.asarray(bounds)
    if b.dtype != np.float32:
        b32 = b.astype(np.float32)
        over = np.isfinite(b32) & (b32.astype(np.float64) > b)
        b32[over] = np.nextafter(b32[over], np.float32(-np.inf))
        b = b32
    return _order_stream(jnp.asarray(b), np.asarray(ids, np.int64),
                         width=b.shape[1])


def make_matching_service(encoder, dataset, mesh: Mesh, *, k: int = 64,
                          pairwise: Callable | None = None):
    """Returns (rep_data, query_fn) — query_fn jitted end-to-end."""
    rep_data = encode_sharded(encoder, dataset, mesh)

    @jax.jit
    def query_fn(queries):
        rep_q = encoder.encode(queries)
        return repr_topk_sharded(encoder, rep_q, rep_data, mesh, k=k,
                                 pairwise=pairwise)

    return rep_data, query_fn


class ShardedRepSweep:
    """Device-resident sharded representation sweep over a
    ``repro.store.SymbolicStore`` that supports streaming ingestion.

    The store owns raw rows + host representation; this class maintains
    round-robin device mirrors (:class:`RoundRobinMirror` — global row
    ``i`` on shard ``i % n_shards``) and keeps them fresh under
    ``ingest``:

    * ``ingest(rows)`` encodes ONLY the new chunk — one sharded
      ``encode_sharded`` pass (padded up to a shard multiple, then
      trimmed) — and appends rows + representation to the store.  Nothing
      already ingested is re-encoded, ever.
    * On the next query the mirrors are refreshed incrementally: only
      the newly appended head-aligned rows are uploaded, landing in the
      next free slot of every shard — host->device traffic AND device
      work per ingest are O(chunk), not O(corpus) (the contiguous-range
      layout this replaced re-laid-out the entire resident corpus on
      every shard-boundary shift).  The largest shard-divisible prefix
      lives in the mirrors; the small remainder (< n_shards rows) is
      swept host-side (``_tail_bounds`` — one shared helper for the
      matrix, frontier and stream sweeps) and merged, so any corpus
      size serves exact answers between ingests.
    * ``candidate_stream`` orders the device-resident bounds by
      (bound, id) on device and hands ``topk_verify`` a
      :class:`DeviceOrderedStream` — the exact path never materializes
      the (Q, N) matrix on the host (``host_order_bytes`` stays 0; the
      legacy ``repr_distances`` matrix path counts what it moves).
    * With ``mirror_raw=True`` the RAW rows are mirrored round-robin
      next to the representation and kept in sync by the same O(chunk)
      append — ``make_dist_fn`` then verifies candidate rows entirely
      on device (``verify="device"``); old rows are never re-encoded
      and never re-uploaded.
    """

    mirror_layout = "round_robin"

    def __init__(self, encoder, mesh: Mesh, store, *,
                 pairwise: Callable | None = None,
                 mirror_raw: bool = False):
        self.encoder = encoder
        self.mesh = mesh
        self.store = store
        self._pw = pairwise or encoder.pairwise_distance
        self.axes = _data_axes(mesh)
        self.n_shards = 1
        for a in self.axes:
            self.n_shards *= mesh.shape[a]
        self.mirror_raw = bool(mirror_raw)
        if self.mirror_raw and not getattr(store, "store_raw", True):
            raise ValueError("device-resident verification needs raw rows "
                             "in the store (store_raw=True)")
        self._synced_version = -1
        self._synced_n = 0               # row frontier the mirrors cover
        self._sync_lock = threading.Lock()
        self._head = 0
        self._mirrors = None             # per-rep-leaf RoundRobinMirror
        self._tail_rep = None            # host, < n_shards rows
        self._raw_mirror = None          # RoundRobinMirror of raw rows
        self.host_order_bytes = 0        # bytes of host bound matrices

    # -- ingest -----------------------------------------------------------
    def _encode_chunk(self, rows: np.ndarray):
        """Sharded one-pass encode of a chunk (pad to shard multiple,
        trim) — bit-identical to the unsharded row-wise encode."""
        from repro.store.symbolic import rep_leaves
        m = rows.shape[0]
        pad = (-m) % self.n_shards
        if pad:
            rows = np.concatenate([rows, rows[-1:].repeat(pad, axis=0)])
        rep = encode_sharded(self.encoder, jnp.asarray(rows), self.mesh)
        leaves = tuple(np.asarray(l)[:m] for l in rep_leaves(rep))
        return leaves if isinstance(rep, tuple) else leaves[0]

    def ingest(self, rows) -> np.ndarray:
        """Append rows to the store; only the new chunk is encoded."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim == 1:
            rows = rows[None]
        return self.store.append(rows, rep=self._encode_chunk(rows))

    # -- device mirror ----------------------------------------------------
    def _restructure(self, leaves):
        single = not isinstance(self.store.rep_view(), tuple)
        return leaves[0] if single else tuple(leaves)

    def _sync(self):
        if self._synced_version == self.store.version:
            return
        with self._sync_lock:
            if self._synced_version == self.store.version:
                return
            from repro.store.symbolic import rep_leaves
            # Capture the frontier FIRST: a writer may append while we
            # sync, so everything below (leaves, tail, version stamp)
            # is sliced to this (version, n) pair — never the live
            # attributes, which could already be past it.
            version = self.store.version
            n = self.store.n
            head = (n // self.n_shards) * self.n_shards
            leaves = tuple(l[:n]
                           for l in rep_leaves(self.store.rep_view()))
            if head != self._head:
                if self._mirrors is None:
                    self._mirrors = tuple(
                        RoundRobinMirror(self.mesh, self.n_shards)
                        for _ in leaves)
                # O(chunk): only head-aligned delta rows are uploaded
                for mir, l in zip(self._mirrors, leaves):
                    mir.append(l[self._head:head])
                if self.mirror_raw:
                    if self._raw_mirror is None:
                        self._raw_mirror = RoundRobinMirror(self.mesh,
                                                            self.n_shards)
                    self._raw_mirror.append(
                        self.store.data[self._head:head])
            self._tail_rep = (self._restructure(
                tuple(jnp.asarray(l[head:]) for l in leaves))
                if head < n else None)
            self._head = head
            self._synced_n = n
            self._synced_version = version

    @property
    def h2d_bytes(self) -> int:
        """Total host->device mirror upload traffic (bytes)."""
        total = sum(m.h2d_bytes for m in (self._mirrors or ()))
        if self._raw_mirror is not None:
            total += self._raw_mirror.h2d_bytes
        return total

    def transfer_stats(self) -> dict:
        """Device<->host transfer counters for the observability layer:
        ``host_order_bytes`` (host-assembled candidate-order matrices —
        0 on the streaming exact path) and ``h2d_bytes`` (mirror
        uploads)."""
        return {"host_order_bytes": int(self.host_order_bytes),
                "h2d_bytes": int(self.h2d_bytes)}

    def _mirror_tree(self):
        return self._restructure(tuple(m.buf for m in self._mirrors))

    def _rr_bounds(self, rep_q):
        """(Q, S*cap) blocked device bound matrix over the mirrors."""
        mt = self._mirror_tree()
        fn = _rr_bounds_fn(self.mesh, self._pw, *_rep_specs(rep_q, mt))
        return fn(rep_q, mt, jnp.int32(self._mirrors[0].per_live))

    def _tail_bounds(self, rep_q):
        """Shared tail-remainder sweep: (device (Q, tn) bounds, int64
        global ids) of the < n_shards host-resident rows, or (None,
        None).  The one helper behind the matrix (``repr_distances``),
        frontier (``candidates``) and stream (``candidate_stream``)
        paths — previously duplicated near-identically per caller."""
        if self._tail_rep is None:
            return None, None
        d = self._pw(rep_q, self._tail_rep)
        # _synced_n, not the live store.n: a concurrent append may have
        # grown the store past the frontier this tail was sliced at
        ids = np.arange(self._head, self._synced_n, dtype=np.int64)
        return d, ids

    # -- sweeps -----------------------------------------------------------
    def repr_distances(self, queries_raw) -> np.ndarray:
        """(Q, N) lower-bound matrix on the HOST (legacy matrix path:
        the blocked device matrix is pulled over and unpermuted to
        natural row order; the traffic is counted in
        ``host_order_bytes``).  The exact top-k path uses
        ``candidate_stream`` instead and never pays this."""
        self._sync()
        rep_q = self.encoder.encode(jnp.asarray(queries_raw, jnp.float32))
        parts = []
        if self._mirrors is not None:
            blk = np.asarray(self._rr_bounds(rep_q))   # (Q, S*cap)
            S, cap = self.n_shards, self._mirrors[0].cap
            # block column s*cap + j  ->  global row j*S + s; dead slots
            # land at ids >= head and are trimmed
            arr = np.ascontiguousarray(
                blk.reshape(-1, S, cap).transpose(0, 2, 1)
                .reshape(-1, S * cap)[:, :self._head])
            self.host_order_bytes += arr.nbytes
            parts.append(arr)
        d_tail, _ = self._tail_bounds(rep_q)
        if d_tail is not None:
            parts.append(np.asarray(d_tail))
        if not parts:
            q_n = np.asarray(queries_raw).shape[0]
            return np.empty((q_n, 0), np.float32)
        return parts[0] if len(parts) == 1 else np.concatenate(parts,
                                                               axis=1)

    def candidates(self, queries_raw, k: int) -> np.ndarray:
        """(Q, k) global candidate frontier: sharded local top-k + gather
        over the mirrors, host top-k over the tail, host merge."""
        from repro.core.engine import merge_topk_numpy
        self._sync()
        rep_q = self.encoder.encode(jnp.asarray(queries_raw, jnp.float32))
        ds, idxs = [], []
        if self._mirrors is not None:
            mt = self._mirror_tree()
            fn = _rr_topk_fn(self.mesh, self._pw, int(k), self.n_shards,
                             *_rep_specs(rep_q, mt))
            d, i = fn(rep_q, mt, jnp.int32(self._mirrors[0].per_live))
            ds.append(np.asarray(d))
            idxs.append(np.asarray(i, np.int64))
        d_tail, tail_ids = self._tail_bounds(rep_q)
        if d_tail is not None:
            d_tail = np.asarray(d_tail)
            ds.append(d_tail)
            idxs.append(np.broadcast_to(tail_ids, d_tail.shape).copy())
        if not ds:                       # empty corpus: no candidates yet
            q_n = np.asarray(queries_raw).shape[0]
            return np.empty((q_n, 0), np.int64)
        d_all = np.concatenate(ds, axis=1)
        i_all = np.concatenate(idxs, axis=1)
        _, out_i = merge_topk_numpy(d_all, i_all, min(k, d_all.shape[1]))
        return out_i

    def candidate_stream(self, queries_raw,
                         mask_fn=None) -> DeviceOrderedStream:
        """Device-ordered exact candidate frontier: the blocked mirror
        bounds and the tail bounds are concatenated and lexsorted by
        (bound, global id) ON DEVICE — no (Q, N) host matrix, no host
        argsort.  The stream yields global ids directly.

        ``mask_fn``, if given, maps the (C,) int64 global-id vector to
        a (Q, C) or (C,) boolean mask of candidates to SUPPRESS (their
        bounds become +inf on device, so they fall past the finite
        frontier and never reach verification) — e.g. the self-join
        trivial-match zone.  The mask is computed and applied on
        device; candidate order still never touches the host."""
        self._sync()
        qs = np.asarray(queries_raw, np.float32)
        if qs.ndim == 1:
            qs = qs[None]
        rep_q = self.encoder.encode(jnp.asarray(qs))
        bparts, iparts = [], []
        if self._mirrors is not None:
            bparts.append(self._rr_bounds(rep_q))
            cap = self._mirrors[0].cap
            S = self.n_shards
            # block column s*cap + j holds global row j*S + s (dead
            # slots get ids >= head but their bounds are +inf, so the
            # finite-frontier cursor never reaches them)
            iparts.append((np.arange(cap, dtype=np.int64)[None, :] * S
                           + np.arange(S, dtype=np.int64)[:, None])
                          .reshape(-1))
        d_tail, tail_ids = self._tail_bounds(rep_q)
        if d_tail is not None:
            bparts.append(d_tail)
            iparts.append(tail_ids)
        if not bparts:
            return DeviceOrderedStream.empty(qs.shape[0])
        b = (bparts[0] if len(bparts) == 1
             else jnp.concatenate([jnp.asarray(p, jnp.float32)
                                   for p in bparts], axis=1))
        ids = np.concatenate(iparts)
        if mask_fn is not None:
            mask = jnp.asarray(mask_fn(jnp.asarray(ids)))
            b = jnp.where(mask, jnp.float32(np.inf), jnp.asarray(b))
        return _order_stream(b, ids, width=self._synced_n)

    # -- device-resident verification -------------------------------------
    def shard_ranges(self):
        """Contiguous row ranges of the device head — the SNAPSHOT raw
        manifest's per-host unit (``store.snapshot._shard_ranges``).
        This is deliberately NOT the device mirror layout (see
        ``mirror_layout`` / ``owned_rows``): on-disk shards stay
        contiguous, device placement is round-robin, and results are
        identical either way."""
        from repro.store.snapshot import _shard_ranges
        return _shard_ranges(self._head, self.n_shards)

    def owned_rows(self, shard: int) -> np.ndarray:
        """Global row ids resident on ``shard`` under the round-robin
        mirror layout (row ``i`` -> shard ``i % n_shards``)."""
        return np.arange(shard, self._head, self.n_shards, dtype=np.int64)

    def make_dist_fn(self, queries_raw):
        """Device-resident verification closure for one query batch:
        ``dist(q_idx, cand) -> (Qa, B)`` true d_ED of candidate row ids,
        computed per shard through the multi-query euclid kernel over
        the round-robin raw mirror — raw rows never move device->host.
        The contract matches ``core.engine.topk_verify``'s
        ``dist_fn``."""
        if not self.mirror_raw:
            raise ValueError("ShardedRepSweep was built without "
                             "mirror_raw=True; no raw device mirror to "
                             "verify against")
        self._sync()
        qs = np.asarray(queries_raw, np.float32)
        if qs.ndim == 1:
            qs = qs[None]
        q_n = qs.shape[0]
        q_dev = jnp.asarray(qs)
        head = self._head
        n_syn = self._synced_n           # frontier at closure creation

        def dist(aq, cand):
            # pad the active-query batch back to the full query set so
            # the jitted shard_map sees ONE (Q, B) shape per batch size
            # — rounds with fewer active queries reuse the compile cache
            aq = np.asarray(aq)
            cand = np.asarray(cand, np.int64)
            full = np.full((q_n, cand.shape[1]), -1, np.int64)
            full[aq] = cand
            out = np.full(full.shape, np.inf, np.float32)
            if self._raw_mirror is not None and \
                    ((full >= 0) & (full < head)).any():
                out = np.minimum(out, cand_dists_rows_rr(
                    self._raw_mirror.buf, q_dev, full, self.mesh,
                    self.n_shards, self._raw_mirror.per_live))
            if n_syn > head and (full >= head).any():
                out = np.minimum(out, _host_cand_dists_rows(
                    self.store.data[head:n_syn], head, qs, full))
            return out[aq]

        return dist


def make_engine_service(encoder, dataset, mesh: Mesh, store=None, *,
                        batch_size: int = 64, verify: str = "auto",
                        pairwise: Callable | None = None,
                        media: str = "ssd", metrics=None):
    """Sharded representation sweep feeding the batched k-NN engine.

    Builds (or adopts) a ``repro.store.SymbolicStore``, runs one sharded
    encode pass over ``dataset``, and returns a ``core.engine.MatchEngine``
    whose exact top-k orders candidates ON DEVICE
    (``ShardedRepSweep.candidate_stream`` — the (Q, N) bound matrix
    never reaches the host) and whose approximate top-k uses the sharded
    candidate frontier (collective volume O(Q*k*shards)) before raw
    verification against the store.

    The engine supports ingest-while-serving: ``engine.ingest(rows)``
    encodes only the new chunk (sharded) and appends it to the
    round-robin device mirrors without touching resident rows —
    per-append cost is O(chunk) regardless of corpus size; the next
    query serves the new rows.  With ``verify="device"`` the raw mirror
    is kept in sync by the same O(chunk) append, so ingest never
    re-uploads old rows.

    ``store``: a ``SymbolicStore`` (adopted as-is; ``dataset`` may be None
    to serve its existing rows), a legacy ``RawStore`` (its cost model AND
    its rows are adopted — verification accounting moves to the returned
    ``engine.store``), or None (a fresh store with the ``media`` preset).

    ``verify``: "device" shards the raw rows across devices alongside the
    representation and verifies per shard through the euclid kernel —
    zero raw rows moved to the host; "host" is the bit-identical
    host-side fallback (store fetch + the same kernel math, modeled-I/O
    oracle); "auto" / "numpy" / "kernel" as in ``core.engine``.
    """
    from repro.core.engine import MatchEngine
    from repro.store import SymbolicStore

    if isinstance(store, SymbolicStore):
        sym = store
        if dataset is not None and sym.n:
            raise ValueError(
                "both a non-empty SymbolicStore and a dataset were given; "
                "pass dataset=None to serve the store's rows, or "
                "engine.ingest(dataset) explicitly to append them")
    elif store is not None:              # legacy RawStore: adopt cost model
        sym = SymbolicStore(encoder, seek_s=store.seek_s,
                            read_bps=store.read_bps)
        if dataset is None and store.data.shape[0]:
            dataset = store.data         # ...and its rows
    else:
        sym = SymbolicStore(encoder, media=media)

    device_verify = verify == "device"
    sweep = ShardedRepSweep(encoder, mesh, sym, pairwise=pairwise,
                            mirror_raw=device_verify)
    if dataset is not None and sym.n == 0:
        sweep.ingest(np.asarray(dataset, np.float32))

    engine = MatchEngine(encoder, sym, batch_size=batch_size,
                         verify=verify, pairwise=pairwise,
                         repr_fn=sweep.repr_distances,
                         cand_fn=sweep.candidates,
                         stream_factory=sweep.candidate_stream,
                         dist_factory=(sweep.make_dist_fn
                                       if device_verify else None),
                         metrics=metrics)
    engine.sweep = sweep
    engine.ingest = sweep.ingest
    return engine


class ShardedWindowSweep:
    """Sharded window sweep + device-resident window verification for
    ``repro.subseq.SubseqEngine``.

    * The (Q, n_windows) representation sweep shards the view's live
      window representation exactly like whole-series matching — an
      inner :class:`ShardedRepSweep` over the view's representation
      store, so stride > 1 and ragged T (already folded into the window
      geometry by ``WindowView``) and any non-shard-divisible window
      count are handled by the same head/tail split, and window appends
      refresh the round-robin mirrors in O(chunk).
    * ``candidate_stream`` is the inner sweep's device-ordered stream:
      window-representation rows ARE window ids, so the exact subsequence
      path feeds ``topk_verify`` without a host (Q, n_windows) matrix.
    * ``make_dist_fn`` verifies candidate WINDOWS device-side: the
      SOURCE long rows are mirrored round-robin on device (row ``i`` on
      shard ``i % n_shards``); each shard slices and z-normalizes its
      own rows' windows (the same ``core.normalize.znormalize`` the host
      fetch path applies) and distances them through the multi-query
      euclid kernel (:func:`cand_dists_windows_rr`).  Window values
      never materialize on the host; rows of the tail remainder are
      distanced host-side through the same kernel.
    """

    mirror_layout = "round_robin"

    def __init__(self, view, mesh: Mesh, *, mirror_raw: bool = True):
        self.view = view
        self.mesh = mesh
        self.rep_sweep = ShardedRepSweep(view.encoder, mesh, view.rep_store)
        self.axes = self.rep_sweep.axes
        self.n_shards = self.rep_sweep.n_shards
        self.mirror_raw = bool(mirror_raw)
        self._raw_mirror = None          # RoundRobinMirror of SOURCE rows
        self._head_rows = 0
        self._rows_synced = -1

    def repr_distances(self, queries_z) -> np.ndarray:
        """(Q, n_windows) lower-bound matrix for already z-normalized
        queries — host matrix path (exclusion re-sweeps mutate it); the
        exact non-exclusion path uses ``candidate_stream``."""
        return self.rep_sweep.repr_distances(queries_z)

    def candidate_stream(self, queries_z,
                         mask_fn=None) -> DeviceOrderedStream:
        """Device-ordered window candidate stream (global window ids).
        ``mask_fn`` suppresses window ids on device (bounds -> +inf)
        before ordering — see ``ShardedRepSweep.candidate_stream``; the
        self-join engine uses it for the trivial-match zone."""
        return self.rep_sweep.candidate_stream(queries_z, mask_fn=mask_fn)

    @property
    def h2d_bytes(self) -> int:
        total = self.rep_sweep.h2d_bytes
        if self._raw_mirror is not None:
            total += self._raw_mirror.h2d_bytes
        return total

    @property
    def host_order_bytes(self) -> int:
        return self.rep_sweep.host_order_bytes

    def transfer_stats(self) -> dict:
        """Same contract as ``ShardedRepSweep.transfer_stats`` with the
        source-row mirror traffic folded in."""
        return {"host_order_bytes": int(self.host_order_bytes),
                "h2d_bytes": int(self.h2d_bytes)}

    def _sync_raw(self):
        """Incremental round-robin mirror of the source rows
        (append-only corpus: a row-count check is a complete freshness
        test)."""
        n_rows = self.view.n_rows
        if n_rows == self._rows_synced:
            return
        head = (n_rows // self.n_shards) * self.n_shards
        if head != self._head_rows:
            if self._raw_mirror is None:
                self._raw_mirror = RoundRobinMirror(self.mesh,
                                                    self.n_shards)
            self._raw_mirror.append(
                np.asarray(self.view.source.data[self._head_rows:head],
                           np.float32))
            self._head_rows = head
        self._rows_synced = n_rows

    def make_dist_fn(self, queries_z):
        """Device-resident window verification closure (the
        ``core.engine.topk_verify`` ``dist_fn`` contract over window
        ids) for one z-normalized query batch."""
        if not self.mirror_raw:
            raise ValueError("ShardedWindowSweep was built without "
                             "mirror_raw=True")
        self._sync_raw()
        qs = np.asarray(queries_z, np.float32)
        if qs.ndim == 1:
            qs = qs[None]
        q_n = qs.shape[0]
        q_dev = jnp.asarray(qs)
        view = self.view
        nw, stride, m = view.windows_per_row, view.stride, view.m
        head_rows = self._head_rows
        head_wid = head_rows * nw

        def dist(aq, cand):
            # full-Q padding: one (Q, B) shard_map shape per batch size
            aq = np.asarray(aq)
            cand = np.asarray(cand, np.int64)
            full = np.full((q_n, cand.shape[1]), -1, np.int64)
            full[aq] = cand
            out = np.full(full.shape, np.inf, np.float32)
            if self._raw_mirror is not None and \
                    ((full >= 0) & (full < head_wid)).any():
                out = np.minimum(out, cand_dists_windows_rr(
                    self._raw_mirror.buf, q_dev, full, self.mesh,
                    n_shards=self.n_shards,
                    per_live=self._raw_mirror.per_live,
                    nw=nw, stride=stride, m=m, head_rows=head_rows))
            if view.n_rows > head_rows and (full >= head_wid).any():
                out = np.minimum(out, _host_cand_dists_windows(
                    view.source.data[head_rows:], head_rows, qs, full,
                    nw=nw, stride=stride, m=m))
            return out[aq]

        return dist
