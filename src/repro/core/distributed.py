"""Distributed matching engine: the paper's pipeline mapped onto a JAX mesh.

The dataset of N series is sharded over the ("pod","data") axes; queries
are replicated.  One ``shard_map`` pass per stage:

  1. ``encode_sharded`` — representation construction (one pass/series,
     exactly the paper's "Representation Time = 1 pass" property, batched).
  2. ``repr_topk_sharded`` — symbolic distances on the local shard
     (Pallas ``sax_dist`` kernel where available, jnp otherwise), local
     top-k, then a global candidate merge via ``all_gather`` of k
     candidates per shard (collective volume independent of N — the
     property that scales to 1000+ nodes, DESIGN.md §3).
  3. Raw verification of the surviving candidates against the cold store
     via the batched k-NN engine (``core.engine.MatchEngine``):
     ``repr_topk_sharded`` produces the candidate frontier for
     approximate top-k, ``repr_distances_sharded`` the full lower-bound
     matrix for exact top-k — ``make_engine_service`` wires both into an
     engine whose raw verification is one batched fetch per round.

The helpers take any encoder with ``encode`` + ``pairwise_distance`` —
SAX, sSAX, tSAX and 1d-SAX all plug in.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def encode_sharded(encoder, dataset, mesh: Mesh):
    """Encode a dataset sharded over the data axes.  dataset: (N, T)."""
    axes = _data_axes(mesh)

    def local(x):
        return encoder.encode(x)

    spec_in = P(axes, None)
    rep_struct = jax.eval_shape(encoder.encode,
                                jax.ShapeDtypeStruct(dataset.shape,
                                                     dataset.dtype))
    spec_out = jax.tree.map(lambda _: P(axes, *([None] * 0)), rep_struct)
    # representation leaves keep their leading N axis sharded; trailing
    # axes replicated
    spec_out = jax.tree.map(
        lambda s: P(axes, *([None] * (len(s.shape) - 1))), rep_struct)
    fn = shard_map(local, mesh=mesh, in_specs=(spec_in,),
                   out_specs=spec_out, check_rep=False)
    return fn(dataset)


def repr_distances_sharded(encoder, rep_query, rep_data, mesh: Mesh,
                           pairwise: Callable | None = None):
    """(Q, N) representation distances, N sharded.  Output replicated-Q,
    N-sharded."""
    axes = _data_axes(mesh)
    pw = pairwise or encoder.pairwise_distance

    def local(rq, rx):
        return pw(rq, rx)

    in_q = jax.tree.map(lambda s: P(*([None] * s.ndim)), rep_query)
    in_x = jax.tree.map(
        lambda s: P(axes, *([None] * (s.ndim - 1))), rep_data)
    fn = shard_map(local, mesh=mesh, in_specs=(in_q, in_x),
                   out_specs=P(None, axes), check_rep=False)
    return fn(rep_query, rep_data)


def repr_topk_sharded(encoder, rep_query, rep_data, mesh: Mesh, *,
                      k: int = 64, pairwise: Callable | None = None):
    """Global top-k candidate (distance, index) per query.

    Local shard computes distances + local top-k; k*shards candidates are
    all-gathered and reduced — collective volume O(Q*k*shards), never O(N).
    Returns (dists (Q, k), global indices (Q, k)).
    """
    axes = _data_axes(mesh)
    pw = pairwise or encoder.pairwise_distance
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    def local(rq, rx):
        d = pw(rq, rx)                                 # (Q, n_local)
        n_local = d.shape[1]
        kk = min(k, n_local)
        neg, idx = jax.lax.top_k(-d, kk)               # smallest distances
        # global index offset of this shard
        shard_id = jax.lax.axis_index(axes[0])
        if len(axes) == 2:
            shard_id = shard_id * jax.lax.axis_size(axes[1]) + \
                jax.lax.axis_index(axes[1])
        gidx = idx + shard_id * n_local
        cand_d = jax.lax.all_gather(-neg, axes, axis=1, tiled=True)
        cand_i = jax.lax.all_gather(gidx, axes, axis=1, tiled=True)
        best_neg, best_pos = jax.lax.top_k(-cand_d, min(k, cand_d.shape[1]))
        best_i = jnp.take_along_axis(cand_i, best_pos, axis=1)
        return -best_neg, best_i

    in_q = jax.tree.map(lambda s: P(*([None] * s.ndim)), rep_query)
    in_x = jax.tree.map(
        lambda s: P(axes, *([None] * (s.ndim - 1))), rep_data)
    fn = shard_map(local, mesh=mesh, in_specs=(in_q, in_x),
                   out_specs=(P(None, None), P(None, None)),
                   check_rep=False)
    return fn(rep_query, rep_data)


def make_matching_service(encoder, dataset, mesh: Mesh, *, k: int = 64,
                          pairwise: Callable | None = None):
    """Returns (rep_data, query_fn) — query_fn jitted end-to-end."""
    rep_data = encode_sharded(encoder, dataset, mesh)

    @jax.jit
    def query_fn(queries):
        rep_q = encoder.encode(queries)
        return repr_topk_sharded(encoder, rep_q, rep_data, mesh, k=k,
                                 pairwise=pairwise)

    return rep_data, query_fn


def make_engine_service(encoder, dataset, mesh: Mesh, store, *,
                        batch_size: int = 64, verify: str = "auto",
                        pairwise: Callable | None = None):
    """Sharded representation sweep feeding the batched k-NN engine.

    Encodes the dataset sharded over the mesh, then returns a
    ``core.engine.MatchEngine`` whose representation distances come from
    ``repr_distances_sharded`` (exact top-k) and whose approximate
    candidate frontier comes from ``repr_topk_sharded`` — collective
    volume O(Q*k*shards) — before raw verification on the host store.
    """
    from repro.core.engine import MatchEngine

    rep_data = encode_sharded(encoder, dataset, mesh)

    def repr_fn(queries_raw):
        rep_q = encoder.encode(jnp.asarray(queries_raw))
        return repr_distances_sharded(encoder, rep_q, rep_data, mesh,
                                      pairwise=pairwise)

    def cand_fn(queries_raw, k):
        rep_q = encoder.encode(jnp.asarray(queries_raw))
        _, idx = repr_topk_sharded(encoder, rep_q, rep_data, mesh, k=k,
                                   pairwise=pairwise)
        return idx

    return MatchEngine(encoder, store, batch_size=batch_size,
                       verify=verify, pairwise=pairwise, rep=rep_data,
                       repr_fn=repr_fn, cand_fn=cand_fn)
